"""MoE routing invariants and dispatch correctness vs a dense-expert oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe
from repro.models.config import BlockCfg, ModelConfig, StageCfg


def _cfg(cf=8.0, E=4, k=2):
    return ModelConfig(
        name="m", d_model=16, n_heads=2, n_kv_heads=2, d_ff=0, vocab=32,
        stages=(StageCfg(1, (BlockCfg("attn", "moe"),)),), n_experts=E,
        top_k=k, moe_d_ff=8, capacity_factor=cf, dtype="float32", max_seq=32)


def _dense_oracle(cfg, p, x):
    """Compute every expert for every token, combine with router weights."""
    from repro.models import layers
    B, S, D = x.shape
    h = layers.apply_norm(cfg, p["norm"], x)
    logits = h.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, e = jax.lax.top_k(probs, cfg.top_k)
    w = w / w.sum(-1, keepdims=True)
    g = jnp.einsum("bsd,edf->bsef", h, p["we_g"])
    u = jnp.einsum("bsd,edf->bsef", h, p["we_u"])
    o = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * u, p["we_d"])
    full_w = jnp.zeros(probs.shape).at[
        jnp.arange(B)[:, None, None], jnp.arange(S)[None, :, None], e].set(w)
    return jnp.einsum("bse,bsed->bsd", full_w, o)


def test_dispatch_matches_dense_oracle():
    cfg = _cfg(cf=8.0)  # dropless
    p = moe.moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    got, _ = moe.moe_fwd(cfg, p, x)
    want = _dense_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_capacity_drop_bounded():
    """With cf=1.0 some tokens may drop but output stays finite and close."""
    cfg = _cfg(cf=1.0)
    p = moe.moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    got, aux = moe.moe_fwd(cfg, p, x)
    assert bool(jnp.isfinite(got).all())
    assert float(aux) >= 0.99  # balance loss lower bound is ~1


def test_single_token_never_drops():
    """Decode groups (S=1): capacity 1 is lossless (distinct top-k)."""
    cfg = _cfg(cf=1.0)
    assert moe.capacity(cfg, 1) == 1
    p = moe.moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 16))
    got, _ = moe.moe_fwd(cfg, p, x)
    want = _dense_oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_shared_experts_added():
    cfg = _cfg(cf=8.0).with_(n_shared_experts=1)
    p = moe.moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))
    with_shared, _ = moe.moe_fwd(cfg, p, x)
    p2 = {k: v for k, v in p.items() if not k.startswith("ws_")}
    cfg2 = cfg.with_(n_shared_experts=0)
    without, _ = moe.moe_fwd(cfg2, p2, x)
    assert float(jnp.abs(with_shared - without).max()) > 1e-6
