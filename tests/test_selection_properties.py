"""Property tests for core/selection.py layer-selection policies.

The LeZO contract every replica and every restart relies on (DESIGN.md
§2): for each policy, the active mask (1) keeps exactly
``num_layers - n_drop`` layers, (2) is a deterministic pure function of
(seed, step, weights), and (3) for ``weighted``, respects the weights —
a high-weight layer survives strictly more often than a low-weight one.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import selection


def _mask(policy, num_layers, n_drop, seed, step, weights=None):
    fn = selection.make_policy(policy, num_layers, n_drop)
    w = None if weights is None else jnp.asarray(weights, jnp.float32)
    return np.asarray(fn(jnp.uint32(seed), jnp.int32(step), w))


def _weights(num_layers, seed):
    return 0.1 + np.random.default_rng(seed).random(num_layers)


@given(st.sampled_from(selection.POLICIES), st.integers(2, 33),
       st.integers(0, 2**32 - 1), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_cardinality_exact(policy, num_layers, seed, step):
    """|active| == num_layers - n_drop for every policy, any n_drop."""
    for n_drop in {0, 1, num_layers // 2, num_layers - 1}:
        m = _mask(policy, num_layers, n_drop, seed, step,
                  weights=_weights(num_layers, 0))
        assert m.shape == (num_layers,)
        assert int(m.sum()) == num_layers - n_drop, (policy, n_drop)


@given(st.sampled_from(selection.POLICIES), st.integers(0, 2**32 - 1),
       st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_mask_deterministic_in_seed_and_step(policy, seed, step):
    """Same (seed, step, weights) -> bit-identical mask; this is what lets
    every data-parallel replica derive the subset with no communication."""
    w = _weights(12, 7)
    a = _mask(policy, 12, 5, seed, step, weights=w)
    b = _mask(policy, 12, 5, seed, step, weights=w)
    assert np.array_equal(a, b)


@pytest.mark.parametrize("policy", selection.POLICIES)
def test_cardinality_exact_grid(policy):
    """Hypothesis-free version of the cardinality property: a fixed
    (num_layers, n_drop, seed, step) grid, so the invariant is enforced
    even in containers without hypothesis."""
    for num_layers in (2, 16, 31):
        w = _weights(num_layers, 1)
        for n_drop in {0, 1, num_layers // 2, num_layers - 1}:
            for seed, step in ((0, 0), (0xFFFFFFFF, 9999)):
                m = _mask(policy, num_layers, n_drop, seed, step, weights=w)
                assert int(m.sum()) == num_layers - n_drop, \
                    (policy, num_layers, n_drop, seed, step)


@pytest.mark.parametrize("policy", selection.POLICIES)
def test_mask_deterministic_grid(policy):
    w = _weights(12, 7)
    for seed, step in ((0, 0), (42, 1), (2**31, 500), (7, 10_000)):
        a = _mask(policy, 12, 5, seed, step, weights=w)
        b = _mask(policy, 12, 5, seed, step, weights=w)
        assert np.array_equal(a, b), (policy, seed, step)


def test_uniform_varies_with_seed_and_round_robin_with_step():
    masks = {tuple(_mask("uniform", 16, 8, s, 0)) for s in range(24)}
    assert len(masks) > 1                     # not a constant function
    rr = {tuple(_mask("round_robin", 16, 8, 0, t)) for t in range(16)}
    assert len(rr) == 16                      # the window actually walks


def test_round_robin_window_contiguous():
    for step in range(20):
        m = _mask("round_robin", 10, 6, 0, step)
        idx = np.flatnonzero(m)
        # contiguous modulo num_layers: gaps sum to num_layers - k
        ext = np.r_[idx, idx[0] + 10]
        assert (np.diff(ext) == 1).sum() >= len(idx) - 1


def test_uniform_rejects_bad_n_drop():
    with pytest.raises(ValueError):
        selection.uniform_active(jnp.uint32(0), 4, 4)
    with pytest.raises(ValueError):
        selection.uniform_active(jnp.uint32(0), 4, -1)


def test_weighted_keeps_high_weight_layer_more_often():
    """Over many seeds, the heaviest layer must survive strictly more
    often than the lightest one (Gumbel top-k respects weights)."""
    num_layers, n_drop = 8, 4
    w = np.ones(num_layers, np.float32)
    hi, lo = 2, 5
    w[hi], w[lo] = 20.0, 0.05
    n_seeds = 160
    kept = np.zeros(num_layers)
    for seed in range(n_seeds):
        kept += _mask("weighted", num_layers, n_drop, seed, 0, weights=w)
    assert kept[hi] > kept[lo] + 0.15 * n_seeds
    assert kept[hi] >= 0.9 * n_seeds          # near-always kept
    # every layer still has a chance: fully stochastic, LISA-style
    assert (kept > 0).all()


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None)
def test_weighted_uniform_weights_cardinality(seed):
    """Degenerate equal weights: still exact cardinality, no ties lost."""
    m = _mask("weighted", 9, 3, seed, 0, weights=np.ones(9, np.float32))
    assert int(m.sum()) == 6


def test_weighted_exact_cardinality_under_score_ties():
    """Regression: the old threshold select (`score >= sort(score)[N-k]`)
    kept MORE than k layers whenever scores tied at the cut.  Saturated
    weights make the tie deterministic (log(inf) + gumbel == inf for
    every such layer): 6 tied top scores with k=4 must still yield
    exactly 4 active layers, all from the tied group."""
    w = jnp.asarray([np.inf] * 6 + [1.0] * 2, jnp.float32)
    for seed in range(8):
        m = np.asarray(selection.weighted_active(jnp.uint32(seed), w, 4))
        assert int(m.sum()) == 4, seed
        assert not m[6:].any()              # winners come from the tie
    # large-N equal weights: exact cardinality as a property sweep
    for seed in range(4):
        m = np.asarray(selection.weighted_active(
            jnp.uint32(seed), jnp.ones((4096,), jnp.float32), 2048))
        assert int(m.sum()) == 2048, seed


def test_weighted_degenerate_k_edges():
    """Regression: k == 0 (n_drop == num_layers) used to index the sorted
    scores out of bounds (clamped under jit to a wrong 1-layer mask);
    n_drop == 0 must keep everything; out-of-range n_drop raises."""
    w = jnp.ones((6,), jnp.float32)
    assert int(selection.weighted_active(jnp.uint32(3), w, 0).sum()) == 6
    assert int(selection.weighted_active(jnp.uint32(3), w, 6).sum()) == 0
    with pytest.raises(ValueError):
        selection.weighted_active(jnp.uint32(3), w, 7)
    with pytest.raises(ValueError):
        selection.weighted_active(jnp.uint32(3), w, -1)
