"""Optimizer-health telemetry + run registry suite (DESIGN.md §13).

Covers the :class:`repro.obs.health.HealthAccumulator` contract
(sync-free record, batched drain, Welford g statistics, LeZO layer
coverage/staleness, the RNG-stream update-norm identity), the run-dir
writer/reader round trip (``repro.obs.runlog``), the ``launch train``
run-registry implication, and the two run-dir commands: ``launch
report`` (markdown health report) and ``launch replay`` (the bitwise
seed-lineage verifier — including corruption detection and
resume-then-replay across a checkpoint boundary).
"""
import json
import math
import os
import shutil

import numpy as np
import pytest

from repro import api
from repro.core import rng
from repro.obs import health, runlog

SMOKE = "tiny-smoke"


def _fold(seed, data):
    return int(np.uint32(rng.fold_py(int(seed), int(data))))


# ===================================================== HealthAccumulator
class _Probe:
    """Sentinel value: counts host conversions, so a test can prove that
    ``record()`` never syncs and ``drain()`` fetches exactly once."""

    def __init__(self, value):
        self.value = value
        self.conversions = 0

    def __float__(self):
        self.conversions += 1
        return float(self.value)


def test_health_record_is_sync_free():
    pytest.importorskip("jax")
    acc = health.HealthAccumulator()
    probe = _Probe(2.5)
    acc.record(0, {"loss": probe, "ignored_key": object()}, seed=11)
    acc.record(1, {"loss": 3.0})
    assert len(acc) == 2
    assert probe.conversions == 0             # record buffered, no sync
    rows = acc.drain()
    assert probe.conversions == 1             # drain fetched exactly once
    assert len(acc) == 0 and acc.drain() == []
    assert rows[0] == {"step": 0, "seed": 11, "loss": 2.5}
    assert rows[1]["loss"] == 3.0 and "seed" not in rows[1]


def test_health_welford_matches_numpy():
    gs = np.random.default_rng(0).normal(size=12)
    acc = health.HealthAccumulator()
    for t, g in enumerate(gs):
        acc.record(t, {"projected_grad": float(g), "loss": 1.0})
        if t % 3 == 2:                        # drain mid-stream, repeatedly
            acc.drain()
    acc.drain()
    assert acc.g_count == len(gs)
    assert acc.g_mean == pytest.approx(np.mean(gs), rel=1e-12)
    assert acc.g_var == pytest.approx(np.var(gs, ddof=1), rel=1e-12)
    # per-row running stats are present, and the first row's var is 0
    assert acc.rows[0]["g_var"] == 0.0
    assert acc.rows[-1]["g_mean"] == pytest.approx(np.mean(gs))
    # non-finite g never poisons the stats
    acc.record(len(gs), {"projected_grad": float("nan")})
    acc.drain()
    assert acc.g_count == len(gs) and math.isfinite(acc.g_mean)


def test_health_layer_coverage_and_staleness():
    acc = health.HealthAccumulator(num_layers=3)
    sels = [[1, 0, 0], [1, 1, 0], [0, 1, 0], [1, 0, 0]]
    for t, sel in enumerate(sels):
        acc.record(t, {"layer_sel": np.asarray(sel),
                       "active_layers": sum(sel), "loss": float(t)})
    acc.drain()
    assert acc.layer_counts == [3, 2, 0]
    assert acc.staleness() == [0, 1, -1]      # -1: never selected
    s = acc.summary()
    assert s["steps_recorded"] == 4 and s["last_step"] == 3
    assert s["layer_counts"] == [3, 2, 0]
    assert s["layer_staleness"] == [0, 1, -1]
    assert s["layers_never_selected"] == 1
    assert s["loss_first"] == 0.0 and s["loss_last"] == 3.0


def test_health_update_norm_identity():
    # estimate: |lr|·sqrt(Σ c²·N) from E||z||² = N; exact: |lr·c0|·||z||
    acc = health.HealthAccumulator(num_layers=2,
                                   norm_fn=lambda seed, sel: 2.0)
    acc.record(0, {"coeffs": np.asarray([0.5]),
                   "n_active_params": np.asarray([100.0]),
                   "lr": 0.01, "layer_sel": np.asarray([1, 0])}, seed=7)
    acc.record(1, {"coeffs": np.asarray([0.5, -0.25]),
                   "n_active_params": np.asarray([100.0, 400.0]),
                   "lr": 0.01, "layer_sel": np.asarray([0, 1])}, seed=8)
    r0, r1 = acc.drain()
    assert r0["update_norm_est"] == pytest.approx(0.01 * math.sqrt(25.0))
    assert r0["update_norm"] == pytest.approx(abs(0.01 * 0.5) * 2.0)
    assert r1["update_norm_est"] == pytest.approx(
        0.01 * math.sqrt(0.25 * 100 + 0.0625 * 400))
    assert "update_norm" not in r1            # exact norm is q == 1 only
    assert acc.summary()["update_norm_est_last"] == r1["update_norm_est"]


# ============================================================== run dirs
def test_runlog_roundtrip(tmp_path):
    root = str(tmp_path)
    log = runlog.RunLog(root, "r1", spec={"estimator": {"name": "x"}})
    log.append([{"step": 1, "loss": 2.0}])
    log.append([{"step": 0, "loss": 1.0}])
    log.finalize({"steps_recorded": 2})
    rd = runlog.load_run("r1", root)
    assert rd.run_id == "r1" and rd.spec == {"estimator": {"name": "x"}}
    assert [r["step"] for r in rd.steps] == [0, 1]    # sorted on load
    assert rd.first_step == 0 and rd.last_step == 1
    assert rd.step_row(1)["loss"] == 2.0
    with pytest.raises(KeyError, match="no recorded step 5"):
        rd.step_row(5)
    assert rd.summary == {"steps_recorded": 2}
    # floats survive the JSON round trip bit-for-bit (replay's bedrock)
    g = float(np.float32(np.pi) * np.float32(1e-7))
    log2 = runlog.RunLog(root, "r2")
    log2.append([{"step": 0, "projected_grad": g}])
    log2.finalize()
    back = runlog.load_run("r2", root).steps[0]["projected_grad"]
    assert np.float32(back).tobytes() == np.float32(g).tobytes()


def test_run_resolution_and_ids(tmp_path):
    root = str(tmp_path)
    assert runlog.list_runs(root) == []
    with pytest.raises(FileNotFoundError, match="no run directories"):
        runlog.resolve_run(None, root)
    rid = runlog.make_run_id(root, seed=3, now=0.0)
    assert rid.endswith("-s3")
    runlog.RunLog(root, rid, spec={}).finalize()
    # collision under the same timestamp gets a -N suffix
    rid2 = runlog.make_run_id(root, seed=3, now=0.0)
    assert rid2 == f"{rid}-2" and rid2 != rid
    os.utime(os.path.join(root, rid))         # make rid the newest
    os.mkdir(os.path.join(root, "not-a-run")) # no spec/steps: not listed
    assert runlog.list_runs(root) == [rid]
    assert runlog.resolve_run(None, root) == os.path.join(root, rid)
    assert runlog.resolve_run(rid, root) == os.path.join(root, rid)
    assert runlog.resolve_run(os.path.join(root, rid)) \
        == os.path.join(root, rid)
    with pytest.raises(FileNotFoundError, match="known runs"):
        runlog.resolve_run("missing", root)


# ============================================== CLI: the train implication
def _capture_api_run(monkeypatch):
    captured = []

    def fake_run(spec):
        captured.append(spec)
        return {"summary": {}, "spec": api.to_dict(spec), "history": {}}

    monkeypatch.setattr(api, "run", fake_run)
    return captured


def test_cli_train_implies_run_registry(monkeypatch):
    from repro.launch import cli
    captured = _capture_api_run(monkeypatch)
    cli.main(["train", "--preset", SMOKE])
    assert captured[-1].telemetry.runs_dir == runlog.DEFAULT_RUNS_DIR
    cli.main(["train", "--preset", SMOKE, "--no-runlog"])
    assert captured[-1].telemetry.runs_dir is None
    # an explicit flag or --set always beats the implication
    cli.main(["train", "--preset", SMOKE, "--runs-dir", "X"])
    assert captured[-1].telemetry.runs_dir == "X"
    cli.main(["train", "--preset", SMOKE, "--set",
              "telemetry.runs_dir=Y"])
    assert captured[-1].telemetry.runs_dir == "Y"


def test_docgen_documents_run_commands():
    from repro.launch import docgen
    for cmd in ("report", "replay"):
        flags = [row[0] for row in docgen._extras_rows(cmd)]
        assert "RUN" in flags                 # positional, not an option
        assert "--runs-root" in flags
    assert "--step" in [r[0] for r in docgen._extras_rows("replay")]
    assert "--no-runlog" in [r[0] for r in docgen._extras_rows("train")]


# ================================= end to end: train -> report -> replay
@pytest.fixture(scope="module")
def trained_run(tmp_path_factory):
    """One real telemetry-on training run (two_point, materialized,
    checkpoints at 2 and 4), shared by the run-dir/report/replay tests."""
    pytest.importorskip("jax")
    root = str(tmp_path_factory.mktemp("runs"))
    ckpt_dir = str(tmp_path_factory.mktemp("ckpt") / "run")
    spec = api.with_overrides(api.presets.get(SMOKE), {
        "run.steps": 4, "run.log_every": 2, "run.eval_every": 0,
        "run.ckpt_every": 2, "run.ckpt_dir": ckpt_dir,
        "run.keep_ckpts": 4,
        "telemetry.enabled": True, "telemetry.runs_dir": root,
        "telemetry.health_norms": True})
    api.validate(spec)
    result = api.run(spec)
    return {"spec": spec, "result": result, "root": root,
            "ckpt_dir": ckpt_dir}


def test_run_dir_contents(trained_run):
    rd = runlog.load_run(None, trained_run["root"])
    assert rd.run_id == trained_run["result"]["summary"]["run_id"]
    for name in (runlog.SPEC_FILE, runlog.STEPS_FILE,
                 runlog.SUMMARY_FILE, runlog.TRACE_FILE):
        assert os.path.isfile(os.path.join(rd.dir, name)), name
    assert rd.spec == api.to_dict(trained_run["spec"])
    assert [r["step"] for r in rd.steps] == [0, 1, 2, 3]
    base = _fold(trained_run["spec"].run.seed, 0xC0FFEE)
    n_layers = len(rd.steps[0]["layer_sel"])
    for t, row in enumerate(rd.steps):
        assert row["seed"] == _fold(base, t)  # the recorded seed lineage
        for key in ("loss", "eps", "lr", "g_mean", "g_var",
                    "update_norm", "update_norm_est"):
            assert key in row, key
        assert len(row["probe_grads"]) == 1   # two_point: q == 1
        assert len(row["coeffs"]) == 1
        assert len(row["n_active_params"]) == 1
        assert len(row["layer_sel"]) == n_layers
        assert row["active_layers"] == sum(row["layer_sel"])
        assert 1 <= row["active_layers"] < n_layers   # LeZO sparsity on
        # applied values are the f32 the step actually used
        assert row["eps"] == float(np.float32(
            trained_run["spec"].optimizer.eps))
        assert row["lr"] == float(np.float32(
            trained_run["spec"].optimizer.lr))
        # E||z||² = N: the estimate must sit close to the exact norm
        assert row["update_norm"] == pytest.approx(
            row["update_norm_est"], rel=0.05)


def test_run_summary_aggregates(trained_run):
    rd = runlog.load_run(None, trained_run["root"])
    s = rd.summary
    gs = [r["projected_grad"] for r in rd.steps]
    assert s["steps_recorded"] == 4 and s["last_step"] == 3
    assert s["g_count"] == 4
    assert s["g_mean"] == pytest.approx(np.mean(gs), rel=1e-9)
    assert s["g_var"] == pytest.approx(np.var(gs, ddof=1), rel=1e-9)
    assert s["loss_first"] == rd.steps[0]["loss"]
    assert s["loss_last"] == rd.steps[-1]["loss"]
    assert sum(s["layer_counts"]) == sum(r["active_layers"]
                                         for r in rd.steps)
    assert len(s["layer_staleness"]) == len(rd.steps[0]["layer_sel"])
    assert s["update_norm_est_last"] == rd.steps[-1]["update_norm_est"]


def test_run_id_lands_in_checkpoint_manifest(trained_run):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(trained_run["ckpt_dir"])
    assert sorted(mgr.all_steps()) == [2, 4]
    extra = mgr.read_manifest()["extra"]
    assert extra["run_id"] == trained_run["result"]["summary"]["run_id"]


def test_report_renders_from_run_dir(trained_run, tmp_path):
    from repro.launch import report as report_mod
    out = str(tmp_path / "r.md")
    rep = report_mod.report_run(None, runs_root=trained_run["root"],
                                out=out)
    md = rep["markdown"]
    for section in ("# Run report", "## Spec", "## Convergence",
                    "## Applied hyperparameters", "## LeZO layer coverage",
                    "## Stage timings"):
        assert section in md, section
    assert rep["run_id"] in md
    assert "two_point" in md
    # written next to the run AND to --out (which becomes the path)
    assert rep["path"] == out
    in_dir = os.path.join(rep["run_dir"], report_mod.REPORT_FILE)
    for path in (out, in_dir):
        with open(path) as f:
            assert f.read() == md
    again = report_mod.report_run(None, runs_root=trained_run["root"])
    assert again["markdown"] == md


def test_cli_report_prints_markdown(trained_run, capsys):
    from repro.launch import cli
    assert cli.console(["report", "--runs-root",
                        trained_run["root"]]) == 0
    assert "# Run report" in capsys.readouterr().out


def test_replay_verifies_run_bitwise(trained_run):
    from repro.launch import replay as replay_mod
    rep = replay_mod.replay_run(None, runs_root=trained_run["root"])
    assert rep["ok"], rep["failures"]
    assert rep["step"] == 3 and rep["estimator"] == "two_point"
    # stateless estimator: fast-forwards to the newest checkpoint <= k
    assert rep["param_start"] == 2
    assert any("seed lineage" in c for c in rep["checks"])
    for key in ("loss", "projected_grad", "eps", "lr", "layer_sel"):
        assert key in rep["matched"], key
    rd = runlog.load_run(None, trained_run["root"])
    assert rep["matched"]["loss"] == rd.step_row(3)["loss"]


@pytest.mark.slow
def test_replay_detects_corruption(trained_run, tmp_path):
    """Golden gate: a single flipped mantissa bit in a recorded g (and a
    broken seed lineage) must fail the replay loudly."""
    from repro.launch import replay as replay_mod
    root = str(tmp_path / "runs")
    rd = runlog.load_run(None, trained_run["root"])
    dst = os.path.join(root, rd.run_id)
    shutil.copytree(rd.dir, dst)
    steps_path = os.path.join(dst, runlog.STEPS_FILE)
    rows = [json.loads(ln) for ln in open(steps_path)]
    for row in rows:
        if row.get("step") == 3:              # flip g's lowest mantissa bit
            # (inside the replayed range — replay fast-forwards to the
            # newest checkpoint, so earlier rows are lineage-checked only)
            bits = np.float32(row["projected_grad"]).view(np.uint32)
            row["projected_grad"] = float(
                (bits ^ np.uint32(1)).view(np.float32))
        if row.get("step") == 0:              # and break the seed lineage
            row["seed"] = (row["seed"] + 1) & 0xFFFFFFFF
    with open(steps_path, "w") as f:
        f.writelines(json.dumps(r) + "\n" for r in rows)
    rep = replay_mod.replay_run(None, runs_root=root)
    assert not rep["ok"]
    assert any("seed lineage" in msg and "step 0" in msg
               for msg in rep["failures"]), rep["failures"]
    assert any("projected_grad" in msg and "step 3" in msg
               for msg in rep["failures"]), rep["failures"]
    # the pristine sibling keys of the corrupted row still matched
    assert not any("loss" in msg for msg in rep["failures"])


@pytest.mark.slow
@pytest.mark.parametrize("est,backend", [
    ("one_sided", "materialized"),
    ("averaged", "virtual_ref"),
    ("importance", "materialized"),
    ("two_point", "virtual_ref"),
])
def test_replay_matrix(tmp_path, est, backend):
    """Bit-identical replay from step 0 across estimators x forward
    backends (no checkpoints: parameters re-derive from the init)."""
    pytest.importorskip("jax")
    from repro.launch import replay as replay_mod
    spec = api.with_overrides(api.presets.get(SMOKE), {
        "run.steps": 3, "run.log_every": 1, "run.eval_every": 0,
        "estimator.name": est, "runtime.forward_backend": backend,
        "telemetry.runs_dir": str(tmp_path)})
    api.validate(spec)
    api.run(spec)
    rep = replay_mod.replay_run(None, runs_root=str(tmp_path))
    assert rep["ok"], rep["failures"]
    assert rep["param_start"] == 0 and rep["step"] == 2
    assert rep["estimator"] == est
    assert rep["forward_backend"] == backend


@pytest.mark.slow
def test_resume_then_replay_across_checkpoint(tmp_path):
    """A resumed run's log starts mid-stream; replay must reconstruct
    the resume point from the checkpoint (importance is stateful, so it
    must re-warm from the run's own first step) and still pin the
    parameters bitwise against a checkpoint inside the replayed range."""
    pytest.importorskip("jax")
    from repro.launch import replay as replay_mod
    ckpt_dir = str(tmp_path / "ckpt")
    base = {"run.log_every": 1, "run.eval_every": 0,
            "run.ckpt_every": 2, "run.ckpt_dir": ckpt_dir,
            "run.keep_ckpts": 8, "estimator.name": "importance"}
    spec1 = api.with_overrides(api.presets.get(SMOKE), dict(
        base, **{"run.steps": 4,
                 "telemetry.runs_dir": str(tmp_path / "runs1")}))
    api.validate(spec1)
    api.run(spec1)
    spec2 = api.with_overrides(api.presets.get(SMOKE), dict(
        base, **{"run.steps": 8,
                 "telemetry.runs_dir": str(tmp_path / "runs2")}))
    api.validate(spec2)
    api.run(spec2)
    rd = runlog.load_run(None, str(tmp_path / "runs2"))
    assert rd.first_step == 4 and rd.last_step == 7   # resumed mid-stream
    rep = replay_mod.replay_run(None, step=7,
                                runs_root=str(tmp_path / "runs2"))
    assert rep["ok"], rep["failures"]
    assert rep["param_start"] == 4            # stateful: the run's start
    assert any("[6]" in c for c in rep["checks"]
               if "checkpoint" in c), rep["checks"]
