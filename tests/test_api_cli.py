"""The unified spec-driven launch CLI (launch/cli.py) and the legacy
module shims that forward to it.

The headline regression here is the default-drift satellite: train and
evaluate used to carry separate argparse tables whose defaults disagreed
(--lr 1e-4 vs 1e-3, batch 16 vs 32).  Both now parse into the same
preset-backed spec, so their shared-field defaults are equal by
construction — and asserted below so they stay that way.
"""
import json
import os

import pytest

from repro import api
from repro.launch import cli


def _spec(argv, implied=None):
    ns = cli.build_parser().parse_args(argv)
    return cli.build_spec(ns, implied)


# ------------------------------------------------------- default drift
def test_train_and_evaluate_defaults_agree():
    train = _spec(["train"])
    ev = _spec(["evaluate"])
    assert train.optimizer == ev.optimizer, \
        "train/evaluate optimizer defaults drifted"
    assert train.estimator == ev.estimator
    assert train.runtime == ev.runtime
    assert train.run == ev.run
    assert train.model == ev.model
    # the historical drift, pinned explicitly: one lr, one batch size
    assert train.optimizer.lr == ev.optimizer.lr == 1e-4
    assert train.run.batch_size == ev.run.batch_size


def test_every_command_shares_the_generated_spec_surface():
    """No per-command argparse duplication for shared fields: every
    spec-driven command accepts every generated spec flag and resolves
    it through the same path.  The run-dir commands (report/replay) are
    the deliberate exception — their spec is the run's own spec.json,
    so they must reject spec flags rather than silently ignore them."""
    for cmd in cli.COMMANDS:
        if cmd in cli._NO_SPEC_CMDS:
            with pytest.raises(SystemExit):
                cli.build_parser().parse_args([cmd, "--optimizer.lr",
                                               "5e-5"])
            continue
        extra = ["--shape", "train_4k"] if cmd == "hillclimb" else []
        spec = _spec([cmd, "--optimizer.lr", "5e-5", "--arch", "opt-13b",
                      *extra])
        assert spec.optimizer.lr == 5e-5, cmd
        assert spec.model.arch == "opt-13b", cmd


# -------------------------------------------------- flags & precedence
def test_alias_and_generated_flags_are_the_same_field():
    a = _spec(["train", "--lr", "3e-4"])
    b = _spec(["train", "--optimizer.lr", "3e-4"])
    assert a == b
    assert a.optimizer.lr == 3e-4


def test_precedence_preset_flags_set():
    spec = _spec(["train", "--preset", "mezo-opt13b",
                  "--sparsity", "0.5", "--set", "optimizer.sparsity=0.25"])
    assert spec.optimizer.sparsity == 0.25     # --set wins over flags
    spec = _spec(["train", "--preset", "mezo-opt13b", "--sparsity", "0.5"])
    assert spec.optimizer.sparsity == 0.5      # flags win over preset
    spec = _spec(["train", "--preset", "mezo-opt13b"])
    assert spec.optimizer.sparsity == 0.0      # preset over base defaults


def test_train_optimizer_implications():
    spec = _spec(["train"], implied={"optimizer.sparsity": 0.0})
    assert spec.optimizer.sparsity == 0.0
    # legacy semantics: `--optimizer mezo --sparsity X` always meant
    # n_drop=0, so the command implication beats the flag ...
    spec = _spec(["train", "--sparsity", "0.6"],
                 implied={"optimizer.sparsity": 0.0})
    assert spec.optimizer.sparsity == 0.0
    # ... while an explicit --set (spec-world) still wins over both
    spec = _spec(["train", "--set", "optimizer.sparsity=0.6"],
                 implied={"optimizer.sparsity": 0.0})
    assert spec.optimizer.sparsity == 0.6


def test_unknown_set_path_and_preset_fail_with_path():
    with pytest.raises(api.SpecError, match="optimizer.bogus"):
        _spec(["train", "--set", "optimizer.bogus=1"])
    with pytest.raises(api.SpecError, match="--set"):
        _spec(["train", "--set", "optimizer.lr"])
    with pytest.raises(api.SpecError, match="preset"):
        _spec(["train", "--preset", "nope"])


# ------------------------------------------------------ specs command
def test_specs_command_dumps_all_presets_byte_identical(tmp_path, capsys):
    written = cli.main(["specs", "--out", str(tmp_path)])
    assert sorted(written) == api.presets.names()
    for name, path in written.items():
        with open(path) as f:
            text = f.read()
        assert text == api.to_json(api.presets.get(name)), name
        assert api.from_json(text) == api.presets.get(name)
    out = json.loads(capsys.readouterr().out)
    assert out == written


# ------------------------------------------------- end-to-end commands
def test_train_command_end_to_end(tmp_path, capsys):
    out = tmp_path / "hist.json"
    result = cli.main([
        "train", "--preset", "tiny-smoke", "--variant", "smoke",
        "--steps", "3", "--batch-size", "4", "--out", str(out)])
    assert result["summary"]["final_loss"] is not None
    assert len(result["history"]["loss"]) == 3
    # stdout carries the summary; --out carries spec + summary + history
    printed = json.loads(capsys.readouterr().out)
    assert printed == result["summary"]
    payload = json.loads(out.read_text())
    assert payload["spec"] == result["spec"]
    assert payload["spec"]["run"]["steps"] == 3
    assert "final_params" not in payload["history"]


def test_legacy_train_shim_accepts_historical_flags(tmp_path):
    from repro.launch import train as train_mod
    out = tmp_path / "h.json"
    result = train_mod.main([
        "--arch", "opt-13b", "--variant", "smoke", "--optimizer", "mezo",
        "--estimator", "two_point", "--q", "1", "--steps", "3",
        "--batch-size", "4", "--lr", "1e-4", "--eps", "1e-3",
        "--backend", "scan", "--seq-len", "32", "--seed", "0",
        "--out", str(out)])
    assert result["summary"]["n_drop"] == 0          # mezo implication
    assert os.path.exists(out)


def test_legacy_serve_shim_smoke(capsys):
    from repro.launch import serve as serve_mod
    result = serve_mod.main(["--variant", "smoke", "--batch", "2",
                             "--prompt-len", "8", "--gen", "3"])
    assert result["spec"]["model"]["arch"] == "xlstm-350m"
    assert len(result["tokens"][0]) == 3
    assert "tok/s" in capsys.readouterr().out
