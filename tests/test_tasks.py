"""Task registry & evaluation subsystem: specs, compilation, metrics."""
import json

import numpy as np
import pytest

import jax

from repro import tasks
from repro.configs import opt
from repro.models import lm
from repro.tasks import metrics, vocab

VOCAB, SEQ = 512, 48
MCFG = opt.opt_tiny(layers=2, d_model=64, vocab=VOCAB)


@pytest.fixture(scope="module")
def params():
    return lm.init_params(MCFG, jax.random.PRNGKey(0))


# ------------------------------------------------------------- registry
def test_registry_has_superglue_coverage():
    names = tasks.names()
    assert len(names) >= 6
    for required in ("sst2", "boolq", "copa", "rte", "wic"):
        assert required in names
    kinds = {tasks.get(n).kind for n in names}
    assert "generation" in kinds          # >=1 generative task
    assert len(tasks.classification_names()) >= 4


def test_register_rejects_duplicates_and_bad_specs():
    with pytest.raises(ValueError):
        tasks.register(tasks.get("sst2"))
    with pytest.raises(ValueError):
        tasks.TaskSpec(name="x", kind="nope", template="{a}",
                       generator=lambda s, n: [])
    with pytest.raises(ValueError):
        tasks.TaskSpec(name="x", kind="classification", template="{a}",
                       generator=lambda s, n: [], verbalizers=("one",))
    with pytest.raises(KeyError):
        tasks.get("not_a_task")


# ---------------------------------------------------------- compilation
@pytest.mark.parametrize("name", tasks.names())
def test_compiled_batch_format(name):
    """Every task compiles to the synthetic.make_dataset batch contract."""
    t = tasks.build(name, vocab=VOCAB, seq_len=SEQ)
    d = t.make_dataset(16)
    assert d["tokens"].shape == (16, SEQ - 1)
    assert d["labels"].shape == (16, SEQ - 1)
    assert d["loss_mask"].shape == (16, SEQ - 1)
    assert d["tokens"].dtype == np.int32 and d["labels"].dtype == np.int32
    assert (d["tokens"] >= 0).all() and (d["tokens"] < VOCAB).all()
    assert (d["loss_mask"].sum(1) >= 1).all()    # every row supervises
    # loss is never on PAD labels
    assert (d["labels"][d["loss_mask"] > 0] != vocab.PAD).all()
    # shifted-by-one alignment: labels[t] == tokens[t+1]
    assert np.array_equal(d["tokens"][:, 1:], d["labels"][:, :-1])


@pytest.mark.parametrize("name", tasks.names())
def test_compiled_dataset_deterministic(name):
    t = tasks.build(name, vocab=VOCAB, seq_len=SEQ)
    a, b = t.make_dataset(8), t.make_dataset(8)
    for k in a:
        assert np.array_equal(a[k], b[k]), k
    c = t.make_dataset(8, seed=123)
    assert not all(np.array_equal(a[k], c[k]) for k in a)


def test_classification_layout():
    t = tasks.build("sst2", vocab=VOCAB, seq_len=SEQ)
    d = t.make_dataset(32)
    # answer position: exactly one supervised label, the verbalizer token
    assert (d["loss_mask"].sum(1) == 1).all()
    assert (d["loss_mask"][:, -1] == 1).all()
    verb = d["labels"][:, -1]
    assert set(np.unique(verb)) <= set(t.verb_ids.tolist())
    assert np.array_equal(verb, t.verb_ids[d["class_labels"]])
    # query marker sits right before the answer
    assert (d["tokens"][:, -1] == vocab.query_token(VOCAB)).all()


def test_multiple_choice_layout():
    t = tasks.build("copa", vocab=VOCAB, seq_len=SEQ)
    d = t.make_dataset(16)
    n, k, s = d["choice_inputs"].shape
    assert (n, k, s) == (16, 2, SEQ - 1)
    # the gold continuation row equals the training sequence
    rows = np.arange(n)
    gold_inp = d["choice_inputs"][rows, d["class_labels"]]
    assert np.array_equal(gold_inp, d["tokens"])
    assert np.array_equal(d["choice_labels"][rows, d["class_labels"]],
                          d["labels"])
    # scoring mask covers the same positions as the training loss mask
    assert np.array_equal(d["choice_mask"][rows, d["class_labels"]],
                          d["loss_mask"])
    assert (d["choice_mask"].sum(-1) >= 1).all()


def test_generation_answer_is_copied_span():
    t = tasks.build("squad_copy", vocab=VOCAB, seq_len=SEQ)
    d = t.make_dataset(16)
    for i in range(16):
        ans = d["labels"][i][d["loss_mask"][i] > 0]
        prompt = d["tokens"][i]
        for tok in ans:                  # extractive: answer ⊂ context
            assert tok in prompt


def test_signal_pools_hash_disjoint_at_reference_vocab():
    """The FNV tokenizer may merge words; a merge ACROSS signal pools
    would leak one class's signal into another (or into neutral filler),
    silently corrupting the planted task signal.  Pin pairwise id-
    disjointness of every generator pool at the reference vocab=512
    (deliberate shared words like WiC's target 'bank' are exempt)."""
    from repro.tasks import generators as g
    pools = {"NEUTRAL": g.NEUTRAL, "POS": g.POS_WORDS, "NEG": g.NEG_WORDS,
             "TRUE": g.TRUE_WORDS, "FALSE": g.FALSE_WORDS,
             "CB0": g.CB_WORDS[0], "CB1": g.CB_WORDS[1], "CB2": g.CB_WORDS[2],
             "SENSE_A": g.SENSE_A, "SENSE_B": g.SENSE_B}
    owner = {}
    clashes = []
    for pname, words in pools.items():
        for w in words:
            wid = vocab.word_id(w, VOCAB)
            prev = owner.setdefault(wid, (pname, w))
            if prev[1] != w:
                clashes.append((prev, (pname, w), wid))
    assert not clashes, f"hash collisions across signal pools: {clashes}"
    # literal word sharing across pools is also signal leakage; only the
    # WiC target word is deliberately shared between its two sense pools
    for pa, pb in [("NEUTRAL", "SENSE_A"), ("NEUTRAL", "SENSE_B"),
                   ("NEUTRAL", "POS"), ("NEUTRAL", "NEG"),
                   ("NEUTRAL", "TRUE"), ("NEUTRAL", "FALSE")]:
        assert not set(pools[pa]) & set(pools[pb]), (pa, pb)
    assert set(g.SENSE_A) & set(g.SENSE_B) == {"bank"}


def test_verbalizers_reserved_and_distinct():
    for name in tasks.classification_names():
        t = tasks.build(name, vocab=VOCAB, seq_len=SEQ)
        ids = t.verb_ids.tolist()
        assert len(set(ids)) == len(ids)
        assert all(i >= VOCAB - vocab.N_RESERVED for i in ids)
        # content words can never collide with control tokens
        assert vocab.word_id("anything", VOCAB) < VOCAB - vocab.N_RESERVED


def test_json_backed_task(tmp_path):
    path = tmp_path / "examples.json"
    examples = [{"text": f"great brilliant superb sample {i}", "label": 1}
                if i % 2 else
                {"text": f"dreadful tedious hollow sample {i}", "label": 0}
                for i in range(10)]
    path.write_text(json.dumps(examples))
    spec = tasks.TaskSpec(
        name="json_sst2_test", kind="classification",
        template="review : {text} . sentiment :",
        generator=tasks.json_examples(str(path)),
        verbalizers=("terrible", "great"))
    t = tasks.compile_task(spec, vocab=VOCAB, seq_len=SEQ)
    d = t.make_dataset(6)
    assert d["tokens"].shape == (6, SEQ - 1)
    assert set(np.unique(d["class_labels"])) <= {0, 1}
    # deterministic subsample
    assert np.array_equal(d["tokens"], t.make_dataset(6)["tokens"])


def _mc_spec(name, gen):
    return tasks.TaskSpec(name=name, kind="multiple_choice",
                          template="p : {premise} ?", generator=gen,
                          answer_len=4)


def test_multiple_choice_rejects_bad_choices():
    """Ragged counts, empty choices, and over-length choices all fail
    loudly at compile time: an all-PAD phantom continuation would
    out-score real ones, and truncation merges distinct choices."""
    ragged = _mc_spec("mc_ragged", lambda s, n: [
        {"premise": "a b", "choices": ("x y", "z w"), "label": 0},
        {"premise": "c d", "choices": ("x y",), "label": 0}][:n])
    with pytest.raises(ValueError, match="choices"):
        tasks.compile_task(ragged, VOCAB, 32).make_dataset(2)
    empty = _mc_spec("mc_empty", lambda s, n: [
        {"premise": "a b", "choices": ("x y", "  "), "label": 0}] * n)
    with pytest.raises(ValueError, match="empty"):
        tasks.compile_task(empty, VOCAB, 32).make_dataset(2)
    overlong = _mc_spec("mc_long", lambda s, n: [
        {"premise": "a b", "choices": ("x y", "one two three four five"),
         "label": 0}] * n)
    with pytest.raises(ValueError, match="answer_len"):
        tasks.compile_task(overlong, VOCAB, 32).make_dataset(2)


# -------------------------------------------------------------- metrics
def test_accuracy_and_macro_f1_aggregates():
    pred = np.array([0, 0, 1, 1, 2, 2])
    gold = np.array([0, 1, 1, 1, 2, 0])
    assert metrics.accuracy(pred, gold) == pytest.approx(4 / 6)
    # hand-computed per-class F1: c0: tp1 fp1 fn1 -> 0.5; c1: tp2 fp0 fn1
    # -> 0.8; c2: tp1 fp1 fn0 -> 2/3
    assert metrics.macro_f1(pred, gold, 3) == pytest.approx(
        (0.5 + 0.8 + 2 / 3) / 3)
    assert metrics.macro_f1(gold, gold, 3) == 1.0
    # absent class contributes zero, never NaN
    assert np.isfinite(metrics.macro_f1(np.zeros(4, int), np.zeros(4, int), 3))


def test_evaluate_protocols_run(params):
    """Each scoring mode produces a finite value in [0, 1].  n=16 keeps
    every forward at the same (16, S-1) shape, so the jitted scorer
    compiles once and is shared across all three protocols (cb's macro-F1
    aggregate is unit-tested above and rides the sst2 scoring path)."""
    for name in ("sst2", "copa", "squad_copy"):
        t = tasks.build(name, vocab=VOCAB, seq_len=SEQ)
        d = t.make_dataset(16)
        v = t.evaluate(MCFG, params, d, lm, max_examples=16)
        assert 0.0 <= v <= 1.0, (name, v)


def test_choice_scoring_prefers_planted_winner(params):
    """Rig one choice's continuation to be the argmax-probable tokens —
    scoring must pick it for every example."""
    t = tasks.build("copa", vocab=VOCAB, seq_len=SEQ)
    d = t.make_dataset(8)
    ci, cl, cm = (d["choice_inputs"].copy(), d["choice_labels"].copy(),
                  d["choice_mask"].copy())
    logits = metrics._full_logits(MCFG, params, ci[:, 0], lm)
    greedy = np.asarray(logits.argmax(-1))
    # plant the greedy tokens as choice 0's continuation
    mask0 = cm[:, 0] > 0
    cl[:, 0][mask0] = greedy[mask0]
    scores = metrics.choice_scores(MCFG, params, ci, cl, cm, lm)
    assert (scores.argmax(-1) == 0).all()


def test_exact_match_perfect_when_gold_is_greedy(params):
    t = tasks.build("squad_copy", vocab=VOCAB, seq_len=SEQ)
    d = t.make_dataset(8)
    logits = metrics._full_logits(MCFG, params, d["tokens"], lm)
    greedy = np.asarray(logits.argmax(-1))
    labels = d["labels"].copy()
    m = d["loss_mask"] > 0
    labels[m] = greedy[m]
    hits = metrics.exact_match_hits(MCFG, params, d["tokens"], labels,
                                    d["loss_mask"], lm)
    assert hits.mean() == 1.0
    # and perturbing one gold token per row breaks EM for that row
    labels2 = labels.copy()
    for i in range(8):
        j = np.argmax(d["loss_mask"][i])
        labels2[i, j] = (labels2[i, j] + 1) % VOCAB
    hits2 = metrics.exact_match_hits(MCFG, params, d["tokens"], labels2,
                                     d["loss_mask"], lm)
    assert hits2.mean() == 0.0
