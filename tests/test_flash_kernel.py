"""Pallas flash-attention kernel vs jnp oracle: shape/dtype/causality sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention_pallas, flash_attention_ref


@pytest.mark.parametrize("BH,S,dh,bq,bk", [
    (2, 128, 64, 64, 64), (1, 256, 128, 128, 64), (3, 64, 32, 64, 32),
    (2, 128, 64, 32, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_kernel_matches_ref(BH, S, dh, bq, bk, causal, dtype):
    k0 = jax.random.PRNGKey(0)
    dt = jnp.dtype(dtype)
    q = jax.random.normal(k0, (BH, S, dh), dt)
    k = jax.random.normal(jax.random.fold_in(k0, 1), (BH, S, dh), dt)
    v = jax.random.normal(jax.random.fold_in(k0, 2), (BH, S, dh), dt)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk)
    want = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_kernel_cross_attention_lengths():
    """Sq != Sk (non-causal cross attention)."""
    k0 = jax.random.PRNGKey(3)
    q = jax.random.normal(k0, (2, 64, 32))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (2, 192, 32))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (2, 192, 32))
    got = flash_attention_pallas(q, k, v, causal=False, bq=64, bk=64)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_kernel_matches_model_flash():
    """Kernel agrees with the production jnp flash in models.layers."""
    from repro.models import layers
    k0 = jax.random.PRNGKey(5)
    B, S, KV, G, dh = 2, 128, 2, 2, 32
    q = jax.random.normal(k0, (B, S, KV, G, dh))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (B, S, KV, dh))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (B, S, KV, dh))
    want = layers.flash_attention(q, k, v, causal=True, q_chunk=64,
                                  k_chunk=64)
    # GQA-expand to the kernel layout
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * KV * G, S, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * KV * G, S, dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * KV * G, S, dh)
    got = flash_attention_pallas(qf, kf, vf, causal=True, bq=64, bk=64)
    got = got.reshape(B, KV, G, S, dh).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)
