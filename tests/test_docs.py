"""Documentation gates (fast tier, no jax).

Two families:

  * generated-doc freshness — docs/cli.md plus the spec tables injected
    into docs/serving.md and docs/observability.md must match what the
    live schema generates (`make docs`), the same pattern as the golden
    spec JSON: change the schema without regenerating and this fails
    before CI's docs-freshness job does.
  * module-docstring audit — every module under src/repro/ carries a
    docstring citing its DESIGN.md section, and every §N cited anywhere
    in a module docstring exists in DESIGN.md (no dangling citations).
"""
import ast
import os
import re

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")
DOCS = os.path.join(REPO, "docs")
SRC = os.path.join(REPO, "src", "repro")


def _read(*parts):
    with open(os.path.join(*parts)) as f:
        return f.read()


# ------------------------------------------------------------- freshness
def test_cli_md_fresh():
    from repro.launch import docgen
    assert _read(DOCS, "cli.md") == docgen.cli_markdown(), (
        "docs/cli.md is stale — run `make docs`")


def test_serving_md_spec_table_fresh():
    from repro.launch import docgen
    text = _read(DOCS, "serving.md")
    assert docgen.inject(text, docgen.serving_spec_markdown()) == text, (
        "docs/serving.md generated span is stale — run `make docs`")


def test_observability_md_spec_table_fresh():
    from repro.launch import docgen
    text = _read(DOCS, "observability.md")
    assert docgen.inject(text, docgen.telemetry_spec_markdown(),
                         docgen.TEL_MARK_BEGIN,
                         docgen.TEL_MARK_END) == text, (
        "docs/observability.md generated span is stale — run `make docs`")


def test_docgen_idempotent_and_deterministic():
    from repro.launch import docgen
    one, two = docgen.cli_markdown(), docgen.cli_markdown()
    assert one == two
    injected = docgen.inject(_read(DOCS, "serving.md"),
                             docgen.serving_spec_markdown())
    assert docgen.inject(injected, docgen.serving_spec_markdown()) \
        == injected


def test_inject_requires_markers():
    from repro.launch import docgen
    with pytest.raises(ValueError, match="marker"):
        docgen.inject("no markers here", "x")


def test_cli_md_covers_every_command_and_spec_field():
    from repro import api
    from repro.launch import cli
    text = _read(DOCS, "cli.md")
    for cmd in cli.COMMANDS:
        assert f"### `{cmd}`" in text, f"command {cmd} undocumented"
    for path in api.field_paths():
        assert f"`{path}`" in text, f"spec field {path} undocumented"
    for flag in cli.ALIASES:
        assert flag in text, f"alias {flag} undocumented"


# -------------------------------------------------------- docstring audit
def _design_sections():
    return set(re.findall(r"^## §(\d+)", _read(REPO, "DESIGN.md"), re.M))


def _modules():
    for root, _, files in os.walk(SRC):
        for fn in sorted(files):
            if fn.endswith(".py"):
                yield os.path.join(root, fn)


def test_every_module_has_docstring_citing_design():
    missing, uncited = [], []
    for path in _modules():
        rel = os.path.relpath(path, REPO)
        ds = ast.get_docstring(ast.parse(_read(path)))
        if not ds:
            missing.append(rel)
        elif "DESIGN.md" not in ds:
            uncited.append(rel)
    assert not missing, f"modules without a docstring: {missing}"
    assert not uncited, f"module docstrings not naming their DESIGN.md " \
                        f"section: {uncited}"


def test_no_dangling_design_citations():
    valid = _design_sections()
    assert valid, "DESIGN.md has no §N sections?"
    dangling = []
    for path in _modules():
        ds = ast.get_docstring(ast.parse(_read(path))) or ""
        for sec in re.findall(r"§\s*(\d+)", ds):
            if sec not in valid:
                dangling.append((os.path.relpath(path, REPO), f"§{sec}"))
    assert not dangling, f"citations of nonexistent DESIGN sections: " \
                         f"{dangling}"


def test_docs_cite_only_existing_design_sections():
    valid = _design_sections()
    for doc in ("serving.md", "cli.md", "observability.md"):
        for sec in re.findall(r"§(\d+)", _read(DOCS, doc)):
            assert sec in valid, f"docs/{doc} cites nonexistent §{sec}"
