"""Estimator subsystem: legacy equivalence, bias, invariants, backends.

The load-bearing test is bit-identity of ``two_point`` (through
``zo.make_zo_step``, now a shim over the subsystem) against an inline
copy of the pre-refactor step — the refactor must not move a single ulp.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import estimators
from repro.core import rng, zo
from repro.kernels import ref as kref


def _params():
    k = jax.random.PRNGKey(0)
    return {"embed": jax.random.normal(k, (40, 8)),
            "blocks": {"w": jax.random.normal(jax.random.fold_in(k, 1),
                                              (6, 16, 8)),
                       "b": jax.random.normal(jax.random.fold_in(k, 2),
                                              (6, 8))}}


def _spec(params):
    return zo.build_spec(params, lambda p: "blk" if p.startswith("blocks")
                         else None)


def _loss(p, batch):
    return 1e-3 * sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))


def _legacy_zo_step(loss_fn, spec, cfg):
    """Verbatim copy of the pre-refactor core/zo.py::make_zo_step body."""
    def step(params, batch, step_idx, base_seed):
        seed = rng.fold(jnp.asarray(base_seed, jnp.uint32),
                        jnp.asarray(step_idx, jnp.uint32))
        if cfg.policy == "stratified":
            masks, idxs, n_active = zo.stratified_select(spec, seed,
                                                         cfg.n_drop)
        else:
            masks, idxs, n_active = zo.uniform_select(spec, seed, cfg.n_drop)
        ax = lambda p, s, d=1.0: zo.tree_axpy(
            p, spec, seed, s, masks, idxs, decay=d,
            backend=cfg.backend, interpret=cfg.interpret)

        p = ax(params, cfg.eps)
        l_plus = loss_fn(p, batch)
        p = ax(p, -2.0 * cfg.eps)
        l_minus = loss_fn(p, batch)
        g = (l_plus - l_minus) / (2.0 * cfg.eps)
        lr = cfg.lr
        decay = 1.0 - lr * cfg.weight_decay
        if cfg.fused_update:
            p = ax(p, cfg.eps - lr * g, decay)
        else:
            p = ax(p, cfg.eps)
            p = ax(p, -lr * g, decay)
        metrics = {"loss": 0.5 * (l_plus + l_minus), "projected_grad": g,
                   "lr": lr, "active_layers": jnp.asarray(n_active,
                                                          jnp.int32)}
        return p, metrics

    return step


# ----------------------------------------------------- legacy equivalence
@pytest.mark.parametrize("backend", ["dense", "scan", "gather"])
@pytest.mark.parametrize("fused", [True, False])
def test_two_point_bit_identical_to_legacy(backend, fused):
    params = _params()
    spec = _spec(params)
    cfg = zo.ZOConfig(n_drop=2, lr=1e-3, weight_decay=0.1, backend=backend,
                      fused_update=fused)
    old = jax.jit(_legacy_zo_step(_loss, spec, cfg))
    new = jax.jit(zo.make_zo_step(_loss, spec, cfg))
    p_old, m_old = old(params, None, jnp.int32(3), jnp.uint32(9))
    p_new, m_new = new(params, None, jnp.int32(3), jnp.uint32(9))
    for a, b in zip(jax.tree.leaves(p_old), jax.tree.leaves(p_new)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for k in m_old:
        assert np.array_equal(np.asarray(m_old[k]), np.asarray(m_new[k])), k


def test_averaged_q1_matches_two_point():
    params = _params()
    spec = _spec(params)
    outs = []
    for name in ("two_point", "averaged"):
        ecfg = estimators.EstimatorConfig(name=name, q=1, n_drop=2, lr=1e-3,
                                          eps=1e-3)
        step, init = estimators.make_step(_loss, spec, ecfg)
        p, _, m = jax.jit(step)(params, init(), None, jnp.int32(2),
                                jnp.uint32(11))
        outs.append((p, m))
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6)
    np.testing.assert_allclose(float(outs[0][1]["projected_grad"]),
                               float(outs[1][1]["projected_grad"]),
                               rtol=1e-5)


# ------------------------------------------------------- one-sided probes
def test_one_sided_bias_quadratic():
    """E[sum_i c_i z_i] over many steps ~= the true gradient of a
    quadratic (the one-sided Hessian term has zero odd moment)."""
    w = jnp.linspace(0.5, 1.5, 16)
    params = {"w": w}
    spec = zo.build_spec(params, lambda s: None)
    loss = lambda p, b: 0.5 * jnp.sum(p["w"] ** 2)   # grad = w
    q = 8
    ecfg = estimators.EstimatorConfig(name="one_sided", q=q, eps=1e-3,
                                      n_drop=0)
    est = estimators.build_estimator(spec, ecfg)
    uid = jnp.uint32(rng.leaf_uid("w"))

    @jax.jit
    def ghat(step_seed):
        _, dirs, _ = est.estimate(loss, params, None, step_seed, {})
        acc = jnp.zeros_like(w)
        for i in range(q):
            lseed = rng.fold(dirs.seeds[i], uid)
            z = kref.leaf_normal_nd(lseed, (1, 16))[0]
            acc = acc + dirs.coeffs[i] * z
        return acc

    total = np.zeros(16)
    steps = 250
    for t in range(steps):
        total += np.asarray(ghat(rng.fold(jnp.uint32(123), jnp.uint32(t))))
    mean = total / steps
    grad = np.asarray(w)
    cos = mean @ grad / (np.linalg.norm(mean) * np.linalg.norm(grad))
    assert cos > 0.97
    np.testing.assert_allclose(mean, grad, atol=0.2)


def test_one_sided_q_chunk_equivalent():
    """Chunked probe evaluation (bounded working set) is numerically the
    single-widened-forward path, same seeds and coefficients."""
    params = _params()
    spec = _spec(params)
    outs = []
    for chunk in (0, 2):
        ecfg = estimators.EstimatorConfig(name="one_sided", q=4,
                                          q_chunk=chunk, n_drop=2, lr=1e-3)
        step, init = estimators.make_step(_loss, spec, ecfg)
        p, _, m = jax.jit(step)(params, init(), None, jnp.int32(1),
                                jnp.uint32(6))
        outs.append(p)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_one_sided_converges_quadratic():
    params = {"w": jnp.full((32,), 2.0)}
    spec = zo.build_spec(params, lambda s: None)
    loss = lambda p, b: 0.5 * jnp.sum(p["w"] ** 2)
    ecfg = estimators.EstimatorConfig(name="one_sided", q=8, eps=1e-3,
                                      lr=1e-2, n_drop=0)
    step, init = estimators.make_step(loss, spec, ecfg)
    step = jax.jit(step)
    p, st = params, init()
    l0 = float(loss(p, None))
    for t in range(200):
        p, st, m = step(p, st, None, jnp.int32(t), jnp.uint32(3))
    assert float(loss(p, None)) < 0.3 * l0


# ------------------------------------------------- importance / selection
def test_weighted_select_quota_invariants():
    params = _params()
    spec = _spec(params)
    quotas = spec.quotas(4)
    for t in range(25):
        wts = jax.random.uniform(jax.random.PRNGKey(t), (spec.num_layers,),
                                 minval=0.01, maxval=5.0)
        masks, idxs, n_active = zo.stratified_select_weighted(
            spec, jnp.uint32(t), 4, wts)
        assert n_active == spec.num_layers - 4
        for g, (start, L) in spec.slices.items():
            k = L - quotas[g]
            m = np.asarray(masks[g])
            ix = np.asarray(idxs[g])
            assert m.sum() == k == len(ix)
            assert np.array_equal(np.sort(ix), ix)          # ascending
            assert m[ix].all()                              # idxs <-> mask


def test_weighted_select_prefers_heavy_layers():
    params = _params()
    spec = _spec(params)
    wts = jnp.asarray([10.0, 10.0, 0.01, 0.01, 0.01, 0.01])
    counts = np.zeros(6)
    for t in range(200):
        masks, _, _ = zo.stratified_select_weighted(spec, jnp.uint32(t), 4,
                                                    wts)
        counts += np.asarray(masks["blk"])
    assert counts[:2].mean() > counts[2:].mean() * 2


def test_importance_state_adapts_and_stays_small():
    params = _params()
    spec = _spec(params)
    ecfg = estimators.EstimatorConfig(name="importance", inner="two_point",
                                      n_drop=2, lr=1e-3, eps=1e-3,
                                      importance_decay=0.5)
    step, init = estimators.make_step(_loss, spec, ecfg)
    step = jax.jit(step)
    p, st = params, init()
    for t in range(12):
        p, st, m = step(p, st, None, jnp.int32(t), jnp.uint32(4))
    imp = np.asarray(st["imp"])
    assert imp.shape == (spec.num_layers,)
    assert np.isfinite(imp).all()
    assert not np.allclose(imp, 1.0)        # EMA moved off the init
    # memory invariant: estimator state is O(num_layers) floats, never
    # anything parameter-shaped
    assert sum(x.size for x in jax.tree.leaves(st)) <= spec.num_layers + 8


@pytest.mark.parametrize("name,q", [("two_point", 1), ("one_sided", 4),
                                    ("averaged", 3), ("importance", 1)])
def test_state_is_o_q_scalars(name, q):
    params = _params()
    spec = _spec(params)
    ecfg = estimators.EstimatorConfig(name=name, q=q, n_drop=2)
    _, init = estimators.make_step(_loss, spec, ecfg)
    n = sum(x.size for x in jax.tree.leaves(init()))
    assert n <= spec.num_layers + q + 8
    # and the analytic cost table agrees with the implementation's claim
    est = estimators.build_estimator(spec, ecfg)
    counts = est.step_counts()
    assert counts == estimators.costs.step_counts(
        name, q=q, fused_update=True, inner="two_point",
        num_layers=spec.num_layers)


# ------------------------------------------------- cross-backend property
@pytest.mark.parametrize("backend", ["scan", "gather", "pallas"])
@pytest.mark.parametrize("name,q", [("two_point", 1), ("one_sided", 4),
                                    ("averaged", 2), ("importance", 1)])
def test_backend_matches_dense_per_estimator(name, q, backend):
    params = _params()
    spec = _spec(params)
    want = got = None
    for be in ("dense", backend):
        ecfg = estimators.EstimatorConfig(name=name, q=q, n_drop=2, lr=1e-3,
                                          eps=1e-3, backend=be)
        step, init = estimators.make_step(_loss, spec, ecfg)
        p, _, _ = jax.jit(step)(params, init(), None, jnp.int32(1),
                                jnp.uint32(5))
        if be == "dense":
            want = p
        else:
            got = p
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_dropped_layers_untouched_under_estimators():
    """No estimator may move a dropped layer (q=1 so exactly one subset)."""
    params = _params()
    spec = _spec(params)
    for name in ("two_point", "averaged", "one_sided"):
        ecfg = estimators.EstimatorConfig(name=name, q=1, n_drop=4, lr=1e-2)
        step, init = estimators.make_step(_loss, spec, ecfg)
        p, _, _ = jax.jit(step)(params, init(), None, jnp.int32(0),
                                jnp.uint32(5))
        seed = rng.fold(jnp.uint32(5), jnp.uint32(0))
        masks, _, _ = zo.stratified_select(spec, seed, 4)
        m = np.asarray(masks["blk"])
        w_moved = np.asarray(jnp.any(p["blocks"]["w"] != params["blocks"]["w"],
                                     axis=(1, 2)))
        assert np.array_equal(w_moved, m), name


# ----------------------------------------------------- cost-model bridge
def test_estimator_step_cost_projection():
    from repro.launch import analysis

    terms = {"compute_s": 1.0, "memory_s": 1.0, "collective_s": 0.5}
    same = analysis.estimator_step_cost(terms, "two_point")
    assert same["compute_s"] == 1.0 and same["memory_s"] == 1.0

    proj = analysis.estimator_step_cost(terms, "one_sided", q=16)
    assert proj["forwards"] == 17 and proj["axpy_sweeps"] == 32
    np.testing.assert_allclose(proj["compute_s"], 17 / 2)
    np.testing.assert_allclose(proj["collective_s"], 0.5 * 17 / 2)

    # with param_bytes, axpy sweeps are priced exactly: more sweeps =>
    # strictly more memory time than the pure forward-scaled projection
    # of a sweep-free graph
    pb = 819e9 / 4                      # 0.5 s per sweep at default bw
    withpb = analysis.estimator_step_cost(
        {"compute_s": 1.0, "memory_s": 2.0, "collective_s": 0.0},
        "averaged", q=4, param_bytes=pb)
    # fwd_mem = 2.0 - 3*0.5 = 0.5 -> 0.5*(8/2) + 16*0.5 = 10.0
    np.testing.assert_allclose(withpb["memory_s"], 10.0)


# --------------------------------------------------- trainer integration
def test_trainer_selects_estimators():
    from repro.configs import opt
    from repro.data import synthetic
    from repro.train.trainer import Trainer, TrainConfig

    mcfg = opt.opt_tiny(layers=2, d_model=64, vocab=256)
    task = synthetic.TaskConfig(vocab=256, seq_len=32, n_classes=2,
                                signal_rate=0.35)
    for name, q in [("one_sided", 4), ("importance", 1)]:
        tr = Trainer(mcfg, task,
                     TrainConfig(steps=30, batch_size=8, eval_every=0,
                                 log_every=29, estimator=name, est_q=q),
                     zo_cfg=zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=1))
        h = tr.train()
        assert np.isfinite(h["loss"]).all(), name
        assert tr.est_cfg.name == name
