"""repro.api: the unified experiment spec (DESIGN.md §11).

Covers the spec tree's JSON round-trip (byte-stable, golden-pinned),
the single build-time validation site (every illegal combination raises
with the offending field path — property-tested), the derive() adapters
(legacy hand-wired Trainer construction vs spec construction is
bit-identical for every estimator x forward backend), the checkpoint
manifest spec embedding, and sweep/overrides plumbing.
"""
import os
import warnings

import pytest

from _hyp import given, settings, st
from repro import api, configs
from repro.core import zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "lezo-opt13b.spec.json")


# ------------------------------------------------------- serialization
def test_roundtrip_byte_stable_all_presets():
    for name in api.presets.names():
        spec = api.presets.get(name)
        text = api.to_json(spec)
        spec2 = api.from_json(text)
        assert spec2 == spec, name
        assert api.to_json(spec2) == text, f"{name}: re-serialize drifted"


def test_golden_spec_json_pinned():
    """The serialized schema of the headline preset is frozen; regenerate
    with `make specs` + copy if a schema change is intentional."""
    with open(GOLDEN) as f:
        golden = f.read()
    assert api.to_json(api.presets.get("lezo-opt13b")) == golden


def test_from_dict_defaults_and_unknown_keys():
    assert api.from_dict({}) == api.Experiment()
    assert api.from_dict({"optimizer": {"lr": 1e-5}}).optimizer.lr == 1e-5
    with pytest.raises(api.SpecError, match="optimizer.bogus"):
        api.from_dict({"optimizer": {"bogus": 1}})
    with pytest.raises(api.SpecError, match="nonsection"):
        api.from_dict({"nonsection": {}})


def test_with_overrides_coercion_and_errors():
    s = api.Experiment()
    s2 = api.with_overrides(s, {
        "optimizer.lr": "1e-5", "estimator.q": "16",
        "optimizer.fused_update": "false", "runtime.peft": "none",
        "runtime.lora_targets": "wq,wk,wv", "optimizer.n_drop": "3"})
    assert s2.optimizer.lr == 1e-5 and s2.estimator.q == 16
    assert s2.optimizer.fused_update is False
    assert s2.runtime.peft is None
    assert s2.runtime.lora_targets == ("wq", "wk", "wv")
    assert s2.optimizer.n_drop == 3
    with pytest.raises(api.SpecError, match="optimizer.bogus"):
        api.with_overrides(s, {"optimizer.bogus": 1})
    with pytest.raises(api.SpecError, match="estimator.q"):
        api.with_overrides(s, {"estimator.q": "sixteen"})
    with pytest.raises(api.SpecError, match="optimizer.fused_update"):
        api.with_overrides(s, {"optimizer.fused_update": "perhaps"})


def test_spec_diff_and_resume_mutable():
    a = api.to_dict(api.Experiment())
    b = api.to_dict(api.with_overrides(api.Experiment(), {
        "optimizer.lr": 1e-5, "run.steps": 999}))
    diff = api.spec_diff(a, b)
    assert any("optimizer.lr" in line for line in diff)
    assert not any("run.steps" in line for line in diff), \
        "run.steps is resume-mutable and must not appear"
    assert api.spec_diff(a, a) == ()


# ---------------------------------------------------------- validation
ILLEGAL = [
    ({"runtime.forward_backend": "virtual_ref", "runtime.peft": "lora"},
     "runtime.peft"),
    ({"runtime.forward_backend": "virtual_ref", "runtime.peft": "prefix"},
     "runtime.peft"),
    ({"runtime.forward_backend": "virtual", "optimizer.mode": "fo"},
     "optimizer.mode"),
    ({"runtime.forward_backend": "virtual_ref",
      "optimizer.mode": "zo_momentum"}, "optimizer.mode"),
    ({"runtime.forward_backend": "virtual_ref",
      "model.arch": "granite-moe-1b-a400m"}, "runtime.forward_backend"),
    ({"runtime.forward_backend": "virtual_ref",
      "model.arch": "xlstm-350m"}, "runtime.forward_backend"),
    ({"runtime.backend": "gather", "optimizer.policy": "uniform"},
     "optimizer.policy"),
    ({"estimator.q": 0}, "estimator.q"),
    ({"estimator.q": -4}, "estimator.q"),
    ({"runtime.quorum": 0.0}, "runtime.quorum"),
    ({"runtime.quorum": 1.5}, "runtime.quorum"),
    ({"estimator.name": "three_point"}, "estimator.name"),
    ({"estimator.inner": "importance"}, "estimator.inner"),
    ({"runtime.backend": "cuda"}, "runtime.backend"),
    ({"runtime.forward_backend": "imaginary"}, "runtime.forward_backend"),
    ({"optimizer.mode": "sgd"}, "optimizer.mode"),
    ({"optimizer.policy": "fancy"}, "optimizer.policy"),
    ({"optimizer.sparsity": 1.0}, "optimizer.sparsity"),
    ({"optimizer.sparsity": -0.1}, "optimizer.sparsity"),
    ({"optimizer.n_drop": 99}, "optimizer.n_drop"),
    ({"optimizer.eps": 0.0}, "optimizer.eps"),
    ({"model.arch": "opt-99t"}, "model.arch"),
    ({"model.variant": "gigantic"}, "model.variant"),
    ({"task.name": "imagenet"}, "task.name"),
    ({"runtime.peft": "adapters"}, "runtime.peft"),
    ({"runtime.n_loss_shards": 3, "run.batch_size": 16}, "run.batch_size"),
    ({"run.steps": 0}, "run.steps"),
    ({"run.ckpt_every": 4}, "run.ckpt_dir"),
    ({"optimizer.schedule": "cosine"}, "optimizer.schedule"),
]


@pytest.mark.parametrize("overrides,path", ILLEGAL,
                         ids=[p + "-" + str(i) for i, (_, p)
                              in enumerate(ILLEGAL)])
def test_illegal_combination_raises_at_build_time(overrides, path):
    """Every invariant that used to surface as a deep-in-Trainer
    ValueError raises at spec-build time, naming the offending field."""
    spec = api.with_overrides(api.presets.get("default"), overrides)
    with pytest.raises(api.SpecError) as ei:
        api.validate(spec)
    assert path in str(ei.value), \
        f"error message must carry the field path {path!r}: {ei.value}"


def test_unknown_task_is_also_keyerror():
    spec = api.with_overrides(api.Experiment(), {"task.name": "imagenet"})
    with pytest.raises(KeyError):
        api.validate(spec)


def test_validate_accepts_every_preset():
    for name in api.presets.names():
        api.validate(api.presets.get(name))


@settings(max_examples=30, deadline=None)
@given(
    lr=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
    eps=st.floats(min_value=1e-8, max_value=1.0, allow_nan=False),
    sparsity=st.floats(min_value=0.0, max_value=0.999),
    q=st.integers(min_value=1, max_value=64),
    estimator=st.sampled_from(("two_point", "one_sided", "averaged",
                               "importance")),
    backend=st.sampled_from(("dense", "scan", "gather", "pallas")),
)
def test_legal_space_always_validates(lr, eps, sparsity, q, estimator,
                                      backend):
    """No legal combination of the core hyperparameters is rejected —
    validate() only hoists real invariants, it adds no new constraints."""
    spec = api.with_overrides(api.presets.get("default"), {
        "optimizer.lr": lr, "optimizer.eps": eps,
        "optimizer.sparsity": sparsity, "estimator.q": q,
        "estimator.name": estimator, "runtime.backend": backend,
    })
    api.validate(spec)   # gather+stratified (the default policy) is legal


@settings(max_examples=30, deadline=None)
@given(path=st.sampled_from(("estimator.q", "runtime.quorum",
                             "optimizer.sparsity", "optimizer.eps",
                             "run.steps", "run.batch_size")),
       bad=st.sampled_from((-5, -1, 0, 2, 99)))
def test_out_of_range_numerics_name_their_field(path, bad):
    lo, hi = {"estimator.q": (1, 10**6), "runtime.quorum": (1e-9, 1.0),
              "optimizer.sparsity": (0.0, 0.999),
              "optimizer.eps": (1e-12, 10**6),
              "run.steps": (1, 10**6), "run.batch_size": (1, 10**6)}[path]
    if lo <= bad <= hi:
        return  # in-range draw: nothing to assert
    spec = api.with_overrides(api.presets.get("default"), {path: bad})
    with pytest.raises(api.SpecError) as ei:
        api.validate(spec)
    assert path in str(ei.value)


# -------------------------------------------------------------- derive
def test_derive_matches_legacy_field_for_field():
    spec = api.with_overrides(api.presets.get("default"), {
        "model.variant": "smoke", "optimizer.lr": 2e-4,
        "estimator.name": "one_sided", "estimator.q": 4,
        "runtime.quorum": 0.75, "runtime.n_loss_shards": 4,
        "run.batch_size": 16})
    d = api.derive(spec)
    assert d.model_cfg.name == "opt-smoke"
    assert d.n_drop == int(0.75 * d.model_cfg.num_layers)
    assert d.tcfg.eval_every == max(1, spec.run.steps // 4)  # auto cadence
    assert d.tcfg.estimator == "one_sided" and d.tcfg.est_q == 4
    assert d.zo_cfg.lr == 2e-4 and d.est_cfg.lr == 2e-4
    assert d.est_cfg.name == "one_sided" and d.est_cfg.q == 4
    assert d.fo_cfg.lr == 2e-4
    # synthetic task mirrors the legacy launch/train construction
    assert isinstance(d.task, synthetic.TaskConfig)
    assert d.task.vocab == d.model_cfg.vocab
    assert d.task.seq_len == spec.model.seq_len


EQUIV_CASES = [(e, fb) for e in ("two_point", "one_sided", "averaged",
                                "importance")
               for fb in ("materialized", "virtual_ref")]


@pytest.mark.parametrize("estimator,fb", EQUIV_CASES)
def test_legacy_vs_spec_bit_identical(estimator, fb):
    """The acceptance gate: a hand-wired legacy Trainer and the spec path
    produce the same per-step losses bit-for-bit, for every estimator x
    materialized/virtual."""
    q = 2 if estimator in ("one_sided", "averaged") else 1
    spec = api.with_overrides(api.presets.get("tiny-smoke"), {
        "model.variant": "smoke", "run.steps": 6, "run.batch_size": 4,
        "run.eval_every": 0, "estimator.name": estimator,
        "estimator.q": q, "runtime.forward_backend": fb})
    res = api.run(spec)

    # the legacy construction, written out the way launch/train used to
    mcfg = configs.get("opt-13b", "smoke")
    task = synthetic.TaskConfig(vocab=mcfg.vocab, seq_len=32, n_classes=2,
                                seed=0)
    tcfg = TrainConfig(steps=6, batch_size=4, eval_every=0, log_every=1,
                       seed=0, estimator=estimator, est_q=q,
                       forward_backend=fb)
    zcfg = zo.ZOConfig(eps=1e-3, lr=1e-4,
                       n_drop=int(0.75 * mcfg.num_layers), backend="scan",
                       forward_backend=fb)
    hist = Trainer(mcfg, task, tcfg, zo_cfg=zcfg).train()
    assert hist["loss"] == res["history"]["loss"]
    assert hist["val_loss"] == res["history"]["val_loss"]


def test_legacy_construction_soft_warns():
    mcfg = configs.get("opt-13b", "smoke")
    task = synthetic.TaskConfig(vocab=mcfg.vocab, seq_len=32, n_classes=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        Trainer(mcfg, task, TrainConfig(steps=2, batch_size=2))
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        Trainer.from_spec(api.with_overrides(
            api.presets.get("tiny-smoke"), {"model.variant": "smoke"}))
    assert not any(issubclass(x.category, DeprecationWarning) for x in w)


# ------------------------------------------------- checkpoint manifest
def _ckpt_spec(tmp_path, **extra):
    return api.with_overrides(api.presets.get("tiny-smoke"), {
        "model.variant": "smoke", "run.steps": 4, "run.batch_size": 4,
        "run.eval_every": 0, "run.ckpt_dir": str(tmp_path / "ckpt"),
        "run.ckpt_every": 2, **extra})


def test_checkpoint_embeds_spec_and_rejects_mismatch(tmp_path):
    spec = _ckpt_spec(tmp_path)
    api.run(spec)
    tr = Trainer.from_spec(spec)
    manifest = tr.ckpt.read_manifest()
    assert manifest["extra"]["spec"] == api.to_dict(spec)

    # resume-mutable drift (longer schedule) is fine
    api.run(api.with_overrides(spec, {"run.steps": 6}))

    # anything else fails loudly with a field diff
    bad = api.with_overrides(spec, {"optimizer.lr": 9e-4})
    with pytest.raises(api.SpecError, match="optimizer.lr"):
        api.run(bad)


def test_legacy_checkpoints_have_no_spec_and_still_resume(tmp_path):
    mcfg = configs.get("opt-13b", "smoke")
    task = synthetic.TaskConfig(vocab=mcfg.vocab, seq_len=32, n_classes=2)
    tcfg = TrainConfig(steps=4, batch_size=4, eval_every=0, log_every=1,
                       ckpt_dir=str(tmp_path / "l"), ckpt_every=2)
    zcfg = zo.ZOConfig(n_drop=1, backend="scan")
    Trainer(mcfg, task, tcfg, zo_cfg=zcfg).train()
    tr = Trainer(mcfg, task, tcfg, zo_cfg=zcfg)
    assert "spec" not in tr.ckpt.read_manifest()["extra"]
    tr.train()   # legacy resume path: no spec check, no crash


# --------------------------------------------------------------- sweep
def test_sweep_returns_structured_results():
    base = api.with_overrides(api.presets.get("tiny-smoke"), {
        "model.variant": "smoke", "run.steps": 3, "run.batch_size": 4,
        "run.eval_every": 0})
    out = api.sweep(base, [{"optimizer.sparsity": 0.0},
                           {"optimizer.sparsity": 0.5}])
    assert [o["overrides"] for o in out] == [
        {"optimizer.sparsity": 0.0}, {"optimizer.sparsity": 0.5}]
    for o in out:
        assert o["result"]["spec"]["optimizer"]["sparsity"] in (0.0, 0.5)
        assert len(o["result"]["history"]["loss"]) == 3
    # MeZO vs LeZO differ only in selection; first-step losses disagree
    # only through the dropped layers, but both must be finite
    assert all(x == x for o in out for x in o["result"]["history"]["loss"])
