"""Checkpoint manager: roundtrip, keep-k GC, resume determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(5, t, base_seed=42, extra={"note": "x"})
    params, step, seed, extra = mgr.restore(t)
    assert step == 5 and seed == 42 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(t)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(), base_seed=0)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _tree(), base_seed=1, blocking=False)
    mgr.wait()
    assert mgr.latest() == 7


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), base_seed=0)
    bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.ones((4,), jnp.int32)}}
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_missing_leaf_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros((2, 3))}, base_seed=0)
    with pytest.raises(KeyError):
        mgr.restore(_tree())


def test_no_partial_checkpoint_on_disk(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(9, _tree(), base_seed=0)
    names = os.listdir(tmp_path)
    assert all(n.startswith("step_") for n in names), names
