"""Resume determinism: an interrupted+resumed run replays the exact
uninterrupted trajectory.

This is the DESIGN.md §7 replay guarantee: every LeZO update derives
from (base_seed, step) and the data stream from (seed,), so restoring
(params, step) reproduces the update stream bit-for-bit — including the
``t < start`` batch-skip path in ``Trainer.train`` that keeps the batch
iterator aligned with the step counter.
"""
import numpy as np
import pytest

import jax

from repro.configs import opt
from repro.core import zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig

MCFG = opt.opt_tiny(layers=2, d_model=64, vocab=256)
TASK = synthetic.TaskConfig(vocab=256, seq_len=32, n_classes=2,
                            signal_rate=0.35)
ZCFG = zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=1, backend="scan")
STEPS, CKPT_AT = 24, 8


def _tcfg(**kw):
    base = dict(steps=STEPS, batch_size=8, eval_every=0, log_every=1, seed=3)
    return TrainConfig(**{**base, **kw})


@pytest.mark.slow
def test_resume_trajectory_bit_identical(tmp_path):
    # uninterrupted reference run
    ref = Trainer(MCFG, TASK, _tcfg(), zo_cfg=ZCFG).train()

    # interrupted run: checkpoint at step CKPT_AT, stop shortly after
    d = str(tmp_path / "ckpt")
    Trainer(MCFG, TASK,
            _tcfg(steps=CKPT_AT + 3, ckpt_dir=d, ckpt_every=CKPT_AT),
            zo_cfg=ZCFG).train()

    # restart from the checkpoint and finish the schedule
    resumed_tr = Trainer(MCFG, TASK, _tcfg(ckpt_dir=d), zo_cfg=ZCFG)
    res = resumed_tr.train()

    # resumed history starts exactly at the checkpoint step
    assert res["step"][0] == CKPT_AT
    assert ref["step"][-len(res["step"]):] == res["step"]
    ref_tail = ref["loss"][-len(res["loss"]):]
    assert ref_tail == res["loss"], "loss trajectory diverged after resume"

    # and the final parameters match bit-for-bit
    for a, b in zip(jax.tree.leaves(ref["final_params"]),
                    jax.tree.leaves(res["final_params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_resume_skips_consumed_batches(tmp_path):
    """The resumed run must not replay steps < start: its logged history
    begins at the restore step, with the same wall-format keys."""
    d = str(tmp_path / "ckpt")
    Trainer(MCFG, TASK, _tcfg(steps=CKPT_AT + 1, ckpt_dir=d,
                              ckpt_every=CKPT_AT), zo_cfg=ZCFG).train()
    res = Trainer(MCFG, TASK, _tcfg(ckpt_dir=d), zo_cfg=ZCFG).train()
    assert min(res["step"]) == CKPT_AT
    assert len(res["loss"]) == STEPS - CKPT_AT
