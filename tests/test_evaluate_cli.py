"""launch/evaluate.py: the task metric-report CLI."""
import json

import pytest

from repro import tasks
from repro.launch import evaluate


@pytest.mark.slow
def test_zeroshot_report_covers_all_registered_tasks(tmp_path, capsys):
    """`--task all --arch opt --variant smoke` emits a JSON metric record
    for every registered task (the >=6-task acceptance gate)."""
    out = tmp_path / "report.json"
    reports = evaluate.main([
        "--task", "all", "--arch", "opt", "--variant", "smoke",
        "--mode", "zeroshot", "--n-examples", "32", "--seq-len", "32",
        "--out", str(out)])
    assert len(reports) == len(tasks.names()) >= 6
    by_name = {r["task"]: r for r in reports}
    for name in tasks.names():
        r = by_name[name]
        assert r["metric"] in tasks.METRICS
        assert 0.0 <= r["zeroshot"] <= 1.0
        assert r["zeroshot_val_loss"] > 0
    # stdout and --out both carry the same parseable JSON
    assert json.loads(capsys.readouterr().out) == json.loads(out.read_text())
    assert json.loads(out.read_text()) == reports


@pytest.mark.slow
def test_single_task_train_mode(tmp_path):
    r = evaluate.main([
        "--task", "sst2", "--arch", "opt", "--variant", "smoke",
        "--mode", "train", "--steps", "20", "--batch-size", "8",
        "--n-examples", "32", "--seq-len", "32"])[0]
    assert r["task"] == "sst2" and r["mode"] == "train"
    assert "trained" in r and "zeroshot" in r
    assert 0.0 <= r["trained"] <= 1.0
    assert len(r["val_metric_curve"]) >= 1


def test_unknown_task_rejected():
    with pytest.raises(KeyError):
        evaluate.evaluate_task("not_a_task", variant="smoke")


def test_single_task_zeroshot_fast():
    """Tier-1 CLI smoke: one task, tiny eval set."""
    r = evaluate.main(["--task", "boolq", "--arch", "opt",
                       "--variant", "smoke", "--mode", "zeroshot",
                       "--n-examples", "16", "--seq-len", "32"])
    assert len(r) == 1 and r[0]["task"] == "boolq"
    assert 0.0 <= r[0]["zeroshot"] <= 1.0
