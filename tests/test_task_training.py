"""End-to-end: smoke-scale ZO fine-tuning beats the zero-shot baseline
on the registry's classification tasks (the paper's Table-1 ordering,
reduced to CPU scale).

Tier-2 (slow): ~250 ZO steps per task.  Single-pool lexicon tasks
(sst2, boolq, cb) are reliably learned at this scale.  rte (premise/
hypothesis overlap) and wic (same-pool-in-both-sentences, an XOR over
two lexicon indicators) both require cross-region comparison and may
stay at chance for a 2-layer smoke model — the gate is >=3 of the 5
classification tasks improving, mirroring the acceptance criterion.
"""
import dataclasses

import numpy as np
import pytest

from repro import tasks
from repro.configs import opt
from repro.core import zo
from repro.train.trainer import Trainer, TrainConfig

MCFG = opt.opt_tiny(layers=2, d_model=64, vocab=512)
SEQ = 48


def _zo_run(task, steps=300):
    tr = Trainer(MCFG, task,
                 TrainConfig(steps=steps, batch_size=32, eval_every=steps // 3,
                             log_every=0, seed=0),
                 zo_cfg=zo.ZOConfig(eps=1e-3, lr=1e-3, n_drop=1,
                                    backend="scan"))
    val = tr.make_dataset(256, seed_shift=1)
    zs_loss, zeroshot = tr.evaluate(tr.trainable, val)
    hist = tr.train(val_data=val)
    return zs_loss, zeroshot, hist


@pytest.mark.slow
def test_zo_beats_zeroshot_on_classification_tasks():
    wins, results = 0, {}
    for name in tasks.classification_names():
        task = tasks.build(name, vocab=MCFG.vocab, seq_len=SEQ)
        _, zeroshot, hist = _zo_run(task)
        # best-checkpoint metric: the subsystem's own selection protocol
        # (ZO metric curves are non-monotone at smoke scale)
        trained = max(hist["val_acc"])
        results[name] = (zeroshot, trained)
        if trained > zeroshot + 0.02:
            wins += 1
    assert wins >= 3, f"ZO beat zero-shot on only {wins} tasks: {results}"


@pytest.mark.slow
def test_best_checkpoint_selected_on_task_metric():
    """Registry tasks select best params by highest metric, and the best
    params really do score what the history claims."""
    task = tasks.build("sst2", vocab=MCFG.vocab, seq_len=SEQ)
    tr = Trainer(MCFG, task,
                 TrainConfig(steps=200, batch_size=32, eval_every=100,
                             log_every=0),
                 zo_cfg=zo.ZOConfig(eps=1e-3, lr=1e-3, n_drop=1,
                                    backend="scan"))
    val = tr.make_dataset(256, seed_shift=1)
    hist = tr.train(val_data=val)
    assert hist["metric_name"] == "accuracy"
    assert "best_params" in hist
    best_i = int(np.argmax(hist["val_acc"]))
    assert hist["best_step"] == hist["val_step"][best_i]
    _, best_metric = tr.evaluate(hist["best_params"], val)
    assert best_metric == pytest.approx(hist["val_acc"][best_i])


@pytest.mark.slow
def test_zo_learns_generative_copy_task():
    """squad_copy: exact-match stays a hard target at smoke scale (4
    exact tokens through a 2-layer model), so the pinned claim is the
    answer-span loss improving over zero-shot while EM never regresses."""
    task = tasks.build("squad_copy", vocab=MCFG.vocab, seq_len=SEQ)
    zs_loss, zeroshot, hist = _zo_run(task, steps=300)
    assert hist["val_loss"][-1] < zs_loss - 0.1
    assert hist["val_acc"][-1] >= zeroshot     # EM never regresses below
