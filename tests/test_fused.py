"""Virtual-perturbation fused runtime (repro.fused, DESIGN.md §10).

Load-bearing claims:

  * z-consistency: the virtual weight views regenerate exactly the z the
    axpy sweeps (kernels/ops.py) draw — bit-for-bit, per leaf, per layer,
    including the tied head's transposed counter window and embedding
    row gathers.
  * kernel == oracle: the Pallas pmatmul (interpret mode) matches the
    pure-JAX oracle over dtypes, ragged tiles, trans layouts, offsets
    and mask patterns.
  * the step contract: a two_point step with forward_backend="virtual"
    performs exactly ONE parameter axpy (the update) — no perturb, no
    restore — while matching the materialized dense step's projected
    gradient and parameters.
  * the pairing contract: the stacked ±εz forward (ProbePair) is
    bit-identical to the two sequential virtual probe forwards it
    replaces — per kernel call, per lm_loss, per estimator step — while
    loading every W tile and regenerating every z tile exactly once for
    the pair (structural counters).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import estimators, fused
from repro.configs import opt
from repro.core import rng, zo
from repro.estimators import costs
from repro.fused import matmul as fused_matmul
from repro.fused import ref as fref
from repro.kernels import ops as kops
from repro.models import lm

# ---------------------------------------------------------------- helpers


def _tiny_cfg(layers=2, d_model=64, vocab=256):
    return opt.opt_tiny(layers=layers, d_model=d_model, vocab=vocab)


def _batch(vocab, B=4, S=32, seed=0):
    r = np.random.default_rng(seed)
    toks = jnp.asarray(r.integers(0, vocab, (B, S)), jnp.int32)
    return {"tokens": toks, "labels": toks,
            "loss_mask": jnp.ones((B, S), jnp.float32)}


def _loss_fn(mcfg):
    return lambda p, b, perturb=None: lm.lm_loss(mcfg, p, b, perturb=perturb)


# ---------------------------------------------------- kernel vs oracle
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", [(16, 32, 48), (5, 7, 13), (33, 129, 65)])
@pytest.mark.parametrize("trans", [False, True])
def test_pmatmul_matches_ref(shape, dtype, trans):
    """Pallas kernel (interpret) == oracle: aligned and ragged tiles,
    both counter layouts, active and skipped layers."""
    M, K, N = shape
    dt = jnp.dtype(dtype)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), dt)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), dt)
    seed = jnp.uint32(1234)
    tol = 1e-6 if dtype == "float32" else 5e-2
    for active in (True, False):
        a = fref.pmatmul(x, w, seed, 1e-3, jnp.bool_(active), trans=trans)
        b = fused_matmul.pmatmul(x, w, seed, 1e-3, jnp.bool_(active),
                                 trans=trans, interpret=True)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol)


def test_pmatmul_batched_input_and_block_invariance():
    """3-D activations flatten correctly and the result is invariant to
    the (static) tile sizes."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 5, 40))
    w = jax.random.normal(jax.random.fold_in(key, 1), (40, 24))
    seed = jnp.uint32(9)
    want = fref.pmatmul(x, w, seed, 1e-2)
    for bm, bn, bk in ((128, 128, 128), (8, 128, 128)):
        got = fused_matmul.pmatmul(x, w, seed, 1e-2, block_m=bm, block_n=bn,
                                   block_k=bk, interpret=True)
        assert got.shape == (2, 5, 24)
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   atol=1e-5)


def test_pmatmul_counter_offsets_match_slices():
    """Shard invariance: computing a (row/col)-slice with the matching
    counter offset reproduces the slice of the full result — the property
    fused/sharded.py's per-shard invocation is built on."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (6, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 48))
    seed = jnp.uint32(5)
    full = fused_matmul.pmatmul(x, w, seed, 1e-3, interpret=True)
    colslice = fused_matmul.pmatmul(x, w[:, 16:40], seed, 1e-3, col_off=16,
                                    ld=48, interpret=True)
    np.testing.assert_allclose(np.asarray(full[:, 16:40]),
                               np.asarray(colslice), atol=1e-6)
    # row shards produce partial sums: sum of shard products == full
    parts = [fused_matmul.pmatmul(x[:, a:b], w[a:b], seed, 1e-3, row_off=a,
                                  ld=48, interpret=True)
             for a, b in ((0, 16), (16, 32))]
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(parts[0] + parts[1]), atol=1e-5)


def test_sharded_wrappers_match_dense():
    """shard_map wrappers on a 1-device mesh reproduce the unsharded
    kernel (the offsets path is covered for >1 shards above)."""
    from jax.sharding import Mesh

    from repro.fused import sharded

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("model",))
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (4, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
    seed, scale, active = jnp.uint32(2), 1e-3, True
    want = fused_matmul.pmatmul(x, w, seed, scale, interpret=True)
    got_c = sharded.pmatmul_col_sharded(mesh, x, w, seed, scale, active)
    got_r = sharded.pmatmul_row_sharded(mesh, x, w, seed, scale, active)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got_c),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got_r),
                               atol=1e-6)


# ------------------------------------------------- z-consistency contract
def test_virtual_weight_matches_axpy_unstacked_and_stacked():
    """fref views draw the exact z the axpy sweeps add: unstacked leaf,
    stacked per-layer leaf under a mask, and vector leaves."""
    key = jax.random.PRNGKey(0)
    step_seed = jnp.uint32(77)
    w = jax.random.normal(key, (24, 40))
    wm = kops.zo_axpy(w, path="head/w", seed=step_seed, scale=1e-3)
    weff = fref.pvec(w, fref.layer_seed(step_seed, "head/w", 0), 1e-3)
    assert np.array_equal(np.asarray(wm), np.asarray(weff))

    ws = jax.random.normal(key, (6, 24, 40))
    mask = jnp.asarray([1, 0, 1, 1, 0, 1], bool)
    wm = kops.zo_axpy(ws, path="stages/s0/b0/mix/wq", seed=step_seed,
                      scale=1e-3, mask=mask)
    for l in range(6):
        weff = fref.pvec(ws[l],
                         fref.layer_seed(step_seed, "stages/s0/b0/mix/wq", l),
                         1e-3, active=mask[l])
        assert np.array_equal(np.asarray(wm[l]), np.asarray(weff)), l


def test_virtual_tied_head_and_embedding_match_axpy():
    """Tied head reads embed/tok.T through trans counters; embedding
    lookups gather the perturbed rows — both exactly the axpy's z."""
    key = jax.random.PRNGKey(1)
    step_seed = jnp.uint32(31)
    tok = jax.random.normal(key, (40, 24))
    tokp = kops.zo_axpy(tok, path="embed/tok", seed=step_seed, scale=1e-3)
    h = jax.random.normal(jax.random.fold_in(key, 1), (4, 24))
    lseed = fref.layer_seed(step_seed, "embed/tok", 0)
    got = fref.pmatmul(h, tok.T, lseed, 1e-3, trans=True, ld=24)
    assert np.array_equal(np.asarray(h @ tokp.T), np.asarray(got))

    toks = jnp.asarray([[1, 5, 2], [0, 3, 39]], jnp.int32)
    ge = fref.pembed(tok, toks, lseed, 1e-3)
    assert np.array_equal(np.asarray(tokp[toks]), np.asarray(ge))

    pos = kops.zo_axpy(tok, path="embed/pos", seed=step_seed, scale=1e-3)
    pp = fref.ppos(tok, 8, 16, fref.layer_seed(step_seed, "embed/pos", 0),
                   1e-3)
    assert np.array_equal(np.asarray(pos[8:24]), np.asarray(pp))


@pytest.mark.parametrize("n_drop", [0, 2])
def test_virtual_loss_equals_materialized(n_drop):
    """lm_loss(params, perturb=ctx) equals lm_loss(materialized perturbed
    params) across mask patterns and both probe signs — embeddings,
    positions, norms, projections, tied head.  The z streams themselves
    are bit-identical (tested above); the losses agree to XLA fusion
    tolerance (the two graphs fuse the same float ops differently)."""
    mcfg = _tiny_cfg(layers=4)
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab)
    for t in range(3):
        seed = rng.fold(jnp.uint32(9), jnp.uint32(t))
        masks, _, _ = zo.stratified_select(spec, seed, n_drop)
        for sign in (1.0, -1.0):
            pmat = zo.tree_axpy(params, spec, seed, sign * 1e-3, masks)
            want = float(lm.lm_loss(mcfg, pmat, batch))
            ctx = fused.make_ctx(seed, sign * 1e-3, masks, "virtual_ref")
            got = float(lm.lm_loss(mcfg, params, batch, perturb=ctx))
            np.testing.assert_allclose(want, got, rtol=1e-6,
                                       err_msg=f"t={t} sign={sign}")


def test_virtual_pallas_loss_close_to_materialized():
    """The kernel path agrees with the materialized loss to float32
    accumulation tolerance."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    seed = jnp.uint32(11)
    masks, _, _ = zo.stratified_select(spec, seed, 1)
    pmat = zo.tree_axpy(params, spec, seed, 1e-3, masks)
    want = float(lm.lm_loss(mcfg, pmat, batch))
    ctx = fused.make_ctx(seed, 1e-3, masks, "virtual")
    got = float(lm.lm_loss(mcfg, params, batch, perturb=ctx))
    np.testing.assert_allclose(want, got, rtol=1e-5)


# -------------------------------------------------------- step contract
@pytest.mark.parametrize("fb", ["virtual_ref", "virtual"])
def test_two_point_virtual_matches_materialized_dense(fb):
    """Acceptance gate: the virtual two_point step matches the dense
    materialized step's projected gradient to <=1e-5 rel and its updated
    parameters to float tolerance on the tiny OPT config."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    loss_fn = _loss_fn(mcfg)
    outs = {}
    for backend in ("materialized", fb):
        ecfg = estimators.EstimatorConfig(
            name="two_point", n_drop=1, lr=1e-4, eps=1e-3,
            weight_decay=0.01, forward_backend=backend)
        step, init = estimators.make_step(loss_fn, spec, ecfg)
        outs[backend] = jax.jit(step)(params, init(), batch, jnp.int32(3),
                                      jnp.uint32(9))
    _, _, m_mat = outs["materialized"]
    p_vir, _, m_vir = outs[fb]
    np.testing.assert_allclose(float(m_mat["projected_grad"]),
                               float(m_vir["projected_grad"]), rtol=1e-5)
    np.testing.assert_allclose(float(m_mat["loss"]), float(m_vir["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs["materialized"][0]),
                    jax.tree.leaves(p_vir)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("name,q", [("one_sided", 3), ("averaged", 2),
                                    ("importance", 1)])
def test_estimators_virtual_matches_materialized(name, q):
    """Every estimator produces the same step under virtual_ref probes as
    under materialized dense probes (identical z, identical floats)."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    loss_fn = _loss_fn(mcfg)
    outs = []
    for fb in ("materialized", "virtual_ref"):
        ecfg = estimators.EstimatorConfig(name=name, q=q, n_drop=1, lr=1e-4,
                                          eps=1e-3, forward_backend=fb)
        step, init = estimators.make_step(loss_fn, spec, ecfg)
        p, _, m = jax.jit(step)(params, init(), batch, jnp.int32(1),
                                jnp.uint32(5))
        outs.append((p, m))
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(float(outs[0][1]["projected_grad"]),
                               float(outs[1][1]["projected_grad"]),
                               rtol=1e-4)


def test_virtual_step_performs_single_axpy(monkeypatch):
    """Zero perturb/restore parameter writes: tracing the virtual step
    invokes the axpy machinery exactly once (the update); materialized
    invokes it three times (perturb, perturb, fused restore+update)."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    loss_fn = _loss_fn(mcfg)
    calls = []
    orig = zo.tree_axpy
    monkeypatch.setattr(zo, "tree_axpy",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    for fb, want in (("materialized", 3), ("virtual_ref", 1)):
        calls.clear()
        ecfg = estimators.EstimatorConfig(name="two_point", n_drop=1,
                                          forward_backend=fb)
        step, init = estimators.make_step(loss_fn, spec, ecfg)
        jax.eval_shape(step, params, init(), batch, jnp.int32(0),
                       jnp.uint32(1))
        assert len(calls) == want, fb


def test_virtual_jaxpr_has_single_param_write():
    """The jaxpr-level version of the write contract: with buffer
    donation, only one donated input can alias each parameter output —
    count scatter/dynamic-update-free full-leaf writes by checking that
    dropping the update scale freezes the params exactly."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    loss_fn = _loss_fn(mcfg)
    # lr=0, weight_decay=0: the lone update axpy has scale -lr*g == 0 and
    # decay 1, so if it is truly the only θ write the step is an exact
    # no-op on parameters.  Any residual perturb/restore write would
    # leave a +-eps*z trace.
    ecfg = estimators.EstimatorConfig(name="two_point", n_drop=1, lr=0.0,
                                      eps=1e-3, forward_backend="virtual_ref")
    step, init = estimators.make_step(loss_fn, spec, ecfg)
    p, _, _ = jax.jit(step)(params, init(), batch, jnp.int32(2),
                            jnp.uint32(7))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_cost_model_virtual_entries():
    assert costs.step_counts("two_point")["axpy_sweeps"] == 3
    for fb in ("virtual", "virtual_ref"):
        assert costs.step_counts("two_point", forward_backend=fb) == {
            "forwards": 2, "axpy_sweeps": 1, "state_scalars": 0}
        assert costs.step_counts("one_sided", q=8, forward_backend=fb) == {
            "forwards": 9, "axpy_sweeps": 8, "state_scalars": 0}
        assert costs.step_counts("averaged", q=4, forward_backend=fb) == {
            "forwards": 8, "axpy_sweeps": 4, "state_scalars": 0}
        imp = costs.step_counts("importance", num_layers=12,
                                forward_backend=fb)
        assert imp["axpy_sweeps"] == 1 and imp["state_scalars"] == 12
    with pytest.raises(ValueError):
        costs.step_counts("two_point", forward_backend="nope")


def test_estimator_step_cost_prices_virtual_sweeps():
    from repro.launch import analysis

    terms = {"compute_s": 1.0, "memory_s": 2.0, "collective_s": 0.0}
    pb = 819e9 / 4                        # 0.5 s per sweep at default bw
    mat = analysis.estimator_step_cost(terms, "two_point", param_bytes=pb)
    vir = analysis.estimator_step_cost(terms, "two_point", param_bytes=pb,
                                       forward_backend="virtual")
    assert mat["axpy_sweeps"] == 3 and vir["axpy_sweeps"] == 1
    # fwd_mem = 2.0 - 3*0.5 = 0.5 -> mat: 0.5 + 1.5 = 2.0, vir: 0.5 + 0.5
    np.testing.assert_allclose(mat["memory_s"], 2.0)
    np.testing.assert_allclose(vir["memory_s"], 1.0)


# ------------------------------------------------- paired ±εz probes
@pytest.mark.parametrize("shape,trans", [((8, 128, 128), False),
                                         ((16, 200, 96), False),
                                         ((6, 40, 24), True)])
def test_pmatmul_stack_bitwise_matches_pmatmul(shape, trans):
    """One stacked kernel pass == P separate pmatmul calls, bitwise:
    aligned and ragged (non-128-multiple) tiles, the tied-head trans
    layout, shared-seed ±εz pairs and per-probe LeZO predicates."""
    M, K, N = shape
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, M, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N))
    seed = jnp.uint32(21)
    seeds = jnp.stack([seed, seed])
    scales = jnp.asarray([1e-3, -1e-3], jnp.float32)
    for active in (None, jnp.asarray([True, False])):
        got = fused_matmul.pmatmul_stack(x, w, seeds, scales, active,
                                         trans=trans, interpret=True,
                                         shared_seed=True)
        ref = fref.pmatmul_stack(x, w, seeds, fref._stack_scales(
            scales, active), trans=trans)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        for p in range(2):
            a = None if active is None else active[p]
            want = fused_matmul.pmatmul(x[p], w, seed, scales[p], a,
                                        trans=trans, interpret=True)
            assert np.array_equal(np.asarray(got[p]), np.asarray(want)), p


def test_paired_z_streams_match_axpy():
    """RNG contract of the pair (satellite): each sign's z stream is
    bit-identical to the materialized ``kernels/ops.zo_axpy`` stream —
    stacked per-layer leaves, the tied head's transposed counter window,
    and vector leaves with per-seed (unshared) streams."""
    key = jax.random.PRNGKey(2)
    step_seed = jnp.uint32(77)
    # stacked per-layer leaf under a LeZO mask: the paired view's
    # effective weight must equal the axpy result for both signs
    ws = jax.random.normal(key, (4, 24, 40))
    mask = jnp.asarray([1, 0, 1, 1], bool)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 6, 24))
    path = "stages/s0/b0/mix/wq"
    for l in range(4):
        lseed = fref.layer_seed(step_seed, path, l)
        got = fref.pmatmul_stack(
            x, ws[l], jnp.stack([lseed, lseed]),
            jnp.asarray([1e-3, -1e-3], jnp.float32),
            jnp.broadcast_to(mask[l], (2,)))
        for p, sign in enumerate((1.0, -1.0)):
            wm = kops.zo_axpy(ws, path=path, seed=step_seed,
                              scale=sign * 1e-3, mask=mask)
            assert np.array_equal(np.asarray(got[p]),
                                  np.asarray(x[p] @ wm[l])), (l, p)
    # tied head: trans counters over embed/tok.T
    tok = jax.random.normal(jax.random.fold_in(key, 1), (40, 24))
    h = jax.random.normal(jax.random.fold_in(key, 2), (2, 4, 24))
    lseed = fref.layer_seed(step_seed, "embed/tok")
    got = fref.pmatmul_stack(h, tok.T, jnp.stack([lseed, lseed]),
                             jnp.asarray([1e-3, -1e-3], jnp.float32),
                             trans=True, ld=24)
    for p, sign in enumerate((1.0, -1.0)):
        tokp = kops.zo_axpy(tok, path="embed/tok", seed=step_seed,
                            scale=sign * 1e-3)
        assert np.array_equal(np.asarray(got[p]),
                              np.asarray(h[p] @ tokp.T)), p
    # unshared per-seed streams (one_sided's stacked q probes)
    w = jax.random.normal(jax.random.fold_in(key, 4), (24, 40))
    seeds = jnp.stack([rng.fold(step_seed, jnp.uint32(c)) for c in (1, 2)])
    got = fref.pvec_stack(w, fref.layer_seed(seeds, "head/w"),
                          jnp.asarray([1e-3, 1e-3], jnp.float32))
    for p in range(2):
        wm = kops.zo_axpy(w, path="head/w", seed=seeds[p], scale=1e-3)
        assert np.array_equal(np.asarray(got[p]), np.asarray(wm)), p


@pytest.mark.parametrize("fb", ["virtual_ref", "virtual"])
def test_paired_loss_bitwise_matches_two_forwards(fb):
    """lm_loss under the paired ctx returns [l+, l-] bit-identical to
    the two sequential single-probe virtual forwards it replaces."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    seed = jnp.uint32(13)
    masks, _, _ = zo.stratified_select(spec, seed, 1)
    pair = lm.lm_loss_pair(mcfg, params, batch,
                           perturb=fused.make_pair_ctx(seed, 1e-3, masks,
                                                       fb))
    assert pair.shape == (2,)
    for i, sign in enumerate((1.0, -1.0)):
        ctx = fused.make_ctx(seed, sign * 1e-3, masks, fb)
        want = lm.lm_loss(mcfg, params, batch, perturb=ctx)
        assert np.array_equal(np.asarray(want), np.asarray(pair[i])), sign


def test_stacked_probes_bitwise_match_sequential():
    """make_stack_ctx (one_sided's q probes, unshared seeds) returns a
    (P,) loss vector bit-identical to P single-probe forwards."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    base = jnp.uint32(23)
    seeds = jnp.stack([rng.fold(base, jnp.uint32(c)) for c in range(3)])
    per = [zo.stratified_select(spec, s, 1)[0] for s in seeds]
    stacked = {g: jnp.stack([m[g] for m in per]) for g in per[0]}
    got = lm.lm_loss(mcfg, params, batch,
                     perturb=fused.make_stack_ctx(seeds, 1e-3, stacked,
                                                  "virtual_ref"))
    assert got.shape == (3,)
    for p in range(3):
        ctx = fused.make_ctx(seeds[p], 1e-3, per[p], "virtual_ref")
        want = lm.lm_loss(mcfg, params, batch, perturb=ctx)
        assert np.array_equal(np.asarray(want), np.asarray(got[p])), p


@pytest.mark.parametrize("name,q", [("two_point", 1), ("one_sided", 3),
                                    ("averaged", 2)])
def test_paired_step_bitwise_matches_unpaired(name, q):
    """The estimator acceptance gate: paired_probes=True produces the
    bit-identical step (params AND loss) to paired_probes=False on the
    virtual path — the pairing is a pure execution-schedule change."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    loss_fn = _loss_fn(mcfg)
    outs = {}
    for paired in (True, False):
        ecfg = estimators.EstimatorConfig(
            name=name, q=q, n_drop=1, lr=1e-4, eps=1e-3,
            forward_backend="virtual_ref", paired_probes=paired)
        step, init = estimators.make_step(loss_fn, spec, ecfg)
        outs[paired] = jax.jit(step)(params, init(), batch, jnp.int32(2),
                                     jnp.uint32(9))
    for a, b in zip(jax.tree.leaves(outs[True][0]),
                    jax.tree.leaves(outs[False][0])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(outs[True][2]["loss"]),
                          np.asarray(outs[False][2]["loss"]))


def test_paired_step_emits_forward_pair_span():
    """The eager staged step emits ONE forward_pair span (and no ±εz
    forward spans) when paired; the unpaired virtual step still emits
    the two forward spans — and the two schedules produce bit-identical
    steps (the fast-tier representative of the pairing gate; the jitted
    per-estimator matrix is tier-2)."""
    from repro import obs
    mcfg = _tiny_cfg(layers=1, d_model=32, vocab=64)
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=1, S=8)
    loss_fn = _loss_fn(mcfg)
    outs = {}
    for paired, want_pair in ((True, True), (False, False)):
        ring = obs.RingSink(64)
        tr = obs.Tracer(sinks=[ring])
        ecfg = estimators.EstimatorConfig(
            name="two_point", n_drop=0, forward_backend="virtual_ref",
            paired_probes=paired)
        step, init = estimators.make_step(loss_fn, spec, ecfg)
        with obs.use(tr):
            outs[paired] = jax.block_until_ready(
                step(params, init(), batch, jnp.int32(0), jnp.uint32(1)))
        names = {r.name for r in ring.records()}
        if want_pair:
            assert obs.FWD_PAIR in names
            assert obs.FWD_PLUS not in names and obs.FWD_MINUS not in names
        else:
            assert obs.FWD_PAIR not in names
            assert obs.FWD_PLUS in names and obs.FWD_MINUS in names
    for a, b in zip(jax.tree.leaves(outs[True][0]),
                    jax.tree.leaves(outs[False][0])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(outs[True][2]["loss"]),
                          np.asarray(outs[False][2]["loss"]))


def test_probe_accessor_and_pair_validation():
    """ProbePair plumbing: probe(i) peels one unpaired probe out of a
    paired ctx; lm_loss_pair insists on a paired ctx; probe() on an
    unpaired ctx is an error."""
    masks = {"g": jnp.asarray([True, False])}
    ctx = fused.make_pair_ctx(7, 1e-3, masks, "virtual_ref")
    for i, sign in enumerate((1.0, -1.0)):
        p = ctx.probe(i)
        assert p.pair is None
        np.testing.assert_allclose(float(p.scale), sign * 1e-3)
        assert p.masks["g"].shape == (2,)
    with pytest.raises(ValueError):
        fused.make_ctx(7, 1e-3, None, "virtual_ref").probe(0)
    mcfg = _tiny_cfg(layers=1, d_model=32, vocab=64)
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    batch = _batch(mcfg.vocab, B=1, S=8)
    with pytest.raises(ValueError):
        lm.lm_loss_pair(mcfg, params, batch,
                        perturb=fused.make_ctx(7, 1e-3, None, "virtual_ref"))
    with pytest.raises(ValueError):
        lm.lm_loss_pair(mcfg, params, batch, perturb=None)


def test_paired_structural_counters_halve():
    """The bench tripwire's claim at unit scope: counting the eager
    forward's grid cells (jax.disable_jit turns the layer scan into a
    Python loop so the lens counters actually fire), ONE paired forward
    loads half the W tiles and regenerates half the z tiles of the two
    probe forwards it replaces."""
    from repro import obs
    mcfg = _tiny_cfg(layers=2, d_model=32, vocab=64)
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    toks = _batch(mcfg.vocab, B=1, S=8)["tokens"]
    seed = jnp.uint32(5)

    def count(ctxs):
        tr = obs.Tracer()
        with obs.use(tr), jax.disable_jit():
            for ctx in ctxs:
                lm.forward(mcfg, params, toks, perturb=ctx)
        return (tr.counters[obs.CTR_WLOAD], tr.counters[obs.CTR_ZREGEN])

    pw, pz = count([fused.make_pair_ctx(seed, 1e-3, None, "virtual_ref")])
    uw, uz = count([fused.make_ctx(seed, 1e-3, None, "virtual_ref"),
                    fused.make_ctx(seed, -1e-3, None, "virtual_ref")])
    assert pw > 0 and 2 * pw == uw
    assert pz > 0 and 2 * pz == uz


def test_interpret_autodetects_platform():
    """interpret=None resolves per-platform: emulator off TPU, compiled
    on it — nothing hardcodes interpret=True anymore."""
    assert fused_matmul.default_interpret() == (
        jax.default_backend() != "tpu")
    assert fused_matmul._resolve_interpret(None) == \
        fused_matmul.default_interpret()
    assert fused_matmul._resolve_interpret(False) is False


# -------------------------------------------------- trainer integration
def test_trainer_virtual_backend_trains():
    from repro.data import synthetic
    from repro.train.trainer import Trainer, TrainConfig

    mcfg = _tiny_cfg(d_model=32, vocab=128)
    task = synthetic.TaskConfig(vocab=128, seq_len=32, n_classes=2,
                                signal_rate=0.35)
    tr = Trainer(mcfg, task,
                 TrainConfig(steps=8, batch_size=4, eval_every=0,
                             log_every=2, forward_backend="virtual_ref"),
                 zo_cfg=zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=1))
    assert tr.est_cfg.forward_backend == "virtual_ref"
    h = tr.train()
    assert np.isfinite(h["loss"]).all()


def test_trainer_virtual_guards():
    from repro.data import synthetic
    from repro.train.trainer import Trainer, TrainConfig

    mcfg = _tiny_cfg(d_model=32, vocab=128)
    task = synthetic.TaskConfig(vocab=128, seq_len=32, n_classes=2)
    with pytest.raises(ValueError, match="PEFT"):
        Trainer(mcfg, task, TrainConfig(peft="lora",
                                        forward_backend="virtual_ref"))
    with pytest.raises(ValueError, match="mode"):
        Trainer(mcfg, task, TrainConfig(mode="fo",
                                        forward_backend="virtual_ref"))
    moe_cfg = dataclasses.replace(
        mcfg, stages=(dataclasses.replace(
            mcfg.stages[0],
            pattern=(dataclasses.replace(mcfg.stages[0].pattern[0],
                                         ffn="moe"),)),),
        n_experts=4, top_k=2, moe_d_ff=64)
    with pytest.raises(ValueError, match="attn"):
        Trainer(moe_cfg, task, TrainConfig(forward_backend="virtual_ref"))
