"""Virtual-perturbation fused runtime (repro.fused, DESIGN.md §10).

Load-bearing claims:

  * z-consistency: the virtual weight views regenerate exactly the z the
    axpy sweeps (kernels/ops.py) draw — bit-for-bit, per leaf, per layer,
    including the tied head's transposed counter window and embedding
    row gathers.
  * kernel == oracle: the Pallas pmatmul (interpret mode) matches the
    pure-JAX oracle over dtypes, ragged tiles, trans layouts, offsets
    and mask patterns.
  * the step contract: a two_point step with forward_backend="virtual"
    performs exactly ONE parameter axpy (the update) — no perturb, no
    restore — while matching the materialized dense step's projected
    gradient and parameters.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import estimators, fused
from repro.configs import opt
from repro.core import rng, zo
from repro.estimators import costs
from repro.fused import matmul as fused_matmul
from repro.fused import ref as fref
from repro.kernels import ops as kops
from repro.models import lm

# ---------------------------------------------------------------- helpers


def _tiny_cfg(layers=2, d_model=64, vocab=256):
    return opt.opt_tiny(layers=layers, d_model=d_model, vocab=vocab)


def _batch(vocab, B=4, S=32, seed=0):
    r = np.random.default_rng(seed)
    toks = jnp.asarray(r.integers(0, vocab, (B, S)), jnp.int32)
    return {"tokens": toks, "labels": toks,
            "loss_mask": jnp.ones((B, S), jnp.float32)}


def _loss_fn(mcfg):
    return lambda p, b, perturb=None: lm.lm_loss(mcfg, p, b, perturb=perturb)


# ---------------------------------------------------- kernel vs oracle
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", [(16, 32, 48), (5, 7, 13), (33, 129, 65)])
@pytest.mark.parametrize("trans", [False, True])
def test_pmatmul_matches_ref(shape, dtype, trans):
    """Pallas kernel (interpret) == oracle: aligned and ragged tiles,
    both counter layouts, active and skipped layers."""
    M, K, N = shape
    dt = jnp.dtype(dtype)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), dt)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), dt)
    seed = jnp.uint32(1234)
    tol = 1e-6 if dtype == "float32" else 5e-2
    for active in (True, False):
        a = fref.pmatmul(x, w, seed, 1e-3, jnp.bool_(active), trans=trans)
        b = fused_matmul.pmatmul(x, w, seed, 1e-3, jnp.bool_(active),
                                 trans=trans, interpret=True)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol)


def test_pmatmul_batched_input_and_block_invariance():
    """3-D activations flatten correctly and the result is invariant to
    the (static) tile sizes."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 5, 40))
    w = jax.random.normal(jax.random.fold_in(key, 1), (40, 24))
    seed = jnp.uint32(9)
    want = fref.pmatmul(x, w, seed, 1e-2)
    for bm, bn, bk in ((128, 128, 128), (8, 128, 128)):
        got = fused_matmul.pmatmul(x, w, seed, 1e-2, block_m=bm, block_n=bn,
                                   block_k=bk, interpret=True)
        assert got.shape == (2, 5, 24)
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   atol=1e-5)


def test_pmatmul_counter_offsets_match_slices():
    """Shard invariance: computing a (row/col)-slice with the matching
    counter offset reproduces the slice of the full result — the property
    fused/sharded.py's per-shard invocation is built on."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (6, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 48))
    seed = jnp.uint32(5)
    full = fused_matmul.pmatmul(x, w, seed, 1e-3, interpret=True)
    colslice = fused_matmul.pmatmul(x, w[:, 16:40], seed, 1e-3, col_off=16,
                                    ld=48, interpret=True)
    np.testing.assert_allclose(np.asarray(full[:, 16:40]),
                               np.asarray(colslice), atol=1e-6)
    # row shards produce partial sums: sum of shard products == full
    parts = [fused_matmul.pmatmul(x[:, a:b], w[a:b], seed, 1e-3, row_off=a,
                                  ld=48, interpret=True)
             for a, b in ((0, 16), (16, 32))]
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(parts[0] + parts[1]), atol=1e-5)


def test_sharded_wrappers_match_dense():
    """shard_map wrappers on a 1-device mesh reproduce the unsharded
    kernel (the offsets path is covered for >1 shards above)."""
    from jax.sharding import Mesh

    from repro.fused import sharded

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("model",))
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (4, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 16))
    seed, scale, active = jnp.uint32(2), 1e-3, True
    want = fused_matmul.pmatmul(x, w, seed, scale, interpret=True)
    got_c = sharded.pmatmul_col_sharded(mesh, x, w, seed, scale, active)
    got_r = sharded.pmatmul_row_sharded(mesh, x, w, seed, scale, active)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got_c),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got_r),
                               atol=1e-6)


# ------------------------------------------------- z-consistency contract
def test_virtual_weight_matches_axpy_unstacked_and_stacked():
    """fref views draw the exact z the axpy sweeps add: unstacked leaf,
    stacked per-layer leaf under a mask, and vector leaves."""
    key = jax.random.PRNGKey(0)
    step_seed = jnp.uint32(77)
    w = jax.random.normal(key, (24, 40))
    wm = kops.zo_axpy(w, path="head/w", seed=step_seed, scale=1e-3)
    weff = fref.pvec(w, fref.layer_seed(step_seed, "head/w", 0), 1e-3)
    assert np.array_equal(np.asarray(wm), np.asarray(weff))

    ws = jax.random.normal(key, (6, 24, 40))
    mask = jnp.asarray([1, 0, 1, 1, 0, 1], bool)
    wm = kops.zo_axpy(ws, path="stages/s0/b0/mix/wq", seed=step_seed,
                      scale=1e-3, mask=mask)
    for l in range(6):
        weff = fref.pvec(ws[l],
                         fref.layer_seed(step_seed, "stages/s0/b0/mix/wq", l),
                         1e-3, active=mask[l])
        assert np.array_equal(np.asarray(wm[l]), np.asarray(weff)), l


def test_virtual_tied_head_and_embedding_match_axpy():
    """Tied head reads embed/tok.T through trans counters; embedding
    lookups gather the perturbed rows — both exactly the axpy's z."""
    key = jax.random.PRNGKey(1)
    step_seed = jnp.uint32(31)
    tok = jax.random.normal(key, (40, 24))
    tokp = kops.zo_axpy(tok, path="embed/tok", seed=step_seed, scale=1e-3)
    h = jax.random.normal(jax.random.fold_in(key, 1), (4, 24))
    lseed = fref.layer_seed(step_seed, "embed/tok", 0)
    got = fref.pmatmul(h, tok.T, lseed, 1e-3, trans=True, ld=24)
    assert np.array_equal(np.asarray(h @ tokp.T), np.asarray(got))

    toks = jnp.asarray([[1, 5, 2], [0, 3, 39]], jnp.int32)
    ge = fref.pembed(tok, toks, lseed, 1e-3)
    assert np.array_equal(np.asarray(tokp[toks]), np.asarray(ge))

    pos = kops.zo_axpy(tok, path="embed/pos", seed=step_seed, scale=1e-3)
    pp = fref.ppos(tok, 8, 16, fref.layer_seed(step_seed, "embed/pos", 0),
                   1e-3)
    assert np.array_equal(np.asarray(pos[8:24]), np.asarray(pp))


@pytest.mark.parametrize("n_drop", [0, 2])
def test_virtual_loss_equals_materialized(n_drop):
    """lm_loss(params, perturb=ctx) equals lm_loss(materialized perturbed
    params) across mask patterns and both probe signs — embeddings,
    positions, norms, projections, tied head.  The z streams themselves
    are bit-identical (tested above); the losses agree to XLA fusion
    tolerance (the two graphs fuse the same float ops differently)."""
    mcfg = _tiny_cfg(layers=4)
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab)
    for t in range(3):
        seed = rng.fold(jnp.uint32(9), jnp.uint32(t))
        masks, _, _ = zo.stratified_select(spec, seed, n_drop)
        for sign in (1.0, -1.0):
            pmat = zo.tree_axpy(params, spec, seed, sign * 1e-3, masks)
            want = float(lm.lm_loss(mcfg, pmat, batch))
            ctx = fused.make_ctx(seed, sign * 1e-3, masks, "virtual_ref")
            got = float(lm.lm_loss(mcfg, params, batch, perturb=ctx))
            np.testing.assert_allclose(want, got, rtol=1e-6,
                                       err_msg=f"t={t} sign={sign}")


def test_virtual_pallas_loss_close_to_materialized():
    """The kernel path agrees with the materialized loss to float32
    accumulation tolerance."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    seed = jnp.uint32(11)
    masks, _, _ = zo.stratified_select(spec, seed, 1)
    pmat = zo.tree_axpy(params, spec, seed, 1e-3, masks)
    want = float(lm.lm_loss(mcfg, pmat, batch))
    ctx = fused.make_ctx(seed, 1e-3, masks, "virtual")
    got = float(lm.lm_loss(mcfg, params, batch, perturb=ctx))
    np.testing.assert_allclose(want, got, rtol=1e-5)


# -------------------------------------------------------- step contract
@pytest.mark.parametrize("fb", ["virtual_ref", "virtual"])
def test_two_point_virtual_matches_materialized_dense(fb):
    """Acceptance gate: the virtual two_point step matches the dense
    materialized step's projected gradient to <=1e-5 rel and its updated
    parameters to float tolerance on the tiny OPT config."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    loss_fn = _loss_fn(mcfg)
    outs = {}
    for backend in ("materialized", fb):
        ecfg = estimators.EstimatorConfig(
            name="two_point", n_drop=1, lr=1e-4, eps=1e-3,
            weight_decay=0.01, forward_backend=backend)
        step, init = estimators.make_step(loss_fn, spec, ecfg)
        outs[backend] = jax.jit(step)(params, init(), batch, jnp.int32(3),
                                      jnp.uint32(9))
    _, _, m_mat = outs["materialized"]
    p_vir, _, m_vir = outs[fb]
    np.testing.assert_allclose(float(m_mat["projected_grad"]),
                               float(m_vir["projected_grad"]), rtol=1e-5)
    np.testing.assert_allclose(float(m_mat["loss"]), float(m_vir["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs["materialized"][0]),
                    jax.tree.leaves(p_vir)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("name,q", [("one_sided", 3), ("averaged", 2),
                                    ("importance", 1)])
def test_estimators_virtual_matches_materialized(name, q):
    """Every estimator produces the same step under virtual_ref probes as
    under materialized dense probes (identical z, identical floats)."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    loss_fn = _loss_fn(mcfg)
    outs = []
    for fb in ("materialized", "virtual_ref"):
        ecfg = estimators.EstimatorConfig(name=name, q=q, n_drop=1, lr=1e-4,
                                          eps=1e-3, forward_backend=fb)
        step, init = estimators.make_step(loss_fn, spec, ecfg)
        p, _, m = jax.jit(step)(params, init(), batch, jnp.int32(1),
                                jnp.uint32(5))
        outs.append((p, m))
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(float(outs[0][1]["projected_grad"]),
                               float(outs[1][1]["projected_grad"]),
                               rtol=1e-4)


def test_virtual_step_performs_single_axpy(monkeypatch):
    """Zero perturb/restore parameter writes: tracing the virtual step
    invokes the axpy machinery exactly once (the update); materialized
    invokes it three times (perturb, perturb, fused restore+update)."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    loss_fn = _loss_fn(mcfg)
    calls = []
    orig = zo.tree_axpy
    monkeypatch.setattr(zo, "tree_axpy",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    for fb, want in (("materialized", 3), ("virtual_ref", 1)):
        calls.clear()
        ecfg = estimators.EstimatorConfig(name="two_point", n_drop=1,
                                          forward_backend=fb)
        step, init = estimators.make_step(loss_fn, spec, ecfg)
        jax.eval_shape(step, params, init(), batch, jnp.int32(0),
                       jnp.uint32(1))
        assert len(calls) == want, fb


def test_virtual_jaxpr_has_single_param_write():
    """The jaxpr-level version of the write contract: with buffer
    donation, only one donated input can alias each parameter output —
    count scatter/dynamic-update-free full-leaf writes by checking that
    dropping the update scale freezes the params exactly."""
    mcfg = _tiny_cfg()
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    batch = _batch(mcfg.vocab, B=2, S=16)
    loss_fn = _loss_fn(mcfg)
    # lr=0, weight_decay=0: the lone update axpy has scale -lr*g == 0 and
    # decay 1, so if it is truly the only θ write the step is an exact
    # no-op on parameters.  Any residual perturb/restore write would
    # leave a +-eps*z trace.
    ecfg = estimators.EstimatorConfig(name="two_point", n_drop=1, lr=0.0,
                                      eps=1e-3, forward_backend="virtual_ref")
    step, init = estimators.make_step(loss_fn, spec, ecfg)
    p, _, _ = jax.jit(step)(params, init(), batch, jnp.int32(2),
                            jnp.uint32(7))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_cost_model_virtual_entries():
    assert costs.step_counts("two_point")["axpy_sweeps"] == 3
    for fb in ("virtual", "virtual_ref"):
        assert costs.step_counts("two_point", forward_backend=fb) == {
            "forwards": 2, "axpy_sweeps": 1, "state_scalars": 0}
        assert costs.step_counts("one_sided", q=8, forward_backend=fb) == {
            "forwards": 9, "axpy_sweeps": 8, "state_scalars": 0}
        assert costs.step_counts("averaged", q=4, forward_backend=fb) == {
            "forwards": 8, "axpy_sweeps": 4, "state_scalars": 0}
        imp = costs.step_counts("importance", num_layers=12,
                                forward_backend=fb)
        assert imp["axpy_sweeps"] == 1 and imp["state_scalars"] == 12
    with pytest.raises(ValueError):
        costs.step_counts("two_point", forward_backend="nope")


def test_estimator_step_cost_prices_virtual_sweeps():
    from repro.launch import analysis

    terms = {"compute_s": 1.0, "memory_s": 2.0, "collective_s": 0.0}
    pb = 819e9 / 4                        # 0.5 s per sweep at default bw
    mat = analysis.estimator_step_cost(terms, "two_point", param_bytes=pb)
    vir = analysis.estimator_step_cost(terms, "two_point", param_bytes=pb,
                                       forward_backend="virtual")
    assert mat["axpy_sweeps"] == 3 and vir["axpy_sweeps"] == 1
    # fwd_mem = 2.0 - 3*0.5 = 0.5 -> mat: 0.5 + 1.5 = 2.0, vir: 0.5 + 0.5
    np.testing.assert_allclose(mat["memory_s"], 2.0)
    np.testing.assert_allclose(vir["memory_s"], 1.0)


# -------------------------------------------------- trainer integration
def test_trainer_virtual_backend_trains():
    from repro.data import synthetic
    from repro.train.trainer import Trainer, TrainConfig

    mcfg = _tiny_cfg(d_model=32, vocab=128)
    task = synthetic.TaskConfig(vocab=128, seq_len=32, n_classes=2,
                                signal_rate=0.35)
    tr = Trainer(mcfg, task,
                 TrainConfig(steps=8, batch_size=4, eval_every=0,
                             log_every=2, forward_backend="virtual_ref"),
                 zo_cfg=zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=1))
    assert tr.est_cfg.forward_backend == "virtual_ref"
    h = tr.train()
    assert np.isfinite(h["loss"]).all()


def test_trainer_virtual_guards():
    from repro.data import synthetic
    from repro.train.trainer import Trainer, TrainConfig

    mcfg = _tiny_cfg(d_model=32, vocab=128)
    task = synthetic.TaskConfig(vocab=128, seq_len=32, n_classes=2)
    with pytest.raises(ValueError, match="PEFT"):
        Trainer(mcfg, task, TrainConfig(peft="lora",
                                        forward_backend="virtual_ref"))
    with pytest.raises(ValueError, match="mode"):
        Trainer(mcfg, task, TrainConfig(mode="fo",
                                        forward_backend="virtual_ref"))
    moe_cfg = dataclasses.replace(
        mcfg, stages=(dataclasses.replace(
            mcfg.stages[0],
            pattern=(dataclasses.replace(mcfg.stages[0].pattern[0],
                                         ffn="moe"),)),),
        n_experts=4, top_k=2, moe_d_ff=64)
    with pytest.raises(ValueError, match="attn"):
        Trainer(moe_cfg, task, TrainConfig(forward_backend="virtual_ref"))
