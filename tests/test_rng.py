"""Counter-RNG statistical and determinism properties."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import rng
from repro.kernels import ref


def test_moments():
    z = np.asarray(ref.leaf_normal(jnp.uint32(7), 4, 200_000))
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01
    # higher moments of N(0,1): skew ~ 0, kurtosis ~ 3
    assert abs(((z - z.mean()) ** 3).mean()) < 0.02
    assert abs(((z - z.mean()) ** 4).mean() - 3.0) < 0.05


def test_rows_decorrelated():
    z = np.asarray(ref.leaf_normal(jnp.uint32(3), 8, 50_000))
    for i in range(7):
        c = np.corrcoef(z[i], z[i + 1])[0, 1]
        assert abs(c) < 0.02


def test_seed_changes_stream():
    a = np.asarray(ref.leaf_normal(jnp.uint32(1), 2, 1000))
    b = np.asarray(ref.leaf_normal(jnp.uint32(2), 2, 1000))
    assert np.abs(a - b).min() > 0  # no element coincides


def test_deterministic():
    a = ref.leaf_normal(jnp.uint32(9), 3, 512)
    b = ref.leaf_normal(jnp.uint32(9), 3, 512)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_fold_py_matches_jnp(seed, data):
    assert rng.fold_py(seed, data) == int(rng.fold(jnp.uint32(seed),
                                                   jnp.uint32(data)))


def test_nd_matches_2d():
    """Natural-shape generation == flattened-2d generation."""
    z2 = ref.leaf_normal(jnp.uint32(5), 3, 24)
    znd = ref.leaf_normal_nd(jnp.uint32(5), (3, 4, 6))
    assert np.array_equal(np.asarray(z2), np.asarray(znd).reshape(3, 24))


def test_layer_ids_subset():
    """gather-backend z (subset layer_ids) matches the full stack's rows."""
    full = ref.leaf_normal_nd(jnp.uint32(5), (8, 10))
    ids = jnp.asarray([1, 4, 6], jnp.uint32)
    sub = ref.leaf_normal_nd(jnp.uint32(5), (3, 10), layer_ids=ids)
    assert np.array_equal(np.asarray(full)[np.asarray(ids)], np.asarray(sub))
