"""Scan-aware HLO cost analyzer: validated against closed-form counts."""
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.launch.analysis import HloCost


def _cost(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return HloCost(txt).total()


def test_scan_trip_multiplier_flops():
    W = jnp.zeros((8, 128, 128))

    def f(x):
        return lax.scan(lambda c, w: (c @ w, None), x, W)[0]
    c = _cost(f, jnp.zeros((4, 128)))
    want = 2 * 4 * 128 * 128 * 8
    assert abs(c.flops - want) / want < 0.02


def test_nested_scan_flops():
    W = jnp.zeros((4, 64, 64))

    def f(x):
        def outer(c, w):
            return lax.scan(lambda c2, _: (c2 @ w, None), c, None,
                            length=5)[0], None
        return lax.scan(outer, x, W)[0]
    c = _cost(f, jnp.zeros((2, 64)))
    want = 2 * 2 * 64 * 64 * 20
    assert abs(c.flops - want) / want < 0.02


def test_scan_slices_not_full_stack():
    """Per-iteration bytes must reflect the slice, not the stacked leaf."""
    W = jnp.zeros((64, 256, 256))  # 16 MiB stack

    def f(x):
        return lax.scan(lambda c, w: (c @ w, None), x, W)[0]
    c = _cost(f, jnp.zeros((4, 256)))
    # slice-aware bound: ~64 iters x (2x 256KiB slice + small carries)
    assert c.bytes < 80e6, f"{c.bytes/1e6} MB suggests full-stack counting"


def test_elementwise_flops_counted():
    def f(x):
        return jnp.exp(x) * 2.0 + 1.0
    c = _cost(f, jnp.zeros((1000,)))
    assert c.flops >= 3000  # 3 elementwise ops x 1000 elems


def test_matmul_bytes_reasonable():
    def f(a, b):
        return a @ b
    c = _cost(f, jnp.zeros((256, 256)), jnp.zeros((256, 256)))
    want = 3 * 256 * 256 * 4
    assert 0.5 * want < c.bytes < 3 * want


def test_cond_takes_max_branch():
    def f(x, p):
        return lax.cond(p, lambda v: v @ v, lambda v: v, x)
    c = _cost(f, jnp.zeros((64, 64)), jnp.bool_(True))
    want = 2 * 64 * 64 * 64
    assert c.flops >= want * 0.9  # the matmul branch is counted
