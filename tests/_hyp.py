"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is an optional test dependency (see pyproject.toml).  The
seed repo imported it unconditionally, which turned a missing package
into a *collection error* that killed the whole suite.  Importing
``given``/``settings``/``st`` from here instead makes each property test
an ordinary pytest skip when hypothesis is absent, while every
non-property test in the same module still runs.

(A bare ``pytest.importorskip("hypothesis")`` at module top would skip
those non-property tests too — this shim keeps them.)
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in minimal containers
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies``: every factory returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f
