"""repro.obs telemetry suite (DESIGN.md §13).

Covers the tracing core (span nesting/ordering, the zero-allocation
disabled path, jit suppression), the sinks (JSONL round-trip, ring
bounds), the Prometheus-style metrics, the telemetry spec node's
validation, counter determinism across seeded runs, the trainer's
final-step/wall_compute logging fixes, the serving engine's metric
export, and the benchmarks/run.py tripwire gate.
"""
import json
import os
import sys

import numpy as np
import pytest

from repro import api, obs

BENCH = os.path.join(os.path.dirname(__file__), "..")
if BENCH not in sys.path:                    # for benchmarks.run import
    sys.path.insert(0, BENCH)


# ------------------------------------------------------------ span core
def test_span_nesting_ordering_and_parents():
    ring = obs.RingSink()
    tr = obs.Tracer(sinks=[ring])
    with tr.span("outer"):
        with tr.span("inner_a"):
            pass
        with tr.span("inner_b"):
            pass
    recs = ring.records()
    # completion order: children before the parent
    assert [r.name for r in recs] == ["inner_a", "inner_b", "outer"]
    outer = recs[-1]
    assert outer.depth == 0 and outer.parent == -1
    for child in recs[:2]:
        assert child.depth == 1
        assert child.parent == outer.index    # entry slot of the parent
    assert recs[0].index < recs[1].index      # entry order preserved
    assert all(r.dt >= 0 for r in recs)


def test_null_tracer_is_shared_singleton_and_free():
    assert obs.get_tracer() is obs.NULL       # default: disabled
    s1 = obs.NULL.span("anything")
    s2 = obs.NULL.span("else", meta={"k": 1})
    assert s1 is s2                           # zero-allocation fast path
    with s1 as s:
        assert s.fence("x") == "x"            # fence is identity
    obs.NULL.count("c", 5)
    obs.NULL.gauge("g", 1.0)
    assert obs.NULL.counters == {} and obs.NULL.gauges == {}
    assert not obs.NULL.enabled


def test_use_scopes_global_tracer():
    tr = obs.Tracer()
    with obs.use(tr):
        assert obs.get_tracer() is tr
        with obs.use(None):
            assert obs.get_tracer() is obs.NULL
        assert obs.get_tracer() is tr
    assert obs.get_tracer() is obs.NULL


def test_ring_sink_bounded():
    ring = obs.RingSink(capacity=3)
    tr = obs.Tracer(sinks=[ring])
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(ring) == 3
    assert [r.name for r in ring.records()] == ["s7", "s8", "s9"]
    with pytest.raises(ValueError, match="capacity"):
        obs.RingSink(capacity=0)


def test_fencing_blocks_on_result():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    ring = obs.RingSink()
    tr = obs.Tracer(sinks=[ring], fence=True)
    with tr.span("fenced") as sp:
        sp.fence(jnp.ones((8, 8)) @ jnp.ones((8, 8)))
    assert ring.spans("fenced")[0].dt > 0


# ----------------------------------------------------------- JSONL sink
def test_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    sink = obs.JSONLSink(path)
    tr = obs.Tracer(sinks=[sink])
    with tr.span("a", meta={"k": 1}):
        with tr.span("b"):
            pass
    tr.count("probes", 3)
    sink.emit_event(tr.snapshot())
    sink.close()

    events = obs.read_jsonl(path)
    assert [e["type"] for e in events] == ["span", "span", "counters"]
    assert events[-1]["counters"] == {"probes": 3}
    back = obs.spans_from_jsonl(path)
    orig = [r for r in [e for e in events if e["type"] == "span"]]
    assert [r.name for r in back] == ["b", "a"]
    assert back[1].meta == {"k": 1}
    # field-level round-trip against the emitted dicts
    for rec, ev in zip(back, orig):
        assert rec.to_dict() == ev


def test_read_jsonl_tolerates_truncated_final_line(tmp_path):
    """A crash mid-append leaves a torn last line; reading the trace
    back must drop it silently — but corruption anywhere *else* in the
    file still raises (that is damage, not an interrupted write)."""
    path = str(tmp_path / "t.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"type": "span", "name": "a"}) + "\n")
        f.write('{"type": "span", "na')       # torn tail, no newline
    assert [e["name"] for e in obs.read_jsonl(path)] == ["a"]
    with open(path, "a") as f:                # trailing blanks don't mask it
        f.write("\n\n")
    assert [e["name"] for e in obs.read_jsonl(path)] == ["a"]
    with open(path, "a") as f:                # torn line now mid-file
        f.write(json.dumps({"type": "span", "name": "c"}) + "\n")
    with pytest.raises(json.JSONDecodeError):
        obs.read_jsonl(path)


# -------------------------------------------------------------- metrics
def test_counter_and_gauge():
    reg = obs.Registry()
    c = reg.counter("reqs", "help text")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError, match="< 0"):
        c.inc(-1)
    reg.gauge("depth").set(7)
    assert reg.gauge("depth").value == 7.0    # get-or-create returns same
    with pytest.raises(TypeError, match="already registered"):
        reg.counter("depth")


def test_histogram_cumulative_buckets_and_text():
    reg = obs.Registry()
    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    text = reg.to_text()
    assert 'lat_bucket{le="0.1"} 1' in text
    assert 'lat_bucket{le="1.0"} 3' in text   # cumulative, not per-bucket
    assert 'lat_bucket{le="+Inf"} 4' in text
    assert "lat_count 4" in text
    assert f"lat_sum {0.05 + 0.5 + 0.5 + 5.0}" in text
    assert "# TYPE lat histogram" in text and "# HELP lat latency" in text
    assert h.quantile(0.5) == 1.0
    assert h.quantile(1.0) == float("inf")


def test_histogram_quantile_edge_cases():
    import math
    reg = obs.Registry()
    h = reg.histogram("q", buckets=(1.0, 2.0))
    assert math.isnan(h.quantile(0.5))        # no data: nan, not an edge
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        h.quantile(1.5)
    h.observe(1.5)                            # lands in the (1, 2] bucket
    # regression: q == 0 must skip empty leading buckets, not report the
    # first bucket's edge
    assert h.quantile(0.0) == 2.0
    assert h.quantile(1.0) == 2.0
    g = reg.histogram("q2", buckets=(1.0, 2.0))
    g.observe(3.0)
    g.observe(4.0)                            # everything past the last edge
    for q in (0.0, 0.5, 1.0):
        assert g.quantile(q) == float("inf")


def test_registry_exposition_deterministic():
    """Same metrics, different registration order -> identical text, so
    the Prometheus dump (and any diff over it) is byte-stable."""
    def build(order):
        reg = obs.Registry()
        ops = {"c": lambda r: r.counter("ctr", "c help").inc(3),
               "g": lambda r: r.gauge("depth").set(2.0),
               "h": lambda r: r.histogram("lat", buckets=(0.5,))
               .observe(0.25)}
        for k in order:
            ops[k](reg)
        return reg.to_text()
    assert build("cgh") == build("hgc") == build("ghc")


def test_registry_dump(tmp_path):
    reg = obs.Registry()
    reg.counter("c").inc(2)
    path = str(tmp_path / "sub" / "metrics.prom")
    reg.dump(path)
    with open(path) as f:
        assert "c 2" in f.read()


# ------------------------------------------------------- spec validation
def test_telemetry_sinks_require_enabled():
    for field, value in [("fence", True), ("jsonl", "t.jsonl"),
                         ("prometheus", "m.prom"), ("profile_dir", "p")]:
        spec = api.with_overrides(api.presets.get("tiny-smoke"),
                                  {f"telemetry.{field}": value})
        with pytest.raises(api.SpecError, match="telemetry.enabled"):
            api.validate(spec)


def test_telemetry_enabled_needs_a_sink_and_sane_ring():
    base = api.presets.get("tiny-smoke")
    with pytest.raises(api.SpecError, match="ring"):
        api.validate(api.with_overrides(
            base, {"telemetry.enabled": True, "telemetry.ring": 0}))
    with pytest.raises(api.SpecError, match="ring"):
        api.validate(api.with_overrides(base, {"telemetry.ring": -1}))
    api.validate(api.with_overrides(base, {"telemetry.enabled": True}))
    api.validate(api.with_overrides(
        base, {"telemetry.enabled": True, "telemetry.ring": 0,
               "telemetry.jsonl": "t.jsonl"}))


def test_health_knobs_require_runs_dir():
    base = api.presets.get("tiny-smoke")
    for field, value in [("run_id", "r1"), ("health_norms", True)]:
        spec = api.with_overrides(base, {f"telemetry.{field}": value})
        with pytest.raises(api.SpecError, match="telemetry.runs_dir"):
            api.validate(spec)
    api.validate(api.with_overrides(base, {
        "telemetry.runs_dir": "artifacts/runs",
        "telemetry.run_id": "r1", "telemetry.health_norms": True}))


def test_telemetry_fields_resume_mutable():
    from repro.api import spec as spec_mod
    import dataclasses
    for f in dataclasses.fields(api.Telemetry):
        assert f"telemetry.{f.name}" in spec_mod.RESUME_MUTABLE


def test_session_wiring(tmp_path):
    assert obs.session(None) is obs.NULL_SESSION
    assert obs.session(api.Telemetry()) is obs.NULL_SESSION
    assert not obs.NULL_SESSION.enabled
    obs.NULL_SESSION.flush()                  # no-ops, never raises
    path = str(tmp_path / "t.jsonl")
    sess = obs.session(api.Telemetry(enabled=True, ring=16, jsonl=path))
    assert sess.enabled and sess.ring is not None
    with sess.tracer.span("x"):
        pass
    sess.close()
    assert len(sess.ring) == 1
    assert [e["name"] for e in obs.read_jsonl(path)
            if e["type"] == "span"] == ["x"]


# --------------------------------------------- estimator instrumentation
def _toy_estimator():
    import jax.numpy as jnp
    from repro import estimators
    from repro.core import zo
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    spec = zo.build_spec(params, lambda p: None)
    cfg = estimators.EstimatorConfig(name="two_point", eps=1e-3, lr=1e-4)
    est = estimators.build_estimator(spec, cfg)
    loss = lambda p, b, perturb=None: (p["w"] * b["x"]).sum() ** 2
    batch = {"x": jnp.ones((4, 4))}
    return est, loss, params, batch


def _one_eager_step(est, loss, params, batch, seed):
    import jax.numpy as jnp
    ring = obs.RingSink()
    tr = obs.Tracer(sinks=[ring], fence=True)
    with obs.use(tr):
        p, dirs, _ = est.estimate(loss, params, batch, jnp.uint32(seed),
                                  est.init_state())
        est.apply_update(p, dirs, est.cfg.lr)
    return [r.name for r in ring.records()], dict(tr.counters)


def test_eager_step_emits_stage_spans():
    pytest.importorskip("jax")
    est, loss, params, batch = _toy_estimator()
    names, counters = _one_eager_step(est, loss, params, batch, 7)
    assert names == [obs.PERTURB, obs.FWD_PLUS, obs.PERTURB,
                     obs.FWD_MINUS, obs.UPDATE]
    assert counters[obs.CTR_PROBES] == 2
    assert counters[obs.CTR_AXPY] == 3        # perturb, perturb, fused upd
    assert counters[obs.CTR_SELECTS] == 1


def test_counters_deterministic_across_identical_seeded_runs():
    pytest.importorskip("jax")
    est, loss, params, batch = _toy_estimator()
    one = _one_eager_step(est, loss, params, batch, 42)
    two = _one_eager_step(est, loss, params, batch, 42)
    assert one == two


def test_spans_and_counters_suppressed_under_jit():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro import estimators
    est, loss, params, batch = _toy_estimator()
    step, init = estimators.make_step(loss, est.spec, est.cfg)
    jstep = jax.jit(step)
    ring = obs.RingSink()
    tr = obs.Tracer(sinks=[ring])
    with obs.use(tr):
        out = jstep(params, init(), batch, jnp.int32(0), jnp.uint32(3))
        jax.block_until_ready(out[0])
    assert len(ring) == 0 and tr.counters == {}


# ------------------------------------------------------------- trainer
def _tiny_trainer(**tkw):
    import warnings
    from repro.configs import opt
    from repro.data import synthetic
    from repro.train.trainer import Trainer, TrainConfig
    mcfg = opt.opt_tiny(layers=2, d_model=32, vocab=64)
    task = synthetic.TaskConfig(vocab=64, seq_len=16, n_classes=2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return Trainer(mcfg, task, TrainConfig(**tkw))


def test_trainer_logs_final_step_off_grid():
    """Regression: steps % log_every != 0 silently dropped the last
    steps from history — short runs looked like they never ran."""
    h = _tiny_trainer(steps=8, batch_size=4, eval_every=0,
                      log_every=3).train()
    assert h["step"] == [0, 3, 6, 7]          # 7 == steps-1, off the grid
    assert len(h["loss"]) == len(h["wall"]) == len(h["wall_compute"]) == 4


def test_trainer_wall_compute_excludes_eval_time():
    """Regression: history['wall'] silently included eval/checkpoint
    time; wall_compute is the compute-only series."""
    h = _tiny_trainer(steps=6, batch_size=4, eval_every=2,
                      log_every=1).train()
    assert len(h["wall_compute"]) == len(h["wall"]) == 6
    assert all(wc <= w for wc, w in zip(h["wall_compute"], h["wall"]))
    # evals ran (incl. a jit compile), so the series must have diverged
    assert h["wall_compute"][-1] < h["wall"][-1]
    assert all(np.diff(h["wall_compute"]) >= 0)   # still monotone


def test_trainer_session_records_steps(tmp_path):
    path = str(tmp_path / "train.jsonl")
    spec = api.with_overrides(api.presets.get("tiny-smoke"), {
        "run.steps": 3, "run.eval_every": 0, "run.log_every": 1,
        "telemetry.enabled": True, "telemetry.jsonl": path})
    api.validate(spec)
    from repro.train.trainer import Trainer
    tr = Trainer.from_spec(spec)
    assert tr.obs.enabled
    h = tr.train()
    assert h["step"] == [0, 1, 2]             # history shape unchanged
    spans = [e for e in obs.read_jsonl(path) if e["type"] == "span"]
    assert [s["name"] for s in spans] == [obs.TRAIN_STEP] * 3
    snaps = [e for e in obs.read_jsonl(path) if e["type"] == "counters"]
    assert snaps, "flush() must append a counter snapshot"


# -------------------------------------------------------------- serving
def test_engine_exports_metrics():
    jax = pytest.importorskip("jax")
    from repro import configs, serving
    from repro.models import lm
    cfg = configs.get("opt-13b", "smoke")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    sess = obs.session(api.Telemetry(enabled=True, ring=256))
    eng = serving.Engine(
        cfg, params, api.Serving(page_size=4, n_pages=32, max_lanes=2,
                                 prefill_chunk=8, max_seq=64), obs=sess)
    rng = np.random.default_rng(0)
    reqs = [serving.Request(rid=i,
                            tokens=rng.integers(0, cfg.vocab, 5).tolist(),
                            max_new_tokens=3, seed=i) for i in range(2)]
    results = eng.run(reqs)
    assert len(results) == 2
    text = eng.metrics_text()
    assert "serving_requests_completed 2" in text
    assert f"serving_tokens_generated {2 * 3}" in text
    assert "serving_ttft_seconds_count 2" in text
    assert "serving_latency_seconds_count 2" in text
    assert "serving_pages_in_use 0" in text   # drained
    assert "serving_tokens_per_second" in text
    names = {r.name for r in sess.ring.records()}
    assert obs.SERVE_PREFILL in names and obs.SERVE_DECODE in names
    for r in results:
        assert r.ttft > 0 and r.latency >= r.ttft


def test_engine_without_session_uses_null(monkeypatch):
    jax = pytest.importorskip("jax")
    from repro import configs, serving
    from repro.models import lm
    cfg = configs.get("opt-13b", "smoke")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = serving.Engine(cfg, params,
                         api.Serving(page_size=4, n_pages=16, max_lanes=2,
                                     prefill_chunk=8, max_seq=32))
    assert eng.obs is obs.NULL_SESSION
    # metrics still exist (registry is always real), tracer is NULL
    assert not eng.obs.tracer.enabled
    assert "serving_queue_depth" in eng.metrics_text()


# ----------------------------------------------------- bench tripwires
def test_run_py_tripwire_gate(tmp_path):
    from benchmarks import run as run_mod
    ok = {"bench": "x", "tripwires": {
        "a": {"ok": True, "value": 1, "limit": 2}}}
    bad = {"bench": "y", "tripwires": {
        "b": {"ok": False, "value": 9, "limit": 2, "note": "broke"},
        "c": {"ok": True, "value": 0, "limit": 1}}}
    no_tw = {"bench": "z", "rows": []}
    assert run_mod.tripwire_failures({"A.json": ok, "C.json": no_tw}) == []
    fails = run_mod.tripwire_failures({"A.json": ok, "B.json": bad})
    assert [(a, t) for a, t, _ in fails] == [("B.json", "b")]
    # a malformed tripwire record counts as a failure, not a pass
    assert run_mod.tripwire_failures({"M.json": {"tripwires": {"t": None}}})

    # end to end through collect_artifacts off a synthetic failing file
    for name, payload in [("BENCH_ok.json", ok), ("BENCH_bad.json", bad)]:
        with open(tmp_path / name, "w") as f:
            json.dump(payload, f)
    arts = run_mod.collect_artifacts(tmp_path)
    assert sorted(arts) == ["BENCH_bad.json", "BENCH_ok.json"]
    fails = run_mod.tripwire_failures(arts)
    assert [(a, t) for a, t, _ in fails] == [("BENCH_bad.json", "b")]
