import os
import re
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from tiers import SLOW_NODE_PATTERNS  # noqa: E402


def _compile(patterns):
    """'*'-only wildcards: unlike fnmatch, '[' / ']' are literal, since
    pytest node ids use brackets for parametrized cases."""
    parts = (".*".join(re.escape(p) for p in pat.split("*"))
             for pat in patterns)
    # one ^(?:...)$ group per pattern: without it the $ would bind only
    # to the last alternative and the rest would prefix-match
    return re.compile("|".join("^(?:%s)$" % p for p in parts))


_SLOW_RE = _compile(SLOW_NODE_PATTERNS)


def pytest_collection_modifyitems(config, items):
    """Apply the tier manifest: mark measured-heavy tests ``slow`` so
    ``pytest -m "not slow"`` (make test-fast) is the <~90s tier-1 gate.
    See tests/tiers.py for the policy and the per-case pattern list."""
    for item in items:
        if _SLOW_RE.match(item.nodeid):
            item.add_marker(pytest.mark.slow)
