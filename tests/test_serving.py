"""Serving engine suite (DESIGN.md §12).

Tiers: the pool/scheduler property tests and spec validation are pure
host-side and stay tier-1; the engine bit-identity gate keeps one fast
representative (opt-smoke) in tier-1 and the heavier cases (rope arch,
sampling reproducibility, EOS, CLI e2e) in tier-2 via tests/tiers.py.
"""
import numpy as np
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim

from repro import api
from repro.serving import (KVPool, PoolExhausted, Request, Scheduler,
                           TRASH_PAGE)


# ------------------------------------------------------------------ pool
def test_pool_alloc_free_roundtrip():
    pool = KVPool(n_pages=8, page_size=4)
    assert pool.available == 7          # page 0 reserved
    a = pool.alloc(3)
    assert len(set(a)) == 3 and TRASH_PAGE not in a
    assert pool.in_use == 3 and pool.available == 4
    pool.free(a)
    assert pool.in_use == 0 and pool.available == 7
    pool.check_invariants()


def test_pool_exhaustion_leaves_pool_untouched():
    pool = KVPool(n_pages=4, page_size=4)
    a = pool.alloc(2)
    with pytest.raises(PoolExhausted):
        pool.alloc(2)                   # only 1 free
    assert pool.available == 1 and pool.in_use == 2
    pool.free(a)
    pool.check_invariants()


def test_pool_double_free_raises():
    pool = KVPool(n_pages=4, page_size=4)
    a = pool.alloc(1)
    pool.free(a)
    with pytest.raises(ValueError, match="double-free"):
        pool.free(a)
    with pytest.raises(ValueError, match="double-free|foreign"):
        pool.free([TRASH_PAGE])
    pool.check_invariants()


def test_pool_never_hands_out_trash_page():
    pool = KVPool(n_pages=5, page_size=2)
    pages = pool.alloc(4)               # drain it completely
    assert TRASH_PAGE not in pages
    pool.check_invariants()


@given(st.lists(st.tuples(st.booleans(), st.integers(0, 5)), max_size=60),
       st.integers(2, 24))
@settings(max_examples=60, deadline=None)
def test_pool_random_trace_no_leak_no_double_free(trace, n_pages):
    """Random alloc/free traces: the pool invariants hold after every
    transition and a full drain restores every page."""
    pool = KVPool(n_pages=n_pages, page_size=4)
    held = []
    for is_alloc, n in trace:
        if is_alloc:
            try:
                held.append(pool.alloc(n))
            except PoolExhausted:
                assert n > pool.available
        elif held:
            pool.free(held.pop(n % len(held)))
        pool.check_invariants()
    for pages in held:
        pool.free(pages)
    pool.check_invariants()
    assert pool.in_use == 0 and pool.available == n_pages - 1


# -------------------------------------------------------------- scheduler
def _sched(n_pages=32, page_size=4, max_lanes=3, prefill_chunk=8,
           max_seq=64, **kw):
    return Scheduler(KVPool(n_pages, page_size), max_lanes=max_lanes,
                     prefill_chunk=prefill_chunk, max_seq=max_seq, **kw)


def test_scheduler_rejects_oversized_request():
    s = _sched(max_seq=32)
    with pytest.raises(ValueError, match="max_seq"):
        s.submit(Request(rid=0, tokens=[1] * 30, max_new_tokens=10))


def test_scheduler_admits_reserve_ahead_and_frees_on_finish():
    s = _sched(n_pages=9, page_size=4, max_lanes=2)
    s.submit(Request(rid=0, tokens=[1] * 8, max_new_tokens=8))   # 4 pages
    s.submit(Request(rid=1, tokens=[1] * 8, max_new_tokens=8))   # 4 pages
    s.submit(Request(rid=2, tokens=[1] * 8, max_new_tokens=8))
    assert s.try_admit() == 0 and s.try_admit() == 1
    assert s.try_admit() is None        # pool drained: 8 of 8 reserved
    s.pool.check_invariants()
    s.finish(0)
    assert s.try_admit() == 0           # freed pages re-admit the head
    assert s.pool.in_use == 8


def test_scheduler_fifo_head_of_line_blocks():
    s = _sched(n_pages=9, page_size=4, max_lanes=3)
    s.submit(Request(rid=0, tokens=[1] * 8, max_new_tokens=24))  # 8 pages
    s.submit(Request(rid=1, tokens=[1] * 4, max_new_tokens=4))   # 2 pages
    assert s.try_admit() == 0
    assert s.try_admit() is None        # head (rid 1) needs 2 > 0 free
    assert s.queue[0].rid == 1          # ...and stays queued, unskipped


def test_scheduler_page_row_trash_padded():
    s = _sched()
    s.submit(Request(rid=0, tokens=[1] * 4, max_new_tokens=4))
    lane = s.lanes[s.try_admit()]
    row = s.page_row(lane)
    assert len(row) == s.table_width
    assert row[len(lane.pages):] == [TRASH_PAGE] * (s.table_width
                                                    - len(lane.pages))


@given(st.lists(st.tuples(st.integers(1, 20), st.integers(1, 12),
                          st.integers(0, 2)), min_size=1, max_size=30))
@settings(max_examples=40, deadline=None)
def test_scheduler_random_admit_finish_trace(reqs):
    """Random submit/admit/finish interleavings never leak or double-free
    a page, and draining every lane returns the pool to full."""
    s = _sched(n_pages=48, page_size=4, max_lanes=4, max_seq=64)
    rng = np.random.default_rng(sum(p for p, _, _ in reqs))
    for rid, (plen, gen, _) in enumerate(reqs):
        s.submit(Request(rid=rid, tokens=[1] * plen, max_new_tokens=gen))
    while s.busy:
        progressed = s.try_admit() is not None
        active = [i for i, l in enumerate(s.lanes) if l is not None]
        if active and (not progressed or rng.integers(2)):
            s.finish(int(rng.choice(active)))
            progressed = True
        s.pool.check_invariants()
        if not progressed and not active:
            break                       # head blocked with empty lanes
    assert s.pool.in_use == sum(
        len(l.pages) for l in s.lanes if l is not None)
    s.pool.check_invariants()


# ----------------------------------------------- priorities / preemption
def test_scheduler_priority_admission_order():
    """Higher classes admit first; FIFO within a class."""
    s = _sched(n_pages=64, max_lanes=1, max_seq=64, priorities=3)
    for rid, prio in [(0, 0), (1, 2), (2, 1), (3, 2), (4, 0)]:
        s.submit(Request(rid=rid, tokens=[1] * 4, max_new_tokens=4,
                         priority=prio))
    order = []
    while s.queue:
        i = s.try_admit()
        order.append(s.lanes[i].req.rid)
        s.finish(i)
    assert order == [1, 3, 2, 0, 4]


def test_scheduler_rejects_out_of_range_priority():
    s = _sched()   # priorities=1 by default
    with pytest.raises(ValueError, match="priority"):
        s.submit(Request(rid=0, tokens=[1] * 4, max_new_tokens=4,
                         priority=1))
    with pytest.raises(ValueError, match="priority"):
        Request(rid=1, tokens=[1] * 4, max_new_tokens=4, priority=-1)


def test_scheduler_preempts_lowest_priority_decoding_lane():
    """A starved higher-priority head evicts the lowest-priority
    decoding lane; the victim requeues at the front of its class with
    its pages released."""
    from repro.serving.scheduler import DECODE
    s = Scheduler(KVPool(n_pages=9, page_size=4), max_lanes=2,
                  prefill_chunk=8, max_seq=32, priorities=3, preempt=True)
    s.submit(Request(rid=0, tokens=[1] * 8, max_new_tokens=8))  # 4 pages
    s.submit(Request(rid=1, tokens=[2] * 8, max_new_tokens=8))  # 4 pages
    a, b = s.try_admit(), s.try_admit()
    s.lanes[a].state = s.lanes[b].state = DECODE
    s.submit(Request(rid=2, tokens=[3] * 8, max_new_tokens=8, priority=2))
    s.submit(Request(rid=3, tokens=[4] * 4, max_new_tokens=4))  # class 0
    i = s.try_admit()
    assert i is not None and s.lanes[i].req.rid == 2
    assert s.preemptions == 1
    # the youngest lane of the lowest class (rid 1) was the victim, and
    # it requeued AHEAD of the later class-0 submission (rid 3)
    assert s.lanes[a].req.rid == 0
    assert [r.rid for r in s.queue] == [1, 3]
    s.pool.check_invariants()
    # equal priority never evicts: rid 1 (class 0) cannot preempt rid 0
    assert s.try_admit() is None and s.preemptions == 1


def test_scheduler_fuzz_priorities_preempt_no_leaks():
    """Seeded random submit/admit/preempt/finish traces across mixed
    priorities: the admitted request is always the (priority desc,
    submit order) head, pool invariants hold after every transition,
    and a full drain leaves zero pages outside the trie."""
    from repro.serving.scheduler import DECODE, PREFILL
    for seed in range(6):
        rng = np.random.default_rng(seed)
        s = Scheduler(KVPool(n_pages=int(rng.integers(12, 40)), page_size=4),
                      max_lanes=int(rng.integers(2, 5)), prefill_chunk=8,
                      max_seq=48, prefix_cache=bool(seed % 2),
                      priorities=3, preempt=True)
        rid = 0
        for _ in range(120):
            op = rng.integers(0, 4)
            if op == 0 and rid < 40:
                plen = int(rng.integers(1, 17))
                s.submit(Request(rid=rid,
                                 tokens=rng.integers(0, 50, plen).tolist(),
                                 max_new_tokens=int(rng.integers(1, 9)),
                                 priority=int(rng.integers(0, 3))))
                rid += 1
            elif op == 1 and s.queue:
                head = s.queue[0]
                assert all(s._key(head) <= s._key(q) for q in s.queue), \
                    "queue lost (priority, FIFO) order"
                i = s.try_admit()
                if i is not None:
                    assert s.lanes[i].req.rid == head.rid
            elif op == 2:
                pre = s.prefilling()
                if pre:
                    lane = s.lanes[int(rng.choice(pre))]
                    lane.state = DECODE          # fake prefill completion
                    s.register_prefix(lane)
            elif op == 3:
                dec = s.decoding()
                if dec:
                    s.finish(int(rng.choice(dec)))
            s.pool.check_invariants()
            if s.trie is not None:
                s.trie.check_invariants()
        # drain: finish everything admitted, admit the stragglers
        stall = 0
        while s.busy and stall < 200:
            stall += 1
            if s.try_admit() is not None:
                stall = 0
            for i in list(s.prefilling()) + list(s.decoding()):
                s.finish(i)
                stall = 0
            s.pool.check_invariants()
        assert not s.busy, "drain stalled (blocked head or stuck lane)"
        trie_pages = s.trie.reclaimable() if s.trie is not None else 0
        assert s.pool.in_use == trie_pages, "pages leaked outside the trie"
        if s.trie is not None:
            s.trie.evict(trie_pages)
            s.trie.check_invariants()
        assert s.pool.in_use == 0
        s.pool.check_invariants()


# ------------------------------------------------------- spec validation
def test_serving_spec_validation_errors():
    base = api.preset("tiny-smoke")
    for path, bad, frag in [
            ("serving.page_size", 0, "page_size"),
            ("serving.n_pages", 1, "trash page"),
            ("serving.max_lanes", 0, "max_lanes"),
            ("serving.prefill_chunk", 12, "multiple"),
            ("serving.max_seq", 20, "multiple"),
            ("serving.max_new_tokens", 0, "max_new_tokens"),
            ("serving.max_new_tokens", 512, "room for a prompt"),
            ("serving.temperature", -0.5, "greedy"),
            ("serving.top_k", -1, "top_k"),
            ("serving.eos_id", 10 ** 9, "vocab"),
            ("serving.priorities", 0, "priorities"),
            # preemption is meaningless with a single priority class
            ("serving.preempt", True, "preempt"),
            # pool that can never cover even the smallest request
            ("serving.n_pages", 2, "usable pages")]:
        with pytest.raises(api.SpecError, match=path.split(".")[1]):
            api.validate(api.with_overrides(base, {path: bad}))


def test_serving_fields_are_resume_mutable():
    from repro.api import spec as spec_mod
    a = spec_mod.to_dict(api.preset("tiny-smoke"))
    b = spec_mod.to_dict(api.with_overrides(
        api.preset("tiny-smoke"), {"serving.max_lanes": 16,
                                   "serving.n_pages": 128}))
    assert spec_mod.spec_diff(a, b) == ()   # serving never blocks resume


# ---------------------------------------------------------------- engine
def _lockstep_reference(cfg, params, tokens, gen):
    import jax.numpy as jnp
    import numpy as np
    from repro.launch import serve as serve_mod
    out = serve_mod.generate(cfg, params,
                             jnp.asarray(np.asarray(tokens)[None],
                                         jnp.int32),
                             gen, max_seq=64)
    return np.asarray(out)[0].tolist()


@pytest.fixture(scope="module")
def opt_smoke():
    import jax
    from repro import configs
    from repro.models import lm
    cfg = configs.get("opt-13b", "smoke")
    return cfg, lm.init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, **over):
    from repro import serving
    kw = dict(page_size=4, n_pages=32, max_lanes=3, prefill_chunk=8,
              max_seq=64)
    kw.update(over)
    return serving.Engine(cfg, params, api.Serving(**kw))


def test_engine_greedy_bit_identical_to_lockstep(opt_smoke):
    """The acceptance gate: every request's engine output equals the
    single-sequence lockstep path token-for-token, whatever lane/batch
    composition served it — and the whole run stays at one compile per
    bucket."""
    cfg, params = opt_smoke
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        int(rng.integers(3, 18))).tolist(),
                    max_new_tokens=int(rng.integers(2, 7)), seed=i)
            for i in range(5)]
    eng = _engine(cfg, params)
    results = {r.rid: r for r in eng.run(reqs)}
    assert sorted(results) == [0, 1, 2, 3, 4]
    for req in reqs:
        assert results[req.rid].tokens == _lockstep_reference(
            cfg, params, req.tokens, req.max_new_tokens), req.rid
    assert eng.n_compiles() == 2
    assert eng.pool.in_use == 0
    eng.pool.check_invariants()


def test_engine_bit_identical_on_rope_arch():
    import jax
    from repro import configs
    from repro.models import lm
    cfg = configs.get("internlm2-1.8b", "smoke")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, tokens=rng.integers(0, cfg.vocab, n).tolist(),
                    max_new_tokens=g, seed=i)
            for i, (n, g) in enumerate([(13, 5), (7, 4), (21, 3)])]
    eng = _engine(cfg, params)
    for r in eng.run(reqs):
        req = reqs[r.rid]
        assert r.tokens == _lockstep_reference(cfg, params, req.tokens,
                                               req.max_new_tokens)


def test_engine_sampling_reproducible_across_batch_composition(opt_smoke):
    """temperature>0: a request's sampled continuation is a pure function
    of (seed, position) — identical served alone or in a full batch."""
    cfg, params = opt_smoke
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, n).tolist()
               for n in (9, 14, 5, 11)]
    mk = lambda: [Request(rid=i, tokens=p, max_new_tokens=6, seed=100 + i)
                  for i, p in enumerate(prompts)]
    full = {r.rid: r.tokens for r in _engine(
        cfg, params, temperature=0.8, top_k=8).run(mk())}
    for i in range(len(prompts)):
        alone = _engine(cfg, params, temperature=0.8, top_k=8).run(
            [mk()[i]])
        assert alone[0].tokens == full[i], f"rid {i} drifted with batch"


def test_engine_eos_stops_early_and_frees_pages(opt_smoke):
    cfg, params = opt_smoke
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 9).tolist()
    ref = _engine(cfg, params).run(
        [Request(rid=0, tokens=prompt, max_new_tokens=8)])[0].tokens
    eos = ref[3]
    eng = _engine(cfg, params, eos_id=int(eos))
    out = eng.run([Request(rid=0, tokens=prompt, max_new_tokens=8)])[0]
    stop = ref.index(eos)
    assert out.tokens == ref[:stop + 1]     # truncated at first EOS
    assert eng.pool.in_use == 0


def test_engine_rejects_unsupported_arch():
    from repro import configs, serving
    cfg = configs.get("xlstm-350m", "smoke")
    with pytest.raises(serving.EngineUnsupported, match="attn mixers"):
        serving.Engine(cfg, None, api.Serving())


def test_engine_interleaves_prefill_with_decode(opt_smoke):
    """A multi-chunk admission must not stall running decode lanes for
    more than one chunk: decode steps keep advancing while the long
    prompt streams in."""
    cfg, params = opt_smoke
    rng = np.random.default_rng(4)
    eng = _engine(cfg, params, prefill_chunk=8, max_seq=64, n_pages=48)
    eng.submit(Request(rid=0, tokens=rng.integers(0, cfg.vocab, 4).tolist(),
                       max_new_tokens=12))
    while not eng.sched.decoding():     # get lane 0 decoding first
        eng.step()
    d0 = eng.n_decode_steps
    eng.submit(Request(rid=1,                       # 4 prefill chunks
                       tokens=rng.integers(0, cfg.vocab, 30).tolist(),
                       max_new_tokens=2))
    for _ in range(4):
        eng.step()
    assert eng.n_decode_steps >= d0 + 4  # decode never paused
    eng.run([])                          # drain


def test_engine_applies_spec_max_new_tokens_default(opt_smoke):
    """A Request without max_new_tokens takes serving.max_new_tokens —
    the spec knob must actually steer generation, and raw Scheduler use
    refuses an unresolved budget."""
    cfg, params = opt_smoke
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, 6).tolist()
    eng = _engine(cfg, params, max_new_tokens=5)
    out = eng.run([Request(rid=0, tokens=prompt)])
    assert len(out[0].tokens) == 5
    with pytest.raises(ValueError, match="unresolved"):
        _sched().submit(Request(rid=1, tokens=prompt))


def test_prefill_serves_oldest_admission_first(opt_smoke):
    """FIFO must hold across lanes: a later admission landing in a
    lower-index lane may not steal prefill chunks from an in-progress
    older request."""
    cfg, params = opt_smoke
    rng = np.random.default_rng(6)
    eng = _engine(cfg, params, max_lanes=2, prefill_chunk=8, n_pages=48,
                  max_seq=64)
    # R0: one-step request that frees lane 0 immediately; A: 4-chunk
    # prompt admitted into lane 1 the same step
    eng.submit(Request(rid=0, tokens=rng.integers(0, cfg.vocab, 4).tolist(),
                       max_new_tokens=1))
    eng.submit(Request(rid=1, tokens=rng.integers(0, cfg.vocab, 30).tolist(),
                       max_new_tokens=2))
    eng.step()                                   # R0 in & out, A waits
    assert eng.sched.lanes[0] is None
    eng.submit(Request(rid=2, tokens=rng.integers(0, cfg.vocab, 4).tolist(),
                       max_new_tokens=2))        # admitted into lane 0
    eng.step()
    a, b = eng.sched.lanes[1], eng.sched.lanes[0]
    assert a is not None and a.next_chunk == 1   # oldest got the chunk
    assert b is not None and b.next_chunk == 0   # newcomer waited
    eng.run([])


def test_engine_reusable_without_result_accumulation(opt_smoke):
    """run() hands results to the caller and retains nothing — a second
    run on the same engine returns only its own requests."""
    cfg, params = opt_smoke
    rng = np.random.default_rng(7)
    eng = _engine(cfg, params)
    mk = lambda rid: Request(rid=rid,
                             tokens=rng.integers(0, cfg.vocab, 6).tolist(),
                             max_new_tokens=2)
    assert len(eng.run([mk(0), mk(1)])) == 2
    second = eng.run([mk(2)])
    assert [r.rid for r in second] == [2]
    assert eng.pool.in_use == 0


def test_engine_prefix_sharing_bit_identical(opt_smoke):
    """The sharing acceptance anchor: greedy output with
    ``prefix_cache=True`` is bit-identical to the sharing-off path on a
    shared-system-prompt convoy, pages actually share (hit rate > 0,
    COW fires), and a drained engine holds pages only through the
    trie."""
    cfg, params = opt_smoke
    rng = np.random.default_rng(11)
    system = rng.integers(0, cfg.vocab, 12).tolist()   # 3 full pages
    tails = [rng.integers(0, cfg.vocab,
                          int(rng.integers(1, 9))).tolist()
             for _ in range(4)]
    mk = lambda: [Request(rid=i, tokens=system + tails[i],
                          max_new_tokens=4, seed=i)
                  for i in range(4)]
    off = {r.rid: r.tokens for r in _engine(cfg, params).run(mk())}
    eng = _engine(cfg, params, prefix_cache=True)
    on = {r.rid: r.tokens for r in eng.run(mk())}
    assert on == off
    assert eng.sched.page_hit_rate > 0.0
    assert eng.sched.cow_copies > 0
    # a second convoy over the warm trie hits at least as often
    hits0 = eng.sched.prefix_hits
    assert {r.rid: r.tokens for r in eng.run(mk())} == off
    assert eng.sched.prefix_hits > hits0
    # drain accounting: every live page is a trie reference, and
    # evicting the (now dead) trie returns the pool to empty
    assert eng.pool.in_use == eng.sched.trie.reclaimable()
    eng.sched.trie.evict(eng.pool.in_use)
    assert eng.pool.in_use == 0
    eng.pool.check_invariants()
    eng.sched.trie.check_invariants()


def test_engine_preempt_resume_bit_identical(opt_smoke):
    """A decoding low-priority request evicted by a high-priority
    arrival must finish with exactly the tokens of an uncontended run —
    preemption discards progress, never corrupts it."""
    cfg, params = opt_smoke
    rng = np.random.default_rng(12)
    lo_prompt = rng.integers(0, cfg.vocab, 6).tolist()
    hi_prompt = rng.integers(0, cfg.vocab, 6).tolist()
    kw = dict(max_lanes=1, n_pages=8, priorities=2, preempt=True,
              prefix_cache=True, max_seq=32)
    mk_lo = lambda: Request(rid=0, tokens=lo_prompt, max_new_tokens=6)
    mk_hi = lambda: Request(rid=1, tokens=hi_prompt, max_new_tokens=3,
                            priority=1)
    solo_lo = _engine(cfg, params, **kw).run([mk_lo()])[0].tokens
    solo_hi = _engine(cfg, params, **kw).run([mk_hi()])[0].tokens
    eng = _engine(cfg, params, **kw)
    eng.submit(mk_lo())
    steps = 0
    while not (eng.sched.decoding()
               and eng.sched.lanes[eng.sched.decoding()[0]].out):
        eng.step()
        steps += 1
        assert steps < 50
    eng.submit(mk_hi())                    # outranks the decoding lane
    got = {r.rid: r.tokens for r in eng.run([])}
    assert eng.sched.preemptions == 1
    assert got[1] == solo_hi               # high priority ran through
    assert got[0] == solo_lo               # victim regenerated identically
    assert eng.pool.in_use == eng.sched.trie.reclaimable()
    eng.pool.check_invariants()


def test_docgen_handles_bare_target_dir(tmp_path, capsys):
    from repro.launch import docgen
    written = docgen.write_docs(str(tmp_path))
    assert (tmp_path / "cli.md").exists()
    assert not (tmp_path / "serving.md").exists()    # skipped, not crashed
    assert len(written) == 1
    assert "skipped" in capsys.readouterr().out


def test_cli_serve_paged_e2e(capsys):
    from repro.launch import cli
    result = cli.main(["serve", "--arch", "opt-13b", "--variant", "smoke",
                       "--batch", "2", "--prompt-len", "8", "--gen", "3",
                       "--set", "serving.page_size=4",
                       "--set", "serving.prefill_chunk=8",
                       "--set", "serving.max_seq=64"])
    assert result["engine"]["mode"] == "paged"
    assert result["engine"]["compiles"] == 2
    assert [len(t) for t in result["tokens"]] == [3, 3]
    assert "tok/s" in capsys.readouterr().out
