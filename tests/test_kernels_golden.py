"""Golden regression pins for the fused ZO axpy Pallas kernel.

``kernels/zo_axpy.py`` currently runs in interpret mode on CPU; future
work will de-interpret it on TPU and may retile/revectorize the body.
These pins freeze today's *semantics* — exact output values for f32 and
bf16, masked and unmasked rows, and a block size that does not divide n
— so any change to the RNG stream, the accumulate dtype (f32 math, cast
on store), the tile indexing, or the mask/aliasing path is caught as a
value diff, not discovered as a silently-diverged training run.

The expected arrays were generated from the kernel at pin time and
cross-checked bit-exact against the pure-jnp oracle (kernels/ref.py);
both are asserted below so kernel and oracle cannot drift apart either.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.zo_axpy import zo_axpy_2d

# Shared inputs: (3, 20) ramp, block=8 (20 % 8 != 0 exercises the ragged
# final tile), row 1 dropped, seed=7, scale=0.125, decay=0.75.
SEED, SCALE, DECAY, BLOCK = 7, 0.125, 0.75, 8
MASK = (True, False, True)

GOLDEN_F32 = [
    [-2.328188419342041, -2.136239767074585, -1.8744629621505737,
     -1.5842370986938477, -1.658737063407898, -1.4480239152908325,
     -0.8561497330665588, -0.9114561676979065, -0.7327646017074585,
     -0.6654055714607239, -0.48222506046295166, -0.16871586441993713,
     0.15204283595085144, 0.2187245488166809, 0.4803268313407898,
     0.7026308178901672, 0.7442966103553772, 1.2369083166122437,
     1.2519561052322388, 1.231925368309021],
    [2.0, 2.25, 2.5, 2.75, 3.0, 3.25, 3.5, 3.75, 4.0, 4.25,
     4.5, 4.75, 5.0, 5.25, 5.5, 5.75, 6.0, 6.25, 6.5, 6.75],
    [5.089682579040527, 5.395804405212402, 5.470516204833984,
     5.6442108154296875, 5.852479934692383, 6.322897911071777,
     6.222381114959717, 6.4823760986328125, 6.535152912139893,
     7.022654056549072, 6.9342498779296875, 7.212704658508301,
     7.444188117980957, 7.642969131469727, 7.786533355712891,
     8.02572250366211, 8.018147468566895, 8.38583755493164,
     8.474709510803223, 8.863702774047852]]

GOLDEN_BF16 = [
    [-2.328125, -2.140625, -1.875, -1.5859375, -1.65625, -1.4453125,
     -0.85546875, -0.91015625, -0.734375, -0.6640625, -0.482421875,
     -0.1689453125, 0.15234375, 0.21875, 0.48046875, 0.703125,
     0.74609375, 1.234375, 1.25, 1.234375],
    [2.0, 2.25, 2.5, 2.75, 3.0, 3.25, 3.5, 3.75, 4.0, 4.25,
     4.5, 4.75, 5.0, 5.25, 5.5, 5.75, 6.0, 6.25, 6.5, 6.75],
    [5.09375, 5.40625, 5.46875, 5.65625, 5.84375, 6.3125, 6.21875,
     6.46875, 6.53125, 7.03125, 6.9375, 7.21875, 7.4375, 7.65625,
     7.78125, 8.0, 8.0, 8.375, 8.5, 8.875]]

# (2, 256) ramp, block=128, both rows active, seed=123, scale=0.5: value
# and magnitude checksums in f64 — a cheap wide-coverage pin.
CHECKSUM_N = 256
CHECKSUM_SUM = 1307.369512297213
CHECKSUM_ABS = 1319.640700943768


def _theta(dtype):
    t = jnp.arange(3 * 20, dtype=jnp.float32).reshape(3, 20) * 0.25 - 3.0
    return t.astype(dtype)


@pytest.mark.parametrize("dtype,golden", [("float32", GOLDEN_F32),
                                          ("bfloat16", GOLDEN_BF16)])
def test_golden_values_pinned(dtype, golden):
    theta = _theta(dtype)
    got = zo_axpy_2d(theta, jnp.asarray(MASK), jnp.uint32(SEED),
                     jnp.float32(SCALE), jnp.float32(DECAY), block=BLOCK)
    assert got.dtype == theta.dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(golden, np.float32))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_kernel_bitexact_vs_oracle(dtype):
    """Kernel and jnp oracle must agree to the bit (DESIGN.md §2), so the
    golden arrays pin both implementations at once."""
    theta = _theta(dtype)
    got = zo_axpy_2d(theta, jnp.asarray(MASK), jnp.uint32(SEED),
                     jnp.float32(SCALE), jnp.float32(DECAY), block=BLOCK)
    want = ref.zo_axpy_2d(theta, jnp.asarray(MASK), jnp.uint32(SEED),
                          SCALE, DECAY)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_masked_row_bitwise_untouched():
    theta = _theta("float32")
    got = zo_axpy_2d(theta, jnp.asarray(MASK), jnp.uint32(SEED),
                     jnp.float32(SCALE), jnp.float32(DECAY), block=BLOCK)
    assert np.array_equal(np.asarray(got)[1], np.asarray(theta)[1])


def test_checksum_full_tiles():
    theta = (jnp.arange(2 * CHECKSUM_N, dtype=jnp.float32)
             .reshape(2, CHECKSUM_N) * 0.01)
    got = zo_axpy_2d(theta, jnp.asarray([True, True]), jnp.uint32(123),
                     jnp.float32(0.5), jnp.float32(1.0), block=128)
    arr = np.asarray(got, np.float64)
    np.testing.assert_allclose(arr.sum(), CHECKSUM_SUM, rtol=1e-12)
    np.testing.assert_allclose(np.abs(arr).sum(), CHECKSUM_ABS, rtol=1e-12)


def test_golden_independent_of_block_size():
    """Retiling must not change values: the RNG counter is the global
    column index, not a tile-local one."""
    theta = _theta("float32")
    outs = [np.asarray(zo_axpy_2d(theta, jnp.asarray(MASK), jnp.uint32(SEED),
                                  jnp.float32(SCALE), jnp.float32(DECAY),
                                  block=b))
            for b in (4, 8, 16, 20, 64)]
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])
