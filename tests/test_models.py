"""Model substrate correctness: attention oracle, cache consistency,
chunked-vs-sequential recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers, lm, ssm
from repro.models.config import BlockCfg, ModelConfig, StageCfg, dense_lm

F32 = jnp.float32


def naive_attention(q, k, v, causal=True):
    B, S, KV, G, dh = q.shape
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k) * dh ** -0.5
    if causal:
        m = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v)


@pytest.mark.parametrize("S,qc,kc", [(64, 16, 16), (64, 64, 8), (96, 32, 32)])
def test_flash_matches_naive(S, qc, kc):
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (2, S, 2, 3, 8), F32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, S, 2, 8), F32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, S, 2, 8), F32)
    got = layers.flash_attention(q, kk, v, q_chunk=qc, k_chunk=kc)
    want = naive_attention(q, kk, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_key_padding_with_prefix_offset():
    """k_offset<0 (prefix tokens) + non-divisible Sk exercises padding."""
    k = jax.random.PRNGKey(1)
    S, P = 32, 5
    q = jax.random.normal(k, (1, S, 1, 2, 8), F32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (1, S + P, 1, 8), F32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (1, S + P, 1, 8), F32)
    got = layers.flash_attention(q, kk, v, k_offset=-P, q_chunk=16, k_chunk=16)
    # oracle: prefix rows always visible
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, kk) * 8 ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S + P)[None, :] - P
    mask = qpos >= kpos
    s = jnp.where(mask[None, None, None], s, -1e30)
    want = jnp.einsum("bkgqs,bskd->bqkgd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def _decode_check(cfg, S=16, tol=5e-5):
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, S)), jnp.int32)
    h, _, _ = lm.forward(cfg, params, toks, mode="train")
    want = lm.logits_fn(cfg, params, h[:, -1])
    _, caches = lm.prefill(cfg, params, toks[:, :S - 1], max_seq=S + 2)
    got, _ = lm.serve_step(cfg, params, caches, toks[:, S - 1:S],
                           jnp.int32(S - 1))
    scale = float(jnp.abs(want).max())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol * max(scale, 1.0))


def test_decode_consistency_dense():
    _decode_check(dense_lm("d", 2, 64, 4, 2, 128, 256, qk_norm=True,
                           dtype="float32", max_seq=64))


def test_decode_consistency_mla():
    _decode_check(ModelConfig(
        name="m", d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
        stages=(StageCfg(2, (BlockCfg("mla", "dense"),)),), kv_lora=32,
        rope_head_dim=8, d_head=16, dtype="float32", max_seq=64))


def test_decode_consistency_mamba():
    _decode_check(ModelConfig(
        name="mm", d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab=256,
        stages=(StageCfg(2, (BlockCfg("mamba", "none"),)),),
        dtype="float32", max_seq=64))


def test_decode_consistency_xlstm():
    _decode_check(ModelConfig(
        name="x", d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab=256,
        stages=(StageCfg(2, (BlockCfg("mlstm", "none"),
                             BlockCfg("slstm", "none"))),),
        dtype="float32", max_seq=64))


def test_decode_consistency_moe_dropless():
    # capacity_factor high enough that no token ever drops
    _decode_check(ModelConfig(
        name="moe", d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        stages=(StageCfg(2, (BlockCfg("attn", "moe"),)),), n_experts=4,
        top_k=2, moe_d_ff=32, capacity_factor=4.0, dtype="float32",
        max_seq=64))


def test_multi_step_decode_matches_train():
    cfg = dense_lm("d", 2, 64, 4, 2, 128, 256, dtype="float32", max_seq=64)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    S = 12
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 256, (1, S)),
                       jnp.int32)
    h, _, _ = lm.forward(cfg, params, toks, mode="train")
    _, caches = lm.prefill(cfg, params, toks[:, :4], max_seq=S + 2)
    for t in range(4, S):
        got, caches = lm.serve_step(cfg, params, caches, toks[:, t:t + 1],
                                    jnp.int32(t))
        want = lm.logits_fn(cfg, params, h[:, t])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4)


def test_mamba_chunk_invariance():
    """Chunked scan result independent of chunk size."""
    B, S, Di, St = 2, 64, 8, 4
    k = jax.random.PRNGKey(0)
    dA = jax.nn.sigmoid(jax.random.normal(k, (B, S, Di, St)))
    dBx = jax.random.normal(jax.random.fold_in(k, 1), (B, S, Di, St))
    C = jax.random.normal(jax.random.fold_in(k, 2), (B, S, St))
    h0 = jnp.zeros((B, Di, St))
    outs = []
    for chunk in (8, 64):
        ssm.CHUNK, old = chunk, ssm.CHUNK
        y, hf = ssm._ssm_chunk_scan(dA, dBx, C, h0)
        ssm.CHUNK = old
        outs.append((y, hf))
    np.testing.assert_allclose(np.asarray(outs[0][0]), np.asarray(outs[1][0]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(outs[0][1]), np.asarray(outs[1][1]),
                               atol=1e-5)


def test_mlstm_chunk_invariance():
    """Chunkwise mLSTM == step-by-step recurrence (chunk=1 vs chunk=32)."""
    B, S, H, dh = 1, 64, 2, 8
    k = jax.random.PRNGKey(3)
    q = jax.random.normal(k, (B, S, H, dh))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, H, dh))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, H, dh))
    li = jax.random.normal(jax.random.fold_in(k, 3), (B, S, H))
    lf = jax.nn.log_sigmoid(jax.random.normal(jax.random.fold_in(k, 4),
                                              (B, S, H)) + 2.0)
    state = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
             jnp.full((B, H), -jnp.inf))

    def run(chunk):
        st = state
        hs = []
        for i in range(0, S, chunk):
            h, st = ssm._mlstm_chunk(q[:, i:i + chunk], kk[:, i:i + chunk],
                                     v[:, i:i + chunk], li[:, i:i + chunk],
                                     lf[:, i:i + chunk], st)
            hs.append(h)
        return jnp.concatenate(hs, 1), st

    h1, st1 = run(1)
    h32, st32 = run(32)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h32), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1[0]), np.asarray(st32[0]),
                               rtol=2e-4, atol=2e-4)


def test_chunked_ce_matches_dense():
    cfg = dense_lm("d", 1, 32, 2, 2, 64, 128, dtype="float32", max_seq=64)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32))
    y = jnp.asarray(np.random.default_rng(0).integers(0, 128, (B, S)))
    m = jnp.asarray(np.random.default_rng(1).random((B, S)) > 0.5, F32)
    got = lm.chunked_ce(cfg, params, h, y, m)
    lg = lm.logits_fn(cfg, params, h)
    lse = jax.nn.logsumexp(lg, -1)
    gold = jnp.take_along_axis(lg, y[..., None], -1)[..., 0]
    want = jnp.sum((lse - gold) * m) / jnp.sum(m)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
