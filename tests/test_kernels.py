"""Kernel backends vs the pure-jnp oracle: shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels import ops, ref
from repro.kernels.zo_axpy import zo_axpy_2d


@pytest.mark.parametrize("L,shape", [(1, (7,)), (3, (16,)), (4, (8, 8)),
                                     (6, (5, 3, 4)), (2, (1000,)),
                                     (5, (129,)), (2, (257, 3))])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("backend", ["scan", "gather", "pallas"])
def test_backend_matches_dense(L, shape, dtype, backend):
    k = jax.random.PRNGKey(0)
    theta = jax.random.normal(k, (L,) + shape, jnp.dtype(dtype))
    mask = jnp.asarray(np.random.default_rng(L).random(L) > 0.4)
    if not bool(mask.any()):
        mask = mask.at[0].set(True)
    aidx = jnp.nonzero(mask)[0].astype(jnp.int32)
    want = ops.zo_axpy(theta, path="w", seed=jnp.uint32(3), scale=0.05,
                       decay=0.99, mask=mask, backend="dense")
    got = ops.zo_axpy(theta, path="w", seed=jnp.uint32(3), scale=0.05,
                      decay=0.99, mask=mask, active_idx=aidx, backend=backend)
    tol = 1e-6 if dtype == "float32" else 1e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)
    # dropped rows untouched in every backend
    drop = ~np.asarray(mask)
    assert np.array_equal(np.asarray(got)[drop], np.asarray(theta)[drop])


@pytest.mark.parametrize("n", [64, 100, 65536, 65537])
def test_pallas_tile_boundaries(n):
    theta = jnp.arange(2 * n, dtype=jnp.float32).reshape(2, n)
    mask = jnp.asarray([True, False])
    got = zo_axpy_2d(theta, mask, jnp.uint32(1), jnp.float32(0.1),
                     jnp.float32(1.0))
    want = ref.zo_axpy_2d(theta, mask, jnp.uint32(1), 0.1, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_unstacked_leaf():
    theta = jnp.ones((13, 7))
    out = ops.zo_axpy(theta, path="embed", seed=jnp.uint32(2), scale=0.1)
    assert out.shape == theta.shape
    assert not np.allclose(np.asarray(out), 1.0)


@given(st.integers(0, 2**31), st.floats(-0.1, 0.1), st.floats(0.9, 1.0))
@settings(max_examples=25, deadline=None)
def test_axpy_linear_property(seed, scale, decay):
    """out == decay*theta + scale*z exactly (oracle linearity)."""
    theta = jnp.ones((3, 50))
    mask = jnp.asarray([True, True, False])
    out = np.asarray(ref.zo_axpy_2d(theta, mask, jnp.uint32(seed), scale,
                                    decay))
    z = np.asarray(ref.leaf_normal(jnp.uint32(seed), 3, 50))
    want = decay * 1.0 + scale * z
    want[2] = 1.0
    np.testing.assert_allclose(out, want.astype(np.float32), atol=1e-6)
