"""Bench trajectory aggregation (benchmarks/run.py).

Regression: artifact collection used to anchor on ``Path.cwd()``, so
``run.py --json`` invoked from anywhere but the repo root silently
emitted an empty ``[]`` trajectory while exiting zero — the CI gate
gated nothing.  Collection is now anchored on the repo root (cwd kept
as a fallback for locally-run scripts) and ``--check`` refuses an empty
trajectory outright.
"""
import json
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks import run as brun  # noqa: E402

CHECKED_IN = {"BENCH_fused.json", "BENCH_serving.json", "BENCH_step.json"}


def test_collects_checked_in_artifacts_from_repo_root():
    arts = brun.collect_artifacts(brun.REPO_ROOT)
    assert CHECKED_IN <= set(arts)
    for name in CHECKED_IN:
        assert "error" not in arts[name], arts[name]
        assert arts[name].get("bench"), name


def test_trajectory_nonempty_regardless_of_cwd(tmp_path, monkeypatch):
    """--collect-only --json from a foreign cwd still aggregates the
    repo's artifacts (the original bug: empty trajectory, exit 0)."""
    monkeypatch.chdir(tmp_path)
    out = tmp_path / "agg.json"
    monkeypatch.setattr(sys, "argv",
                        ["run.py", "--collect-only", "--check",
                         "--json", str(out)])
    brun.main()
    payload = json.loads(out.read_text())
    assert CHECKED_IN <= set(payload["trajectory"])
    assert CHECKED_IN <= set(payload["artifacts"])


def test_collect_skips_aggregates_and_reports_unreadable(tmp_path):
    (tmp_path / "BENCH_a.json").write_text(json.dumps({"bench": "a"}))
    (tmp_path / "BENCH_all.json").write_text(json.dumps({"bench": "all"}))
    (tmp_path / "BENCH_bad.json").write_text("{not json")
    arts = brun.collect_artifacts(tmp_path)
    assert set(arts) == {"BENCH_a.json", "BENCH_bad.json"}
    assert "error" in arts["BENCH_bad.json"]
    excl = brun.collect_artifacts(tmp_path, exclude=tmp_path / "BENCH_a.json")
    assert "BENCH_a.json" not in excl


def test_check_fails_on_tripwire_and_empty_trajectory(tmp_path, monkeypatch):
    bad = {"bench": "x", "tripwires": {"t": {"ok": False, "value": 1,
                                             "limit": 0}}}
    assert brun.tripwire_failures({"BENCH_x.json": bad}) == [
        ("BENCH_x.json", "t", bad["tripwires"]["t"])]
    # a failed tripwire in the collected set exits nonzero
    (tmp_path / "BENCH_x.json").write_text(json.dumps(bad))
    monkeypatch.chdir(tmp_path)
    monkeypatch.setattr(brun, "REPO_ROOT", tmp_path)
    monkeypatch.setattr(sys, "argv", ["run.py", "--collect-only", "--check"])
    with pytest.raises(SystemExit, match="tripwires failed"):
        brun.main()
    # an empty trajectory is itself a gate failure, not a silent pass
    empty = tmp_path / "empty"
    empty.mkdir()
    monkeypatch.chdir(empty)
    monkeypatch.setattr(brun, "REPO_ROOT", empty)
    with pytest.raises(SystemExit, match="gates nothing"):
        brun.main()


def test_fused_tripwires_require_exact_halving():
    from benchmarks import fused_forward as ff
    good = {"paired": {"w_tile_loads": 10, "z_regens": 10},
            "unpaired": {"w_tile_loads": 20, "z_regens": 20}}
    tw = ff.build_tripwires(good)
    assert set(tw) == {"paired_w_tile_loads_halved", "paired_z_regens_halved"}
    assert all(rec["ok"] for rec in tw.values())
    for broken in ({"paired": {"w_tile_loads": 10, "z_regens": 10},
                    "unpaired": {"w_tile_loads": 19, "z_regens": 20}},
                   {"paired": {"w_tile_loads": 0, "z_regens": 0},
                    "unpaired": {"w_tile_loads": 0, "z_regens": 0}}):
        assert not all(r["ok"] for r in ff.build_tripwires(broken).values())


def test_checked_in_fused_artifact_carries_passing_tripwires():
    """The committed BENCH_fused.json must itself satisfy the halving
    tripwires run.py gates on — a stale artifact fails here, not in CI
    archaeology."""
    payload = json.loads((REPO / "BENCH_fused.json").read_text())
    tw = payload.get("tripwires", {})
    assert {"paired_w_tile_loads_halved", "paired_z_regens_halved"} <= set(tw)
    assert all(rec["ok"] for rec in tw.values()), tw
    s = payload["structural"]
    assert 2 * s["paired"]["w_tile_loads"] == s["unpaired"]["w_tile_loads"]
