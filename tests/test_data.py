"""Synthetic data pipeline invariants."""
import numpy as np

from repro.data import synthetic


def test_deterministic():
    t = synthetic.TaskConfig(seed=3)
    a = synthetic.make_dataset(t, 32)
    b = synthetic.make_dataset(t, 32)
    for k in a:
        assert np.array_equal(a[k], b[k])


def test_label_alignment_classification():
    t = synthetic.TaskConfig(n_classes=3)
    d = synthetic.make_dataset(t, 64)
    # answer position: loss_mask marks exactly one position per row
    assert (d["loss_mask"].sum(1) == 1).all()
    pos = d["loss_mask"].argmax(1)
    verb = t.verbalizers
    for i in range(64):
        assert d["labels"][i, pos[i]] == verb[d["class_labels"][i]]


def test_generation_copies_span():
    t = synthetic.TaskConfig(kind="generation", answer_len=6, seq_len=64)
    d = synthetic.make_dataset(t, 16)
    assert (d["loss_mask"].sum(1) == 6).all()  # one per answer token


def test_batches_shapes():
    t = synthetic.TaskConfig()
    d = synthetic.make_dataset(t, 50)
    bs = list(synthetic.batches(d, 8, 3))
    assert len(bs) == 3
    assert bs[0]["tokens"].shape == (8, t.seq_len - 1)
