"""Sharding rules + analysis unit tests (mesh-free where possible; a
subprocess runs a real 64-device dry-run cell)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import ctx, sharding
from repro.launch import specs
from repro.launch.analysis import HloCost


class FakeMesh:
    """Shape-only stand-in so rules are testable without devices."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_attention_rules():
    cfg = configs.get("internlm2-1.8b")
    assert sharding.param_pspec(cfg, "stages/s0/b0/mix/wq", (24, 2048, 2048),
                                MESH) == P(None, None, "model")
    assert sharding.param_pspec(cfg, "stages/s0/b0/mix/wo", (24, 2048, 2048),
                                MESH) == P(None, "model", None)
    assert sharding.param_pspec(cfg, "stages/s0/b0/mix/norm/scale", (24, 2048),
                                MESH) == P(None, None)


def test_vocab_sharding():
    cfg = configs.get("internlm2-1.8b")
    assert sharding.param_pspec(cfg, "embed/tok", (92544, 2048), MESH) \
        == P("model", None)
    assert sharding.param_pspec(cfg, "head/w", (2048, 92544), MESH) \
        == P(None, "model")


def test_indivisible_falls_back_to_replication():
    cfg = configs.get("internlm2-1.8b")
    # 7 doesn't divide by 16
    assert sharding.param_pspec(cfg, "stages/s0/b0/mix/wq", (24, 2048, 7),
                                MESH) == P(None, None, None)


def test_lstm_blocks_replicated():
    cfg = configs.get("xlstm-350m")
    assert sharding.param_pspec(cfg, "stages/s0/b0/mix/wq", (3, 2048, 2048),
                                MESH) == P(None, None, None)


def test_cache_seq_sharding():
    spec = sharding.cache_pspec("s0/b0/k", (24, 128, 32768, 8, 128), MESH)
    assert spec == P(None, ("data",), "model", None, None)
    # batch=1 long-context: batch dim replicated
    spec = sharding.cache_pspec("s0/b0/ckv", (26, 1, 524288, 512), MP)
    assert spec[1] is None and spec[2] == "model"


def test_batch_pspec_fallback():
    assert sharding.data_pspec((256, 4096), MESH) == P(("data",), None)
    assert sharding.data_pspec((1, 4096), MESH) == P(None, None)
    assert sharding.data_pspec((256, 4096), MP) == P(("pod", "data"), None)


def test_constrain_noop_without_mesh():
    ctx.set_mesh(None)
    x = jnp.ones((4, 4))
    assert ctx.constrain(x, "batch", "model") is x


def test_hlo_cost_scan_multiplier():
    W = jnp.zeros((8, 64, 64))

    def f(x):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, W)[0]
    txt = jax.jit(f).lower(jnp.zeros((4, 64))).compile().as_text()
    c = HloCost(txt).total()
    assert abs(c.flops - 2 * 4 * 64 * 64 * 8) / (2 * 4 * 64 * 64 * 8) < 0.01


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """Real lower+compile of one small arch cell on a 64-device host mesh
    (subprocess so the device-count env doesn't leak into this process)."""
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=64';"
        "import sys; sys.path.insert(0, 'src');"
        "import jax, jax.numpy as jnp;"
        "from repro.launch import specs;"
        "from repro import configs;"
        "from repro.configs.shapes import SHAPES;"
        "cfg = configs.get('granite-moe-1b-a400m');"
        "mesh = jax.make_mesh((8, 8), ('data', 'model'));"
        "sf, ps = specs.build_train_step(cfg, mesh, 'optimized');"
        "ins = specs.input_specs(cfg, SHAPES['train_4k']);"
        "f = sf(ins['batch']);"
        "l = f.lower(ps, ins['batch'], jax.ShapeDtypeStruct((), jnp.int32),"
        "            jax.ShapeDtypeStruct((), jnp.uint32));"
        "c = l.compile();"
        "assert c.memory_analysis() is not None;"
        "print('OK')"
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".", timeout=500)
    assert "OK" in r.stdout, r.stderr[-2000:]
