"""Stateful property suite for the refcounted KVPool (DESIGN.md §12).

Random alloc/share/incref/decref/COW/free sequences are interpreted
against the pool while a *shadow model* tracks every reference the test
holds (a page appears in ``held`` once per reference).  After every
single operation the suite asserts:

  * ``check_invariants()`` never throws,
  * ``available + in_use`` equals the usable page count,
  * no page is simultaneously free and referenced,
  * each allocated page's refcount equals the shadow model's count
    (refcounts >= 1, never negative),

and a full drain at the end returns every page.

Two drivers share one interpreter: a hypothesis ``@given`` (via the
optional-dependency shim in tests/_hyp.py) and a pure-random seeded
fallback loop that runs regardless — the invariants stay machine-checked
even in containers without hypothesis.
"""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.serving import KVPool, PoolExhausted, TRASH_PAGE

ALLOC, INCREF, DECREF, COW, FREE = range(5)


def _check(pool: KVPool, held):
    pool.check_invariants()
    assert pool.available + pool.in_use == pool.n_pages - 1
    counts = {}
    for p in held:
        counts[p] = counts.get(p, 0) + 1
    assert pool.in_use == len(counts)
    for p, n in counts.items():
        assert p != TRASH_PAGE
        assert pool.refcount(p) == n, \
            f"page {p}: pool says rc={pool.refcount(p)}, model says {n}"


def _interpret(pool: KVPool, ops):
    """Run (op, a) pairs against ``pool``; ``held`` is the shadow
    reference multiset (one entry per reference this test owns)."""
    held = []
    for op, a in ops:
        if op == ALLOC:
            n = a % 6
            try:
                held.extend(pool.alloc(n))
            except PoolExhausted:
                assert n > pool.available
        elif op == INCREF and held:
            p = held[a % len(held)]
            pool.incref(p)
            held.append(p)
        elif op == DECREF and held:
            p = held.pop(a % len(held))
            freed = pool.decref(p)
            assert freed == (p not in held)
        elif op == COW and held:
            i = a % len(held)
            p = held[i]
            try:
                q, copied = pool.cow(p)
            except PoolExhausted:
                assert pool.available == 0 and pool.refcount(p) > 1
            else:
                assert copied == (q != p)
                held[i] = q
        elif op == FREE and held:
            k = 1 + a % min(4, len(held))
            batch, held = held[:k], held[k:]
            pool.free(batch)
        _check(pool, held)
    # drain: every reference dropped returns every page to the free list
    pool.free(held)
    _check(pool, [])
    assert pool.in_use == 0 and pool.available == pool.n_pages - 1


@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 10 ** 6)),
                max_size=120),
       st.integers(2, 24))
@settings(max_examples=80, deadline=None)
def test_pool_refcount_trace_hypothesis(ops, n_pages):
    _interpret(KVPool(n_pages=n_pages, page_size=4), ops)


@pytest.mark.parametrize("seed", range(8))
def test_pool_refcount_trace_random_fallback(seed):
    """The same interpreter on seeded numpy traces — runs with or
    without hypothesis installed."""
    rng = np.random.default_rng(seed)
    n_pages = int(rng.integers(2, 25))
    ops = [(int(rng.integers(0, 5)), int(rng.integers(0, 10 ** 6)))
           for _ in range(200)]
    _interpret(KVPool(n_pages=n_pages, page_size=4), ops)


# ------------------------------------------------- targeted error paths
def test_incref_decref_cow_of_unallocated_raise():
    pool = KVPool(n_pages=6, page_size=4)
    (p,) = pool.alloc(1)
    pool.free([p])
    with pytest.raises(ValueError, match="double-free|foreign"):
        pool.decref(p)
    with pytest.raises(ValueError, match="incref of unallocated"):
        pool.incref(p)
    with pytest.raises(ValueError, match="cow of unallocated"):
        pool.cow(p)
    with pytest.raises(ValueError, match="incref of unallocated"):
        pool.incref(TRASH_PAGE)
    pool.check_invariants()


def test_cow_semantics():
    pool = KVPool(n_pages=6, page_size=4)
    (p,) = pool.alloc(1)
    assert pool.cow(p) == (p, False)          # sole owner writes in place
    pool.incref(p)                            # now shared
    q, copied = pool.cow(p)
    assert copied and q != p
    assert pool.refcount(p) == 1 and pool.refcount(q) == 1
    pool.free([p, q])
    pool.check_invariants()


def test_cow_exhausted_leaves_pool_untouched():
    pool = KVPool(n_pages=3, page_size=4)
    a, b = pool.alloc(2)                      # pool now empty
    pool.incref(a)
    with pytest.raises(PoolExhausted):
        pool.cow(a)
    assert pool.refcount(a) == 2 and pool.refcount(b) == 1
    pool.decref(a)
    pool.free([a, b])
    pool.check_invariants()
