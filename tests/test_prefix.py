"""Prefix-trie regression suite (DESIGN.md §12, serving/prefix.py).

Host-side only (no jax): insert/lookup, the partial-page boundary,
token-exact matching (no hash-collision false shares), LRU leaf-first
eviction under pool pressure, and a zipfian-prompt workload through the
scheduler asserting page hits occur only on true token-prefix matches.
"""
import numpy as np
import pytest

from repro.serving import KVPool, PrefixTrie, Request, Scheduler

PS = 4


def _trie(n_pages=64):
    pool = KVPool(n_pages=n_pages, page_size=PS)
    return pool, PrefixTrie(pool)


# ------------------------------------------------------- insert / lookup
def test_insert_then_match_returns_same_pages():
    pool, trie = _trie()
    pages = pool.alloc(3)
    trie.insert(list(range(12)), pages)
    assert [n.page for n in trie.match(list(range(12)))] == pages
    # a longer prompt with the same prefix matches the full chain
    assert [n.page for n in trie.match(list(range(12)) + [9, 9])] == pages
    trie.check_invariants()
    pool.check_invariants()


def test_match_splits_at_partial_page():
    """Only *full* pages share: the 10-token prompt contributes 2 trie
    nodes, and a lookup diverging inside page 2 still matches them."""
    pool, trie = _trie()
    prompt = list(range(10))                 # 2 full pages + 2 spare slots
    pages = pool.alloc(3)                    # lane owns 3, trie takes 2
    trie.insert(prompt, pages)
    assert trie.n_nodes == 2
    assert pool.refcount(pages[0]) == 2 and pool.refcount(pages[1]) == 2
    assert pool.refcount(pages[2]) == 1      # partial tail page never shared
    assert [n.page for n in trie.match(prompt)] == pages[:2]
    # divergence mid-page-2 (token 9 != 99): page 2 must not be offered
    assert [n.page for n in trie.match(list(range(9)) + [99])] == pages[:2]
    # divergence mid-page-1 kills the whole second edge
    assert [n.page for n in trie.match([0, 1, 2, 3, 4, 99, 6, 7])] \
        == pages[:1]
    trie.check_invariants()


def test_no_false_share_on_non_prefix():
    """Matching is token-exact (dict keyed by the token tuple): a match
    can only ever return nodes whose concatenated tokens are a true
    prefix of the query — there is no hash-only comparison to collide."""
    pool, trie = _trie()
    a, b = pool.alloc(2), pool.alloc(2)
    trie.insert([1, 2, 3, 4, 5, 6, 7, 8], a)
    trie.insert([1, 2, 3, 4, 9, 9, 9, 9], b[:1] + b[1:])
    # shared first page: second insert reuses the existing node
    assert trie.n_nodes == 3
    for query in ([8, 7, 6, 5], [1, 2, 3, 9], [2, 3, 4, 5, 6, 7, 8, 9]):
        path = trie.match(query)
        got = [t for n in path for t in n.tokens]
        assert got == query[:len(got)], \
            f"false share: {got} is not a prefix of {query}"
    assert trie.match([1, 2, 3, 4, 5, 6, 7, 8])[-1].page == a[1]
    assert trie.match([1, 2, 3, 4, 9, 9, 9, 9])[-1].page == b[1]
    trie.check_invariants()


def test_insert_keeps_first_writer_on_duplicate():
    """Two lanes racing the same prompt: the second insert must not
    replace the first chain's pages (peers may already read them)."""
    pool, trie = _trie()
    a, b = pool.alloc(2), pool.alloc(2)
    trie.insert(list(range(8)), a)
    trie.insert(list(range(8)), b)
    assert [n.page for n in trie.match(list(range(8)))] == a
    assert pool.refcount(b[0]) == 1 and pool.refcount(b[1]) == 1
    trie.check_invariants()


# ------------------------------------------------------------- eviction
def test_evict_dead_leaves_first_lru():
    pool, trie = _trie()
    old = pool.alloc(3)
    trie.insert(list(range(12)), old)
    young = pool.alloc(2)
    trie.insert([50, 51, 52, 53, 54, 55, 56, 57], young)
    pool.free(old)
    pool.free(young)                         # both chains now trie-only
    # deepest + least-recently-used leaf goes first: old chain's tail
    assert trie.reclaimable() == 5
    assert trie.evict(1) == [old[2]]
    # a fresh match refreshes the old chain; the young chain now ages out
    trie.match(list(range(8)))
    assert trie.evict(1) == [young[1]]
    assert trie.evict(10) == [young[0], old[1], old[0]]
    assert trie.n_nodes == 0 and pool.in_use == 0
    trie.check_invariants()
    pool.check_invariants()


def test_evict_spares_live_and_kept_nodes():
    pool, trie = _trie()
    live = pool.alloc(2)                     # a lane still references these
    trie.insert(list(range(8)), live)
    dead = pool.alloc(1)
    trie.insert([9, 9, 9, 9], dead)
    pool.free(dead)
    path = trie.match(list(range(8)))
    keep = frozenset(id(n) for n in path)
    # live chain (rc 2) is not reclaimable; dead one is unless kept
    assert trie.reclaimable() == 1
    assert trie.reclaimable(keep=frozenset(id(n) for n in
                                           trie.match([9, 9, 9, 9]))) == 0
    assert trie.evict(5, keep=keep) == dead
    assert [n.page for n in trie.match(list(range(8)))] == live
    trie.check_invariants()


def test_eviction_under_pool_pressure_via_scheduler():
    """Satellite regression (ISSUE 10): a pool whose free pages all sit
    in dead trie chains must evict and admit, not raise/refuse."""
    s = Scheduler(KVPool(n_pages=9, page_size=PS), max_lanes=2,
                  prefill_chunk=8, max_seq=32, prefix_cache=True)
    rng = np.random.default_rng(0)
    # two dead 16-token prompts fill all 8 usable pages with trie-only
    # references (register, then drop the lane's share)
    for base in (100, 200):
        pages = s.pool.alloc(4)
        s.trie.insert(list(range(base, base + 16)), pages)
        s.pool.free(pages)
    assert s.pool.available == 0 and s.trie.reclaimable() == 8
    # a non-matching request needs 4 fresh pages: dead chains must go
    s.submit(Request(rid=99, tokens=[1, 2, 3, 4, 5, 6, 7, 8],
                     max_new_tokens=8))
    i = s.try_admit()
    assert i is not None, "full-of-dead-prefixes pool refused admission"
    assert s.trie_evictions >= 4
    s.pool.check_invariants()
    s.trie.check_invariants()
    s.finish(i)


# ------------------------------------------------------ zipfian workload
def test_zipfian_prompts_hit_only_true_prefixes():
    """Zipf-distributed traffic over a small prompt population: the hit
    rate is positive, and every page attached shared corresponds to a
    true token-prefix of the admitted prompt."""
    s = Scheduler(KVPool(n_pages=257, page_size=PS), max_lanes=4,
                  prefill_chunk=8, max_seq=64, prefix_cache=True)
    rng = np.random.default_rng(7)
    population = [rng.integers(0, 1000, int(rng.integers(8, 25))).tolist()
                  for _ in range(6)]
    ranks = np.minimum(rng.zipf(1.5, size=60) - 1, len(population) - 1)
    seen = set()
    for rid, k in enumerate(ranks):
        prompt = population[int(k)]
        s.submit(Request(rid=rid, tokens=prompt, max_new_tokens=4))
        i = s.try_admit()
        assert i is not None
        lane = s.lanes[i]
        n_shared = len(lane.shared_idx)
        if int(k) not in seen:
            assert n_shared == 0, "hit on a never-seen prompt"
        seen.add(int(k))
        # every attached page's trie tokens must prefix the prompt
        path = s.trie.match(prompt)
        got = [t for n in path[:n_shared] for t in n.tokens]
        assert got == prompt[:len(got)]
        s.register_prefix(lane)
        s.finish(i)
        s.pool.check_invariants()
        s.trie.check_invariants()
    assert s.page_hit_rate > 0.0
    assert s.prefix_hits > 0 and s.prefix_lookups > s.prefix_hits
