"""End-to-end data-parallel ZO training on a real multi-device mesh.

Runs in a subprocess with 8 host devices: trains the same tiny model
(same seeds) on a 1-device setup and on a (4 data x 2 model) mesh and
asserts the loss trajectories match — the distributed LeZO step is
*semantically identical* to the single-device one (z is seed-derived per
element, losses all-reduce inside the jit).  This is the runnability
proof for the DP story: the only cross-replica values are scalars.
"""
import subprocess
import sys

import pytest

_CODE = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys
sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import opt
from repro.core import zo, rng
from repro.data import synthetic
from repro.distributed import ctx, sharding
from repro.models import lm

mcfg = opt.opt_tiny(layers=2, d_model=64, vocab=256)
task = synthetic.TaskConfig(vocab=256, seq_len=48, n_classes=2)
data = synthetic.make_dataset(task, 256)
params = lm.init_params(mcfg, jax.random.PRNGKey(0))
spec = zo.build_spec(params, lm.zo_group_fn)
zcfg = zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=1, backend='gather')
loss_fn = lambda p, b: lm.lm_loss(mcfg, p, b)
base_seed = jnp.uint32(rng.fold_py(0, 0xC0FFEE))

def run(mesh):
    if mesh is not None:
        ctx.set_mesh(mesh)
        p_sh = sharding.params_sharding(mcfg, params, mesh)
        scal = NamedSharding(mesh, P())
        step = zo.make_zo_step(loss_fn, spec, zcfg)
        bshape = {k: jnp.asarray(v[:16]) for k, v in data.items()
                  if k != 'class_labels'}
        b_sh = sharding.batch_sharding(bshape, mesh)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh, scal, scal),
                     out_shardings=(p_sh, None))
        p = jax.device_put(params, p_sh)
    else:
        ctx.set_mesh(None)
        fn = jax.jit(zo.make_zo_step(loss_fn, spec, zcfg))
        p = params
    losses = []
    for t, batch in enumerate(synthetic.batches(data, 16, 12, seed=7)):
        b = {k: jnp.asarray(v) for k, v in batch.items()
             if k != 'class_labels'}
        if mesh is not None:
            b = jax.device_put(b, sharding.batch_sharding(b, mesh))
        p, m = fn(p, b, jnp.int32(t), base_seed)
        losses.append(float(m['loss']))
    return losses, jax.tree.map(np.asarray, p)

l1, p1 = run(None)
mesh = jax.make_mesh((4, 2), ('data', 'model'))
l2, p2 = run(mesh)
d_loss = max(abs(a - b) for a, b in zip(l1, l2))
d_par = max(float(np.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
print(f'loss_diff={d_loss:.2e} param_diff={d_par:.2e}')
assert d_loss < 1e-4, (l1, l2)
assert d_par < 1e-4
print('OK')
"""


@pytest.mark.slow
def test_dp_tp_training_matches_single_device():
    r = subprocess.run([sys.executable, "-c", _CODE], capture_output=True,
                       text=True, cwd=".", timeout=500)
    assert "OK" in r.stdout, (r.stdout[-1000:], r.stderr[-2000:])
