"""LoRA / prefix structural correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import opt
from repro.core import zo
from repro.models import lm
from repro.peft import lora, prefix

MCFG = opt.opt_tiny(layers=2, d_model=64, vocab=128)


def test_lora_zero_init_is_identity():
    params = lm.init_params(MCFG, jax.random.PRNGKey(0))
    lcfg = lora.LoRAConfig(rank=4, targets=("wq", "wv"))
    lt = lora.init_lora(params, lcfg, jax.random.PRNGKey(1))
    merged = lora.merge(params, lt, lcfg)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))  # B=0 => W+0


def test_lora_nonzero_changes_targets_only():
    params = lm.init_params(MCFG, jax.random.PRNGKey(0))
    lcfg = lora.LoRAConfig(rank=4, targets=("wq",))
    lt = lora.init_lora(params, lcfg, jax.random.PRNGKey(1))
    lt = jax.tree.map(lambda x: x + 0.1, lt)
    merged = lora.merge(params, lt, lcfg)
    for si in range(len(MCFG.stages)):
        blk = merged["stages"][f"s{si}"]["b0"]["mix"]
        base = params["stages"][f"s{si}"]["b0"]["mix"]
        assert not np.allclose(np.asarray(blk["wq"]), np.asarray(base["wq"]))
        assert np.array_equal(np.asarray(blk["wk"]), np.asarray(base["wk"]))


def test_lora_zo_spec_groups():
    params = lm.init_params(MCFG, jax.random.PRNGKey(0))
    lt = lora.init_lora(params, lora.LoRAConfig(), jax.random.PRNGKey(1))
    spec = zo.build_spec(lt, lora.lora_group_fn)
    assert spec.num_layers == MCFG.num_layers


def test_prefix_changes_forward():
    params = lm.init_params(MCFG, jax.random.PRNGKey(0))
    pt = prefix.init_prefix(MCFG, jax.random.PRNGKey(1))
    injected = prefix.inject(params, pt)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (2, 16)),
                       jnp.int32)
    h0, _, _ = lm.forward(MCFG, params, toks, mode="train")
    h1, _, _ = lm.forward(MCFG, injected, toks, mode="train")
    assert float(jnp.abs(h0 - h1).max()) > 1e-6


def test_prefix_does_not_mutate_base():
    params = lm.init_params(MCFG, jax.random.PRNGKey(0))
    snapshot = jax.tree.map(lambda x: np.asarray(x).copy(), params)
    pt = prefix.init_prefix(MCFG, jax.random.PRNGKey(1))
    prefix.inject(params, pt)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(snapshot)):
        assert np.array_equal(np.asarray(a), b)
