"""End-to-end training behaviour: convergence, PEFT, quorum, FO baseline."""
import dataclasses

import numpy as np
import pytest

from repro.configs import opt
from repro.core import fo, zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig

MCFG = opt.opt_tiny(layers=2, d_model=64, vocab=256)
TASK = synthetic.TaskConfig(vocab=256, seq_len=48, n_classes=2,
                            signal_rate=0.35)


def test_lezo_converges():
    tr = Trainer(MCFG, TASK,
                 TrainConfig(steps=200, batch_size=16, eval_every=0,
                             log_every=50),
                 zo_cfg=zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=1,
                                    backend="scan"))
    h = tr.train()
    assert h["loss"][-1] < h["loss"][0] - 0.5


def test_lezo_tracks_mezo():
    """LeZO per-step progress is comparable to MeZO (paper claim)."""
    res = {}
    for name, nd in [("mezo", 0), ("lezo", 1)]:
        tr = Trainer(MCFG, TASK,
                     TrainConfig(steps=150, batch_size=16, eval_every=0,
                                 log_every=149),
                     zo_cfg=zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=nd))
        res[name] = tr.train()["loss"][-1]
    assert res["lezo"] < res["mezo"] + 0.5


def test_fo_baseline_converges():
    tr = Trainer(MCFG, TASK,
                 TrainConfig(steps=60, batch_size=16, eval_every=0,
                             log_every=20, mode="fo"),
                 fo_cfg=fo.FOConfig(lr=3e-4))
    h = tr.train()
    assert h["loss"][-1] < h["loss"][0]


@pytest.mark.parametrize("peft", ["lora", "prefix"])
def test_peft_runs_and_moves_loss(peft):
    tr = Trainer(MCFG, TASK,
                 TrainConfig(steps=40, batch_size=8, eval_every=0,
                             log_every=39, peft=peft),
                 zo_cfg=zo.ZOConfig(eps=1e-2, lr=1e-3, n_drop=1))
    h = tr.train()
    assert np.isfinite(h["loss"]).all()
    # trainable tree is only PEFT params
    n_trainable = sum(x.size for x in
                      __import__("jax").tree.leaves(tr.trainable))
    n_total = sum(x.size for x in
                  __import__("jax").tree.leaves(tr.base_params))
    assert n_trainable < n_total / 10


def test_quorum_still_converges():
    tr = Trainer(MCFG, TASK,
                 TrainConfig(steps=200, batch_size=16, eval_every=0,
                             log_every=50, n_loss_shards=4, quorum=0.75),
                 zo_cfg=zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=1))
    h = tr.train()
    assert h["loss"][-1] < h["loss"][0] - 0.3


def test_eval_accuracy_classification():
    tr = Trainer(MCFG, TASK, TrainConfig(steps=1, batch_size=4, eval_every=0,
                                         log_every=0))
    data = synthetic.make_dataset(TASK, 64)
    vl, va = tr.evaluate(tr.trainable, data)
    assert 0.0 <= va <= 1.0 and np.isfinite(vl)


def test_zo_momentum_beats_zo_sgd():
    """Beyond-paper: memory-free ZO-momentum accelerates convergence."""
    res = {}
    for mode in ("zo", "zo_momentum"):
        tr = Trainer(MCFG, TASK,
                     TrainConfig(steps=120, batch_size=16, eval_every=0,
                                 log_every=119, mode=mode),
                     zo_cfg=zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=1,
                                        backend="scan"))
        res[mode] = tr.train()["loss"][-1]
    assert res["zo_momentum"] < res["zo"]
