"""ZO-momentum/Adam (memory-free, regenerated directions) correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng, zo, zo_adaptive
from repro.kernels import ref as kref


def _params():
    k = jax.random.PRNGKey(0)
    return {"embed": jax.random.normal(k, (20, 6)),
            "blocks": {"w": jax.random.normal(jax.random.fold_in(k, 1),
                                              (4, 8, 6))}}


def _spec(p):
    return zo.build_spec(p, lambda s: "blk" if s.startswith("blocks") else None)


def _loss(p, batch):
    return 1e-2 * sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))


def _explicit_reference(params, spec, cfg, steps, base_seed):
    """Momentum with an explicit K-truncated buffer of (g, seed) pairs,
    materializing z via the oracle — the semantics zo_adaptive must match."""
    p = jax.tree.map(lambda x: np.asarray(x, np.float64), params)
    hist = []  # list of (g, step_idx), newest first
    for t in range(steps):
        seed = rng.fold(jnp.uint32(base_seed), jnp.uint32(t))
        masks, idxs, _ = zo.stratified_select(spec, seed, cfg.n_drop)

        def z_tree(seed_t, masks_t):
            out = {}
            leaves, treedef = jax.tree_util.tree_flatten(params)
            zs = []
            for leaf, path, group in zip(leaves, spec.paths, spec.groups):
                lseed = rng.fold(seed_t, jnp.uint32(rng.leaf_uid(path)))
                L = leaf.shape[0] if group is not None else 1
                shape = leaf.shape if group is not None else (1,) + leaf.shape
                z = np.asarray(kref.leaf_normal_nd(lseed, shape),
                               np.float64).reshape(leaf.shape if group
                                                   else leaf.shape)
                if group is not None:
                    m = np.asarray(masks_t[group])
                    z = z * m.reshape((-1,) + (1,) * (leaf.ndim - 1))
                else:
                    z = np.asarray(kref.leaf_normal_nd(
                        lseed, (1,) + leaf.shape), np.float64)[0]
                zs.append(z)
            return jax.tree_util.tree_unflatten(treedef, zs)

        z = z_tree(seed, masks)
        pp = jax.tree.map(lambda a, b: a + cfg.eps * b, p, z)
        lp = float(_loss(pp, None))
        pm = jax.tree.map(lambda a, b: a - cfg.eps * b, p, z)
        lmn = float(_loss(pm, None))
        g = (lp - lmn) / (2 * cfg.eps)
        hist.insert(0, (g, t))
        hist = hist[:cfg.history]
        for j, (gj, tj) in enumerate(hist):
            seed_j = rng.fold(jnp.uint32(base_seed), jnp.uint32(tj))
            masks_j, _, _ = zo.stratified_select(spec, seed_j, cfg.n_drop)
            zj = z_tree(seed_j, masks_j)
            w = cfg.lr * (cfg.beta ** j) * gj
            p = jax.tree.map(lambda a, b: a - w * b, p, zj)
    return p


def test_momentum_matches_explicit_buffer():
    params = _params()
    spec = _spec(params)
    cfg = zo_adaptive.ZOMomentumConfig(eps=1e-3, lr=1e-3, beta=0.8,
                                       history=4, n_drop=1)
    step, init = zo_adaptive.make_zo_momentum_step(_loss, spec, cfg)
    step = jax.jit(step)
    p, st = params, init()
    for t in range(6):
        p, st, m = step(p, st, None, jnp.int32(t), jnp.uint32(5))
    want = _explicit_reference(params, spec, cfg, 6, 5)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a, np.float64), b,
                                   atol=5e-5, rtol=5e-4)


def test_adam_variant_runs_and_scales_lr():
    params = _params()
    spec = _spec(params)
    cfg = zo_adaptive.ZOMomentumConfig(eps=1e-3, lr=1e-3, history=4,
                                       n_drop=1, adam=True)
    step, init = zo_adaptive.make_zo_momentum_step(_loss, spec, cfg)
    step = jax.jit(step)
    p, st = params, init()
    lrs = []
    for t in range(5):
        p, st, m = step(p, st, None, jnp.int32(t), jnp.uint32(9))
        lrs.append(float(m["lr"]))
        assert np.isfinite(float(m["loss"]))
    assert lrs[0] != lrs[-1]  # adaptive scaling active


def test_momentum_converges_quadratic():
    """On a quadratic bowl, momentum-ZO reduces loss."""
    params = {"w": jnp.full((16,), 2.0)}
    spec = zo.build_spec(params, lambda s: None)
    cfg = zo_adaptive.ZOMomentumConfig(eps=1e-3, lr=1e-2, beta=0.9,
                                       history=8, n_drop=0)
    loss = lambda p, b: jnp.mean(p["w"] ** 2)
    step, init = zo_adaptive.make_zo_momentum_step(loss, spec, cfg)
    step = jax.jit(step)
    p, st = params, init()
    l0 = float(loss(p, None))
    for t in range(300):
        p, st, m = step(p, st, None, jnp.int32(t), jnp.uint32(3))
    assert float(loss(p, None)) < 0.5 * l0
