"""Per-assigned-architecture smoke tests: reduced config, one forward +
one LeZO train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import zo
from repro.data import synthetic
from repro.models import frontends, lm


@pytest.mark.parametrize("arch", configs.list_archs())
def test_arch_smoke(arch):
    cfg = configs.get(arch, "smoke")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"labels": toks, "loss_mask": jnp.ones((B, S))}
    if frontends.uses_embeds(cfg):
        batch["embeds"] = frontends.stub_embeddings(cfg, B, S)
    else:
        batch["tokens"] = toks

    # forward shapes + finiteness
    hidden, _, aux = lm.forward(cfg, params, batch.get("tokens"),
                                embeds=batch.get("embeds"), mode="train")
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all())
    logits = lm.logits_fn(cfg, params, hidden[:, -1])
    assert logits.shape == (B, cfg.vocab)

    # one LeZO train step
    spec = zo.build_spec(params, lm.zo_group_fn)
    n_drop = max(1, int(0.5 * spec.num_layers))
    step = jax.jit(zo.make_zo_step(
        lambda p, b: lm.lm_loss(cfg, p, b), spec,
        zo.ZOConfig(n_drop=n_drop, lr=1e-4, backend="gather")))
    p2, metrics = step(params, batch, jnp.int32(0), jnp.uint32(1))
    assert np.isfinite(float(metrics["loss"]))
    assert int(metrics["active_layers"]) == spec.num_layers - n_drop
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)):
        assert bool(jnp.isfinite(a).all())


@pytest.mark.parametrize("arch", ["xlstm-350m", "jamba-v0.1-52b"])
def test_subquadratic_flag(arch):
    assert configs.get(arch).subquadratic


def test_full_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters."""
    c = configs.get("deepseek-coder-33b")
    assert (c.num_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (62, 7168, 56, 8, 19200, 32256)
    c = configs.get("qwen3-14b")
    assert c.qk_norm and c.head_dim == 128 and c.vocab == 151936
    c = configs.get("deepseek-v2-lite-16b")
    assert c.kv_lora == 512 and c.top_k == 6 and c.n_shared_experts == 2
    c = configs.get("jamba-v0.1-52b")
    kinds = [b.kind for b in c.stages[0].pattern]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    ffns = [b.ffn for b in c.stages[0].pattern]
    assert ffns.count("moe") == 4
    c = configs.get("granite-moe-1b-a400m")
    assert c.n_experts == 32 and c.top_k == 8 and c.moe_d_ff == 512
