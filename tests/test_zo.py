"""ZO optimizer invariants: restore identity, fused==unfused, determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import rng, selection, zo


def _params():
    k = jax.random.PRNGKey(0)
    return {"embed": jax.random.normal(k, (40, 8)),
            "blocks": {"w": jax.random.normal(jax.random.fold_in(k, 1),
                                              (6, 16, 8)),
                       "b": jax.random.normal(jax.random.fold_in(k, 2),
                                              (6, 8))}}


def _spec(params):
    return zo.build_spec(params, lambda p: "blk" if p.startswith("blocks")
                         else None)


def _loss(p, batch):
    return 1e-3 * sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))


def test_perturb_restore_identity():
    params = _params()
    spec = _spec(params)
    seed = jnp.uint32(11)
    masks, idxs, _ = zo.stratified_select(spec, seed, 3)
    p = zo.tree_axpy(params, spec, seed, 1e-3, masks, idxs)
    p = zo.tree_axpy(p, spec, seed, -2e-3, masks, idxs)
    p = zo.tree_axpy(p, spec, seed, 1e-3, masks, idxs)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("backend", ["dense", "scan", "gather"])
def test_fused_equals_unfused(backend):
    params = _params()
    spec = _spec(params)
    outs = []
    for fused in (True, False):
        cfg = zo.ZOConfig(n_drop=2, lr=1e-3, backend=backend,
                          fused_update=fused)
        step = jax.jit(zo.make_zo_step(_loss, spec, cfg))
        p, _ = step(params, None, jnp.int32(0), jnp.uint32(7))
        outs.append(p)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_mezo_is_lezo_zero_drop():
    params = _params()
    spec = _spec(params)
    s0 = jax.jit(zo.make_zo_step(_loss, spec, zo.ZOConfig(n_drop=0)))
    p0, m0 = s0(params, None, jnp.int32(1), jnp.uint32(3))
    assert int(m0["active_layers"]) == spec.num_layers
    # every layer moved
    moved = np.asarray(jnp.any(p0["blocks"]["w"] != params["blocks"]["w"],
                               axis=(1, 2)))
    assert moved.all()


def test_dropped_layers_untouched():
    params = _params()
    spec = _spec(params)
    seed = jnp.uint32(5)
    masks, idxs, _ = zo.stratified_select(spec, rng.fold(seed, jnp.uint32(0)),
                                          4)
    cfg = zo.ZOConfig(n_drop=4, lr=1e-2, backend="gather")
    step = jax.jit(zo.make_zo_step(_loss, spec, cfg))
    p, _ = step(params, None, jnp.int32(0), seed)
    m = np.asarray(masks["blk"])
    w_moved = np.asarray(jnp.any(p["blocks"]["w"] != params["blocks"]["w"],
                                 axis=(1, 2)))
    assert np.array_equal(w_moved, m)
    # embed is always-on
    assert bool(jnp.any(p["embed"] != params["embed"]))


def test_step_deterministic_replay():
    params = _params()
    spec = _spec(params)
    cfg = zo.ZOConfig(n_drop=2, lr=1e-3)
    step = jax.jit(zo.make_zo_step(_loss, spec, cfg))
    a, _ = step(params, None, jnp.int32(4), jnp.uint32(9))
    b, _ = step(params, None, jnp.int32(4), jnp.uint32(9))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


@given(st.integers(1, 23), st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_uniform_selection_count(n_drop, seed):
    active = selection.uniform_active(jnp.uint32(seed), 24, n_drop)
    assert int(active.sum()) == 24 - n_drop


@given(st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_selection_coverage(seed):
    """over many steps every layer is active sometimes (full-param tuning)."""
    hits = np.zeros(12, bool)
    for t in range(60):
        s = rng.fold(jnp.uint32(seed), jnp.uint32(t))
        hits |= np.asarray(selection.uniform_active(s, 12, 9))
    assert hits.all()


def test_quota_apportionment():
    params = {"a": {"w": jnp.ones((21, 2))}, "b": {"w": jnp.ones((3, 2))}}
    spec = zo.build_spec(params, lambda p: p.split("/")[0])
    q = spec.quotas(18)
    assert sum(q.values()) == 18
    assert q["a"] <= 20 and q["b"] <= 2


def test_round_robin_policy():
    act0 = selection.round_robin_active(0, 8, 6)
    act1 = selection.round_robin_active(1, 8, 6)
    assert int(act0.sum()) == 2 and int(act1.sum()) == 2
    assert not np.array_equal(np.asarray(act0), np.asarray(act1))


def test_weighted_policy_prefers_heavy():
    w = jnp.asarray([10.0] * 4 + [0.01] * 12)
    counts = np.zeros(16)
    for t in range(200):
        act = selection.weighted_active(jnp.uint32(t), w, 12)
        counts += np.asarray(act)
    assert counts[:4].mean() > counts[4:].mean() * 2
