"""Swarm subsystem suite (DESIGN.md §14).

Fast tier: the wire protocol (framing round trip, byte counters, EOF),
the fixed-order commit reduction (arrival-order invariance, quorum
threshold/reweighting against the in-trainer quorum math), the step
ledger's stale-epoch/stale-step/duplicate handling, the deterministic
chaos schedules, and the spec-validation constraints.

Slow tier: subprocess end-to-end — a 2-worker swarm must commit a
scalar stream AND final parameters bit-identical to the single-process
trainer on the same spec; a chaos-crashed swarm must recover through
the elastic-rejoin path without changing a committed bit; and both
chaos and quorum-degraded runs must pass ``launch replay``.
"""
import dataclasses
import json
import os
import pathlib
import socket

import numpy as np
import pytest

from repro import api
from repro.swarm import chaos as chaos_mod
from repro.swarm import commit, proto

STREAM_KEYS = ("loss", "projected_grad", "seed", "arrived", "shard_losses",
               "active_layers")


# ========================================================== wire protocol
def _conn_pair():
    a, b = socket.socketpair()
    return proto.Conn(a), proto.Conn(b)


def test_proto_roundtrip_and_counters():
    a, b = _conn_pair()
    c = proto.StepContribution(
        run_id="r1", membership_epoch=3, step=7, seed=123456789,
        shard_losses={"0": [4.25, 4.5], "2": [3.75, 4.0]}, worker_id=1)
    cm = proto.StepCommit(step=7, seed=123456789, g=-0.125, loss=4.125,
                          active_layers=2, membership_epoch=3,
                          arrived=[1, 0, 1], ckpt_worker=0)
    a.send(c.to_wire())
    a.send(cm.to_wire())
    got_c = proto.StepContribution.from_wire(b.recv(timeout=5.0))
    got_cm = proto.StepCommit.from_wire(b.recv(timeout=5.0))
    assert got_c == c
    assert got_cm == cm
    # floats survive JSON exactly (repr round trip)
    assert got_cm.g == -0.125 and got_c.shard_losses["2"] == [3.75, 4.0]
    assert a.bytes_sent == b.bytes_recv > 0
    assert b.msgs_recv == 2
    a.close()
    assert b.recv(timeout=5.0) is None      # EOF -> None, not an exception
    b.close()


def test_proto_recv_timeout_preserves_partial_frame():
    a, b = _conn_pair()
    payload = proto.encode({"type": "bye"})
    a.sock.sendall(payload[:3])             # half a length prefix
    with pytest.raises(socket.timeout):
        b.recv(timeout=0.05)
    a.sock.sendall(payload[3:])
    assert b.recv(timeout=5.0) == {"type": "bye"}
    a.close(), b.close()


def test_proto_rejects_unknown_type_and_oversized_frame():
    with pytest.raises(proto.ProtocolError):
        proto.encode({"type": "gossip"})
    a, b = _conn_pair()
    a.sock.sendall(proto._LEN.pack(proto.MAX_FRAME + 1))
    with pytest.raises(proto.ProtocolError):
        b.recv(timeout=5.0)
    a.close(), b.close()


# ===================================================== commit reduction
def test_quorum_count_matches_trainer_formula():
    for n in range(1, 9):
        for q in (0.25, 0.5, 0.75, 0.9, 1.0):
            assert commit.quorum_count(n, q) == max(1, int(round(q * n)))


def test_reduce_losses_fixed_order_left_to_right_f32():
    pairs = [(4.125, 4.0), (3.5, 3.75), (5.0, 4.875)]
    lp, lm, arrived = commit.reduce_losses(pairs)
    f = np.float32
    want_lp = f(0.0)
    for p, _ in pairs:
        want_lp = f(want_lp + f(p))
    assert lp == f(want_lp / f(3.0))
    assert arrived == [1, 1, 1]
    assert lp.dtype == np.float32 and lm.dtype == np.float32


def test_commit_is_arrival_order_invariant():
    """The ledger keys contributions by shard index, so any arrival
    permutation commits the same bits."""
    from repro.swarm.coordinator import StepLedger
    losses = {0: [4.25, 4.0], 1: [3.5, 3.75], 2: [5.0, 4.875],
              3: [4.0, 4.125]}

    def run(order):
        led = StepLedger("r", 0, 99, 1, 4)
        for wid, shard in enumerate(order):
            c = proto.StepContribution(
                run_id="r", membership_epoch=1, step=0, seed=99,
                shard_losses={str(shard): losses[shard]}, worker_id=wid)
            assert led.add(c, 1) == "ok"
        return led.commit(1e-3)

    base = run([0, 1, 2, 3])
    for order in ([3, 2, 1, 0], [1, 3, 0, 2], [2, 0, 3, 1]):
        other = run(order)
        for k in ("l_plus", "l_minus", "loss", "projected_grad"):
            assert np.float32(other[k]) == np.float32(base[k]), (order, k)
        assert other["arrived"] == base["arrived"]


def test_duplicate_contribution_overwrites_bit_identically():
    from repro.swarm.coordinator import StepLedger
    led = StepLedger("r", 0, 99, 1, 2)
    c = proto.StepContribution(run_id="r", membership_epoch=1, step=0,
                               seed=99, shard_losses={"0": [4.0, 4.25]})
    assert led.add(c, 1) == "ok"
    assert led.add(c, 1) == "ok"            # resend after a nudge
    assert led.add(dataclasses.replace(c, shard_losses={"1": [3.5, 3.0]}),
                   1) == "ok"
    scal = led.commit(1e-3)
    assert scal["arrived"] == [1, 1]
    assert scal["l_plus"] == np.float32(np.float32(4.0 + 3.5) / 2)


def test_ledger_rejects_stale_epoch_step_and_foreign_run():
    from repro.swarm.coordinator import StepLedger
    led = StepLedger("r", 5, 99, 3, 2)
    mk = lambda **kw: proto.StepContribution(**{
        "run_id": "r", "membership_epoch": 3, "step": 5, "seed": 99,
        "shard_losses": {"0": [1.0, 2.0]}, **kw})
    assert led.add(mk(membership_epoch=2), 3) == "stale_epoch"
    assert led.add(mk(step=4), 3) == "stale_step"
    assert led.add(mk(run_id="other"), 3) == "run_id"
    assert led.add(mk(shard_losses={"7": [1.0, 2.0]}), 3) == "bad_shard"
    assert led.n_arrived == 0 and sum(led.rejected.values()) == 4
    assert led.add(mk(), 3) == "ok"
    assert led.missing() == [1]


def test_quorum_reweighting_matches_in_trainer_math():
    """Arrived-weighted mean == the trainer quorum_loss formula
    sum(w*l)/sum(w) over the arrived subset."""
    rng_np = np.random.default_rng(0)
    losses = rng_np.uniform(2, 6, size=8).astype(np.float32)
    pairs = [None if i in (2, 5) else (float(losses[i]), float(losses[i]))
             for i in range(8)]
    lp, _, arrived = commit.reduce_losses(pairs)
    arrived_mask = np.asarray(arrived, np.float32)
    want = np.sum(losses * arrived_mask) / np.sum(arrived_mask)
    np.testing.assert_allclose(float(lp), float(want), rtol=1e-6)


def test_commit_refuses_zero_arrived():
    with pytest.raises(ValueError):
        commit.reduce_losses([None, None])


# ================================================================ chaos
def test_chaos_schedule_is_deterministic():
    cfg = chaos_mod.ChaosConfig(seed=7, drop=0.5, delay_ms=3.0,
                                crashes=((1, 4),), partitions=((0, 2, 5),))
    a = chaos_mod.Chaos(cfg, worker_id=1)
    b = chaos_mod.Chaos(cfg, worker_id=1)
    decisions = [(k, t, at) for k in ("contribution", "commit")
                 for t in range(10) for at in range(3)]
    assert ([a.drop(*d) for d in decisions]
            == [b.drop(*d) for d in decisions])
    # a fresh attempt re-rolls the dice: not every attempt is dropped
    dropped = [a.drop("contribution", 3, at) for at in range(16)]
    assert not all(dropped) and any(dropped)
    # different workers get different streams
    c = chaos_mod.Chaos(cfg, worker_id=2)
    assert any(a.drop("contribution", t) != c.drop("contribution", t)
               for t in range(32))


def test_chaos_partition_windows_and_crash_points():
    cfg = chaos_mod.ChaosConfig(seed=0, drop=0.0, delay_ms=0.0,
                                crashes=((1, 4),), partitions=((0, 2, 5),))
    w0 = chaos_mod.Chaos(cfg, worker_id=0)
    w1 = chaos_mod.Chaos(cfg, worker_id=1)
    assert [w0.partitioned(t) for t in range(7)] == [
        False, False, True, True, True, True, False]
    assert not any(w1.partitioned(t) for t in range(7))
    # partition implies both directions drop
    assert w0.drop("contribution", 3) and w0.drop("commit", 3)
    assert w1.crash_point(4) and not w0.crash_point(4)
    assert not w1.crash_point(3)


def test_chaos_parsers_reject_malformed_schedules():
    assert chaos_mod.parse_crashes("1:4,0:9") == ((1, 4), (0, 9))
    assert chaos_mod.parse_partitions("1:3-5") == ((1, 3, 5),)
    for bad in ("1", "1:", "a:4", "1:4:9"):
        with pytest.raises(ValueError):
            chaos_mod.parse_crashes(bad)
    for bad in ("1:3", "1:5-3", "x:1-2"):
        with pytest.raises(ValueError):
            chaos_mod.parse_partitions(bad)


# ============================================================ spec layer
def test_validate_swarm_constraints():
    base = api.preset("swarm-smoke")
    api.validate(base)
    api.validate(api.with_overrides(base, {"swarm.workers": 4}))
    bad = [
        {"swarm.quorum": 1.5},
        {"swarm.quorum": 0.0},
        {"run.batch_size": 5},              # 5 % 2 != 0
        {"optimizer.mode": "fo"},
        {"estimator.name": "one_sided"},
        {"runtime.n_loss_shards": 4},
        {"swarm.chaos_crash": "nope"},
        {"swarm.chaos_partition": "1:9-3"},
        {"swarm.chaos_drop": 1.0},
    ]
    for ov in bad:
        with pytest.raises(api.SpecError):
            api.validate(api.with_overrides(base, ov))
    # workers may not exceed a pinned shard count
    with pytest.raises(api.SpecError):
        api.validate(api.with_overrides(base, {"swarm.n_shards": 2,
                                               "swarm.workers": 4}))


def test_swarm_shards_derivation():
    import importlib
    vmod = importlib.import_module("repro.api.validate")
    base = api.preset("swarm-smoke")
    assert vmod.swarm_active(base)
    assert not vmod.swarm_active(api.preset("tiny-smoke"))
    assert vmod.swarm_shards(base) == 2
    assert vmod.swarm_shards(
        api.with_overrides(base, {"swarm.n_shards": 4})) == 4


# ==================================================== subprocess e2e (slow)
def _rows(runs_root):
    (run_dir,) = [d for d in pathlib.Path(runs_root).iterdir() if d.is_dir()]
    with open(run_dir / "steps.jsonl") as f:
        rows = [json.loads(line) for line in f]
    return run_dir, rows


def _stream(rows):
    return [[r.get(k) for k in STREAM_KEYS] for r in rows]


def _smoke_spec(tmp, **over):
    spec = api.with_overrides(api.preset("swarm-smoke"), {
        "run.steps": 10, "run.ckpt_every": 5,
        "run.ckpt_dir": str(tmp / "ckpt"), **over})
    return dataclasses.replace(
        spec, telemetry=dataclasses.replace(spec.telemetry,
                                            runs_dir=str(tmp / "runs")))


@pytest.mark.slow
def test_two_worker_swarm_bit_identical_to_single_process(tmp_path):
    """Acceptance gate: swarm(2 workers) == single-process trainer on the
    same spec — scalar stream and final parameters, to the bit."""
    jax = pytest.importorskip("jax")
    from repro.checkpoint.manager import CheckpointManager
    from repro.swarm import driver

    sw = _smoke_spec(tmp_path / "sw")
    driver.run_swarm(sw, runs_root=str(tmp_path / "sw" / "runs"))
    _, rows_sw = _rows(tmp_path / "sw" / "runs")

    sp = _smoke_spec(tmp_path / "sp")
    hist = api.run(sp)["history"]
    _, rows_sp = _rows(tmp_path / "sp" / "runs")

    assert _stream(rows_sw) == _stream(rows_sp)

    # the swarm's designated-worker checkpoint holds the same bits the
    # single-process trainer finished with
    ck = CheckpointManager(str(tmp_path / "sw" / "ckpt"))
    params, step, _, _ = ck.restore(hist["final_params"])
    assert step == sw.run.steps
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(hist["final_params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_crash_rejoin_and_replay(tmp_path):
    """One injected hard crash: the epoch bumps, shards reassign, the
    respawned worker rejoins elastically, and not one committed bit
    differs from a calm run.  The recorded run passes launch replay."""
    pytest.importorskip("jax")
    from repro.launch import replay
    from repro.swarm import driver

    calm = _smoke_spec(tmp_path / "calm", **{"run.steps": 30,
                                             "run.ckpt_every": 10})
    driver.run_swarm(calm, runs_root=str(tmp_path / "calm" / "runs"))
    _, rows_calm = _rows(tmp_path / "calm" / "runs")

    chaos = _smoke_spec(tmp_path / "chaos", **{
        "run.steps": 30, "run.ckpt_every": 10,
        "swarm.chaos_crash": "1:3", "swarm.chaos_seed": 7})
    summary = driver.run_swarm(chaos,
                               runs_root=str(tmp_path / "chaos" / "runs"))
    run_dir, rows_chaos = _rows(tmp_path / "chaos" / "runs")

    assert chaos_mod.CRASH_EXIT in summary["worker_exits"]
    assert summary["membership_epochs"] >= 3    # 2 joins + death (+ rejoin)
    assert _stream(rows_chaos) == _stream(rows_calm)
    out = replay.replay_run(str(run_dir))
    assert out["ok"], out


@pytest.mark.slow
def test_quorum_degraded_run_replays(tmp_path):
    """A partitioned worker forces deadline commits from a partial shard
    set; the recorded ``arrived`` mask makes the run replayable anyway."""
    pytest.importorskip("jax")
    from repro.launch import replay
    from repro.swarm import driver

    spec = _smoke_spec(tmp_path, **{
        "swarm.n_shards": 4, "swarm.quorum": 0.5,
        "swarm.step_deadline_s": 1.0,
        "swarm.chaos_seed": 7, "swarm.chaos_partition": "1:2-6"})
    driver.run_swarm(spec, runs_root=str(tmp_path / "runs"))
    run_dir, rows = _rows(tmp_path / "runs")
    degraded = [r for r in rows if 0 in (r.get("arrived") or [])]
    assert degraded, "partition produced no quorum-degraded step"
    for r in degraded:
        assert len(r["shard_losses"]) == sum(r["arrived"])
    out = replay.replay_run(str(run_dir))
    assert out["ok"], out
