"""Tier manifest: node-id patterns auto-marked ``slow`` by conftest.py.

Tier-1 (`make test-fast`, `pytest -m "not slow"`) is the per-push CI
gate and must stay under ~90s on an idle CPU; tier-2 (`make test`) is
everything.  Tests land here when they measure over ~2-3s on the CI
reference box (`pytest --durations=0`) — mostly convergence runs, the
full kernel-backend x estimator matrices, decode-consistency sweeps,
and the heavyweight arch smokes.

Patterns are fnmatch'd against the full node id, so individual
parametrized cases can be tiered while cheap siblings of the same test
stay in tier-1 as representatives (e.g. ``two_point_bit_identical
[False-dense]`` remains fast while the other seven cases are tier-2).
Decorator ``@pytest.mark.slow`` still works and is preferred for tests
that are slow by design (end-to-end training); this manifest exists so
per-case tiering doesn't require rewriting parametrize lists.
"""

SLOW_NODE_PATTERNS = [
    # -- end-to-end convergence / trainer runs
    "tests/test_trainer.py::test_lezo_tracks_mezo",
    "tests/test_trainer.py::test_zo_momentum_beats_zo_sgd",
    "tests/test_trainer.py::test_lezo_converges",
    "tests/test_trainer.py::test_quorum_still_converges",
    "tests/test_trainer.py::test_fo_baseline_converges",
    "tests/test_trainer.py::test_peft_runs_and_moves_loss[*]",
    "tests/test_trainer.py::test_eval_accuracy_classification",
    # -- arch smokes: every zoo config costs 4-40s to lower+run; the opt
    #    stack itself is covered fast by the task/trainer/kernel tests
    "tests/test_archs_smoke.py::test_arch_smoke[*",
    # -- estimator subsystem: full matrices are tier-2; the cheapest
    #    bit-identical case and the dense/bf16 kernel cases stay tier-1
    "tests/test_estimators.py::test_trainer_selects_estimators",
    "tests/test_estimators.py::test_one_sided_q_chunk_equivalent",
    "tests/test_estimators.py::test_backend_matches_dense_per_estimator[one_sided-*",
    "tests/test_estimators.py::test_backend_matches_dense_per_estimator[averaged-*",
    "tests/test_estimators.py::test_backend_matches_dense_per_estimator[importance-1-scan]",
    "tests/test_estimators.py::test_backend_matches_dense_per_estimator[importance-1-gather]",
    "tests/test_estimators.py::test_backend_matches_dense_per_estimator[importance-1-pallas]",
    "tests/test_estimators.py::test_backend_matches_dense_per_estimator[two_point-1-scan]",
    "tests/test_estimators.py::test_backend_matches_dense_per_estimator[two_point-1-gather]",
    "tests/test_estimators.py::test_backend_matches_dense_per_estimator[two_point-1-pallas]",
    "tests/test_estimators.py::test_two_point_bit_identical_to_legacy[True-*",
    "tests/test_estimators.py::test_two_point_bit_identical_to_legacy[False-gather]",
    "tests/test_estimators.py::test_two_point_bit_identical_to_legacy[False-scan]",
    "tests/test_estimators.py::test_averaged_q1_matches_two_point",
    "tests/test_estimators.py::test_dropped_layers_untouched_under_estimators",
    "tests/test_estimators.py::test_one_sided_converges_quadratic",
    # -- distributed / sharding subprocess cells
    "tests/test_sharding.py::test_dryrun_cell_subprocess",
    "tests/test_distributed_train.py::test_dp_tp_training_matches_single_device",
    # -- model stack: decode-consistency sweeps and chunk invariances
    "tests/test_models.py::test_mlstm_chunk_invariance",
    "tests/test_models.py::test_mamba_chunk_invariance",
    "tests/test_models.py::test_flash_key_padding_with_prefix_offset",
    "tests/test_models.py::test_multi_step_decode_matches_train",
    "tests/test_models.py::test_decode_consistency_dense",
    "tests/test_models.py::test_decode_consistency_xlstm",
    "tests/test_models.py::test_decode_consistency_mla",
    "tests/test_models.py::test_decode_consistency_moe_dropless",
    "tests/test_models.py::test_decode_consistency_mamba",
    "tests/test_models.py::test_chunked_ce_matches_dense",
    "tests/test_models.py::test_flash_matches_naive[*",
    "tests/test_moe.py::test_dispatch_matches_dense_oracle",
    "tests/test_moe.py::test_single_token_never_drops",
    "tests/test_moe.py::test_shared_experts_added",
    "tests/test_moe.py::test_capacity_drop_bounded",
    "tests/test_zo_adaptive.py::test_momentum_matches_explicit_buffer",
    "tests/test_peft.py::test_prefix_changes_forward",
    # -- ZO core / kernels: the scan sweeps and the 64Ki boundary tiles;
    #    gather/pallas/dense cases stay tier-1 as backend representatives
    "tests/test_zo.py::test_fused_equals_unfused[*",
    "tests/test_zo.py::test_perturb_restore_identity",
    "tests/test_kernels.py::test_backend_matches_dense[scan-float32-*",
    "tests/test_kernels.py::test_backend_matches_dense[scan-bfloat16-*",
    "tests/test_kernels.py::test_backend_matches_dense[gather-float32-*",
    # ragged/boundary tiles are pinned fast by test_kernels_golden.py
    "tests/test_kernels.py::test_pallas_tile_boundaries[*",
    "tests/test_rng.py::test_layer_ids_subset",
    "tests/test_estimators.py::test_one_sided_bias_quadratic",
    # -- fused virtual-perturbation runtime: the acceptance gates
    #    (test_two_point_virtual_matches_materialized_dense, the zero-write
    #    single-axpy check, the z-consistency contract and the f32 kernel
    #    property cases) stay tier-1; the full-model loss sweeps, the
    #    per-estimator matrices and the bf16/trans kernel grid are tier-2
    "tests/test_fused.py::test_virtual_loss_equals_materialized[*",
    "tests/test_fused.py::"
    "test_two_point_virtual_matches_materialized_dense[virtual_ref]",
    "tests/test_fused.py::test_estimators_virtual_matches_materialized[*",
    "tests/test_fused.py::test_virtual_pallas_loss_close_to_materialized",
    "tests/test_fused.py::test_trainer_virtual_backend_trains",
    "tests/test_fused.py::test_virtual_jaxpr_has_single_param_write",
    "tests/test_fused.py::test_pmatmul_matches_ref[*bfloat16]",
    "tests/test_fused.py::test_pmatmul_matches_ref[True-*",
    # -- paired ±εz probes: tier-1 keeps the cheap representatives (the
    #    eager span+bit-identity step, the RNG-stream property, the
    #    aligned/trans kernel stacks, the probe accessor); the jitted
    #    per-estimator matrix, full-model loss pairs, q-probe stacks and
    #    the disable_jit counter walk are tier-2
    "tests/test_fused.py::test_paired_structural_counters_halve",
    "tests/test_fused.py::test_stacked_probes_bitwise_match_sequential",
    "tests/test_fused.py::test_paired_step_bitwise_matches_unpaired[*",
    "tests/test_fused.py::test_paired_loss_bitwise_matches_two_forwards[*",
    "tests/test_fused.py::test_pmatmul_stack_bitwise_matches_pmatmul[shape1-*",
    "tests/test_flash_kernel.py::test_flash_kernel_matches_ref[float32-True-3-64-32-64-32]",
    "tests/test_flash_kernel.py::test_flash_kernel_matches_model_flash",
    # -- unified experiment spec (repro.api, DESIGN.md §11): the
    #    serialization / validation / CLI-parse tests are milliseconds
    #    and stay tier-1; the canonical two_point-materialized legacy-vs-
    #    spec equivalence case and the train-command e2e stay tier-1 as
    #    representatives, the rest of the matrix and the multi-run
    #    checkpoint/sweep/shim cases are tier-2
    "tests/test_api.py::test_legacy_vs_spec_bit_identical[two_point-virtual_ref]",
    "tests/test_api.py::test_legacy_vs_spec_bit_identical[one_sided-*",
    "tests/test_api.py::test_legacy_vs_spec_bit_identical[averaged-*",
    "tests/test_api.py::test_legacy_vs_spec_bit_identical[importance-*",
    "tests/test_api.py::test_checkpoint_embeds_spec_and_rejects_mismatch",
    "tests/test_api.py::test_legacy_checkpoints_have_no_spec_and_still_resume",
    "tests/test_api.py::test_sweep_returns_structured_results",
    "tests/test_api_cli.py::test_legacy_train_shim_accepts_historical_flags",
    "tests/test_api_cli.py::test_legacy_serve_shim_smoke",
    # -- serving engine (DESIGN.md §12): the greedy bit-identity gate,
    #    the prefill/decode interleave check and the CLI e2e stay tier-1;
    #    the temperature/batch-composition sweep, the rope-arch identity
    #    and the EOS path are tier-2 (each recompiles a fresh engine)
    "tests/test_serving.py::"
    "test_engine_sampling_reproducible_across_batch_composition",
    "tests/test_serving.py::test_engine_bit_identical_on_rope_arch",
    "tests/test_serving.py::test_engine_eos_stops_early_and_frees_pages",
    # -- swarm (tests/test_swarm.py): the subprocess e2e runs carry
    #    @pytest.mark.slow directly (slow by design: each spawns 2-3
    #    worker processes for ~30-40s); the protocol/commit/chaos/spec
    #    property tests all stay tier-1 (<1s total)
]
