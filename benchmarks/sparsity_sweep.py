"""Paper Fig. 3 / Fig. 4: step time and convergence vs sparsity ratio.

Sweeps the dropout number (layers dropped per step); reports per-step
wall time and final training loss at a fixed budget.  Paper: runtime
falls monotonically with sparsity; accuracy holds (and improves) up to
rho=0.75-0.9, collapsing only at rho=1.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_model, emit, make_batch, make_zo_parts, timeit
from repro.configs import opt
from repro.core import zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig


def run():
    rows = []
    cfg, seq = bench_model()
    batch = make_batch(cfg, 16, seq)
    N = cfg.num_layers
    base = None
    for frac in (0.0, 0.25, 0.5, 0.75):
        n_drop = int(frac * N)
        params, _, _, step = make_zo_parts(cfg, n_drop, backend="scan")
        t = timeit(step, params, batch, jnp.int32(0), jnp.uint32(1))
        base = base or t
        rows.append((f"steptime_rho{frac:.2f}", t * 1e6,
                     f"speedup={base / t:.2f}x"))

    mcfg = opt.opt_tiny(layers=4, d_model=128, vocab=512)
    task = synthetic.TaskConfig(vocab=512, seq_len=64, n_classes=2,
                                signal_rate=0.35)
    for n_drop in (0, 1, 2, 3):
        tr = Trainer(mcfg, task,
                     TrainConfig(steps=250, batch_size=16, eval_every=0,
                                 log_every=249),
                     zo_cfg=zo.ZOConfig(eps=1e-3, lr=3e-4, n_drop=n_drop,
                                        backend="scan"))
        h = tr.train()
        rows.append((f"final_loss_drop{n_drop}of4", 0.0,
                     f"{h['loss'][-1]:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
