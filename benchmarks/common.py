"""Shared benchmark utilities."""
from __future__ import annotations

import json
import platform
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import opt  # noqa: E402
from repro.core import zo  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.models import lm  # noqa: E402


def timeit(fn, *args, warmup=2, iters=5):
    """Median wall time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_model(seq=32):
    """A CPU-timeable model whose params/token ratio mirrors the paper's
    short-sequence fine-tuning regime (perturb work ~ forward work).
    The shape is the registry's ``bench`` variant — the same model the
    ``bench-smoke`` spec preset resolves to — so every benchmark suite
    measures one config."""
    return opt.bench(), seq


def make_batch(cfg, batch, seq, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)
    return {"tokens": toks, "labels": toks,
            "loss_mask": jnp.ones((batch, seq), jnp.float32)}


def make_zo_parts(cfg, n_drop, backend="scan", lr=1e-4, eps=1e-3):
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    zcfg = zo.ZOConfig(eps=eps, lr=lr, n_drop=n_drop, backend=backend)
    step = jax.jit(zo.make_zo_step(lambda p, b: lm.lm_loss(cfg, p, b),
                                   spec, zcfg))
    return params, spec, zcfg, step


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


def rows_to_json(rows):
    """The emit() row triples as JSON-ready dicts."""
    return [{"name": n, "us_per_call": float(us), "derived": str(d)}
            for n, us, d in rows]


def write_json(path, payload, spec=None):
    """Write a BENCH_*.json trajectory file with environment metadata.
    ``spec`` (a ``repro.api.Experiment``) is embedded when given, so
    bench artifacts carry the exact experiment they measured."""
    payload = dict(payload)
    if spec is not None:
        from repro import api
        payload["spec"] = api.to_dict(spec)
    payload.setdefault("meta", {})
    payload["meta"].update({
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
    })
    p = Path(path)
    if p.parent != Path("."):
        p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}")
    return payload
