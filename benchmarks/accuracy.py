"""Paper Tables 1/2/3, generalized: the estimator x task accuracy matrix.

Sweeps the task registry (repro/tasks/: SuperGLUE stand-ins, DESIGN.md
§9) against the estimator registry (repro/estimators/) at LeZO sparsity,
plus MeZO (n_drop=0), the FO(AdamW) ceiling, and the zero-shot floor.
The reproducible claim is the ORDERING per task:

    zero-shot  <  ZO estimators (LeZO ~= MeZO)  <=  FO

with per-task metrics following the SuperGLUE protocol (accuracy,
macro-F1 for cb, exact-match for squad_copy).

``--smoke`` shrinks steps/tasks for CI-speed sanity runs.
"""
from __future__ import annotations

import sys

from benchmarks.common import emit
from repro import tasks
from repro.configs import opt
from repro.core import fo, zo
from repro.train.trainer import Trainer, TrainConfig

MCFG = opt.opt_tiny(layers=4, d_model=128, vocab=512)
STEPS = 500
SEQ = 48

# (row label, mode, estimator, q, n_drop)
OPTIMIZERS = (
    ("mezo", "zo", "two_point", 1, 0),
    ("lezo50", "zo", "two_point", 1, 2),
    ("lezo50_one_sided_q4", "zo", "one_sided", 4, 2),
    ("lezo50_averaged_q4", "zo", "averaged", 4, 2),
    ("ft_adamw", "fo", "two_point", 1, 0),
)


def _train(task, mode, estimator, q, n_drop, steps, seed=0):
    zo_steps = steps if mode == "zo" else max(60, steps // 5)
    tcfg = TrainConfig(steps=zo_steps, batch_size=32, eval_every=zo_steps,
                       log_every=0, mode=mode, seed=seed,
                       estimator=estimator, est_q=q)
    tr = Trainer(MCFG, task, tcfg,
                 zo_cfg=zo.ZOConfig(eps=1e-3, lr=1e-3, n_drop=n_drop,
                                    backend="scan"),
                 fo_cfg=fo.FOConfig(lr=5e-4))
    h = tr.train()
    metric = h["val_acc"][-1] if h["val_acc"] else -1.0
    vloss = h["val_loss"][-1] if h["val_loss"] else float("inf")
    return metric, vloss


def run(smoke: bool = False):
    steps = 100 if smoke else STEPS
    names = ("sst2", "copa") if smoke else tasks.names()
    optimizers = OPTIMIZERS[:2] + OPTIMIZERS[-1:] if smoke else OPTIMIZERS
    rows = []
    for tname in names:
        task = tasks.build(tname, vocab=MCFG.vocab, seq_len=SEQ)
        # average the zero-shot floor over a few inits: at tiny d_model a
        # single random init can score far off 1/k through tied-embedding
        # luck, which would misstate the ordering claim
        zs_metrics, zs_losses = [], []
        val = None
        for s in range(3):
            zs = Trainer(MCFG, task, TrainConfig(steps=1, batch_size=4,
                                                 eval_every=0, log_every=0,
                                                 seed=s))
            if val is None:      # val set depends on the task, not the seed
                val = zs.make_dataset(256, seed_shift=1)
            l, m = zs.evaluate(zs.trainable, val)
            zs_losses.append(l)
            zs_metrics.append(m)
        rows.append((f"{tname}_zeroshot", 0.0,
                     f"{task.metric}={sum(zs_metrics) / 3:.3f} "
                     f"loss={sum(zs_losses) / 3:.3f}"))
        for label, mode, est, q, nd in optimizers:
            metric, vl = _train(task, mode, est, q, nd, steps)
            rows.append((f"{tname}_{label}", 0.0,
                         f"{task.metric}={metric:.3f} loss={vl:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
