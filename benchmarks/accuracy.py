"""Paper Tables 1/2/3: MeZO vs LeZO vs FO(AdamW) across task types.

Synthetic stand-ins (see DESIGN.md §8): classification, multiple-choice,
generation.  The reproducible claim is the ORDERING: LeZO >= MeZO on most
tasks at equal step budget, both below/near FO, all above zero-shot.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import opt
from repro.core import fo, zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig

MCFG = opt.opt_tiny(layers=4, d_model=128, vocab=512)
STEPS = 600


def _train(task, mode, n_drop=0, seed=0):
    tcfg = TrainConfig(steps=STEPS if mode == "zo" else 120, batch_size=16,
                       eval_every=STEPS if mode == "zo" else 120,
                       log_every=0, mode=mode, seed=seed)
    tr = Trainer(MCFG, task, tcfg,
                 zo_cfg=zo.ZOConfig(eps=1e-3, lr=5e-4, n_drop=n_drop,
                                    backend="scan"),
                 fo_cfg=fo.FOConfig(lr=5e-4))
    h = tr.train()
    return h["val_acc"][-1] if h["val_acc"] else -1.0, \
        h["val_loss"][-1] if h["val_loss"] else np.inf


def run():
    rows = []
    tasks = {
        "classification": synthetic.TaskConfig(vocab=512, seq_len=64,
                                               n_classes=2, signal_rate=0.35),
        "multiple_choice": synthetic.TaskConfig(kind="multiple_choice",
                                                vocab=512, seq_len=64,
                                                n_classes=4,
                                                signal_rate=0.45),
        "generation": synthetic.TaskConfig(kind="generation", vocab=512,
                                           seq_len=64, answer_len=8),
    }
    for tname, task in tasks.items():
        zs_tr = Trainer(MCFG, task, TrainConfig(steps=1, batch_size=4,
                                                eval_every=0, log_every=0))
        val = synthetic.make_dataset(
            __import__("dataclasses").replace(task, seed=task.seed + 1), 256)
        zs_loss, zs_acc = zs_tr.evaluate(zs_tr.trainable, val)
        rows.append((f"{tname}_zeroshot", 0.0,
                     f"acc={zs_acc:.3f} loss={zs_loss:.3f}"))
        for name, mode, nd in [("mezo", "zo", 0), ("lezo75", "zo", 3),
                               ("ft_adamw", "fo", 0)]:
            acc, vl = _train(task, mode, nd)
            rows.append((f"{tname}_{name}", 0.0,
                         f"acc={acc:.3f} loss={vl:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
