"""Paper Table 4: ZO x PEFT — MeZO/LeZO with LoRA and prefix tuning."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import opt
from repro.core import zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig

MCFG = opt.opt_tiny(layers=4, d_model=128, vocab=512)
TASK = synthetic.TaskConfig(vocab=512, seq_len=64, n_classes=2,
                            signal_rate=0.35)


def run():
    rows = []
    grid = [("mezo_lora", "lora", 0, 1e-3, 1e-2),
            ("lezo_lora", "lora", 2, 1e-3, 1e-2),       # paper: 50% sparse
            ("mezo_prefix", "prefix", 0, 1e-2, 1e-1),
            ("lezo_prefix", "prefix", 3, 1e-2, 1e-1)]   # paper: 75% sparse
    for name, peft, n_drop, lr, eps in grid:
        tr = Trainer(MCFG, TASK,
                     TrainConfig(steps=300, batch_size=16, eval_every=300,
                                 log_every=0, peft=peft),
                     zo_cfg=zo.ZOConfig(eps=eps, lr=lr, n_drop=n_drop,
                                        backend="dense"))
        h = tr.train()
        acc = h["val_acc"][-1] if h["val_acc"] else -1
        vl = h["val_loss"][-1] if h["val_loss"] else -1
        rows.append((name, 0.0, f"acc={acc:.3f} loss={vl:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
