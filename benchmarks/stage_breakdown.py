"""Paper Fig. 2: fraction of ZO step time in forward vs perturb vs update.

The paper measures >50% of MeZO step time in perturbation+updating on
OPT-13B / SST-2 (short sequences).  We time the three stages of our MeZO
step separately (each jit'd standalone) at a params-per-token ratio
mirroring that regime, and report the perturb+update share.

Under ``forward_backend="virtual"`` (repro.fused, DESIGN.md §10) the
perturb sweeps disappear entirely — the probes run against in-kernel-
regenerated weights — so the step is 2 virtual forwards + 1 update sweep
and the perturb+update share collapses to the lone update pass; the
second half of the rows measures exactly that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import bench_model, emit, make_batch, timeit
from repro import fused
from repro.core import rng as zrng
from repro.core import zo
from repro.models import lm


def run():
    cfg, seq = bench_model()
    batch = make_batch(cfg, 16, seq)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    masks = {g: jnp.ones((l,), bool) for g, (_, l) in spec.slices.items()}

    fwd = jax.jit(lambda p, b: lm.lm_loss(cfg, p, b))
    perturb = jax.jit(functools.partial(
        zo.tree_axpy, spec=spec, seed=jnp.uint32(1), scale=1e-3,
        masks=masks, backend="dense"))
    update = jax.jit(functools.partial(
        zo.tree_axpy, spec=spec, seed=jnp.uint32(1), scale=-1e-6,
        masks=masks, backend="dense"))

    t_fwd = timeit(fwd, params, batch)
    t_pert = timeit(perturb, params)
    t_upd = timeit(update, params)
    # one MeZO step = 2 forwards + 3 perturbs (+eps, -2eps, restore) + 1 update
    total = 2 * t_fwd + 3 * t_pert + t_upd
    share = (3 * t_pert + t_upd) / total
    rows = [
        ("stage_forward_x2", 2 * t_fwd * 1e6, f"{2 * t_fwd / total:.1%}"),
        ("stage_perturb_x3", 3 * t_pert * 1e6, f"{3 * t_pert / total:.1%}"),
        ("stage_update_x1", t_upd * 1e6, f"{t_upd / total:.1%}"),
        ("perturb_update_share", (3 * t_pert + t_upd) * 1e6,
         f"{share:.1%} (paper: >50% on OPT-13B/SST-2)"),
    ]

    # --- virtual backend: the perturb sweeps are gone by construction ---
    ctx = fused.make_ctx(jnp.uint32(1), 1e-3, masks, "virtual_ref")
    vfwd = jax.jit(lambda p, b: lm.lm_loss(cfg, p, b, perturb=ctx))
    t_vfwd = timeit(vfwd, params, batch)
    vtotal = 2 * t_vfwd + t_upd          # 2 virtual forwards + 1 update
    vshare = t_upd / vtotal
    rows += [
        ("virtual_forward_x2", 2 * t_vfwd * 1e6,
         f"{2 * t_vfwd / vtotal:.1%} (z regenerated in the forward)"),
        ("virtual_update_x1", t_upd * 1e6, f"{vshare:.1%}"),
        ("virtual_perturb_update_share", t_upd * 1e6,
         f"{vshare:.1%} (vs {share:.1%} materialized; perturb share = 0)"),
        ("virtual_step_speedup", 0.0, f"{total / vtotal:.2f}x"),
    ]
    return emit(rows)


if __name__ == "__main__":
    run()
