"""Beyond-paper ablation: LeZO-SGD vs memory-free LeZO-momentum.

Same budget, same sparsity, same seeds — momentum regenerates its K=8
directions from seeds (state = 8 scalars), so memory parity with MeZO
holds while convergence accelerates substantially.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import opt
from repro.core import zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig

MCFG = opt.opt_tiny(layers=4, d_model=128, vocab=512)
TASK = synthetic.TaskConfig(vocab=512, seq_len=64, n_classes=2,
                            signal_rate=0.35)


def run():
    rows = []
    for mode in ("zo", "zo_momentum"):
        tr = Trainer(MCFG, TASK,
                     TrainConfig(steps=300, batch_size=16, eval_every=300,
                                 log_every=100, mode=mode),
                     zo_cfg=zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=3,
                                        backend="scan"))
        h = tr.train()
        acc = h["val_acc"][-1] if h["val_acc"] else -1
        rows.append((f"lezo75_{mode}", 0.0,
                     f"final_loss={h['loss'][-1]:.3f} val_acc={acc:.3f}"))
    return emit(rows)


if __name__ == "__main__":
    run()
