"""Paper Fig. 1 / Fig. 5: LeZO computation + convergence speedup vs MeZO.

Computation speedup: wall time per full optimization step at 75% layer
sparsity.  Convergence speedup: steps for the train loss to first reach a
target, MeZO / LeZO (paper reports 1.5-3.4x depending on task).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (bench_model, emit, make_batch, make_zo_parts,
                               timeit)
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig
from repro.core import zo
from repro.configs import opt


def run():
    rows = []
    # ---- computation speedup (per-step wall time) -----------------------
    cfg, seq = bench_model()
    batch = make_batch(cfg, 16, seq)
    n = cfg.num_layers
    times = {}
    for name, n_drop in [("mezo", 0), ("lezo75", int(0.75 * n))]:
        params, _, _, step = make_zo_parts(cfg, n_drop, backend="scan")
        times[name] = timeit(step, params, batch, jnp.int32(0), jnp.uint32(1))
        rows.append((f"step_time_{name}", times[name] * 1e6, f"n_drop={n_drop}"))
    rows.append(("computation_speedup", 0.0,
                 f"{times['mezo'] / times['lezo75']:.2f}x (paper: ~1.4-3.4x)"))

    # ---- convergence speedup (steps to target loss) ---------------------
    # Paper protocol (Appendix A): learning rate is grid-searched PER
    # METHOD, and LeZO's optimum sits higher than MeZO's (Fig. 3: sparser
    # perturbation supports larger lr).  Best-of-grid per method:
    mcfg = opt.opt_tiny(layers=4, d_model=128, vocab=512)
    task = synthetic.TaskConfig(vocab=512, seq_len=64, n_classes=2,
                                signal_rate=0.35)
    target = 3.0
    reached = {}
    for name, n_drop, lrs in [("mezo", 0, (2e-4, 3e-4)),
                              ("lezo75", 3, (3e-4, 6e-4))]:
        best = None
        for lr in lrs:
            tr = Trainer(mcfg, task,
                         TrainConfig(steps=400, batch_size=16, eval_every=0,
                                     log_every=10),
                         zo_cfg=zo.ZOConfig(eps=1e-3, lr=lr, n_drop=n_drop,
                                            backend="scan"))
            h = tr.train()
            idx = next((s for s, l in zip(h["step"], h["loss"])
                        if l < target), None)
            if idx is not None and (best is None or idx < best):
                best = idx
        reached[name] = best
        rows.append((f"steps_to_loss{target}_{name}",
                     0.0 if best is None else float(best),
                     f"best of lr grid {lrs}"))
    if reached["mezo"] and reached["lezo75"]:
        rows.append(("convergence_speedup", 0.0,
                     f"{reached['mezo'] / max(reached['lezo75'], 1):.2f}x"))
    return emit(rows)


if __name__ == "__main__":
    run()
