"""Paper Fig. 6: computational speedup vs input token length.

LeZO's absolute saving per step is fixed (perturb/update bytes); the
forward grows with tokens, so speedup decays with sequence length.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_model, emit, make_batch, make_zo_parts, timeit


def run():
    rows = []
    cfg, _ = bench_model()
    N = cfg.num_layers
    for seq in (16, 32, 64, 128):
        batch = make_batch(cfg, 16, seq)
        t = {}
        for name, nd in [("mezo", 0), ("lezo", int(0.75 * N))]:
            params, _, _, step = make_zo_parts(cfg, nd, backend="scan")
            t[name] = timeit(step, params, batch, jnp.int32(0), jnp.uint32(1))
        rows.append((f"seqlen_{seq}", t["mezo"] * 1e6,
                     f"speedup={t['mezo'] / t['lezo']:.2f}x"))
    return emit(rows)


if __name__ == "__main__":
    run()
