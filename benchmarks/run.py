"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  See DESIGN.md §5 for the
paper-artifact mapping.  ``--json PATH`` additionally writes the full
trajectory as one JSON file: every module's rows, environment metadata,
AND every per-script ``BENCH_*.json`` artifact found on disk
(BENCH_fused.json, BENCH_serving.json, ...) — previously those
artifacts were written but never collected, so the aggregated
trajectory was missing them entirely.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))


def collect_artifacts(root: Path, exclude: Path = None) -> dict:
    """Every per-script BENCH_*.json under ``root``, keyed by filename;
    unreadable files are reported, not silently dropped.  ``exclude``
    (the aggregate being written) and any previous aggregate
    (``"bench": "all"``) are skipped — otherwise rerunning with the
    same --json path would nest its own prior output without bound."""
    out = {}
    for p in sorted(root.glob("BENCH_*.json")):
        if exclude is not None and p.resolve() == exclude.resolve():
            continue
        try:
            with open(p) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            out[p.name] = {"error": repr(e)}
            continue
        if isinstance(payload, dict) and payload.get("bench") == "all":
            continue                    # someone else's aggregate
        out[p.name] = payload
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as a JSON trajectory file")
    args = ap.parse_args()

    from benchmarks import (accuracy, common, estimator_sweep, fused_forward,
                            peft, roofline, serving, sparsity_sweep, speedup,
                            stage_breakdown, token_length, zo_momentum)
    print("name,us_per_call,derived")
    results = {}
    for mod in (stage_breakdown, fused_forward, speedup, sparsity_sweep,
                token_length, accuracy, peft, zo_momentum, estimator_sweep,
                serving, roofline):
        print(f"# --- {mod.__name__} ---")
        rows = mod.run()
        results[mod.__name__.split(".")[-1]] = common.rows_to_json(rows)
    if args.json:
        common.write_json(args.json, {
            "bench": "all", "modules": results,
            "artifacts": collect_artifacts(Path.cwd(),
                                           exclude=Path(args.json))})


if __name__ == "__main__":
    main()
