"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  See DESIGN.md §5 for the
paper-artifact mapping.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))


def main() -> None:
    from benchmarks import (accuracy, estimator_sweep, peft, roofline,
                            sparsity_sweep, speedup, stage_breakdown,
                            token_length, zo_momentum)
    print("name,us_per_call,derived")
    for mod in (stage_breakdown, speedup, sparsity_sweep, token_length,
                accuracy, peft, zo_momentum, estimator_sweep, roofline):
        print(f"# --- {mod.__name__} ---")
        mod.run()


if __name__ == "__main__":
    main()
