"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  See DESIGN.md §5 for the
paper-artifact mapping.  ``--json PATH`` additionally writes the full
trajectory as one JSON file: every module's rows, environment metadata,
AND every per-script ``BENCH_*.json`` artifact found on disk
(BENCH_fused.json, BENCH_serving.json, BENCH_step.json, ...) —
previously those artifacts were written but never collected, so the
aggregated trajectory was missing them entirely.

``--check`` turns the collected artifacts into a CI gate: any artifact
may carry a ``tripwires`` block (``{name: {ok, value, limit, ...}}`` —
benchmarks/step_time.py and benchmarks/serving.py write one) and a
single failed tripwire exits nonzero with every failure listed.
``--collect-only`` skips re-running the suite and just aggregates +
checks what's already on disk (the CI bench-smoke job runs the
individual scripts, then this as the gate).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))
sys.path.insert(0, str(REPO_ROOT))


def collect_artifacts(root: Path, exclude: Path = None) -> dict:
    """Every per-script BENCH_*.json under ``root``, keyed by filename;
    unreadable files are reported, not silently dropped.  ``exclude``
    (the aggregate being written) and any previous aggregate
    (``"bench": "all"``) are skipped — otherwise rerunning with the
    same --json path would nest its own prior output without bound."""
    out = {}
    for p in sorted(root.glob("BENCH_*.json")):
        if exclude is not None and p.resolve() == exclude.resolve():
            continue
        try:
            with open(p) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            out[p.name] = {"error": repr(e)}
            continue
        if isinstance(payload, dict) and payload.get("bench") == "all":
            continue                    # someone else's aggregate
        out[p.name] = payload
    return out


def tripwire_failures(artifacts: dict) -> list:
    """-> [(artifact_name, tripwire_name, record)] for every tripwire
    with ``ok`` falsy in any collected artifact's ``tripwires`` block."""
    bad = []
    for aname, payload in sorted(artifacts.items()):
        if not isinstance(payload, dict):
            continue
        for tname, rec in sorted(payload.get("tripwires", {}).items()):
            if not (isinstance(rec, dict) and rec.get("ok")):
                bad.append((aname, tname, rec))
    return bad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as a JSON trajectory file")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when any collected BENCH_*.json "
                         "artifact carries a failed tripwire")
    ap.add_argument("--collect-only", action="store_true",
                    help="skip running the suite; aggregate/check the "
                         "BENCH_*.json artifacts already on disk")
    args = ap.parse_args()

    results = {}
    if not args.collect_only:
        from benchmarks import (accuracy, common, distributed,
                                estimator_sweep, fused_forward, peft,
                                roofline, serving, sparsity_sweep, speedup,
                                stage_breakdown, step_time, token_length,
                                zo_momentum)
        print("name,us_per_call,derived")
        for mod in (stage_breakdown, step_time, fused_forward, speedup,
                    sparsity_sweep, token_length, accuracy, peft,
                    zo_momentum, estimator_sweep, serving, distributed,
                    roofline):
            print(f"# --- {mod.__name__} ---")
            rows = mod.run()
            results[mod.__name__.split(".")[-1]] = common.rows_to_json(rows)

    # Artifacts are collected from the REPO ROOT, not the cwd: bench
    # scripts write BENCH_*.json beside the Makefile, and anchoring on
    # Path.cwd() made `run.py --json` invoked from anywhere else emit an
    # empty `[]` trajectory while exiting zero.  The cwd is still
    # scanned as a fallback for locally-run scripts.
    exclude = Path(args.json) if args.json else None
    artifacts = collect_artifacts(REPO_ROOT, exclude=exclude)
    if Path.cwd().resolve() != REPO_ROOT:
        for name, payload in collect_artifacts(Path.cwd(),
                                               exclude=exclude).items():
            artifacts.setdefault(name, payload)
    trajectory = sorted(artifacts)
    if args.json:
        from benchmarks import common
        common.write_json(args.json, {
            "bench": "all", "modules": results, "artifacts": artifacts,
            "trajectory": trajectory})
    if args.check:
        bad = tripwire_failures(artifacts)
        for aname, tname, rec in bad:
            rec = rec or {}
            print(f"TRIPWIRE {aname}:{tname} value={rec.get('value')!r} "
                  f"limit={rec.get('limit')!r} ({rec.get('note', '')})",
                  file=sys.stderr)
        if bad:
            raise SystemExit(f"bench tripwires failed: {len(bad)}")
        if not artifacts:
            raise SystemExit(
                "bench check: no BENCH_*.json artifacts found under "
                f"{REPO_ROOT} — an empty trajectory gates nothing")
        print(f"tripwires ok across {len(artifacts)} artifact(s): "
              + ", ".join(trajectory))


if __name__ == "__main__":
    main()
