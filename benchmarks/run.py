"""Benchmark harness: one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  See DESIGN.md §5 for the
paper-artifact mapping.  ``--json PATH`` additionally writes the full
trajectory (every module's rows + environment metadata) as one JSON
file, the format CI archives (e.g. BENCH_fused.json from
benchmarks/fused_forward.py).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as a JSON trajectory file")
    args = ap.parse_args()

    from benchmarks import (accuracy, common, estimator_sweep, fused_forward,
                            peft, roofline, sparsity_sweep, speedup,
                            stage_breakdown, token_length, zo_momentum)
    print("name,us_per_call,derived")
    results = {}
    for mod in (stage_breakdown, fused_forward, speedup, sparsity_sweep,
                token_length, accuracy, peft, zo_momentum, estimator_sweep,
                roofline):
        print(f"# --- {mod.__name__} ---")
        rows = mod.run()
        results[mod.__name__.split(".")[-1]] = common.rows_to_json(rows)
    if args.json:
        common.write_json(args.json, {"bench": "all", "modules": results})


if __name__ == "__main__":
    main()
