"""Stage-level ZO step decomposition, measured through repro.obs spans.

The paper's headline observability claim is that a MeZO step spends the
majority of its wall time in the perturb/update parameter sweeps, not
the forwards — and that the virtual (fused-forward) runtime removes the
perturb sweeps entirely.  This benchmark measures that decomposition
with the *production instrumentation* rather than bespoke stopwatches:
the estimator step runs eagerly under a fencing ``obs.Tracer`` (spans
no-op inside jit, so eager execution is the staged-measurement mode —
DESIGN.md §13), and the per-stage shares come straight out of the ring
buffer the trainer itself would use.

Three measurements per forward backend (materialized, virtual_ref):

  * eager staged profile — median per-stage seconds + share of step,
    plus the deterministic per-step counters (axpy sweeps, probes, RNG
    folds) that pin the structural claim (3 sweeps -> 1 under virtual);
  * jitted step time — the real training throughput number;
  * telemetry overhead — the jitted step timed with the default NULL
    tracer vs an installed active tracer.  All instrumentation either
    no-ops under jit tracing or lives outside the compiled step, so the
    ratio must stay ~1; the tripwire allows 25% for CI noise.

Writes ``BENCH_step.json`` with a ``tripwires`` block that
``benchmarks/run.py --check`` (and this script's own ``--check``)
turns into a CI gate; ``--jsonl`` additionally writes a sample span
trace (the artifact CI uploads next to the JSON).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import (emit, make_batch, rows_to_json,  # noqa: E402
                               timeit, write_json)
from repro import api, estimators, obs  # noqa: E402
from repro.core import zo  # noqa: E402
from repro.models import lm  # noqa: E402

BACKENDS = ("materialized", "virtual_ref")
# Stages each backend must emit: virtual probes never write parameters,
# so a virtual step has no perturb spans at all — and with paired probes
# (the default) the ±εz pair rides ONE forward_pair span instead of the
# forward+εz / forward-εz pair (the structural claim this PR adds).
EXPECTED_STAGES = {
    "materialized": (obs.PERTURB, obs.FWD_PLUS, obs.FWD_MINUS, obs.UPDATE),
    "virtual_ref": (obs.FWD_PAIR, obs.UPDATE),
}
# axpy sweeps per step: perturb + perturb + fused restore+update vs the
# single virtual update pass (estimators/costs.py derives the same).
EXPECTED_SWEEPS = {"materialized": 3, "virtual_ref": 1}
MAX_OVERHEAD_RATIO = 1.25   # jit step, tracer installed vs NULL
MIN_OVERHEAD_RATIO = 0.80   # a ratio well under 1.0 means the baseline
                            # series absorbed compile/warmup cost instead


def _parts(mcfg, espec, fb):
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    ecfg = dataclasses.replace(api.derive(espec).est_cfg,
                               forward_backend=fb)
    loss_fn = lambda p, b, perturb=None: lm.lm_loss(mcfg, p, b,
                                                    perturb=perturb)
    est = estimators.build_estimator(spec, ecfg)
    step, init = estimators.make_step(loss_fn, spec, ecfg)
    return params, est, loss_fn, ecfg, jax.jit(step), init


def _median(xs):
    return float(np.median(np.asarray(xs))) if xs else 0.0


def stage_profile(est, loss_fn, params, batch, iters, jsonl_path=None):
    """Run the instrumented estimator step eagerly under a fencing
    tracer; aggregate the ring buffer into per-stage medians/shares."""
    ring = obs.RingSink()
    sinks = [ring]
    jsonl = None
    if jsonl_path:
        jsonl = obs.JSONLSink(jsonl_path)
        sinks.append(jsonl)
    tr = obs.Tracer(sinks=sinks, fence=True)
    with obs.use(tr):
        for i in range(iters + 1):           # +1 warmup iteration
            if i == 1:                        # drop warmup spans/counters
                ring.clear()
                tr.reset()
            with tr.span(obs.TRAIN_STEP) as sp:
                p, dirs, _ = est.estimate(loss_fn, params, batch,
                                          jnp.uint32(i + 1), est.init_state())
                sp.fence(est.apply_update(p, dirs, est.cfg.lr))
    if jsonl is not None:
        jsonl.emit_event(tr.snapshot())
        jsonl.close()
    step_s = _median([r.dt for r in ring.spans(obs.TRAIN_STEP)])
    stages = {}
    for name in (obs.PERTURB, obs.FWD_PLUS, obs.FWD_MINUS, obs.FWD_PAIR,
                 obs.FWD_BASE, obs.UPDATE):
        recs = ring.spans(name)
        if not recs:
            continue
        per_step = sum(r.dt for r in recs) / iters
        stages[name] = {"s": per_step,
                        "share": per_step / step_s if step_s else 0.0,
                        "spans_per_step": len(recs) / iters}
    counters = {k: v / iters for k, v in tr.counters.items()}
    return {"step_s": step_s, "stages": stages, "counters": counters}


def measure_overhead(step, init, params, batch, iters):
    """Jitted step under the NULL tracer vs an installed active tracer:
    recording is suppressed inside jit, so the compiled path is shared
    and the ratio pins the <2% disabled-telemetry claim (with noise
    headroom).

    The step is fully warmed (compile + first-touch allocations) BEFORE
    either series, and the two series interleave sample-by-sample, so
    neither side absorbs one-time cost or drift the other skips — the
    previous back-to-back ordering timed the disabled series first on a
    cold cache and reported ratios like 0.59x, which is telemetry making
    the step *faster*, i.e. a measurement artifact, not a result."""
    import time
    args = (params, init(), batch, jnp.int32(0), jnp.uint32(1))
    for _ in range(2):                       # compile + steady-state warm
        jax.block_until_ready(step(*args))
    tr = obs.Tracer(sinks=[obs.RingSink()], fence=False)
    off, on = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(step(*args))
        off.append(time.perf_counter() - t0)
        with obs.use(tr):
            t0 = time.perf_counter()
            jax.block_until_ready(step(*args))
            on.append(time.perf_counter() - t0)
    t_off, t_on = _median(off), _median(on)
    return {"disabled_s": t_off, "enabled_s": t_on,
            "ratio": t_on / t_off if t_off else 1.0}


def measure_health_overhead(step, init, params, batch, iters, num_layers):
    """Jitted step alone vs jitted step + ``HealthAccumulator.record``
    (with a drain every 8 steps — the log_every cadence the trainer
    uses): record() only buffers device references, so the ratio pins
    the claim that per-step health telemetry never syncs the device.
    Same interleaved, pre-warmed protocol as ``measure_overhead``."""
    import time
    args = (params, init(), batch, jnp.int32(0), jnp.uint32(1))
    for _ in range(2):                       # compile + steady-state warm
        jax.block_until_ready(step(*args))
    acc = obs.HealthAccumulator(num_layers)
    off, on = [], []
    for i in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(step(*args))
        off.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        out = step(*args)
        acc.record(i, out[2], seed=i)
        if (i + 1) % 8 == 0:
            acc.drain()
        jax.block_until_ready(out)
        on.append(time.perf_counter() - t0)
    acc.drain()
    t_off, t_on = _median(off), _median(on)
    return {"disabled_s": t_off, "enabled_s": t_on,
            "ratio": t_on / t_off if t_off else 1.0,
            "steps_recorded": acc.summary()["steps_recorded"]}


def build_tripwires(backends, overhead, health):
    """-> {name: {ok, value, limit, note}} — the convention run.py
    --check collects across every BENCH_*.json artifact."""
    tw = {}
    for fb, rec in backends.items():
        seen = set(rec["eager"]["stages"])
        want = set(EXPECTED_STAGES[fb])
        extra = (seen - want - {obs.FWD_BASE}) if fb == "materialized" \
            else (seen & {obs.PERTURB, obs.FWD_PLUS, obs.FWD_MINUS})
        tw[f"stages_{fb}"] = {
            "ok": want <= seen and not extra,
            "value": sorted(seen), "limit": sorted(want),
            "note": "every expected stage span present"
                    + ("" if fb == "materialized"
                       else " and no perturb sweep or split ±εz forwards"
                            " under virtual (paired probes ride one"
                            " forward_pair span)")}
        sweeps = rec["eager"]["counters"].get(obs.CTR_AXPY, 0)
        tw[f"axpy_sweeps_{fb}"] = {
            "ok": sweeps == EXPECTED_SWEEPS[fb],
            "value": sweeps, "limit": EXPECTED_SWEEPS[fb],
            "note": "parameter sweeps per step (3 materialized -> "
                    "1 virtual is the paper's structural claim)"}
    tw["telemetry_overhead"] = {
        "ok": (MIN_OVERHEAD_RATIO <= overhead["ratio"]
               <= MAX_OVERHEAD_RATIO),
        "value": overhead["ratio"],
        "limit": [MIN_OVERHEAD_RATIO, MAX_OVERHEAD_RATIO],
        "note": "jitted step, active tracer vs NULL (must be ~1: spans "
                "no-op inside jit; well under 1 means the disabled "
                "baseline absorbed warmup cost)"}
    tw["health_overhead"] = {
        "ok": (MIN_OVERHEAD_RATIO <= health["ratio"]
               <= MAX_OVERHEAD_RATIO),
        "value": health["ratio"],
        "limit": [MIN_OVERHEAD_RATIO, MAX_OVERHEAD_RATIO],
        "note": "jitted step + HealthAccumulator record/drain vs plain "
                "(must be ~1: record buffers device refs without sync)"}
    return tw


def run(smoke=False, json_path=None, preset="bench-smoke", jsonl_path=None,
        check=False):
    espec = api.presets.get(preset)
    d = api.derive(espec)
    mcfg, seq = d.model_cfg, espec.model.seq_len
    batch = make_batch(mcfg, espec.run.batch_size if smoke else 16, seq)
    eager_iters = 2 if smoke else 4
    jit_iters = 3 if smoke else 5

    rows, backends = [], {}
    for fb in BACKENDS:
        params, est, loss_fn, ecfg, step, init = _parts(mcfg, espec, fb)
        eager = stage_profile(est, loss_fn, params, batch, eager_iters,
                              jsonl_path=(jsonl_path
                                          if fb == "materialized" else None))
        t_jit = timeit(lambda: step(params, init(), batch, jnp.int32(0),
                                    jnp.uint32(1)),
                       warmup=1, iters=jit_iters)
        backends[fb] = {"eager": eager, "jit_step_s": t_jit}
        # one row per measurement mode, each derived field describing
        # ITS OWN number (the old single row was named steptime_jit_*
        # but carried an "eager ... us" derived label)
        rows.append((f"steptime_jit_{fb}", t_jit * 1e6,
                     "jitted step (compiled, tracer-free)"))
        rows.append((f"steptime_eager_{fb}", eager["step_s"] * 1e6,
                     "eager staged step (fencing tracer installed)"))
        for name, st in eager["stages"].items():
            rows.append((f"stage_{fb}_{name}", st["s"] * 1e6,
                         f"{st['share'] * 100:.0f}% of eager step"))
    # overhead measured once, on the materialized jitted step
    params, est_m, _, _, step, init = _parts(mcfg, espec, "materialized")
    overhead = measure_overhead(step, init, params, batch, jit_iters)
    rows.append(("telemetry_overhead_ratio", 0.0,
                 f"{overhead['ratio']:.3f}x (enabled/disabled, jit)"))
    health = measure_health_overhead(step, init, params, batch, jit_iters,
                                     est_m.spec.num_layers)
    rows.append(("health_overhead_ratio", 0.0,
                 f"{health['ratio']:.3f}x (record+drain/plain, jit)"))

    sweep_share = sum(
        st["s"] for n, st in backends["materialized"]["eager"]["stages"]
        .items() if n in (obs.PERTURB, obs.UPDATE))
    ms = backends["materialized"]["eager"]["step_s"]
    rows.append(("perturb_update_share", 0.0,
                 f"{sweep_share / ms * 100:.0f}% of materialized eager step"
                 if ms else "n/a"))

    emit(rows)
    tripwires = build_tripwires(backends, overhead, health)
    if json_path:
        write_json(json_path, {
            "bench": "step_time",
            "model": mcfg.name,
            "stages": list(obs.STAGES),
            "backends": backends,
            "perturb_update_share": sweep_share / ms if ms else None,
            "telemetry_overhead": overhead,
            "health_overhead": health,
            "tripwires": tripwires,
            "rows": rows_to_json(rows),
        }, spec=espec)
    bad = {k: v for k, v in tripwires.items() if not v["ok"]}
    if check and bad:
        for k, v in bad.items():
            print(f"TRIPWIRE {k}: value={v['value']!r} "
                  f"limit={v['limit']!r} ({v['note']})", file=sys.stderr)
        raise SystemExit(f"step_time: {len(bad)} tripwire(s) failed")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--preset", default="bench-smoke",
                    help="experiment spec preset (repro.api.presets)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_step.json trajectory here")
    ap.add_argument("--jsonl", default=None, metavar="PATH",
                    help="write a sample span trace (JSONL) here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when any tripwire fails")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, json_path=args.json, preset=args.preset,
        jsonl_path=args.jsonl, check=args.check)


if __name__ == "__main__":
    main()
