"""§Roofline: tabulate the dry-run artifacts (artifacts/dryrun/*.json).

Run the dry-run first:  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
from __future__ import annotations

import glob
import json
import os


def run():
    rows = []
    files = sorted(glob.glob("artifacts/dryrun/*.json"))
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        t = r["roofline"]
        dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = t["compute_s"] / bound if bound else 0.0
        name = f"{r['arch']}|{r['shape']}|{r['mesh']}|{r['variant']}"
        rows.append((name, bound * 1e6,
                     f"dom={dom[:-2]} roofline_frac={frac:.3f} "
                     f"useful={r['useful_flop_ratio'] and round(r['useful_flop_ratio'], 3)}"))
        print(f"{name},{bound * 1e6:.1f},{rows[-1][2]}")
    if not files:
        print("roofline,0,no dry-run artifacts found (run repro.launch.dryrun)")
    return rows


if __name__ == "__main__":
    run()
