"""Beyond-paper: the estimator quality/cost frontier (DESIGN.md §5, §6).

two_point vs one_sided(q in {4,16}) vs averaged(q=4), all at LeZO
sparsity 0.75:

  * wall-clock per optimizer step (CPU, pallas in interpret mode via the
    default dense backend) — multi-probe estimators pay more compute per
    step, visible here;
  * steps-to-target-loss on the synthetic classification task — the
    FZOO claim: q batched one-sided probes cut the *step count* to a
    fixed loss.  Each estimator runs at the variance-matched learning
    rate lr * sqrt(q) (q probes cut gradient variance ~q-fold, which is
    exactly what lets FZOO push the step size).

The target is the two_point baseline's final smoothed training loss at
a fixed step budget; ``steps`` reports when each estimator's smoothed
loss first reaches it (capped at the budget).
"""
from __future__ import annotations

import sys
from pathlib import Path

# runnable standalone (`make bench-smoke`) as well as via benchmarks/run.py
sys.path.insert(0, str(Path(__file__).parent.parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, make_batch, timeit
from repro import estimators
from repro.configs import opt
from repro.core import zo
from repro.data import synthetic
from repro.models import lm

GRID = (("two_point", 1), ("one_sided", 4), ("one_sided", 16),
        ("averaged", 4))
_SMOOTH = 20  # steps in the running-mean loss window


def _estimator_step(mcfg, name, q, n_drop, lr, eps=1e-3):
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    ecfg = estimators.EstimatorConfig(name=name, q=q, n_drop=n_drop, lr=lr,
                                      eps=eps)
    loss_fn = lambda p, b: lm.lm_loss(mcfg, p, b)
    # no buffer donation: the timing loop re-feeds the same params
    step, init = estimators.make_step(loss_fn, spec, ecfg)
    return params, jax.jit(step), init


def _loss_curve(name, q, lr, steps, mcfg, task):
    params, step, init = _estimator_step(mcfg, name, q,
                                         n_drop=int(0.75 * mcfg.num_layers),
                                         lr=lr)
    data = synthetic.make_dataset(task, 2048)
    stream = synthetic.batches(data, 16, steps, seed=7)
    p, st = params, init()
    losses = []
    for t, np_batch in enumerate(stream):
        batch = {k: jnp.asarray(v) for k, v in np_batch.items()
                 if k != "class_labels"}
        p, st, m = step(p, st, batch, jnp.int32(t), jnp.uint32(1))
        losses.append(float(m["loss"]))
    return np.asarray(losses)


def _smoothed(losses):
    c = np.convolve(losses, np.ones(_SMOOTH) / _SMOOTH, mode="valid")
    return c


def run(smoke=False, preset="bench-smoke"):
    # the sweep's model / batch / eps / lr / sparsity come from the shared
    # experiment-spec preset, so CI and the CLI can't drift on them
    from repro import api
    espec = api.presets.get(preset)
    d = api.derive(espec)
    rows = []
    budget = espec.run.steps if smoke else 300

    # ---- wall-clock per step at the preset's sparsity -------------------
    mcfg, seq = d.model_cfg, espec.model.seq_len
    batch = make_batch(mcfg, espec.run.batch_size, seq)
    n_drop = d.n_drop
    for name, q in GRID:
        params, step, init = _estimator_step(mcfg, name, q, n_drop,
                                             espec.optimizer.lr,
                                             eps=espec.optimizer.eps)
        counts = estimators.costs.step_counts(name, q=q)
        t = timeit(lambda: step(params, init(), batch, jnp.int32(0),
                                jnp.uint32(1)), warmup=1, iters=3)
        rows.append((f"steptime_{name}_q{q}", t * 1e6,
                     f"forwards={counts['forwards']}"))

    # ---- steps to the two_point target loss -----------------------------
    mcfg = opt.opt_tiny(layers=4, d_model=128, vocab=512)
    task = synthetic.TaskConfig(vocab=512, seq_len=64, n_classes=2,
                                signal_rate=0.35)
    base_lr = 3e-4
    curves = {}
    for name, q in GRID:
        lr = base_lr * float(np.sqrt(q))      # variance-matched step size
        curves[(name, q)] = _smoothed(_loss_curve(name, q, lr, budget,
                                                  mcfg, task))
    target = curves[("two_point", 1)][-1]
    rows.append(("target_loss_two_point", 0.0, f"{target:.3f}"))
    for name, q in GRID:
        c = curves[(name, q)]
        hit = np.nonzero(c <= target)[0]
        steps = int(hit[0]) + _SMOOTH if hit.size else budget
        rows.append((f"steps_to_target_{name}_q{q}", 0.0, f"{steps}"))
    return emit(rows)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--preset", default="bench-smoke",
                    help="experiment spec preset the bench runs off "
                         "(repro.api.presets)")
    args = ap.parse_args()
    run(smoke=args.smoke, preset=args.preset)
