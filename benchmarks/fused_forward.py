"""Virtual-perturbation fused runtime: materialized vs virtual step time.

A materialized two_point step is 2 forwards + 3 parameter axpy sweeps
(perturb, perturb, fused restore+update); the virtual backend
(``repro.fused``, DESIGN.md §10) evaluates both probes against
in-kernel-regenerated perturbed weights, so the step is 2 (slightly
heavier) forwards + 1 update sweep — and with paired probes (the
default) the ±εz pair rides ONE stacked forward whose kernels load each
W tile and regenerate each z tile once for both signs.  This benchmark
times full optimizer steps at LeZO sparsity rho in {0, 0.5, 0.75},
times paired vs unpaired virtual stepping, and *proves* the pairing's
W-traffic halving structurally: the eager forward runs under an obs
tracer whose ``w_tile_loads`` / ``z_regens`` counters come from the
same grid arithmetic the kernel executes (host-side Python ints —
CPU-provable, no wall clock involved).  Writes the ``BENCH_fused.json``
trajectory (``--json``; CI uploads it) with a ``tripwires`` block that
``--check`` and ``benchmarks/run.py --check`` gate on.

On CPU the virtual rows use the pure-JAX oracle (``virtual_ref`` — the
same floats the Pallas kernels produce, which the test suite pins in
interpret mode); timing the Pallas *interpreter* would measure the
emulator, not the kernel, so the kernel path gets a single microbench
row for reference instead.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))
sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks.common import (emit, make_batch, rows_to_json,  # noqa: E402
                               timeit, write_json)
from repro import estimators, fused, obs  # noqa: E402
from repro.core import zo  # noqa: E402
from repro.estimators import costs  # noqa: E402
from repro.fused import matmul as fused_matmul  # noqa: E402
from repro.fused import ref as fused_ref  # noqa: E402
from repro.models import lm  # noqa: E402

RHOS = (0.0, 0.5, 0.75)


def _bench_spec(preset="bench-smoke"):
    """The experiment spec this benchmark is a projection of — model and
    optimizer knobs come from the shared preset, not inline flags."""
    from repro import api
    return api.presets.get(preset)


def _step(mcfg, espec, n_drop, forward_backend, paired=True):
    import dataclasses

    from repro import api
    params = lm.init_params(mcfg, jax.random.PRNGKey(0))
    spec = zo.build_spec(params, lm.zo_group_fn)
    ecfg = dataclasses.replace(api.derive(espec).est_cfg, n_drop=n_drop,
                               forward_backend=forward_backend,
                               paired_probes=paired)
    loss_fn = lambda p, b, perturb=None: lm.lm_loss(mcfg, p, b,
                                                    perturb=perturb)
    step, init = estimators.make_step(loss_fn, spec, ecfg)
    return params, jax.jit(step), init


def structural_counters(mcfg, params, tokens):
    """The pairing's halving claim as deterministic Python ints: run the
    eager forward under a counting tracer for (a) ONE paired ±εz ctx and
    (b) the two unpaired ±εz ctxs it replaces, and read back the
    ``w_tile_loads`` / ``z_regens`` counters — host-side grid arithmetic
    (``fused.matmul.grid_cells``), identical for ref and pallas impls,
    so the halving is provable on CPU where wall-clock speedups are not.

    Runs under ``jax.disable_jit()``: the transformer blocks live inside
    a ``lax.scan`` whose body traces once, and obs counters no-op under
    tracing — disabling jit turns the scan into an eager Python loop so
    every layer's lens call actually counts.
    """
    seed, eps = jnp.uint32(7), 1e-3

    def count(ctxs):
        tr = obs.Tracer(sinks=[])
        with obs.use(tr), jax.disable_jit():
            for ctx in ctxs:
                jax.block_until_ready(
                    lm.forward(mcfg, params, tokens, perturb=ctx))
        return {"w_tile_loads": tr.counters.get(obs.CTR_WLOAD, 0),
                "z_regens": tr.counters.get(obs.CTR_ZREGEN, 0)}

    paired = count([fused.make_pair_ctx(seed, eps, None, "virtual_ref")])
    unpaired = count([fused.make_ctx(seed, eps, None, "virtual_ref"),
                      fused.make_ctx(seed, -eps, None, "virtual_ref")])
    return {"paired": paired, "unpaired": unpaired}


def build_tripwires(struct):
    """-> {name: {ok, value, limit, note}} (run.py --check collects)."""
    tw = {}
    for key in ("w_tile_loads", "z_regens"):
        p, u = struct["paired"][key], struct["unpaired"][key]
        tw[f"paired_{key}_halved"] = {
            "ok": p > 0 and 2 * p == u,
            "value": {"paired": p, "unpaired": u},
            "limit": "paired == unpaired / 2, both > 0",
            "note": f"per-forward-pass {key} (host-side grid arithmetic "
                    "over every block matmul; the ±εz pair shares one "
                    "stacked kernel pass)"}
    return tw


def run(smoke=False, json_path=None, preset="bench-smoke", check=False):
    from repro import api
    espec = _bench_spec(preset)
    d = api.derive(espec)
    mcfg, seq = d.model_cfg, espec.model.seq_len
    batch = make_batch(mcfg, espec.run.batch_size if smoke else 16, seq)
    iters = 3 if smoke else 5
    rows, cells = [], []
    for rho in RHOS:
        n_drop = int(rho * mcfg.num_layers)
        times = {}
        for fb in ("materialized", "virtual_ref"):
            params, step, init = _step(mcfg, espec, n_drop, fb)
            t = timeit(lambda: step(params, init(), batch, jnp.int32(0),
                                    jnp.uint32(1)), warmup=1, iters=iters)
            times[fb] = t
            sweeps = costs.step_counts("two_point",
                                       forward_backend=fb)["axpy_sweeps"]
            rows.append((f"steptime_{fb}_rho{rho:g}", t * 1e6,
                         f"axpy_sweeps={sweeps}"))
        speedup = times["materialized"] / times["virtual_ref"]
        rows.append((f"virtual_speedup_rho{rho:g}", 0.0, f"{speedup:.2f}x"))
        cells.append({"rho": rho,
                      "materialized_s": times["materialized"],
                      "virtual_s": times["virtual_ref"],
                      "speedup": speedup})

    # Paired vs unpaired virtual stepping: same estimator, same floats
    # (tests/test_fused.py pins bit-identity), ±εz stacked into one
    # forward vs two sequential probe forwards.
    times_pair = {}
    for paired in (True, False):
        params, step, init = _step(mcfg, espec, 0, "virtual_ref",
                                   paired=paired)
        t = timeit(lambda: step(params, init(), batch, jnp.int32(0),
                                jnp.uint32(1)), warmup=1, iters=iters)
        times_pair[paired] = t
        name = "paired" if paired else "unpaired"
        rows.append((f"steptime_virtual_{name}_rho0", t * 1e6,
                     "1 stacked ±εz forward" if paired
                     else "2 probe forwards"))
    rows.append(("paired_speedup_rho0", 0.0,
                 f"{times_pair[False] / times_pair[True]:.2f}x"))

    # Structural proof of the halving (deterministic, wall-clock-free).
    struct = structural_counters(mcfg, params, batch["tokens"])
    for side in ("paired", "unpaired"):
        for key in ("w_tile_loads", "z_regens"):
            rows.append((f"struct_{side}_{key}", 0.0,
                         str(struct[side][key])))
    tripwires = build_tripwires(struct)

    # Pallas kernel reference point: one fused pmatmul tile pass in
    # interpret mode vs its oracle (numbers are emulator-bound on CPU).
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 512), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 512), jnp.float32)
    seed = jnp.uint32(7)
    t_k = timeit(lambda: fused_matmul.pmatmul(x, w, seed, 1e-3,
                                              interpret=True),
                 warmup=1, iters=iters)
    t_r = timeit(jax.jit(lambda: fused_ref.pmatmul(x, w, seed, 1e-3)),
                 warmup=1, iters=iters)
    rows.append(("pmatmul_pallas_interpret_512", t_k * 1e6,
                 "emulator-bound on CPU"))
    rows.append(("pmatmul_ref_512", t_r * 1e6, "oracle (XLA-compiled)"))

    emit(rows)
    if json_path:
        write_json(json_path, {
            "bench": "fused_forward",
            "model": mcfg.name,
            "impl": "virtual_ref on CPU (kernel pinned vs oracle by "
                    "tests/test_fused.py in interpret mode)",
            "cells": cells,
            "structural": struct,
            "tripwires": tripwires,
            "rows": rows_to_json(rows),
        }, spec=espec)
    if check:
        bad = sorted(n for n, r in tripwires.items() if not r["ok"])
        for n in bad:
            r = tripwires[n]
            print(f"TRIPWIRE {n} value={r['value']!r} limit={r['limit']!r}",
                  file=sys.stderr)
        if bad:
            raise SystemExit(f"fused bench tripwires failed: {bad}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--preset", default="bench-smoke",
                    help="experiment spec preset the bench runs off "
                         "(repro.api.presets)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_fused.json trajectory here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when a structural tripwire "
                         "(±εz pairing must halve W-tile loads and "
                         "z regens) fails")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json, preset=args.preset,
        check=args.check)
