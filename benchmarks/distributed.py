"""Swarm scaling: throughput vs worker count at scalar-only traffic.

The swarm's claim (DESIGN.md §14) is that seed-synchronized ZO training
makes data parallelism nearly free on the wire: each worker ships two
float32 losses per shard and receives one ``(seed, g)`` commit, so the
per-step traffic is a few hundred bytes *independent of model size* —
against ``4·|θ|`` bytes for a first-order gradient all-reduce of the
same trainable set.

This benchmark runs the same spec (``swarm-smoke`` shapes, ``n_shards``
pinned to 4 so the reduction tree never changes) under 1, 2 and 4 local
worker processes and records:

* steps/s and measured steady-state wire bytes/step per worker count,
* the FO all-reduce baseline ``4·trainable_params`` for contrast,
* a quorum-degradation row: ``quorum=0.5`` with a chaos partition on
  one worker — the coordinator's deadline fallback commits degraded
  steps from the arrived shard subset,
* full-stream bit-identity across worker counts (the committed
  ``loss``/``projected_grad``/``seed`` trajectories must be equal to
  the bit — the decomposed sharded step makes commits a function of
  the shard set, not of who computed the shards).

Writes BENCH_dist.json with ``--check`` tripwires: steady bytes/step
under 1 KB, bit-identity across worker counts, and at least one
quorum-degraded committed step in the chaos run.
``benchmarks/run.py --check`` aggregates and gates on them.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import shutil
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks import common  # noqa: E402
from repro import api  # noqa: E402

# --check tripwire: scalar-only traffic.  Measured per worker *link*
# (contribution up + commit broadcast down), the same unit as the FO
# all-reduce baseline — total cluster traffic grows linearly with
# workers because the commit is broadcast, per-link it does not.
MAX_BYTES_PER_STEP = 1024
WORKER_COUNTS = (1, 2, 4)
N_SHARDS = 4                     # fixed => commits worker-count-invariant
_STREAM_KEYS = ("loss", "projected_grad", "seed", "active_layers",
                "shard_losses")


def _base_spec(steps: int) -> api.Experiment:
    spec = api.PRESETS["swarm-smoke"]
    return dataclasses.replace(
        spec,
        swarm=dataclasses.replace(spec.swarm, n_shards=N_SHARDS),
        run=dataclasses.replace(spec.run, steps=steps))


def _rows_of(runs_root: Path) -> list:
    (run_dir,) = [d for d in runs_root.iterdir() if d.is_dir()]
    with open(run_dir / "steps.jsonl") as f:
        return [json.loads(line) for line in f]


def _stream(rows: list) -> list:
    return [[row.get(k) for k in _STREAM_KEYS] for row in rows]


def _swarm_run(spec: api.Experiment, root: Path) -> dict:
    from repro.swarm import driver
    root.mkdir(parents=True, exist_ok=True)
    t0 = time.perf_counter()
    summary = driver.run_swarm(spec, runs_root=str(root))
    summary["bench_wall_s"] = time.perf_counter() - t0
    summary["rows"] = _rows_of(root)
    return summary


def run(smoke: bool = False, json_path: str = None, check: bool = False):
    from repro.swarm import shardstep
    steps = 6 if smoke else 10
    spec = _base_spec(steps)
    fo_bytes = 4 * shardstep.trainable_param_count(spec)

    tmp = Path(tempfile.mkdtemp(prefix="bench_dist_"))
    rows, scaling, streams = [], {}, {}
    try:
        for w in WORKER_COUNTS:
            s = dataclasses.replace(
                spec, swarm=dataclasses.replace(spec.swarm, workers=w))
            summary = _swarm_run(s, tmp / f"w{w}")
            streams[w] = _stream(summary["rows"])
            bps = summary["steady_bytes_per_step"] / w
            scaling[str(w)] = {
                "workers": w,
                "steps_per_s": steps / summary["wall_s"],
                "wall_s": summary["wall_s"],
                "steady_bytes_per_step_per_link": bps,
                "steady_bytes_per_step_total": summary[
                    "steady_bytes_per_step"],
                "total_wire_bytes": summary["wire_bytes"],
                "membership_epochs": summary["membership_epochs"],
            }
            rows.append((f"swarm_w{w}", summary["wall_s"] / steps * 1e6,
                         f"{bps:.0f} B/step/link "
                         f"({fo_bytes / max(bps, 1):.0f}x under FO "
                         "all-reduce)"))

        # quorum fallback: partition one worker for a step window; the
        # deadline commits from the arrived shard subset at quorum=0.5
        qspec = dataclasses.replace(
            spec, swarm=dataclasses.replace(
                spec.swarm, workers=2, quorum=0.5, step_deadline_s=1.0,
                chaos_seed=7, chaos_partition=f"1:2-{steps - 2}"))
        qsum = _swarm_run(qspec, tmp / "quorum")
        degraded = sum(1 for r in qsum["rows"]
                       if 0 in (r.get("arrived") or []))
        scaling["quorum_degraded"] = {
            "workers": 2, "quorum": 0.5,
            "degraded_steps": degraded,
            "straggler_steps": qsum["straggler_steps"],
            "steady_bytes_per_step_per_link":
                qsum["steady_bytes_per_step"] / 2,
        }
        rows.append(("swarm_quorum0.5_partition",
                     qsum["wall_s"] / steps * 1e6,
                     f"{degraded}/{steps} steps committed degraded"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    identical = all(streams[w] == streams[WORKER_COUNTS[0]]
                    for w in WORKER_COUNTS)
    worst_bps = max(s["steady_bytes_per_step_per_link"]
                    for s in scaling.values())
    rows.append(("fo_allreduce_baseline", 0.0,
                 f"{fo_bytes} B/step (4*trainable_params)"))
    rows.append(("bit_identity_1_2_4", 0.0, str(identical)))
    common.emit(rows)

    if json_path:
        common.write_json(json_path, {
            "bench": "distributed", "n_shards": N_SHARDS, "steps": steps,
            "scaling": scaling,
            "fo_allreduce_bytes_per_step": fo_bytes,
            "bit_identical_across_worker_counts": identical,
            "tripwires": {
                "swarm_bytes_per_step": {
                    "ok": worst_bps < MAX_BYTES_PER_STEP,
                    "value": worst_bps, "limit": MAX_BYTES_PER_STEP,
                    "note": "steady-state wire bytes per committed step "
                            "per worker link (scalar-only sync broken "
                            "above this)"},
                "swarm_bit_identity": {
                    "ok": identical, "value": identical, "limit": True,
                    "note": "committed scalar streams must match to the "
                            "bit across 1/2/4 workers"},
                "swarm_quorum_degraded": {
                    "ok": degraded >= 1, "value": degraded, "limit": 1,
                    "note": "partition run must commit >=1 step from a "
                            "partial shard set (deadline fallback dead "
                            "otherwise)"},
            },
        }, spec=spec)
    if check:
        problems = []
        if worst_bps >= MAX_BYTES_PER_STEP:
            problems.append(f"bytes/step {worst_bps:.0f} >= "
                            f"{MAX_BYTES_PER_STEP}")
        if not identical:
            problems.append("streams differ across worker counts")
        if degraded < 1:
            problems.append("no quorum-degraded step committed")
        if problems:
            raise SystemExit("distributed bench tripwires: "
                             + "; ".join(problems))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_dist.json here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero when a swarm tripwire fails "
                         "(bytes/step, bit-identity, quorum fallback)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, json_path=args.json, check=args.check)


if __name__ == "__main__":
    main()
