"""Continuous batching vs lockstep serving on mixed-length traffic.

The lockstep loop (launch/serve.generate) pays the convoy tax twice:
every prompt in a batch is padded to the longest, and every lane decodes
until the *slowest* request's budget — a batch with one 8x-longer
generation runs 8x decode steps for everyone.  The paged engine
(repro/serving/, DESIGN.md §12) frees lanes the moment a request
finishes and admits the next one, so wall time tracks *total* tokens,
not ``batches x max``.

Traffic: each arrival group of ``max_lanes`` requests holds one
long-generation request and ``lanes-1`` short ones (the convoy shape).
Writes BENCH_serving.json — request throughput, p50/p99 latency, engine
vs lockstep speedup — which CI uploads next to BENCH_fused.json.  The
acceptance target is engine >= 2x lockstep request throughput
(measured 2.0-2.6x on CPU smoke sizes, recorded in the JSON);
``--check`` enforces the MIN_SPEEDUP regression tripwire (1.5x, below
which continuous batching is broken, with headroom for noisy CI boxes)
and ``make bench-smoke`` runs with it.

Two prefix-sharing scenarios ride along (DESIGN.md §12): a
shared-system-prompt convoy run twice — ``prefix_cache`` off then on —
and a zipfian repeat workload.  The JSON carries page_hit_rate,
cow_copies, and the on/off wall-time ratio; ``--check`` additionally
trips when the convoy hit rate drops below MIN_HIT_RATE, when sharing
runs slower than not sharing, or when a drained engine leaks pages the
trie does not account for.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks import common  # noqa: E402
from repro import api, serving  # noqa: E402
from repro.models import lm  # noqa: E402

LONG_GEN, SHORT_GENS = 64, (2, 4, 6)
PROMPT_RANGE = (4, 16)
MIN_SPEEDUP = 1.5     # --check tripwire; the acceptance target is 2x

# --- prefix-sharing scenario (DESIGN.md §12) -------------------------
SYS_LEN = 40          # shared system prompt: 5 full pages — NOT chunk-
                      # aligned, so the re-run chunk COWs its last page
TAIL_RANGE = (2, 9)   # per-request unique suffix
SHARE_GENS = (3, 4, 5)
MIN_HIT_RATE = 0.5    # --check: shared-convoy page hit rate floor
MIN_SHARE_RATIO = 1.0  # --check: sharing-on must not run slower than off


def make_traffic(rng, n_requests, lanes, vocab, long_gen, short_gens):
    """One long-generation request per arrival group of ``lanes``."""
    reqs = []
    for rid in range(n_requests):
        gen = (long_gen if rid % lanes == 0
               else int(short_gens[rid % len(short_gens)]))
        plen = int(rng.integers(*PROMPT_RANGE))
        reqs.append(serving.Request(
            rid=rid, tokens=rng.integers(0, vocab, plen).tolist(),
            max_new_tokens=gen, seed=rid))
    return reqs


def make_lockstep(cfg, params, lanes, prompt_bucket, max_seq):
    """The old loop as a *fair* baseline: arrival-order batches of
    ``lanes``, prompts padded to one fixed bucket and caches to one
    fixed ``max_seq``, with prefill/decode jitted ONCE up front — the
    measured gap is pure convoy tax, not compile time (the naive
    launch/serve.generate re-jits per call and would flatter the
    engine)."""
    pstep = jax.jit(lambda p, t: lm.prefill(cfg, p, t, max_seq=max_seq))

    @jax.jit
    def dstep(p, caches, tok, pos):
        lg, caches = lm.serve_step(cfg, p, caches, tok, pos)
        return jnp.argmax(lg, -1)[:, None].astype(jnp.int32), caches

    def serve(reqs):
        latencies, t0 = {}, time.perf_counter()
        for base in range(0, len(reqs), lanes):
            batch = reqs[base:base + lanes]
            toks = np.zeros((lanes, prompt_bucket), np.int32)
            for i, r in enumerate(batch):
                toks[i, :len(r.tokens)] = r.tokens
            gen = max(r.max_new_tokens for r in batch)
            logits, caches = pstep(params, jnp.asarray(toks))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            for s in range(gen - 1):
                tok, caches = dstep(params, caches, tok,
                                    jnp.int32(prompt_bucket + s))
            jax.block_until_ready(tok)
            done = time.perf_counter() - t0
            for r in batch:
                latencies[r.rid] = done   # everyone waits for the convoy
        return time.perf_counter() - t0, latencies
    return serve


def make_shared_traffic(rng, n_requests, vocab):
    """Shared-system-prompt convoy: every request opens with the same
    SYS_LEN tokens (the per-tenant prompt shape) plus a short unique
    tail — the workload prefix caching exists for."""
    system = rng.integers(0, vocab, SYS_LEN).tolist()
    return [serving.Request(
        rid=rid,
        tokens=system + rng.integers(
            0, vocab, int(rng.integers(*TAIL_RANGE))).tolist(),
        max_new_tokens=int(SHARE_GENS[rid % len(SHARE_GENS)]), seed=rid)
        for rid in range(n_requests)]


def make_zipf_traffic(rng, n_requests, vocab, n_prompts=6):
    """Zipf-distributed repeats over a small prompt population —
    realistic cache-hit structure without a designed shared prefix."""
    population = [rng.integers(0, vocab,
                               int(rng.integers(16, 41))).tolist()
                  for _ in range(n_prompts)]
    ranks = np.minimum(rng.zipf(1.3, size=n_requests) - 1, n_prompts - 1)
    return [serving.Request(rid=rid, tokens=population[int(k)],
                            max_new_tokens=int(SHARE_GENS[rid % 3]),
                            seed=rid)
            for rid, k in enumerate(ranks)]


def _serve_prefix(cfg, params, sv, reqs, prefix_cache):
    """One engine pass over ``reqs``; returns (seconds, engine) with the
    drain leak count asserted into the engine's scheduler."""
    import dataclasses
    engine = serving.Engine(cfg, params,
                            dataclasses.replace(sv,
                                                prefix_cache=prefix_cache))
    warm = make_traffic(np.random.default_rng(1), sv.max_lanes,
                        sv.max_lanes, cfg.vocab, 2, (2,))
    engine.run(warm)
    if prefix_cache:
        # a same-prompt pair with a non-chunk-aligned prefix forces one
        # COW, compiling the page-clone step outside the measured run
        wrng = np.random.default_rng(2)
        wsys = wrng.integers(0, cfg.vocab, SYS_LEN).tolist()
        for i in range(2):      # sequential: second run hits, COWs
            engine.run([serving.Request(rid=10 ** 6 + i,
                                        tokens=wsys + [int(i)] * 3,
                                        max_new_tokens=2, seed=i)])
    sched = engine.sched
    sched.prefix_hits = sched.prefix_lookups = 0     # report post-warm
    sched.cow_copies = sched.trie_evictions = 0
    t0 = time.perf_counter()
    engine.run(reqs)
    return time.perf_counter() - t0, engine


def _leaked(engine):
    """Pages still allocated after drain that the trie does not hold."""
    trie = engine.sched.trie
    return engine.pool.in_use - (trie.reclaimable() if trie else 0)


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def run(smoke: bool = False, json_path=None, preset: str = "bench-smoke",
        check: bool = False):
    n_requests = 16 if smoke else 32
    long_gen = LONG_GEN
    # pool sized to the worst case (lanes x pages-per-request + trash):
    # the arena rides through every bucketed call, so oversizing it is
    # pure per-step copy tax (DESIGN.md §12)
    spec = api.with_overrides(api.preset(preset), {
        "model.variant": "tiny",
        "serving.page_size": 8, "serving.n_pages": 44,
        "serving.max_lanes": 4, "serving.prefill_chunk": 16,
        "serving.max_seq": 96,
        "serving.max_new_tokens": long_gen})
    cfg = api.validate(spec)
    sv = spec.serving
    params = lm.init_params(cfg, jax.random.PRNGKey(spec.run.seed))
    rng = np.random.default_rng(spec.run.seed)
    reqs = make_traffic(rng, n_requests, sv.max_lanes, cfg.vocab,
                        long_gen, SHORT_GENS)

    # warm both paths so the comparison is steady-state, not compile time
    warm = make_traffic(np.random.default_rng(1), sv.max_lanes,
                        sv.max_lanes, cfg.vocab, 2, (2,))
    engine = serving.Engine(cfg, params, sv)
    engine.run(warm)
    engine.n_prefill_calls = engine.n_decode_steps = 0   # report post-warm
    lockstep = make_lockstep(cfg, params, sv.max_lanes, PROMPT_RANGE[1],
                             max_seq=PROMPT_RANGE[1] + long_gen + 1)
    lockstep(warm)

    t0 = time.perf_counter()
    results = engine.run(reqs)
    dt_engine = time.perf_counter() - t0
    lat_engine = [r.t_done - r.t_submit for r in results]
    # -1 = old jax without _cache_size(); the promise is unobservable then
    assert engine.n_compiles() in (2, -1), "bucket promise broken"

    dt_lock, lat_lock = lockstep(reqs)

    # --- prefix-sharing scenarios (same spec, prefix_cache toggled) ---
    share_rng = np.random.default_rng(spec.run.seed + 1)
    shared = make_shared_traffic(share_rng, n_requests, cfg.vocab)
    dt_off, eng_off = _serve_prefix(cfg, params, sv, shared, False)
    dt_on, eng_on = _serve_prefix(cfg, params, sv, shared, True)
    share_ratio = dt_off / dt_on
    hit_rate = eng_on.sched.page_hit_rate
    cow_copies = eng_on.sched.cow_copies
    leaked = _leaked(eng_on) + _leaked(eng_off)
    dt_zipf, eng_zipf = _serve_prefix(
        cfg, params, sv, make_zipf_traffic(share_rng, n_requests,
                                           cfg.vocab), True)
    zipf_hit_rate = eng_zipf.sched.page_hit_rate
    leaked += _leaked(eng_zipf)

    rps_e, rps_l = n_requests / dt_engine, n_requests / dt_lock
    speedup = rps_e / rps_l
    rows = common.emit([
        ("serving_engine_req", dt_engine * 1e6 / n_requests,
         f"{rps_e:.1f} req/s ({engine.n_prefill_calls} prefill + "
         f"{engine.n_decode_steps} decode calls)"),
        ("serving_lockstep_req", dt_lock * 1e6 / n_requests,
         f"{rps_l:.1f} req/s"),
        ("serving_engine_p50_ms", _pct(lat_engine, 50) * 1e3,
         f"p99 {_pct(lat_engine, 99) * 1e3:.0f} ms"),
        ("serving_lockstep_p50_ms", _pct(list(lat_lock.values()), 50) * 1e3,
         f"p99 {_pct(list(lat_lock.values()), 99) * 1e3:.0f} ms"),
        ("serving_speedup", 0.0, f"{speedup:.2f}x request throughput"),
        ("serving_shared_prefix_on", dt_on * 1e6 / n_requests,
         f"hit rate {hit_rate:.2f}, {cow_copies} COW copies"),
        ("serving_shared_prefix_off", dt_off * 1e6 / n_requests,
         f"{share_ratio:.2f}x from sharing"),
        ("serving_zipf_hit_rate", 0.0,
         f"{zipf_hit_rate:.2f} over zipf(1.3) repeats"),
    ])
    if json_path:
        common.write_json(json_path, {
            "bench": "serving",
            "traffic": {"n_requests": n_requests, "long_gen": long_gen,
                        "short_gens": list(SHORT_GENS),
                        "prompt_range": list(PROMPT_RANGE)},
            "engine": {"seconds": dt_engine, "req_per_s": rps_e,
                       "p50_s": _pct(lat_engine, 50),
                       "p99_s": _pct(lat_engine, 99),
                       "prefill_calls": engine.n_prefill_calls,
                       "decode_steps": engine.n_decode_steps,
                       "compiles": engine.n_compiles()},
            "lockstep": {"seconds": dt_lock, "req_per_s": rps_l,
                         "p50_s": _pct(list(lat_lock.values()), 50),
                         "p99_s": _pct(list(lat_lock.values()), 99)},
            "speedup": speedup,
            "sharing": {"on_seconds": dt_on, "off_seconds": dt_off,
                        "ratio": share_ratio,
                        "page_hit_rate": hit_rate,
                        "cow_copies": cow_copies,
                        "trie_evictions": eng_on.sched.trie_evictions,
                        "zipf_seconds": dt_zipf,
                        "zipf_hit_rate": zipf_hit_rate,
                        "leaked_pages": leaked,
                        "sys_len": SYS_LEN, "tail_range": list(TAIL_RANGE)},
            "tripwires": {
                "serving_speedup": {
                    "ok": speedup >= MIN_SPEEDUP, "value": speedup,
                    "limit": MIN_SPEEDUP,
                    "note": "engine vs lockstep request throughput "
                            "(continuous batching broken below this)"},
                "serving_page_hit_rate": {
                    "ok": hit_rate >= MIN_HIT_RATE, "value": hit_rate,
                    "limit": MIN_HIT_RATE,
                    "note": "shared-system-prompt convoy: fraction of "
                            "prompt pages served from the prefix trie"},
                "serving_sharing_throughput": {
                    "ok": share_ratio >= MIN_SHARE_RATIO,
                    "value": share_ratio, "limit": MIN_SHARE_RATIO,
                    "note": "sharing-on vs sharing-off wall time on the "
                            "shared convoy (below 1.0 sharing costs more "
                            "than it saves)"},
                "serving_page_leaks": {
                    "ok": leaked == 0, "value": leaked, "limit": 0,
                    "note": "pages still allocated after drain that the "
                            "prefix trie does not account for"}},
            "rows": common.rows_to_json(rows),
        }, spec=spec)
    if check:
        fails = []
        if speedup < MIN_SPEEDUP:
            fails.append(f"speedup {speedup:.2f}x < {MIN_SPEEDUP}x")
        if hit_rate < MIN_HIT_RATE:
            fails.append(f"page hit rate {hit_rate:.2f} < {MIN_HIT_RATE}")
        if share_ratio < MIN_SHARE_RATIO:
            fails.append(f"sharing ratio {share_ratio:.2f}x < "
                         f"{MIN_SHARE_RATIO}x")
        if leaked:
            fails.append(f"{leaked} leaked pages after drain")
        if fails:
            raise SystemExit("serving tripwires failed: "
                             + "; ".join(fails))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_serving.json here")
    ap.add_argument("--preset", default="bench-smoke")
    ap.add_argument("--check", action="store_true",
                    help=f"exit nonzero when speedup < {MIN_SPEEDUP}x, "
                         f"convoy page hit rate < {MIN_HIT_RATE}, sharing "
                         "runs slower than not sharing, or pages leak")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, json_path=args.json, preset=args.preset,
        check=args.check)


if __name__ == "__main__":
    main()
