"""Continuous batching vs lockstep serving on mixed-length traffic.

The lockstep loop (launch/serve.generate) pays the convoy tax twice:
every prompt in a batch is padded to the longest, and every lane decodes
until the *slowest* request's budget — a batch with one 8x-longer
generation runs 8x decode steps for everyone.  The paged engine
(repro/serving/, DESIGN.md §12) frees lanes the moment a request
finishes and admits the next one, so wall time tracks *total* tokens,
not ``batches x max``.

Traffic: each arrival group of ``max_lanes`` requests holds one
long-generation request and ``lanes-1`` short ones (the convoy shape).
Writes BENCH_serving.json — request throughput, p50/p99 latency, engine
vs lockstep speedup — which CI uploads next to BENCH_fused.json.  The
acceptance target is engine >= 2x lockstep request throughput
(measured 2.0-2.6x on CPU smoke sizes, recorded in the JSON);
``--check`` enforces the MIN_SPEEDUP regression tripwire (1.5x, below
which continuous batching is broken, with headroom for noisy CI boxes)
and ``make bench-smoke`` runs with it.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).parent.parent))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks import common  # noqa: E402
from repro import api, serving  # noqa: E402
from repro.models import lm  # noqa: E402

LONG_GEN, SHORT_GENS = 64, (2, 4, 6)
PROMPT_RANGE = (4, 16)
MIN_SPEEDUP = 1.5     # --check tripwire; the acceptance target is 2x


def make_traffic(rng, n_requests, lanes, vocab, long_gen, short_gens):
    """One long-generation request per arrival group of ``lanes``."""
    reqs = []
    for rid in range(n_requests):
        gen = (long_gen if rid % lanes == 0
               else int(short_gens[rid % len(short_gens)]))
        plen = int(rng.integers(*PROMPT_RANGE))
        reqs.append(serving.Request(
            rid=rid, tokens=rng.integers(0, vocab, plen).tolist(),
            max_new_tokens=gen, seed=rid))
    return reqs


def make_lockstep(cfg, params, lanes, prompt_bucket, max_seq):
    """The old loop as a *fair* baseline: arrival-order batches of
    ``lanes``, prompts padded to one fixed bucket and caches to one
    fixed ``max_seq``, with prefill/decode jitted ONCE up front — the
    measured gap is pure convoy tax, not compile time (the naive
    launch/serve.generate re-jits per call and would flatter the
    engine)."""
    pstep = jax.jit(lambda p, t: lm.prefill(cfg, p, t, max_seq=max_seq))

    @jax.jit
    def dstep(p, caches, tok, pos):
        lg, caches = lm.serve_step(cfg, p, caches, tok, pos)
        return jnp.argmax(lg, -1)[:, None].astype(jnp.int32), caches

    def serve(reqs):
        latencies, t0 = {}, time.perf_counter()
        for base in range(0, len(reqs), lanes):
            batch = reqs[base:base + lanes]
            toks = np.zeros((lanes, prompt_bucket), np.int32)
            for i, r in enumerate(batch):
                toks[i, :len(r.tokens)] = r.tokens
            gen = max(r.max_new_tokens for r in batch)
            logits, caches = pstep(params, jnp.asarray(toks))
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            for s in range(gen - 1):
                tok, caches = dstep(params, caches, tok,
                                    jnp.int32(prompt_bucket + s))
            jax.block_until_ready(tok)
            done = time.perf_counter() - t0
            for r in batch:
                latencies[r.rid] = done   # everyone waits for the convoy
        return time.perf_counter() - t0, latencies
    return serve


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def run(smoke: bool = False, json_path=None, preset: str = "bench-smoke",
        check: bool = False):
    n_requests = 16 if smoke else 32
    long_gen = LONG_GEN
    # pool sized to the worst case (lanes x pages-per-request + trash):
    # the arena rides through every bucketed call, so oversizing it is
    # pure per-step copy tax (DESIGN.md §12)
    spec = api.with_overrides(api.preset(preset), {
        "model.variant": "tiny",
        "serving.page_size": 8, "serving.n_pages": 44,
        "serving.max_lanes": 4, "serving.prefill_chunk": 16,
        "serving.max_seq": 96,
        "serving.max_new_tokens": long_gen})
    cfg = api.validate(spec)
    sv = spec.serving
    params = lm.init_params(cfg, jax.random.PRNGKey(spec.run.seed))
    rng = np.random.default_rng(spec.run.seed)
    reqs = make_traffic(rng, n_requests, sv.max_lanes, cfg.vocab,
                        long_gen, SHORT_GENS)

    # warm both paths so the comparison is steady-state, not compile time
    warm = make_traffic(np.random.default_rng(1), sv.max_lanes,
                        sv.max_lanes, cfg.vocab, 2, (2,))
    engine = serving.Engine(cfg, params, sv)
    engine.run(warm)
    engine.n_prefill_calls = engine.n_decode_steps = 0   # report post-warm
    lockstep = make_lockstep(cfg, params, sv.max_lanes, PROMPT_RANGE[1],
                             max_seq=PROMPT_RANGE[1] + long_gen + 1)
    lockstep(warm)

    t0 = time.perf_counter()
    results = engine.run(reqs)
    dt_engine = time.perf_counter() - t0
    lat_engine = [r.t_done - r.t_submit for r in results]
    # -1 = old jax without _cache_size(); the promise is unobservable then
    assert engine.n_compiles() in (2, -1), "bucket promise broken"

    dt_lock, lat_lock = lockstep(reqs)

    rps_e, rps_l = n_requests / dt_engine, n_requests / dt_lock
    speedup = rps_e / rps_l
    rows = common.emit([
        ("serving_engine_req", dt_engine * 1e6 / n_requests,
         f"{rps_e:.1f} req/s ({engine.n_prefill_calls} prefill + "
         f"{engine.n_decode_steps} decode calls)"),
        ("serving_lockstep_req", dt_lock * 1e6 / n_requests,
         f"{rps_l:.1f} req/s"),
        ("serving_engine_p50_ms", _pct(lat_engine, 50) * 1e3,
         f"p99 {_pct(lat_engine, 99) * 1e3:.0f} ms"),
        ("serving_lockstep_p50_ms", _pct(list(lat_lock.values()), 50) * 1e3,
         f"p99 {_pct(list(lat_lock.values()), 99) * 1e3:.0f} ms"),
        ("serving_speedup", 0.0, f"{speedup:.2f}x request throughput"),
    ])
    if json_path:
        common.write_json(json_path, {
            "bench": "serving",
            "traffic": {"n_requests": n_requests, "long_gen": long_gen,
                        "short_gens": list(SHORT_GENS),
                        "prompt_range": list(PROMPT_RANGE)},
            "engine": {"seconds": dt_engine, "req_per_s": rps_e,
                       "p50_s": _pct(lat_engine, 50),
                       "p99_s": _pct(lat_engine, 99),
                       "prefill_calls": engine.n_prefill_calls,
                       "decode_steps": engine.n_decode_steps,
                       "compiles": engine.n_compiles()},
            "lockstep": {"seconds": dt_lock, "req_per_s": rps_l,
                         "p50_s": _pct(list(lat_lock.values()), 50),
                         "p99_s": _pct(list(lat_lock.values()), 99)},
            "speedup": speedup,
            "tripwires": {"serving_speedup": {
                "ok": speedup >= MIN_SPEEDUP, "value": speedup,
                "limit": MIN_SPEEDUP,
                "note": "engine vs lockstep request throughput "
                        "(continuous batching broken below this)"}},
            "rows": common.rows_to_json(rows),
        }, spec=spec)
    if check and speedup < MIN_SPEEDUP:
        raise SystemExit(f"serving speedup regression: {speedup:.2f}x < "
                         f"{MIN_SPEEDUP}x tripwire")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write BENCH_serving.json here")
    ap.add_argument("--preset", default="bench-smoke")
    ap.add_argument("--check", action="store_true",
                    help=f"exit nonzero when speedup < {MIN_SPEEDUP}x "
                         "(the continuous-batching regression tripwire)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, json_path=args.json, preset=args.preset,
        check=args.check)


if __name__ == "__main__":
    main()
