# One memorable entry point per CI stage.
#   make test-fast   - tier-1: every test not marked `slow` (<~90s on CPU);
#                      this is what .github/workflows/ci.yml runs per push
#   make test        - tier-2: the full suite (the ROADMAP.md verify command)
#   make bench-smoke - fast estimator-sweep + fused-runtime + serving +
#                      stage-breakdown benchmarks on CPU (interpret-mode
#                      kernels), driven by the shared `bench-smoke` spec
#                      preset; writes BENCH_fused.json, BENCH_serving.json,
#                      BENCH_step.json (+ a sample obs span trace) and
#                      gates every artifact's tripwires via run.py --check;
#                      then exercises the run registry end to end: a short
#                      `launch train` with health telemetry on writes
#                      artifacts/runs/<run_id>/, `launch report` renders
#                      its health report, and `launch replay` re-executes
#                      the run and verifies every recorded scalar bitwise
#   make swarm-smoke - distributed-swarm gate: the scaling/bytes-per-step
#                      benchmark with its tripwires (BENCH_dist.json), then
#                      a 2-worker swarm run that hard-kills a worker
#                      mid-run (chaos_crash) and recovers through the
#                      elastic-rejoin path, verified bit-for-bit by
#                      `launch replay`
#   make specs       - dump every repro.api preset to artifacts/specs/
#                      (the serialized experiment-spec surface CI archives)
#   make docs        - regenerate the generated docs (docs/cli.md and the
#                      serving spec table in docs/serving.md) from the live
#                      spec schema; idempotent, and CI fails on any diff
#   make lint        - bytecode-compile everything (+ ruff when installed)

PY ?= python
export PYTHONPATH := src

.PHONY: test test-fast bench-smoke swarm-smoke specs docs lint

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -q -m "not slow"

bench-smoke:
	$(PY) benchmarks/estimator_sweep.py --smoke --preset bench-smoke
	$(PY) benchmarks/fused_forward.py --smoke --preset bench-smoke --json BENCH_fused.json --check
	$(PY) benchmarks/serving.py --smoke --preset bench-smoke --json BENCH_serving.json --check
	$(PY) benchmarks/step_time.py --smoke --preset bench-smoke --json BENCH_step.json --jsonl BENCH_step_trace.jsonl --check
	$(PY) benchmarks/run.py --collect-only --check
	$(PY) -m repro.launch train --preset tiny-smoke --telemetry true \
		--set run.eval_every=0 --set telemetry.health_norms=true
	$(PY) -m repro.launch report --out artifacts/runs/report.md
	$(PY) -m repro.launch replay

swarm-smoke:
	$(PY) benchmarks/distributed.py --smoke --json BENCH_dist.json --check
	$(PY) -m repro.launch swarm --preset swarm-smoke \
		--set run.steps=30 --set run.ckpt_every=10 \
		--set run.ckpt_dir=artifacts/swarm-ckpt \
		--set swarm.chaos_crash=1:3 --set swarm.chaos_seed=7 \
		--out artifacts/swarm.json
	$(PY) -m repro.launch replay
	$(PY) benchmarks/run.py --collect-only --check

specs:
	$(PY) -m repro.launch specs --out artifacts/specs

docs:
	$(PY) -m repro.launch specs --out artifacts/specs --markdown docs

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples; \
	else \
		echo "lint: compileall passed (ruff not installed)"; \
	fi
