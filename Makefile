# One memorable entry point per CI stage.
#   make test        - tier-1 suite (the ROADMAP.md verify command)
#   make bench-smoke - fast estimator-sweep benchmark on CPU interpret mode
#   make lint        - bytecode-compile everything (+ ruff when installed)

PY ?= python
export PYTHONPATH := src

.PHONY: test bench-smoke lint

test:
	$(PY) -m pytest -x -q

bench-smoke:
	$(PY) benchmarks/estimator_sweep.py --smoke

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests benchmarks examples; \
	else \
		echo "lint: compileall passed (ruff not installed)"; \
	fi
