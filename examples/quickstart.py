"""Quickstart: fine-tune a small OPT-family model with LeZO vs MeZO
through the unified experiment API (DESIGN.md §11).

Run:  PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core claim at CPU scale: LeZO (75% of layers
dropped per step) converges at least as fast as MeZO per *step* while
doing ~4x less perturbation/update work per step.  Every scenario below
is a spec diff on the same preset — no hand-wired config plumbing.
"""
import sys, pathlib, time
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro import api, estimators
from repro.core import zo
from repro.models import lm

BASE = api.with_overrides(api.preset("tiny-smoke"), {
    "task.signal_rate": 0.35, "model.seq_len": 64,
    "optimizer.lr": 3e-4,
    "run.steps": 400, "run.batch_size": 16,
    "run.eval_every": 100, "run.log_every": 100,
})

for name, sparsity in [("MeZO", 0.0), ("LeZO (75% sparse)", 0.75)]:
    spec = api.with_overrides(BASE, {"optimizer.sparsity": sparsity})
    h = api.run(spec)["history"]
    print(f"{name:20s} loss: " + " -> ".join(f"{x:.3f}" for x in h["loss"])
          + f"   val_acc: {h['val_acc']}")

# --- virtual-perturbation fused runtime (repro.fused, DESIGN.md §10) ---
# The same two-point step with runtime.forward_backend="virtual"
# evaluates both probes against in-kernel-regenerated perturbed weights:
# the perturb and restore parameter sweeps vanish and only the update
# axpy writes theta.  Timed here at a perturb-heavy params/token ratio
# (the paper's regime) via the bench-smoke preset; "virtual_ref" is the
# pure-JAX oracle — the Pallas kernel path (forward_backend="virtual")
# produces the same floats on TPU.
bspec = api.preset("bench-smoke")
bd = api.derive(bspec)
bcfg = bd.model_cfg
bparams = lm.init_params(bcfg, jax.random.PRNGKey(0))
bzospec = zo.build_spec(bparams, lm.zo_group_fn)
bbatch = {"tokens": (toks := jnp.zeros((8, 32), jnp.int32)), "labels": toks,
          "loss_mask": jnp.ones((8, 32), jnp.float32)}
bloss = lambda p, b, perturb=None: lm.lm_loss(bcfg, p, b, perturb=perturb)

times = {}
for fb in ("materialized", "virtual_ref"):
    ecfg = api.derive(api.with_overrides(
        bspec, {"optimizer.sparsity": 0.75,
                "optimizer.lr": 3e-4,
                "runtime.forward_backend": fb})).est_cfg
    step, init = estimators.make_step(bloss, bzospec, ecfg)
    step = jax.jit(step)
    jax.block_until_ready(step(bparams, init(), bbatch, jnp.int32(0),
                               jnp.uint32(1)))          # compile
    t0 = time.perf_counter()
    for t in range(3):
        jax.block_until_ready(step(bparams, init(), bbatch, jnp.int32(t),
                                   jnp.uint32(1)))
    times[fb] = (time.perf_counter() - t0) / 3
    sweeps = estimators.costs.step_counts("two_point",
                                          forward_backend=fb)["axpy_sweeps"]
    print(f"two_point step [{fb:12s}] {times[fb]*1e3:7.1f} ms/step "
          f"(param sweeps: {sweeps})")
print(f"virtual vs materialized: "
      f"{times['materialized'] / times['virtual_ref']:.2f}x "
      f"(sweeps 3 -> 1; kernel path removes the remaining temp traffic "
      f"on TPU)")
