"""Quickstart: fine-tune a small OPT-family model with LeZO vs MeZO.

Run:  PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core claim at CPU scale: LeZO (75% of layers
dropped per step) converges at least as fast as MeZO per *step* while
doing ~4x less perturbation/update work per step.
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.configs import opt
from repro.core import zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig

mcfg = opt.opt_tiny(layers=4, d_model=128, vocab=512)
task = synthetic.TaskConfig(vocab=512, seq_len=64, n_classes=2,
                            signal_rate=0.35)
STEPS = 400

for name, n_drop in [("MeZO", 0), ("LeZO (75% sparse)", 3)]:
    tr = Trainer(mcfg, task,
                 TrainConfig(steps=STEPS, batch_size=16, eval_every=100,
                             log_every=100),
                 zo_cfg=zo.ZOConfig(eps=1e-3, lr=3e-4, n_drop=n_drop,
                                    backend="scan"))
    h = tr.train()
    print(f"{name:20s} loss: " + " -> ".join(f"{x:.3f}" for x in h["loss"])
          + f"   val_acc: {h['val_acc']}")
