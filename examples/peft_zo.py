"""LeZO composed with PEFT (paper Table 4): LoRA and prefix tuning,
each a two-line spec diff on the shared preset (DESIGN.md §11).

Run:  PYTHONPATH=src python examples/peft_zo.py

Only the PEFT parameters are perturbed/updated; the base model is
frozen.  LeZO's layer dropping applies to the PEFT tree's layer groups.
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro import api

BASE = api.with_overrides(api.preset("tiny-smoke"), {
    "task.signal_rate": 0.35, "model.seq_len": 64,
    "optimizer.n_drop": 2, "runtime.backend": "dense",
    "run.steps": 300, "run.batch_size": 16,
    "run.eval_every": 100, "run.log_every": 100,
})

for peft, lr, eps in [("lora", 3e-3, 1e-2), ("prefix", 1e-2, 1e-1)]:
    spec = api.with_overrides(BASE, {"runtime.peft": peft,
                                     "optimizer.lr": lr,
                                     "optimizer.eps": eps})
    h = api.run(spec)["history"]
    print(f"LeZO({peft}): loss " + " -> ".join(f"{x:.3f}" for x in h["loss"])
          + f"   val_acc: {h['val_acc']}")
