"""LeZO composed with PEFT (paper Table 4): LoRA and prefix tuning.

Run:  PYTHONPATH=src python examples/peft_zo.py

Only the PEFT parameters are perturbed/updated; the base model is
frozen.  LeZO's layer dropping applies to the PEFT tree's layer groups.
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro.configs import opt
from repro.core import zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig

mcfg = opt.opt_tiny(layers=4, d_model=128, vocab=512)
task = synthetic.TaskConfig(vocab=512, seq_len=64, n_classes=2,
                            signal_rate=0.35)

for peft, lr, eps in [("lora", 3e-3, 1e-2), ("prefix", 1e-2, 1e-1)]:
    tr = Trainer(mcfg, task,
                 TrainConfig(steps=300, batch_size=16, eval_every=100,
                             log_every=100, peft=peft),
                 zo_cfg=zo.ZOConfig(eps=eps, lr=lr, n_drop=2,
                                    backend="dense"))
    h = tr.train()
    print(f"LeZO({peft}): loss " + " -> ".join(f"{x:.3f}" for x in h["loss"])
          + f"   val_acc: {h['val_acc']}")
