"""Serving example: prefill + batched greedy decode on the smoke configs
of three different architecture families (dense GQA, MoE+MLA, xLSTM).

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import generate
from repro.models import lm

for arch in ["internlm2-1.8b", "deepseek-v2-lite-16b", "xlstm-350m"]:
    cfg = configs.get(arch, "smoke")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 24)), jnp.int32)
    out = generate(cfg, params, toks, gen_steps=8, max_seq=40)
    print(f"{arch:24s} generated: {np.asarray(out[0])}")
