"""Serving example: the continuous-batching paged engine on an attn
arch, and the lockstep prefill+decode loop on the families the engine
does not cover (MoE+MLA, xLSTM) — see DESIGN.md §12.

Run:  PYTHONPATH=src python examples/serve_demo.py

Everything flows through the unified experiment spec (DESIGN.md §11):
the model config comes from ``api.derive(spec)`` and the engine is
built straight from the spec's ``serving`` section.
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import api, serving
from repro.launch.serve import generate
from repro.models import lm

# -- paged engine: mixed-length requests share one KV arena
spec = api.with_overrides(api.preset("default"), {
    "model.arch": "internlm2-1.8b", "model.variant": "smoke",
    "serving.page_size": 4, "serving.n_pages": 32, "serving.max_lanes": 2,
    "serving.prefill_chunk": 8, "serving.max_seq": 64,
})
cfg = api.derive(spec).model_cfg
params = lm.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
engine = serving.Engine(cfg, params, spec.serving)
reqs = [serving.Request(rid=i, tokens=rng.integers(0, cfg.vocab, n).tolist(),
                        max_new_tokens=g, seed=i)
        for i, (n, g) in enumerate([(24, 8), (9, 4), (17, 6)])]
for r in sorted(engine.run(reqs), key=lambda r: r.rid):
    print(f"{cfg.name:24s} engine rid={r.rid} prompt={r.prompt_len:2d} "
          f"-> {r.tokens}")

# -- lockstep loop: the fallback path for non-attn mixers
for arch in ["deepseek-v2-lite-16b", "xlstm-350m"]:
    cfg = api.derive(api.with_overrides(
        spec, {"model.arch": arch})).model_cfg
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 24)), jnp.int32)
    out = generate(cfg, params, toks, gen_steps=8, max_seq=40)
    print(f"{arch:24s} lockstep generated: {np.asarray(out[0])}")
