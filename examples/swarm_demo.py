"""Swarm demo: kill a worker mid-run, watch the swarm recover —
and prove the result with a bit-for-bit replay (DESIGN.md §14).

Run:  PYTHONPATH=src python examples/swarm_demo.py

Two local worker processes train one spec data-parallel.  The only
cross-process traffic is scalars: each worker ships an ``(l+, l-)``
float pair per batch shard and receives the committed ``(seed, g)``
pair back — a few hundred bytes per step regardless of model size.

Chaos hard-kills worker 1 at step 3 (``os._exit`` — no cleanup).  The
coordinator bumps the membership epoch, reassigns the dead worker's
shards, and the survivor recomputes them, so every step still commits.
The supervisor respawns the slot; the replacement joins **elastically**:
it attaches with nothing but the address, restores the newest
checkpoint, fetches the committed ``(seed, g)`` backlog, and folds it
forward — arriving bit-identical without a single weight on the wire.

The punchline: the chaos run's recorded scalar stream replays clean,
and it matches a run that never crashed at all.
"""
import json, pathlib, shutil, sys, tempfile
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

from repro import api
from repro.launch import replay
from repro.swarm import driver

root = pathlib.Path(tempfile.mkdtemp(prefix="swarm_demo_"))
BASE = api.with_overrides(api.preset("swarm-smoke"), {
    "run.steps": 40, "run.ckpt_every": 10,
    "run.ckpt_dir": str(root / "ckpt"),
})


def scalar_stream(runs_dir):
    (run_dir,) = [d for d in pathlib.Path(runs_dir).iterdir() if d.is_dir()]
    with open(run_dir / "steps.jsonl") as f:
        rows = [json.loads(line) for line in f]
    return run_dir, [(r["step"], r["loss"], r["projected_grad"]) for r in rows]


try:
    # calm run: 2 workers, nobody dies
    calm = driver.run_swarm(api.with_overrides(
        BASE, {"run.ckpt_dir": str(root / "ckpt_calm")}),
        runs_root=str(root / "calm"))
    _, calm_rows = scalar_stream(root / "calm")
    print(f"calm:  {calm['steps']} steps, epochs={calm['membership_epochs']}"
          f", {calm['steady_bytes_per_step']:.0f} wire B/step")

    # chaos run: worker 1 is hard-killed at step 3 and respawned
    chaos = driver.run_swarm(api.with_overrides(BASE, {
        "swarm.chaos_crash": "1:3", "swarm.chaos_seed": 7}),
        runs_root=str(root / "chaos"))
    run_dir, chaos_rows = scalar_stream(root / "chaos")
    print(f"chaos: {chaos['steps']} steps, epochs="
          f"{chaos['membership_epochs']} (death + elastic rejoin), "
          f"exits={chaos['worker_exits']}, respawns={chaos['respawns']}")

    assert 43 in chaos["worker_exits"], "chaos crash should have fired"
    assert chaos_rows == calm_rows, \
        "crash + rejoin must not change a single committed bit"
    print("chaos scalar stream == calm scalar stream: True")

    out = replay.replay_run(str(run_dir))
    print(f"replay of the chaos run: ok={out['ok']}")
    for check in out["checks"]:
        print(f"  - {check}")
    assert out["ok"]
finally:
    shutil.rmtree(root, ignore_errors=True)
print("OK")
