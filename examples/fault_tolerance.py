"""Fault tolerance demo: checkpoint, crash, resume — bit-identical stream.

Run:  PYTHONPATH=src python examples/fault_tolerance.py

Because every LeZO update is a pure function of (base_seed, step), a
restore reproduces the exact parameter trajectory the uninterrupted run
would have produced.  Also shows the straggler loss-quorum mode.  Every
scenario is a spec diff on the unified experiment API (DESIGN.md §11) —
the multi-process version of the same story is examples/swarm_demo.py.
"""
import sys, pathlib, shutil, tempfile
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro import api

ckpt = tempfile.mkdtemp(prefix="lezo_ckpt_")
BASE = api.with_overrides(api.preset("tiny-smoke"), {
    "model.seq_len": 48, "optimizer.lr": 2e-4,
    "run.steps": 60, "run.batch_size": 8,
    "run.eval_every": 0, "run.log_every": 0,
})

# uninterrupted run
h_full = api.run(BASE)["history"]

# run that checkpoints every 20 steps, "crashes" at 30, resumes
CKPT = {"run.ckpt_dir": ckpt, "run.ckpt_every": 20}
api.run(api.with_overrides(BASE, {**CKPT, "run.steps": 30}))  # dies at 30
h_resumed = api.run(api.with_overrides(BASE, CKPT))["history"]

diff = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
           for a, b in zip(jax.tree.leaves(h_full["final_params"]),
                           jax.tree.leaves(h_resumed["final_params"])))
print(f"max |uninterrupted - crash/resume| over all params: {diff:.2e}")
assert diff < 1e-5, "resume must reproduce the exact update stream"

# straggler quorum: 1 of 4 loss shards dropped per step
hq = api.run(api.with_overrides(BASE, {
    "run.batch_size": 16, "run.log_every": 30,
    "runtime.n_loss_shards": 4, "runtime.quorum": 0.75}))["history"]
print("quorum=0.75 loss trace:", [round(x, 3) for x in hq["loss"]])
shutil.rmtree(ckpt, ignore_errors=True)
print("OK")
