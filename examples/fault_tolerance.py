"""Fault tolerance demo: checkpoint, crash, resume — bit-identical stream.

Run:  PYTHONPATH=src python examples/fault_tolerance.py

Because every LeZO update is a pure function of (base_seed, step), a
restore reproduces the exact parameter trajectory the uninterrupted run
would have produced.  Also shows the straggler loss-quorum mode.
"""
import sys, pathlib, shutil, tempfile
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.configs import opt
from repro.core import zo
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig

mcfg = opt.opt_tiny(layers=2, d_model=64, vocab=256)
task = synthetic.TaskConfig(vocab=256, seq_len=48, n_classes=2)
zcfg = zo.ZOConfig(eps=1e-3, lr=2e-4, n_drop=1, backend="scan")
ckpt = tempfile.mkdtemp(prefix="lezo_ckpt_")

# uninterrupted run
tr = Trainer(mcfg, task, TrainConfig(steps=60, batch_size=8, eval_every=0,
                                     log_every=0), zo_cfg=zcfg)
h_full = tr.train()

# run that checkpoints every 20 steps, "crashes" at 30, resumes
tcfg = TrainConfig(steps=30, batch_size=8, eval_every=0, log_every=0,
                   ckpt_dir=ckpt, ckpt_every=20)
Trainer(mcfg, task, tcfg, zo_cfg=zcfg).train()          # dies at step 30
tcfg2 = TrainConfig(steps=60, batch_size=8, eval_every=0, log_every=0,
                    ckpt_dir=ckpt, ckpt_every=20)
h_resumed = Trainer(mcfg, task, tcfg2, zo_cfg=zcfg).train()

diff = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
           for a, b in zip(jax.tree.leaves(h_full["final_params"]),
                           jax.tree.leaves(h_resumed["final_params"])))
print(f"max |uninterrupted - crash/resume| over all params: {diff:.2e}")
assert diff < 1e-5, "resume must reproduce the exact update stream"

# straggler quorum: 1 of 4 loss shards dropped per step
trq = Trainer(mcfg, task, TrainConfig(steps=60, batch_size=16, eval_every=0,
                                      log_every=30, n_loss_shards=4,
                                      quorum=0.75), zo_cfg=zcfg)
hq = trq.train()
print("quorum=0.75 loss trace:", [round(x, 3) for x in hq["loss"]])
shutil.rmtree(ckpt, ignore_errors=True)
print("OK")
