"""Toy deterministic tokenizer for the offline task registry.

The container has no real tokenizer or downloaded vocab, so task prompts
are whitespace-split words hashed (FNV-1a) into the *content* region of
the model's token-id space.  The top ``N_RESERVED`` ids are reserved so a
task's control tokens can never collide with content words:

    vocab-1              query/answer marker (same slot synthetic.py uses)
    vocab-2 .. vocab-2-k verbalizer slots, assigned per task in order

Hashing is stable across processes and sessions (pure integer FNV), so a
dataset compiled from the same (spec, vocab, seq_len, seed) is
bit-identical everywhere — the same property core/rng.py gives the
perturbation stream.

Task registry & metric protocol (DESIGN.md §9).
"""
from __future__ import annotations

from typing import List

PAD = 0          # filler id; loss/score masks always exclude it
N_RESERVED = 16  # top-of-vocab ids reserved for control tokens
_CONTENT_LO = 2  # 0 = PAD, 1 = spare


def query_token(vocab: int) -> int:
    """Answer-position marker (matches synthetic.TaskConfig.query_token)."""
    return vocab - 1


def verbalizer_id(vocab: int, index: int) -> int:
    """Reserved token id for a task's index-th verbalizer word."""
    if index >= N_RESERVED - 1:
        raise ValueError(f"at most {N_RESERVED - 1} verbalizers, got index {index}")
    return vocab - 2 - index


def word_id(word: str, vocab: int) -> int:
    """FNV-1a hash of a word into the content region [2, vocab-N_RESERVED)."""
    span = vocab - N_RESERVED - _CONTENT_LO
    if span <= 0:
        raise ValueError(f"vocab {vocab} too small for content + reserved ids")
    h = 2166136261
    for ch in word.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return _CONTENT_LO + h % span


def encode(text: str, vocab: int) -> List[int]:
    """Whitespace tokenizer: one content id per word."""
    return [word_id(w, vocab) for w in text.split()]
