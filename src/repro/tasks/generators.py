"""Deterministic synthetic example generators for the task registry.

Each generator is ``fn(seed, n) -> list[dict]`` where every dict holds the
template fields plus an integer ``label``.  Two signal families (DESIGN.md
§9) mirror how the real SuperGLUE tasks are solved:

  * **lexicon** tasks (sst2, boolq, cb, wic): the class is carried by
    which word pool the content words are drawn from — the embedding
    table can learn pool→verbalizer directly (SST-2's sentiment words).
  * **overlap** tasks (rte, copa, squad_copy): the answer is carried by
    token *identity reuse* between prompt regions — requires attention,
    like entailment word-overlap or span extraction.

Everything is a pure function of (seed, n) via one ``np.default_rng``.

A task can also be backed by a JSON file instead of a generator:
:func:`json_examples` wraps a path (a list of example dicts) in the same
interface, with deterministic subsampling when ``n`` < file size.
"""
from __future__ import annotations

import json
from typing import Callable, Dict, List

import numpy as np

Example = Dict[str, object]
Generator = Callable[[int, int], List[Example]]

# Signal pools.  Some members look arbitrary ('copper', 'velvet'): the
# FNV tokenizer (vocab.py) can hash two words to one id, and a collision
# across pools leaks one class's signal into another, so every word here
# was chosen to keep ALL pools pairwise id-disjoint at the reference
# vocab=512 used by the tests/benchmarks.  tests/test_tasks.py pins this;
# when editing a pool, run it and swap any word it flags.

# Neutral filler — no class information.
NEUTRAL = ("the a an it this that was were is are on in at of for with by "
           "from as but and or so then still quite rather very really just "
           "also even both most some few each other same new old long short "
           "day time man woman city house harvest story music stream "
           "meadow").split()

POS_WORDS = ("brilliant copper moving superb charming hilarious "
             "heartfelt gorgeous").split()
NEG_WORDS = ("dreadful tedious clumsy violin grating lifeless "
             "incoherent shoddy").split()

TRUE_WORDS = ("confirmed verified documented established recorded "
              "official proven standard").split()
FALSE_WORDS = ("myth thunder hoax lantern debunked fictional "
               "alleged imaginary").split()

# CB: 3-way entailment lexicons.
CB_WORDS = (("certainly harbor undoubtedly clearly timber velvet".split()),
            ("never walnut contrary saddle marble denied".split()),
            ("cedar possibly maybe unclear ambiguous uncertain".split()))

# WiC: two "sense" topic pools sharing only the target word 'bank'.
SENSE_A = "bank amber loan deposit teller vault account credit".split()
SENSE_B = "bank shore water raven barley current bend ripple".split()

QUESTIONS = ("is the claim supported", "does the passage agree",
             "is this statement true", "can we conclude this")


def _mix(rng, pool, n_sig, n_total):
    """n_sig words from pool + neutral filler, shuffled."""
    words = list(rng.choice(pool, size=n_sig)) + \
        list(rng.choice(NEUTRAL, size=n_total - n_sig))
    rng.shuffle(words)
    return " ".join(words)


# ----------------------------------------------------------- lexicon tasks
def sst2_examples(seed: int, n: int) -> List[Example]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        label = int(rng.integers(0, 2))
        pool = (NEG_WORDS, POS_WORDS)[label]
        out.append({"text": _mix(rng, pool, 8, 20), "label": label})
    return out


def boolq_examples(seed: int, n: int) -> List[Example]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        label = int(rng.integers(0, 2))          # 0 = no, 1 = yes
        pool = (FALSE_WORDS, TRUE_WORDS)[label]
        out.append({"passage": _mix(rng, pool, 9, 16),
                    "question": str(rng.choice(QUESTIONS)),
                    "label": label})
    return out


def cb_examples(seed: int, n: int) -> List[Example]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        label = int(rng.integers(0, 3))  # entailment | contradiction | neutral
        out.append({"premise": _mix(rng, CB_WORDS[label], 6, 14),
                    "hypothesis": _mix(rng, NEUTRAL, 0, 6),
                    "label": label})
    return out


def wic_examples(seed: int, n: int) -> List[Example]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        label = int(rng.integers(0, 2))          # 1 = same sense
        first = int(rng.integers(0, 2))
        pools = (SENSE_A, SENSE_B)
        p1 = pools[first]
        p2 = pools[first if label else 1 - first]
        out.append({"word": "bank",
                    "sentence1": _mix(rng, p1, 4, 9),
                    "sentence2": _mix(rng, p2, 4, 9),
                    "label": label})
    return out


# ----------------------------------------------------------- overlap tasks
def rte_examples(seed: int, n: int) -> List[Example]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        label = int(rng.integers(0, 2))          # 0 = entailed, 1 = not
        premise = list(rng.choice(NEUTRAL, size=10, replace=False))
        if label == 0:                           # hypothesis ⊂ premise
            hyp = list(rng.choice(premise, size=5, replace=False))
        else:                                    # disjoint word set
            rest = [w for w in NEUTRAL if w not in premise]
            hyp = list(rng.choice(rest, size=5, replace=False))
        out.append({"premise": " ".join(premise),
                    "hypothesis": " ".join(hyp), "label": label})
    return out


def copa_examples(seed: int, n: int) -> List[Example]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        premise = list(rng.choice(NEUTRAL, size=8, replace=False))
        good = " ".join(rng.choice(premise, size=4, replace=False))
        rest = [w for w in NEUTRAL if w not in premise]
        bad = " ".join(rng.choice(rest, size=4, replace=False))
        label = int(rng.integers(0, 2))          # index of the good choice
        choices = (bad, good) if label else (good, bad)
        out.append({"premise": " ".join(premise),
                    "question": str(rng.choice(["cause", "effect"])),
                    "choices": choices, "label": label})
    return out


def squad_copy_examples(seed: int, n: int, answer_words: int = 4) -> List[Example]:
    """SQuAD-like extractive QA reduced to span copy: the answer is the
    ``answer_words``-word span following a cue word in the context."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        ctx = list(rng.choice(NEUTRAL, size=14, replace=False))
        start = int(rng.integers(1, len(ctx) - answer_words))
        cue = ctx[start - 1]
        answer = ctx[start:start + answer_words]
        out.append({"context": " ".join(ctx),
                    "question": f"which words follow {cue}",
                    "answer": " ".join(answer), "label": 0})
    return out


# ------------------------------------------------------------ JSON backing
def json_examples(path: str) -> Generator:
    """Wrap a JSON file (list of example dicts with ``label``) as a
    generator; ``seed`` controls the deterministic subsample order."""
    def gen(seed: int, n: int) -> List[Example]:
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, list) or not data:
            raise ValueError(f"{path}: expected a non-empty JSON list")
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, len(data), size=n) if n > len(data) else \
            rng.permutation(len(data))[:n]
        return [dict(data[int(i)]) for i in idx]
    return gen
