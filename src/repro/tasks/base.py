"""Declarative task specs and their compilation to model batches.

A :class:`TaskSpec` is the whole task as data: prompt template,
verbalizer words / choice continuations, example generator, and metric.
:func:`compile_task` binds it to a model's (vocab, seq_len) and returns a
:class:`CompiledTask` whose ``make_dataset`` emits exactly the batch
format ``data/synthetic.py`` established — ``{tokens, labels, loss_mask,
class_labels}`` (+ per-choice arrays for multiple choice) — so the model,
trainer loss, kernels, and estimators are untouched by the new subsystem.

Sequence layout (full length S; inputs = full[:, :-1], labels =
full[:, 1:], as everywhere else in the repo):

  classification    [pad ... prompt] [QUERY] [verbalizer]
  multiple_choice   [pad ... prompt] [QUERY] [continuation, A tokens]
  generation        [pad ... prompt] [QUERY] [answer, A tokens]

Prompts are right-aligned (truncated from the front) so the tokens
nearest the answer survive truncation; continuations/answers are
left-aligned and PAD-padded, with the loss/score mask excluding PAD.

Task registry & metric protocol (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.tasks import metrics as metrics_mod
from repro.tasks import vocab as vb
from repro.tasks.generators import Generator

KINDS = ("classification", "multiple_choice", "generation")
METRICS = ("accuracy", "macro_f1", "exact_match")
# Keys a model/loss batch may contain; everything else is eval-side only.
MODEL_BATCH_KEYS = ("tokens", "labels", "loss_mask", "embeds")


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One SuperGLUE-style task, declaratively."""
    name: str
    kind: str                      # classification | multiple_choice | generation
    template: str                  # "{field}"-style prompt template
    generator: Generator           # fn(seed, n) -> list of example dicts
    verbalizers: Tuple[str, ...] = ()   # classification: one word per class
    choices_field: str = "choices"      # multiple_choice: field with k strings
    answer_field: str = "answer"        # generation: field with the gold span
    metric: str = "accuracy"
    answer_len: int = 4            # continuation/answer token budget
    description: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"{self.name}: unknown kind {self.kind!r}")
        if self.metric not in METRICS:
            raise ValueError(f"{self.name}: unknown metric {self.metric!r}")
        if self.kind == "classification" and len(self.verbalizers) < 2:
            raise ValueError(f"{self.name}: classification needs >=2 verbalizers")

    @property
    def n_classes(self) -> int:
        return len(self.verbalizers) if self.kind == "classification" else 2


class CompiledTask:
    """A TaskSpec bound to (vocab, seq_len): dataset factory + metric."""

    def __init__(self, spec: TaskSpec, vocab: int, seq_len: int, seed: int = 0):
        if seq_len < spec.answer_len + 8:
            raise ValueError(f"{spec.name}: seq_len {seq_len} too short")
        self.spec, self.vocab, self.seq_len, self.seed = spec, vocab, seq_len, seed
        self.verb_ids = np.array([vb.verbalizer_id(vocab, i)
                                  for i in range(len(spec.verbalizers))],
                                 np.int32)

    # convenience mirrors of the spec
    name = property(lambda self: self.spec.name)
    kind = property(lambda self: self.spec.kind)
    metric = property(lambda self: self.spec.metric)

    # ------------------------------------------------------------ compile
    def _prompt_ids(self, ex: Dict) -> Sequence[int]:
        return vb.encode(self.spec.template.format(**ex), self.vocab)

    @staticmethod
    def _right_align(ids, width):
        """Prompts truncate from the front: tokens nearest the answer
        survive."""
        out = np.full((width,), vb.PAD, np.int64)
        ids = ids[-width:]
        out[width - len(ids):] = ids
        return out

    def _answer_ids(self, text: str, A: int, what: str, i: int):
        """Continuation/answer tokens, left-aligned into A slots.  Empty
        or over-length spans are rejected: an all-PAD continuation would
        out-score every real (negative log-prob) choice, and silent
        truncation can make two distinct choices compile identically."""
        ids = vb.encode(str(text), self.vocab)
        if not ids:
            raise ValueError(
                f"{self.spec.name}: example {i} has an empty {what}")
        if len(ids) > A:
            raise ValueError(
                f"{self.spec.name}: example {i} {what} is {len(ids)} tokens "
                f"but answer_len={A}; raise TaskSpec.answer_len")
        out = np.full((A,), vb.PAD, np.int64)
        out[:len(ids)] = ids
        return out

    def make_dataset(self, n: int, seed: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Compile n generated examples to the synthetic-batch format."""
        spec, S, V = self.spec, self.seq_len, self.vocab
        seed = self.seed if seed is None else seed
        examples = spec.generator(seed, n)

        full = np.full((n, S), vb.PAD, np.int64)
        loss_mask = np.zeros((n, S - 1), np.float32)
        class_labels = np.array([int(ex.get("label", 0)) for ex in examples],
                                np.int32)
        extras: Dict[str, np.ndarray] = {}

        if spec.kind == "classification":
            for i, ex in enumerate(examples):
                full[i, :S - 2] = self._right_align(self._prompt_ids(ex), S - 2)
            full[:, S - 2] = vb.query_token(V)
            full[:, S - 1] = self.verb_ids[class_labels]
            loss_mask[:, -1] = 1.0
        elif spec.kind in ("multiple_choice", "generation"):
            A = spec.answer_len
            W = S - 1 - A                       # prompt width; full[W] = QUERY
            full[:, W] = vb.query_token(V)
            if spec.kind == "multiple_choice":
                k = len(examples[0][spec.choices_field])
                ragged = [i for i, ex in enumerate(examples)
                          if len(ex[spec.choices_field]) != k]
                if ragged:
                    # an all-PAD phantom choice would out-score every real
                    # (negative log-prob) continuation, so reject up front
                    raise ValueError(
                        f"{spec.name}: all examples need exactly {k} "
                        f"choices; examples {ragged[:5]} differ")
                cont = np.full((n, k, A), vb.PAD, np.int64)
                for i, ex in enumerate(examples):
                    full[i, :W] = self._right_align(self._prompt_ids(ex), W)
                    for j, choice in enumerate(ex[spec.choices_field]):
                        cont[i, j] = self._answer_ids(choice, A, f"choice {j}", i)
                gold = cont[np.arange(n), class_labels]
                # all k candidate sequences, for continuation scoring
                cand = np.repeat(full[:, None], k, axis=1)
                cand[:, :, W + 1:] = cont
                extras["choice_inputs"] = cand[:, :, :-1].astype(np.int32)
                extras["choice_labels"] = cand[:, :, 1:].astype(np.int32)
                cmask = np.zeros((n, k, S - 1), np.float32)
                cmask[:, :, W:] = (cont != vb.PAD)
                extras["choice_mask"] = cmask
            else:
                gold = np.full((n, A), vb.PAD, np.int64)
                for i, ex in enumerate(examples):
                    full[i, :W] = self._right_align(self._prompt_ids(ex), W)
                    gold[i] = self._answer_ids(ex[spec.answer_field], A,
                                               "answer", i)
            full[:, W + 1:] = gold
            loss_mask[:, W:] = (gold != vb.PAD)   # label idx W+j predicts gold[j]
        else:  # pragma: no cover - guarded in TaskSpec.__post_init__
            raise ValueError(spec.kind)

        return {"tokens": full[:, :-1].astype(np.int32),
                "labels": full[:, 1:].astype(np.int32),
                "loss_mask": loss_mask, "class_labels": class_labels, **extras}

    # --------------------------------------------------------------- eval
    def predict(self, mcfg, params, dataset, lm_module, max_examples=256):
        """Per-example predictions: class ids, or (for generation) EM hits."""
        n = min(max_examples, dataset["tokens"].shape[0])
        if self.kind == "classification":
            return metrics_mod.verbalizer_predict(
                mcfg, params, dataset["tokens"][:n], self.verb_ids, lm_module)
        if self.kind == "multiple_choice":
            scores = metrics_mod.choice_scores(
                mcfg, params, dataset["choice_inputs"][:n],
                dataset["choice_labels"][:n], dataset["choice_mask"][:n],
                lm_module)
            return np.argmax(scores, axis=-1)
        return metrics_mod.exact_match_hits(
            mcfg, params, dataset["tokens"][:n], dataset["labels"][:n],
            dataset["loss_mask"][:n], lm_module)

    def evaluate(self, mcfg, params, dataset, lm_module,
                 max_examples: int = 256) -> float:
        """The task's primary metric on (up to) max_examples rows."""
        n = min(max_examples, dataset["tokens"].shape[0])
        pred = np.asarray(self.predict(mcfg, params, dataset, lm_module, n))
        gold = np.asarray(dataset["class_labels"][:n])
        if self.metric == "exact_match":
            return metrics_mod.exact_match(pred)  # pred is per-row EM already
        if self.metric == "macro_f1":
            return metrics_mod.macro_f1(pred, gold, self.spec.n_classes)
        return metrics_mod.accuracy(pred, gold)


def compile_task(spec: TaskSpec, vocab: int, seq_len: int,
                 seed: int = 0) -> CompiledTask:
    return CompiledTask(spec, vocab, seq_len, seed)
