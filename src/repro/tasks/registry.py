"""The task registry: SuperGLUE-style specs behind ``--task <name>``.

Seven built-in tasks — five classification (sst2, boolq, rte, wic, cb),
one multiple-choice (copa), one generative (squad_copy) — covering all
three metric protocols (accuracy, macro-F1, exact match) and both signal
families (lexicon / overlap, see generators.py).  ``register`` accepts
new specs at runtime, e.g. JSON-file-backed tasks built with
``generators.json_examples``.

Task registry & metric protocol (DESIGN.md §9).
"""
from __future__ import annotations

from typing import Dict, List

from repro.tasks import generators as g
from repro.tasks.base import CompiledTask, TaskSpec, compile_task

TASKS: Dict[str, TaskSpec] = {}


def register(spec: TaskSpec, overwrite: bool = False) -> TaskSpec:
    if spec.name in TASKS and not overwrite:
        raise ValueError(f"task {spec.name!r} already registered")
    TASKS[spec.name] = spec
    return spec


def get(name: str) -> TaskSpec:
    if name not in TASKS:
        raise KeyError(f"unknown task {name!r}; known: {names()}")
    return TASKS[name]


def names() -> List[str]:
    return sorted(TASKS)


def classification_names() -> List[str]:
    return [n for n in names() if TASKS[n].kind == "classification"]


def build(name: str, vocab: int, seq_len: int, seed: int = 0) -> CompiledTask:
    """Compile a registered task against a model's (vocab, seq_len)."""
    return compile_task(get(name), vocab, seq_len, seed)


register(TaskSpec(
    name="sst2", kind="classification",
    template="review : {text} . sentiment :",
    generator=g.sst2_examples, verbalizers=("terrible", "great"),
    description="SST-2 stand-in: sentiment lexicon classification"))

register(TaskSpec(
    name="boolq", kind="classification",
    template="passage : {passage} . question : {question} ? answer :",
    generator=g.boolq_examples, verbalizers=("no", "yes"),
    description="BoolQ stand-in: passage-conditioned yes/no QA"))

register(TaskSpec(
    name="rte", kind="classification",
    template="premise : {premise} . hypothesis : {hypothesis} . entailed :",
    generator=g.rte_examples, verbalizers=("yes", "no"),
    description="RTE stand-in: entailment via hypothesis-premise overlap"))

register(TaskSpec(
    name="wic", kind="classification",
    template="word : {word} . first : {sentence1} . second : {sentence2} . same :",
    generator=g.wic_examples, verbalizers=("no", "yes"),
    description="WiC stand-in: same word sense across two contexts"))

register(TaskSpec(
    name="cb", kind="classification",
    template="premise : {premise} . hypothesis : {hypothesis} . label :",
    generator=g.cb_examples, verbalizers=("yes", "no", "maybe"),
    metric="macro_f1",
    description="CB stand-in: 3-way entailment, macro-F1 (imbalanced SuperGLUE protocol)"))

register(TaskSpec(
    name="copa", kind="multiple_choice",
    template="premise : {premise} . what is the {question} ?",
    generator=g.copa_examples, answer_len=4,
    description="COPA stand-in: pick the continuation coherent with the premise"))

register(TaskSpec(
    name="squad_copy", kind="generation",
    template="context : {context} . question : {question} ? answer :",
    generator=g.squad_copy_examples, answer_field="answer",
    metric="exact_match", answer_len=4,
    description="SQuAD stand-in: extract the span following a cue word"))
