"""Task metrics over logit-scored verbalizers and choice continuations.

Three scoring modes, matching how MeZO-style prompt fine-tuning is
evaluated (DESIGN.md §9):

  * verbalizer argmax — classification: logits at the answer position,
    restricted to the task's verbalizer token ids;
  * continuation log-likelihood — multiple choice: length-normalized
    sum of per-token log-probs over each candidate continuation;
  * teacher-forced exact match — generation: argmax at every answer
    position must equal the gold token.

Aggregates (accuracy, macro-F1, exact match) are plain numpy over the
per-example predictions; model scoring is jnp and works on any params
tree the trainer produces.
"""
from __future__ import annotations

import functools

import numpy as np


def accuracy(pred: np.ndarray, gold: np.ndarray) -> float:
    return float(np.mean(pred == gold))


def macro_f1(pred: np.ndarray, gold: np.ndarray, n_classes: int) -> float:
    """Unweighted mean of per-class F1 (classes absent from both sides
    contribute 0, the sklearn zero_division=0 convention)."""
    f1s = []
    for c in range(n_classes):
        tp = float(np.sum((pred == c) & (gold == c)))
        fp = float(np.sum((pred == c) & (gold != c)))
        fn = float(np.sum((pred != c) & (gold == c)))
        denom = 2 * tp + fp + fn
        f1s.append(2 * tp / denom if denom > 0 else 0.0)
    return float(np.mean(f1s))


def exact_match(pred_rows: np.ndarray) -> float:
    return float(np.mean(pred_rows))


# -------------------------------------------------------- model scoring
@functools.lru_cache(maxsize=None)
def _logits_runner(lm_module, last_only: bool = False):
    """One jitted scorer per (lm module, position mode); ModelConfig is a
    frozen (hashable) dataclass, so jit then caches per (config, shapes)
    across the many eval calls a training run makes.  ``last_only``
    projects just the answer position — at real vocab sizes the full
    (B, S-1, V) logits tensor is S-times the cost and only the
    choice/EM scorers actually need it."""
    import jax

    @functools.partial(jax.jit, static_argnums=(0,))
    def run(cfg, p, toks):
        hidden, _, _ = lm_module.forward(cfg, p, toks, mode="train")
        hidden = hidden[:, -1] if last_only else hidden
        return lm_module.logits_fn(cfg, p, hidden)

    return run


def _full_logits(mcfg, params, inputs, lm_module):
    import jax.numpy as jnp
    return _logits_runner(lm_module)(mcfg, params, jnp.asarray(inputs))


def verbalizer_predict(mcfg, params, inputs, verb_ids, lm_module) -> np.ndarray:
    """Argmax over verbalizer logits at the answer position -> class ids."""
    import jax.numpy as jnp
    logits = _logits_runner(lm_module, last_only=True)(
        mcfg, params, jnp.asarray(inputs))                # (B, V) f32
    return np.asarray(jnp.argmax(logits[:, jnp.asarray(verb_ids)], axis=-1))


def choice_scores(mcfg, params, choice_inputs, choice_labels, choice_mask,
                  lm_module) -> np.ndarray:
    """Length-normalized continuation log-prob for each of k choices.

    choice_inputs/labels: (n, k, S-1) int32; choice_mask: (n, k, S-1).
    Returns (n, k) float scores.
    """
    import jax
    import jax.numpy as jnp
    n, k, s = choice_inputs.shape
    flat = lambda a: jnp.asarray(a).reshape(n * k, s)
    logits = _full_logits(mcfg, params, flat(choice_inputs), lm_module)
    logp = jax.nn.log_softmax(logits, axis=-1)
    gold = jnp.take_along_axis(logp, flat(choice_labels)[..., None],
                               axis=-1)[..., 0]
    m = flat(choice_mask)
    score = jnp.sum(gold * m, axis=-1) / jnp.maximum(jnp.sum(m, axis=-1), 1.0)
    return np.asarray(score.reshape(n, k))


def exact_match_hits(mcfg, params, inputs, labels, loss_mask,
                     lm_module) -> np.ndarray:
    """Per-row 0/1: teacher-forced argmax equals gold at every answer
    position (positions where loss_mask is set)."""
    import jax.numpy as jnp
    pred = jnp.argmax(_full_logits(mcfg, params, inputs, lm_module), axis=-1)
    ok = (pred == jnp.asarray(labels)) | (jnp.asarray(loss_mask) == 0)
    return np.asarray(jnp.all(ok, axis=-1).astype(np.float32))
