"""SuperGLUE-style task & evaluation subsystem (DESIGN.md §9).

    spec = tasks.get("sst2")                      # declarative TaskSpec
    task = tasks.build("sst2", vocab=512, seq_len=64)
    data = task.make_dataset(4096)                # synthetic-batch format
    acc  = task.evaluate(mcfg, params, data, lm)  # task's primary metric

Tasks compile down to the exact batch dict ``data/synthetic.py``
produces, so the model stack, kernels, and estimators never see the
difference; ``train.Trainer`` and ``launch/evaluate.py`` consume the
metric protocol.
"""
from repro.tasks.base import (CompiledTask, KINDS, METRICS,
                              MODEL_BATCH_KEYS, TaskSpec, compile_task)
from repro.tasks.generators import json_examples
from repro.tasks.registry import (TASKS, build, classification_names, get,
                                  names, register)
from repro.tasks import metrics, vocab

__all__ = ["CompiledTask", "KINDS", "METRICS", "MODEL_BATCH_KEYS", "TASKS",
           "TaskSpec", "build", "classification_names", "compile_task",
           "get", "json_examples", "metrics", "names", "register", "vocab"]
