"""Decoder-only LM assembly: init, train loss, prefill, decode.

Parameters live in a dict tree::

    {"embed": {"tok": (V, D) [, "pos": (max_seq, D)]},
     "final_norm": {...},
     ["head": {"w": (D, V)}]                      # absent when tied
     "stages": {"s0": {"b0": {...}, "b1": {...}}, ...}}

where every leaf under ``stages/s{i}/b{j}`` is stacked over that stage's
``repeat`` on axis 0.  Execution is a ``lax.scan`` over repeat per stage
(compile-time O(1) in depth — critical for 62-layer models on a
512-device mesh); each scan body runs the stage's block *pattern* in
order, so heterogeneous interleaves (jamba's mamba/attn, xlstm's
mlstm/slstm) execute in their true layer order.

LeZO integration: ``zo_group_fn`` labels each stages/ leaf with its
(stage, pattern-position) group; embeddings / head / final norm are
always-perturbed (the paper never drops them — and Fig. 3 shows dropping
everything *but* them collapses).

The LM loss is a chunked cross-entropy (scan over sequence chunks): the
(B, S, V) logits tensor never materializes — at 152k vocab x 4k seq that
is the difference between fitting a v5e and a 20 GiB OOM.

Model stack (DESIGN.md §8); paged serving mode: DESIGN.md §12.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.fused import ref as fused_ref
from repro.models import layers, moe, ssm
from repro.models.config import BlockCfg, ModelConfig

F32 = jnp.float32
CE_CHUNK = 512


# ------------------------------------------------------------------ init
def _block_params(cfg: ModelConfig, b: BlockCfg, key):
    kmix, kffn = jax.random.split(key)
    if b.kind == "attn":
        p = {"mix": layers.attn_params(cfg, kmix)}
    elif b.kind == "mla":
        p = {"mix": layers.mla_params(cfg, kmix)}
    elif b.kind == "mamba":
        p = {"mix": ssm.mamba_params(cfg, kmix)}
    elif b.kind == "mlstm":
        p = {"mix": ssm.mlstm_params(cfg, kmix)}
    elif b.kind == "slstm":
        p = {"mix": ssm.slstm_params(cfg, kmix)}
    else:
        raise ValueError(f"unknown block kind {b.kind!r}")
    if b.ffn == "dense":
        p["ffn"] = layers.ffn_params(cfg, kffn, d_ff=b.d_ff or cfg.d_ff)
    elif b.ffn == "moe":
        p["ffn"] = moe.moe_params(cfg, kffn)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, len(cfg.stages) + 2)
    dt = jnp.dtype(cfg.dtype)
    params: Dict[str, Any] = {
        "embed": {"tok": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), dt)
                  * 0.02},
        "final_norm": layers.norm_params(cfg, cfg.d_model),
    }
    if cfg.pos_emb == "learned":
        params["embed"]["pos"] = (
            jax.random.normal(jax.random.fold_in(keys[0], 1),
                              (cfg.max_seq, cfg.d_model), dt) * 0.02)
    if not cfg.tie_embeddings:
        params["head"] = {"w": jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab), dt) * cfg.d_model ** -0.5}
    stages = {}
    for si, st in enumerate(cfg.stages):
        skey = keys[2 + si]
        blocks = {}
        for bj, b in enumerate(st.pattern):
            bkeys = jax.random.split(jax.random.fold_in(skey, bj), st.repeat)
            blocks[f"b{bj}"] = jax.vmap(
                functools.partial(_block_params, cfg, b))(bkeys)
        stages[f"s{si}"] = blocks
    params["stages"] = stages
    return params


def zo_group_fn(path: str) -> Optional[str]:
    """Leaf path -> LeZO layer group (stacked axis 0) or None (always on)."""
    if path.startswith("stages/"):
        parts = path.split("/")
        return f"{parts[1]}.{parts[2]}"          # e.g. "s0.b3"
    return None


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def count_active_params(cfg: ModelConfig, params) -> int:
    """MoE-aware 'active per token' count for MODEL_FLOPS = 6*N_active*D."""
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = leaf.size
        if "we_g" in ps or "we_u" in ps or "we_d" in ps:
            n = n * cfg.top_k // cfg.n_experts
        if "embed/tok" in ps or "embed/pos" in ps:
            n = 0  # embedding lookup is not a matmul
        total += n
    return total


# --------------------------------------------------------------- forward
_MIX_FWD = {"attn": layers.attn_fwd, "mla": layers.mla_fwd,
            "mamba": ssm.mamba_fwd, "mlstm": ssm.mlstm_fwd,
            "slstm": ssm.slstm_fwd}


def _run_block(cfg, b: BlockCfg, p, x, *, mode, cache, pos, pc=None,
               pages=None):
    if pc is not None and (b.kind != "attn" or b.ffn == "moe"):
        raise NotImplementedError(
            f"virtual perturbation covers attn + dense blocks; got "
            f"{b.kind}+{b.ffn} (use forward_backend='materialized')")
    if mode == "paged" and b.kind != "attn":
        raise NotImplementedError(
            f"paged serving covers attn mixers only; got {b.kind!r} "
            "(the engine falls back to the lockstep path — DESIGN.md §12)")
    mix_kw = {} if pc is None else {"pc": pc.child("mix")}
    if pages is not None:
        mix_kw["pages"] = pages
    mix_out, new_cache = _MIX_FWD[b.kind](cfg, p["mix"], x, mode=mode,
                                          cache=cache, pos=pos, **mix_kw)
    x = x + mix_out
    aux = jnp.zeros((), F32)
    if b.ffn == "dense":
        ffn_kw = {} if pc is None else {"pc": pc.child("ffn")}
        x = x + layers.ffn_fwd(cfg, p["ffn"], x, d_ff=b.d_ff or cfg.d_ff,
                               **ffn_kw)
    elif b.ffn == "moe":
        y, aux = moe.moe_fwd(cfg, p["ffn"], x)
        x = x + y
    return x, new_cache, aux


def forward(cfg: ModelConfig, params, tokens, *, mode="train", caches=None,
            pos=0, embeds=None, perturb=None, pages=None):
    """tokens: (B, S) int32, or ``embeds``: (B, S, D) for stub frontends.

    mode: train (no cache) | prefill (build cache) | decode (S==1, use+
    advance cache) | paged (serving engine bucket: ``caches`` is the
    paged KV arena, ``pages`` the (B, max_pages) page table and ``pos``
    a (B,) per-lane start position — DESIGN.md §12).  Returns
    (hidden (B,S,D), new_caches, aux_loss).

    ``perturb`` (fused.PerturbCtx) runs the forward against the virtually
    perturbed weights theta + s*eps*z: every weight read regenerates its
    z slice from the counter RNG (per-layer predicated by the LeZO
    masks), so the loss equals the materialized perturb-forward-restore
    sequence's without any parameter writes (DESIGN.md §10).
    """
    P = 0 if (perturb is None or perturb.pair is None) else perturb.pair.n
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    elif perturb is None:
        x = params["embed"]["tok"][tokens]
    elif P:
        # stacked probes ride the batch axis p-major: (P, B, S, D) ->
        # (P*B, S, D), so every probe-agnostic op (attention, rope,
        # residuals) runs unchanged and only weight reads split by probe
        x = fused_ref.pembed_stack(
            params["embed"]["tok"], tokens,
            fused_ref.layer_seed(perturb.seed, "embed/tok"), perturb.scale)
        x = x.reshape((-1,) + x.shape[2:])
    else:
        x = fused_ref.pembed(params["embed"]["tok"], tokens,
                             fused_ref.layer_seed(perturb.seed, "embed/tok"),
                             perturb.scale)
    if cfg.pos_emb == "learned":
        S = x.shape[1]
        if mode == "paged":
            ppos = jnp.asarray(pos)[:, None] + jnp.arange(S)[None, :]
            x = x + params["embed"]["pos"][ppos]
        elif perturb is None:
            x = x + lax.dynamic_slice_in_dim(params["embed"]["pos"], pos, S, 0)
        elif P:
            rows = fused_ref.ppos_stack(
                params["embed"]["pos"], pos, S,
                fused_ref.layer_seed(perturb.seed, "embed/pos"),
                perturb.scale)                                # (P, S, D)
            x = (x.reshape(P, -1, *x.shape[1:]) + rows[:, None]
                 ).reshape(x.shape)
        else:
            x = x + fused_ref.ppos(params["embed"]["pos"], pos, S,
                                   fused_ref.layer_seed(perturb.seed,
                                                        "embed/pos"),
                                   perturb.scale)

    aux_total = jnp.zeros((), F32)
    new_caches: Dict[str, Any] = {}
    for si, st in enumerate(cfg.stages):
        sp = params["stages"][f"s{si}"]
        scache = caches[f"s{si}"] if caches is not None else None
        if perturb is not None:
            # per-block LeZO masks + layer ids ride the scan alongside the
            # stacked params; group names match models.lm.zo_group_fn
            pmasks = {f"b{bj}": perturb.group_mask(f"s{si}.b{bj}", st.repeat)
                      for bj in range(len(st.pattern))}
            lids = jnp.arange(st.repeat, dtype=jnp.uint32)

        def body(x_aux, sliced):
            x, aux = x_aux
            if perturb is None:
                bp_all, bc_all = sliced
            else:
                bp_all, bc_all, pm, lid = sliced
            ncs = {}
            for bj, b in enumerate(st.pattern):
                bc = bc_all[f"b{bj}"] if bc_all is not None else None
                pc = (None if perturb is None else
                      perturb.block(f"stages/s{si}/b{bj}", lid,
                                    pm[f"b{bj}"]))
                x, nc, a = _run_block(cfg, b, bp_all[f"b{bj}"], x,
                                      mode=mode, cache=bc, pos=pos, pc=pc,
                                      pages=pages)
                aux = aux + a
                if nc is not None:
                    ncs[f"b{bj}"] = nc
            return (x, aux), (ncs if ncs else None)

        xs = ((sp, scache) if perturb is None
              else (sp, scache, pmasks, lids))
        if st.repeat == 1:
            squeeze = lambda t: (jax.tree.map(lambda a: a[0], t)
                                 if t is not None else None)
            (x, aux_total), nc = body((x, aux_total),
                                      tuple(squeeze(t) for t in xs))
            if nc is not None:
                new_caches[f"s{si}"] = jax.tree.map(lambda a: a[None], nc)
        else:
            (x, aux_total), nc = lax.scan(body, (x, aux_total), xs)
            if nc is not None:
                new_caches[f"s{si}"] = nc
    if perturb is None:
        x = layers.apply_norm(cfg, params["final_norm"], x)
    else:
        x = perturb.leaf("final_norm").apply_norm(cfg, params["final_norm"],
                                                  x)
    return x, (new_caches if new_caches else None), aux_total


def _head_matrix(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"]["tok"].T
    return params["head"]["w"]


def logits_fn(cfg, params, hidden):
    return (hidden @ _head_matrix(cfg, params)).astype(F32)


def chunked_ce(cfg, params, hidden, labels, loss_mask, perturb=None):
    """Mean CE over masked positions without materializing (B,S,V) logits.

    Under a paired ctx ``hidden`` is (P*B, S, D) (p-major); each probe's
    CE runs the *literally unpaired* program on its slice (a Python loop
    over the static P), so the (P,) loss vector is bit-identical to P
    separate forwards by construction — XLA's float association inside
    the fused scan body is not stable across batch shapes, so a stacked
    head reduction cannot make that guarantee (the transformer blocks,
    where the W traffic lives, still share the paired pass)."""
    P = 0 if (perturb is None or perturb.pair is None) else perturb.pair.n
    if P:
        B0 = hidden.shape[0] // P
        return jnp.stack([
            chunked_ce(cfg, params, hidden[pi * B0:(pi + 1) * B0], labels,
                       loss_mask, perturb=perturb.probe(pi))
            for pi in range(P)])
    B, S, D = hidden.shape
    chunk = min(CE_CHUNK, S)
    assert S % chunk == 0
    n = S // chunk
    W = _head_matrix(cfg, params)
    if perturb is not None:
        # tied head reads embed/tok through a transpose: trans counters
        # with the stored row length keep z identical to the axpy's
        head = perturb.leaf("embed/tok" if cfg.tie_embeddings else "head/w")
        head_kw = ({"trans": True, "ld": cfg.d_model}
                   if cfg.tie_embeddings else {})
    resh = lambda a: a.reshape(B, n, chunk, *a.shape[2:]).swapaxes(0, 1)

    def body(carry, inp):
        h, y, m = inp
        if perturb is None:
            lg = (h @ W).astype(F32)                          # (B,chunk,V)
        else:
            lg = head.matmul(h, W, **head_kw).astype(F32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, y[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(m)), None

    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), F32), jnp.zeros((), F32)),
                             (resh(hidden), resh(labels), resh(loss_mask.astype(F32))))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(cfg: ModelConfig, params, batch, aux_coef=0.0, perturb=None):
    """batch: {tokens (B,S) int32, labels (B,S) int32, loss_mask (B,S)} or
    {embeds (B,S,D), labels, loss_mask} for stub-frontend archs.

    ``perturb`` (fused.PerturbCtx): evaluate loss(theta + s*eps*z)
    virtually — see forward().  A paired ctx (``perturb.pair``) runs all
    P stacked probes through ONE forward and returns a (P,) loss vector
    (probe order = ctx order; ``fused.make_pair_ctx`` puts +eps first)."""
    hidden, _, aux = forward(cfg, params, batch.get("tokens"),
                             embeds=batch.get("embeds"), mode="train",
                             perturb=perturb)
    loss = chunked_ce(cfg, params, hidden, batch["labels"],
                      batch["loss_mask"], perturb=perturb)
    return loss + aux_coef * aux


def lm_loss_pair(cfg: ModelConfig, params, batch, aux_coef=0.0,
                 perturb=None):
    """The paired-probe entry point: ``perturb`` must be a stacked ctx
    (``fused.make_pair_ctx`` / ``make_stack_ctx``); returns the (P,)
    per-probe loss vector from one fused forward.  Exists as an explicit
    surface for callers that want the pair contract checked."""
    if perturb is None or perturb.pair is None:
        raise ValueError("lm_loss_pair requires a stacked PerturbCtx "
                         "(fused.make_pair_ctx / make_stack_ctx)")
    return lm_loss(cfg, params, batch, aux_coef=aux_coef, perturb=perturb)


# ---------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Build an empty cache pytree matching forward(mode='decode')."""
    dt = jnp.dtype(dtype or cfg.dtype)
    B = batch
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    caches: Dict[str, Any] = {}
    for si, st in enumerate(cfg.stages):
        blocks = {}
        for bj, b in enumerate(st.pattern):
            R = st.repeat
            if b.kind == "attn":
                c = {"k": jnp.zeros((R, B, max_seq, KV, dh), dt),
                     "v": jnp.zeros((R, B, max_seq, KV, dh), dt)}
            elif b.kind == "mla":
                c = {"ckv": jnp.zeros((R, B, max_seq, cfg.kv_lora), dt),
                     "kr": jnp.zeros((R, B, max_seq, cfg.rope_head_dim), dt)}
            elif b.kind == "mamba":
                Di = cfg.mamba_d_inner
                c = {"conv": jnp.zeros((R, B, cfg.mamba_conv - 1, Di), dt),
                     "ssm": jnp.zeros((R, B, Di, cfg.mamba_d_state), F32)}
            elif b.kind == "mlstm":
                Di = cfg.lstm_d_inner
                dhh = Di // H
                c = {"conv": jnp.zeros((R, B, 3, Di), dt),
                     "C": jnp.zeros((R, B, H, dhh, dhh), F32),
                     "n": jnp.zeros((R, B, H, dhh), F32),
                     "m": jnp.full((R, B, H), -jnp.inf, F32)}
            else:  # slstm
                dhh = cfg.d_model // H
                z = jnp.zeros((R, B, H, dhh), F32)
                c = {"c": z, "n": z, "h": z,
                     "m": jnp.full((R, B, H, dhh), -jnp.inf, F32)}
            blocks[f"b{bj}"] = c
        caches[f"s{si}"] = blocks
    return caches


def serve_step(cfg: ModelConfig, params, caches, token, pos, embeds=None):
    """One decode step. token: (B, 1) int32; pos: scalar int32 (current
    length of the cache). Returns (logits (B, V) f32, new_caches)."""
    hidden, new_caches, _ = forward(cfg, params, token, mode="decode",
                                    caches=caches, pos=pos, embeds=embeds)
    return logits_fn(cfg, params, hidden[:, -1]), new_caches


def prefill(cfg: ModelConfig, params, tokens, max_seq: int, embeds=None):
    """Run the prompt through the model, returning (logits_last, caches)."""
    B = (tokens if tokens is not None else embeds).shape[0]
    caches = init_cache(cfg, B, max_seq)
    hidden, new_caches, _ = forward(cfg, params, tokens, mode="prefill",
                                    caches=caches, pos=0, embeds=embeds)
    return logits_fn(cfg, params, hidden[:, -1]), new_caches


# --------------------------------------------------------- paged serving
def supports_paged(cfg: ModelConfig) -> bool:
    """True when every mixer is attn — the block family the paged
    serving engine covers (DESIGN.md §12); SSM/MLA state is per-lane
    fixed-size and served by the lockstep path instead."""
    from repro.models import frontends
    return (not frontends.uses_embeds(cfg)
            and all(b.kind == "attn" for s in cfg.stages for b in s.pattern))


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=None):
    """The serving engine's KV arena: one (R, n_pages, page_size, KV, dh)
    buffer per stage-block leaf, shared by every request via per-lane
    page tables (DESIGN.md §12).  Page 0 is reserved as the trash page —
    inactive lanes write there; the allocator never hands it out."""
    dt = jnp.dtype(dtype or cfg.dtype)
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    caches: Dict[str, Any] = {}
    for si, st in enumerate(cfg.stages):
        blocks = {}
        for bj, b in enumerate(st.pattern):
            if b.kind != "attn":
                raise NotImplementedError(
                    f"paged serving covers attn mixers only; "
                    f"{cfg.name} stage {si} has {b.kind!r} "
                    "(use the lockstep serve path)")
            shape = (st.repeat, n_pages, page_size, KV, dh)
            blocks[f"b{bj}"] = {"k": jnp.zeros(shape, dt),
                                "v": jnp.zeros(shape, dt)}
        caches[f"s{si}"] = blocks
    return caches


def paged_step(cfg: ModelConfig, params, arena, tokens, pages, pos, sel):
    """One bucketed serving call — a prefill chunk or a batched decode
    step are the same computation at different (B, C) buckets
    (DESIGN.md §12).

    tokens: (B, C) int32 — C == 1 for a decode step, C == prefill_chunk
    for a prefill call; pages: (B, max_pages) int32 page-table rows
    (entry 0 = trash page); pos: (B,) int32 absolute position of
    ``tokens[:, 0]``; sel: (B,) int32 chunk index whose logits each lane
    returns (the last valid prompt token for a final prefill chunk, 0
    for decode).  Returns (logits (B, V) f32, new_arena).
    """
    hidden, new_arena, _ = forward(cfg, params, tokens, mode="paged",
                                   caches=arena, pos=pos, pages=pages)
    h_sel = jnp.take_along_axis(hidden, sel[:, None, None], axis=1)[:, 0]
    return logits_fn(cfg, params, h_sel), new_arena
