"""Stub modality frontends (per the assignment: backbone only).

For `[audio]` (musicgen: EnCodec frame embeddings) and `[vlm]`
(internvl2: InternViT patch embeddings) the frontend is NOT implemented;
``input_specs()`` hands the backbone precomputed (B, S, D) embeddings.
These helpers produce deterministic pseudo-embeddings for smoke tests and
the matching ShapeDtypeStructs for the dry-run.

Model stack / zoo (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def stub_embeddings(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    """Deterministic fake frame/patch embeddings, unit RMS."""
    key = jax.random.PRNGKey(seed)
    e = jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)
    return (e / jnp.sqrt(jnp.mean(e ** 2, -1, keepdims=True))).astype(
        jnp.dtype(cfg.dtype))


def uses_embeds(cfg: ModelConfig) -> bool:
    return cfg.frontend in ("audio", "vision")
