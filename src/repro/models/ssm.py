"""Recurrent blocks: Mamba (selective SSM), mLSTM and sLSTM (xLSTM).

All three expose the block contract used by lm.py::

    *_params(cfg, key) -> param dict (one layer)
    *_fwd(cfg, p, x, mode, cache, pos) -> (out, new_cache)

Training/prefill use chunkwise-parallel forms (lax.scan over time chunks,
associative/parallel math inside a chunk) so activation memory is
O(chunk), not O(S); decode is the exact O(1)-state recurrence — this is
what makes the `long_500k` shapes feasible for xlstm/jamba while
full-attention archs must skip them.

Simplifications vs. the reference CUDA implementations (documented in
DESIGN.md §8): mLSTM/sLSTM blocks omit the learnable-skip/small-conv
details that don't change cost structure; sLSTM uses a single
block-diagonal recurrent matrix per head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers

F32 = jnp.float32
CHUNK = 128


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv. x: (B,S,Di), w: (K,Di), b: (Di,).

    cache: (B, K-1, Di) trailing context (decode) or None (train: zero pad).
    Returns (y, new_cache).
    """
    B, S, Di = x.shape
    K = w.shape[0]
    ctx = cache if cache is not None else jnp.zeros((B, K - 1, Di), x.dtype)
    xp = jnp.concatenate([ctx, x], axis=1)          # (B, S+K-1, Di)
    y = sum(xp[:, i:i + S] * w[i] for i in range(K)) + b
    new_cache = xp[:, -(K - 1):] if K > 1 else ctx
    return y.astype(x.dtype), new_cache


# ------------------------------------------------------------------ Mamba
def mamba_params(cfg, key):
    D, Di, St = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    R, K = cfg.mamba_dt_rank, cfg.mamba_conv
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    return {
        "norm": layers.norm_params(cfg, D),
        "in_proj": jax.random.normal(ks[0], (D, 2 * Di), dt) * D ** -0.5,
        "conv_w": jax.random.normal(ks[1], (K, Di), dt) * K ** -0.5,
        "conv_b": jnp.zeros((Di,), dt),
        "x_proj": jax.random.normal(ks[2], (Di, R + 2 * St), dt) * Di ** -0.5,
        "dt_w": jax.random.normal(ks[3], (R, Di), dt) * R ** -0.5,
        "dt_b": jnp.full((Di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, St + 1, dtype=jnp.float32), (Di, St)).copy()),
        "Dskip": jnp.ones((Di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (Di, D), dt) * Di ** -0.5,
    }


def _ssm_chunk_scan(dA, dBx, C, h0):
    """Chunked diagonal SSM scan.

    dA, dBx: (B, S, Di, St) f32; C: (B, S, St); h0: (B, Di, St).
    h_t = dA_t * h_{t-1} + dBx_t ; y_t = sum_s h_t[., s] * C_t[s].
    """
    B, S, Di, St = dA.shape
    chunk = min(CHUNK, S)
    assert S % chunk == 0
    n = S // chunk

    def body(h, inp):
        a, bx, c = inp                                # (B,chunk,Di,St) x2, (B,chunk,St)
        def comb(e1, e2):
            return e1[0] * e2[0], e2[0] * e1[1] + e2[1]
        acc_a, acc_b = lax.associative_scan(comb, (a, bx), axis=1)
        h_all = acc_a * h[:, None] + acc_b            # (B,chunk,Di,St)
        y = jnp.einsum("bcds,bcs->bcd", h_all, c)
        return h_all[:, -1], y

    dAc = dA.reshape(B, n, chunk, Di, St).transpose(1, 0, 2, 3, 4)
    dBc = dBx.reshape(B, n, chunk, Di, St).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(B, n, chunk, St).transpose(1, 0, 2, 3)
    h_final, ys = lax.scan(body, h0, (dAc, dBc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, Di)
    return y, h_final


def mamba_fwd(cfg, p, x, *, mode, cache=None, pos=0):
    B, S, D = x.shape
    Di, St, R = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_dt_rank
    h = layers.apply_norm(cfg, p["norm"], x)
    xz = h @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_cache)
    xc = jax.nn.silu(xc.astype(F32)).astype(x.dtype)

    proj = xc @ p["x_proj"]
    dt_in, Bp, Cp = jnp.split(proj.astype(F32), [R, R + St], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_w"].astype(F32) + p["dt_b"])   # (B,S,Di)
    A = -jnp.exp(p["A_log"])                                           # (Di,St)
    dA = jnp.exp(dt[..., None] * A)                                    # (B,S,Di,St)
    dBx = dt[..., None] * Bp[:, :, None, :] * xc.astype(F32)[..., None]

    h0 = (cache["ssm"].astype(F32) if cache is not None
          else jnp.zeros((B, Di, St), F32))
    if mode == "decode":
        h1 = dA[:, 0] * h0 + dBx[:, 0]
        y = jnp.einsum("bds,bs->bd", h1, Cp[:, 0])[:, None]
        h_final = h1
    else:
        y, h_final = _ssm_chunk_scan(dA, dBx, Cp, h0)

    y = y + p["Dskip"] * xc.astype(F32)
    y = y * jax.nn.silu(z.astype(F32))
    out = (y.astype(x.dtype) @ p["out_proj"]).astype(x.dtype)
    new_cache = None
    if mode in ("decode", "prefill"):
        new_cache = {"conv": new_conv, "ssm": h_final.astype(F32)}
    return out, new_cache


# ------------------------------------------------------------------ mLSTM
def mlstm_params(cfg, key):
    D, Di, H = cfg.d_model, cfg.lstm_d_inner, cfg.n_heads
    ks = jax.random.split(key, 7)
    dt = jnp.dtype(cfg.dtype)
    return {
        "norm": layers.norm_params(cfg, D),
        "in_proj": jax.random.normal(ks[0], (D, 2 * Di), dt) * D ** -0.5,
        "conv_w": jax.random.normal(ks[1], (4, Di), dt) * 0.5,
        "conv_b": jnp.zeros((Di,), dt),
        "wq": jax.random.normal(ks[2], (Di, Di), dt) * Di ** -0.5,
        "wk": jax.random.normal(ks[3], (Di, Di), dt) * Di ** -0.5,
        "wv": jax.random.normal(ks[4], (Di, Di), dt) * Di ** -0.5,
        "wif": jax.random.normal(ks[5], (Di, 2 * H), dt) * Di ** -0.5,
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # forget-gate bias init
        "out_norm": {"scale": jnp.ones((Di,), jnp.float32)},
        "out_proj": jax.random.normal(ks[6], (Di, D), dt) * Di ** -0.5,
    }


def _mlstm_chunk(q, k, v, li, lf, state):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B,c,H,dh) f32; li,lf: (B,c,H) log input / log-sigmoid forget
    gates; state = (C (B,H,dh,dh), n (B,H,dh), m (B,H)) — C and n are
    stored *stabilized*: true value = exp(m) * stored.
    Returns (h (B,c,H,dh), new_state).
    """
    B, c, H, dh = q.shape
    C0, n0, m0 = state
    scale = dh ** -0.5
    lf_cum = jnp.cumsum(lf, axis=1)                       # (B,c,H) inclusive
    lf_tot = lf_cum[:, -1]

    # intra-chunk log decay matrix: Dm[t,j] = lf_cum[t]-lf_cum[j]+li[j], j<=t
    Dm = lf_cum[:, :, None] - lf_cum[:, None, :] + li[:, None]   # (B,t,j,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    Dm = jnp.where(tri[None, :, :, None], Dm, -jnp.inf)
    m_intra = jnp.max(Dm, axis=2)                         # (B,c,H)
    m_inter = lf_cum + m0[:, None]                        # (B,c,H)
    m_t = jnp.maximum(m_intra, m_inter)

    wexp = jnp.where(tri[None, :, :, None],
                     jnp.exp(Dm - m_t[:, :, None]), 0.0)  # (B,t,j,H)
    s = jnp.einsum("bthd,bjhd->btjh", q, k) * scale
    w = s * wexp
    dec = jnp.exp(m_inter - m_t)                          # (B,c,H) carry decay

    num = (jnp.einsum("btjh,bjhd->bthd", w, v)
           + jnp.einsum("bthd,bhde->bthe", q * scale, C0) * dec[..., None])
    n_t = (jnp.einsum("btjh,bjhd->bthd", wexp, k)
           + dec[..., None] * n0[:, None])
    qn = jnp.einsum("bthd,bthd->bth", q * scale, n_t)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
    h = num / denom[..., None]

    # carry update (end of chunk), re-stabilized to m_next
    g = lf_tot[:, None] - lf_cum + li                     # (B,j,H)
    m_next = jnp.maximum(lf_tot + m0, jnp.max(g, axis=1))
    wC = jnp.exp(g - m_next[:, None])                     # (B,j,H)
    carry = jnp.exp(lf_tot + m0 - m_next)                 # (B,H)
    C1 = (carry[:, :, None, None] * C0
          + jnp.einsum("bjh,bjhd,bjhe->bhde", wC, k, v))
    n1 = carry[..., None] * n0 + jnp.einsum("bjh,bjhd->bhd", wC, k)
    return h, (C1, n1, m_next)


def mlstm_fwd(cfg, p, x, *, mode, cache=None, pos=0):
    B, S, D = x.shape
    Di, H = cfg.lstm_d_inner, cfg.n_heads
    dh = Di // H
    h0 = layers.apply_norm(cfg, p["norm"], x)
    up = h0 @ p["in_proj"]
    u, gate = jnp.split(up, 2, axis=-1)
    conv_cache = cache["conv"] if cache is not None else None
    uc, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_cache)
    uc = jax.nn.silu(uc.astype(F32)).astype(x.dtype)

    q = (uc @ p["wq"]).reshape(B, S, H, dh).astype(F32)
    k = (uc @ p["wk"]).reshape(B, S, H, dh).astype(F32)
    v = (u @ p["wv"]).reshape(B, S, H, dh).astype(F32)
    gif = (uc @ p["wif"]).astype(F32).reshape(B, S, 2, H)
    li = gif[:, :, 0] + p["b_i"]                       # log-space input gate
    lf = jax.nn.log_sigmoid(gif[:, :, 1] + p["b_f"])   # log forget gate

    if cache is not None:
        state = (cache["C"].astype(F32), cache["n"].astype(F32),
                 cache["m"].astype(F32))
    else:
        state = (jnp.zeros((B, H, dh, dh), F32), jnp.zeros((B, H, dh), F32),
                 jnp.full((B, H), -jnp.inf, F32))

    chunk = min(CHUNK, S)
    assert S % chunk == 0
    n_chunks = S // chunk

    def body(st, inp):
        qc, kc, vc, lic, lfc = inp
        hc, st = _mlstm_chunk(qc, kc, vc, lic, lfc, st)
        return st, hc

    split = lambda a: a.reshape(B, n_chunks, chunk, *a.shape[2:]).transpose(
        1, 0, 2, *range(3, a.ndim + 1))
    state, hs = lax.scan(body, state, (split(q), split(k), split(v),
                                       split(li), split(lf)))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, Di)

    h = layers.rms_norm(h.astype(x.dtype), p["out_norm"]["scale"])
    h = h * jax.nn.silu(gate.astype(F32)).astype(x.dtype)
    out = (h @ p["out_proj"]).astype(x.dtype)
    new_cache = None
    if mode in ("decode", "prefill"):
        C1, n1, m1 = state
        new_cache = {"conv": new_conv, "C": C1, "n": n1, "m": m1}
    return out, new_cache


# ------------------------------------------------------------------ sLSTM
def slstm_params(cfg, key):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    return {
        "norm": layers.norm_params(cfg, D),
        "wx": jax.random.normal(ks[0], (D, 4 * D), dt) * D ** -0.5,
        "rh": jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32) * dh ** -0.5,
        "b": jnp.concatenate([jnp.zeros((2 * D,)), jnp.full((D,), 3.0),
                              jnp.zeros((D,))]).astype(jnp.float32),
        "out_norm": {"scale": jnp.ones((D,), jnp.float32)},
        "out_proj": jax.random.normal(ks[2], (D, D), dt) * D ** -0.5,
    }


def slstm_fwd(cfg, p, x, *, mode, cache=None, pos=0):
    """Sequential sLSTM with exponential gating + stabilizer state.

    Gate preacts = x W + h_{t-1} R (block-diagonal per head) + b.
    Truly recurrent (h feeds back) -> lax.scan over every step.
    """
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    xin = layers.apply_norm(cfg, p["norm"], x)
    gx = (xin @ p["wx"]).astype(F32) + p["b"]            # (B,S,4D)
    gx = gx.reshape(B, S, 4, H, dh)

    if cache is not None:
        st = (cache["c"].astype(F32), cache["n"].astype(F32),
              cache["h"].astype(F32), cache["m"].astype(F32))
    else:
        z = jnp.zeros((B, H, dh), F32)
        st = (z, z, z, jnp.full((B, H, dh), -jnp.inf, F32))

    rh = p["rh"].astype(F32).reshape(H, dh, 4, dh)

    def step(st, gxt):
        c, n, h, m = st
        gr = jnp.einsum("bhd,hdge->bghe", h, rh)          # (B,4,H,dh)
        zt, it, ft, ot = [gxt[:, i] + gr[:, i] for i in range(4)]
        m_new = jnp.maximum(ft + m, it)
        i_g = jnp.exp(it - m_new)
        f_g = jnp.exp(ft + m - m_new)
        zt = jnp.tanh(zt)
        o_g = jax.nn.sigmoid(ot)
        c = f_g * c + i_g * zt
        n = f_g * n + i_g
        h = o_g * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, h, m_new), h

    st, hs = lax.scan(step, st, gx.transpose(1, 0, 2, 3, 4))  # scan over S
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    h = layers.rms_norm(h, p["out_norm"]["scale"])
    out = (h @ p["out_proj"]).astype(x.dtype)
    new_cache = None
    if mode in ("decode", "prefill"):
        c, n, hh, m = st
        new_cache = {"c": c, "n": n, "h": hh, "m": m}
    return out, new_cache
