"""Core transformer layers: norms, RoPE, flash attention (GQA + MLA), FFN.

All functions are pure; a *block*'s parameters arrive unstacked (one
layer's slice — the stage scan in lm.py slices the stacked leaves).
Activations are (B, S, D) in cfg.dtype; matmuls accumulate in f32 via
``preferred_element_type``.

Attention is a chunked flash implementation (double lax.scan over q- and
k-chunks with running log-sum-exp), so peak memory is O(q_chunk * k_chunk)
instead of O(S^2) — required for the 32k-prefill shapes to fit a v5e.
Fully-masked k-chunks are skipped with a real ``lax.cond`` branch, halving
causal-attention FLOPs at the HLO level.

Model stack (DESIGN.md §8); paged attention: DESIGN.md §12.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed import ctx

F32 = jnp.float32
NEG_INF = -1e30


# ----------------------------------------------------------------- norms
def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    y = x.astype(F32) * lax.rsqrt(var + eps) * scale.astype(F32)
    return y.astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps) * scale.astype(F32) + bias.astype(F32)
    return y.astype(x.dtype)


def apply_norm(cfg, p, x):
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_params(cfg, d):
    if cfg.norm == "rms":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


# ------------------------------------------------------------------ rope
def rope(x, positions, theta=10000.0):
    """x: (B, S, n, d) with d even; positions: (S,) shared across the
    batch, or (B, S) per-lane (the paged serving path, where every lane
    sits at its own decode position — DESIGN.md §12)."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=F32) / d))
    ang = positions.astype(F32)[..., None] * freqs       # (S, d/2) | (B, S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if positions.ndim == 1:
        cos, sin = cos[None], sin[None]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------- flash attention
@functools.partial(jax.jit, static_argnames=("causal", "q_chunk", "k_chunk",
                                              "k_offset"))
def flash_attention(q, k, v, *, causal=True, q_offset=0, k_offset=0,
                    q_chunk=512, k_chunk=512):
    """q: (B,Sq,KV,G,dh), k/v: (B,Sk,KV,dh). Returns (B,Sq,KV,G,dh).

    ``q_offset``: absolute position of q[0] (for prefill continuation).
    ``k_offset``: position of k[0]; a negative value marks leading
    always-visible tokens (prefix tuning).
    """
    B, Sq, KV, G, dh = q.shape
    Sk = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    assert Sq % q_chunk == 0
    if Sk % k_chunk:  # pad keys (padded slots masked out via position test)
        pad = k_chunk - Sk % k_chunk
        k = jnp.pad(k, [(0, 0), (0, pad), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, pad), (0, 0), (0, 0)])
    nq, nk = Sq // q_chunk, k.shape[1] // k_chunk
    scale = dh ** -0.5
    q_offset = jnp.asarray(q_offset, jnp.int32)

    qc = q.reshape(B, nq, q_chunk, KV, G, dh).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, k_chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, k_chunk, KV, dh).transpose(1, 0, 2, 3, 4)

    def q_body(_, qi_and_chunk):
        qi, qblk = qi_and_chunk
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def k_body(carry, ki_and_chunk):
            ki, kblk, vblk = ki_and_chunk
            k_idx = ki * k_chunk + jnp.arange(k_chunk)
            k_pos = k_offset + k_idx

            def compute(carry):
                m, l, acc = carry
                s = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                               preferred_element_type=F32) * scale
                msk = k_idx[None, :] < Sk          # mask key padding
                if causal:
                    msk = msk & (q_pos[:, None] >= k_pos[None, :])
                s = jnp.where(msk[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(qblk.dtype), vblk,
                                preferred_element_type=F32)
                acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
                return m_new, l_new, acc_new

            if causal:
                needed = k_offset + ki * k_chunk <= q_pos[-1]
                carry = lax.cond(needed, compute, lambda c: c, carry)
            else:
                carry = compute(carry)
            return carry, None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, F32)
        l0 = jnp.zeros((B, KV, G, q_chunk), F32)
        a0 = jnp.zeros((B, q_chunk, KV, G, dh), F32)
        (m, l, acc), _ = lax.scan(
            k_body, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return None, out.astype(q.dtype)

    _, out = lax.scan(q_body, None, (jnp.arange(nq), qc))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, dh)


def decode_attention(q, k_cache, v_cache, cur_len):
    """Single-token attention over a (possibly partially filled) cache.

    q: (B,1,KV,G,dh); caches: (B,Smax,KV,dh); cur_len: int32 — number of
    valid cache entries *including* the current token.  Scalar ``cur_len``
    is the lockstep path (every lane at the same depth); a (B,) array is
    the continuous-batching path (per-lane depths).
    """
    B, _, KV, G, dh = q.shape
    Smax = k_cache.shape[1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k_cache,
                   preferred_element_type=F32) * (dh ** -0.5)
    cur = jnp.reshape(jnp.asarray(cur_len), (-1, 1))     # (1|B, 1)
    valid = jnp.arange(Smax)[None, :] < cur              # (1|B, Smax)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(q.dtype), v_cache,
                     preferred_element_type=F32)
    return out.astype(q.dtype)


def paged_attention(q, k, v, q_positions):
    """Causal attention over page-gathered caches (DESIGN.md §12).

    q: (B,C,KV,G,dh) — C query tokens per lane (C==1 for a decode step,
    C==prefill_chunk for a prefill call); k/v: (B,Smax,KV,dh), the lane's
    page table gathered back into position order, so buffer index s IS
    absolute position s; q_positions: (B,C) absolute position per query.

    The single causal test ``s <= q_position`` doubles as the validity
    mask: pages are written front-to-back, so every position <= the
    query's is live and everything beyond it is trash-page garbage.
    Dense (not flash) on purpose — serving buckets keep Smax at
    max_seq-bucket scale, and one (C, Smax) score block per lane is the
    flash-decode memory shape anyway.
    """
    B, C, KV, G, dh = q.shape
    Smax = k.shape[1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                   preferred_element_type=F32) * (dh ** -0.5)
    msk = jnp.arange(Smax)[None, None, :] <= q_positions[:, :, None]
    s = jnp.where(msk[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(q.dtype), v,
                     preferred_element_type=F32)
    return out.astype(q.dtype)


# --------------------------------------------------------------- GQA block
def attn_params(cfg, key):
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    std = D ** -0.5
    dt = jnp.dtype(cfg.dtype)
    p = {
        "norm": norm_params(cfg, D),
        "wq": jax.random.normal(ks[0], (D, H * dh), dt) * std,
        "wk": jax.random.normal(ks[1], (D, KV * dh), dt) * std,
        "wv": jax.random.normal(ks[2], (D, KV * dh), dt) * std,
        "wo": jax.random.normal(ks[3], (H * dh, D), dt) * (H * dh) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
    return p


def attn_fwd(cfg, p, x, *, mode, cache=None, pos=0, pc=None, pages=None):
    """mode: train | prefill | decode | paged.  Returns (out, new_cache).

    ``pc`` (fused.LayerPerturb) switches every weight read to its
    virtually perturbed view — loss(theta + s*eps*z) with no perturbed
    weights ever materialized (DESIGN.md §10); None is the plain path.

    mode="paged" is the serving engine's bucketed call (DESIGN.md §12):
    ``cache`` holds this layer's arena slice {"k"/"v": (P, psz, KV, dh)},
    ``pages`` is the (B, max_pages) page table (page 0 = trash), and
    ``pos`` is a (B,) per-lane start position.  The new K/V land at
    page ``pages[b, pos_b // psz]`` slot ``pos_b % psz``; attention then
    gathers each lane's pages back into position order.
    """
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    if pc is not None and "pk" in p:
        raise NotImplementedError(
            "virtual perturbation does not cover prefix-KV leaves")
    mm = (lambda a, w, name: a @ w) if pc is None else pc.matmul
    h = (apply_norm(cfg, p["norm"], x) if pc is None
         else pc.apply_norm(cfg, p["norm"], x, "norm"))
    q = mm(h, p["wq"], "wq").reshape(B, S, H, dh)
    k = mm(h, p["wk"], "wk").reshape(B, S, KV, dh)
    v = mm(h, p["wv"], "wv").reshape(B, S, KV, dh)
    if cfg.qk_norm:
        if pc is None:
            q = rms_norm(q, p["q_norm"]["scale"])
            k = rms_norm(k, p["k_norm"]["scale"])
        else:
            q = pc.rms_norm(q, p["q_norm"]["scale"], "q_norm/scale")
            k = pc.rms_norm(k, p["k_norm"]["scale"], "k_norm/scale")
    if mode == "paged":
        positions = jnp.asarray(pos)[:, None] + jnp.arange(S)[None, :]
    else:
        positions = pos + jnp.arange(S)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, KV, G, dh)
    if mode not in ("decode", "paged"):
        mesh = ctx.get_mesh()
        nm = mesh.shape.get("model", 1) if mesh is not None else 1
        if mesh is not None and KV % nm == 0:
            # Pin q/k/v head-sharded once per layer so the flash scan sees
            # a stable layout (otherwise the partitioner re-gathers k/v in
            # f32 every inner iteration).
            k = ctx.constrain(k, "batch", None, "model", None)
            v = ctx.constrain(v, "batch", None, "model", None)
            q = ctx.constrain(q, "batch", None, "model", None, None)
        elif mesh is not None and nm > 1 and S % nm == 0:
            # Heads don't divide the model axis (e.g. 56 heads / 16): left
            # alone, the partitioner keeps dh sharded and ALL-REDUCES the
            # score blocks of every flash iteration (TBs/step).  Instead
            # shard attention over *query stripes* (sequence parallel):
            # one bf16 k/v gather per layer, zero score collectives.
            q = ctx.constrain(q, "batch", "model", None, None, None)
            k = ctx.constrain(k, "batch", None, None, None)
            v = ctx.constrain(v, "batch", None, None, None)

    if mode == "paged":
        Pn, psz = cache["k"].shape[0], cache["k"].shape[1]
        page = pages[jnp.arange(B)[:, None], positions // psz]  # (B, S)
        flat = (page * psz + positions % psz).reshape(-1)
        k_arena = cache["k"].reshape(Pn * psz, KV, dh).at[flat].set(
            k.reshape(B * S, KV, dh)).reshape(Pn, psz, KV, dh)
        v_arena = cache["v"].reshape(Pn * psz, KV, dh).at[flat].set(
            v.reshape(B * S, KV, dh)).reshape(Pn, psz, KV, dh)
        kg = k_arena[pages].reshape(B, -1, KV, dh)   # (B, max_pg*psz, ...)
        vg = v_arena[pages].reshape(B, -1, KV, dh)
        o = paged_attention(q, kg, vg, positions)
        new_cache = {"k": k_arena, "v": v_arena}
    elif mode == "decode":
        k_cache = lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        v_cache = lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        o = decode_attention(q, k_cache, v_cache, pos + 1)
        new_cache = {"k": k_cache, "v": v_cache}
    elif "pk" in p:  # prefix tuning: always-visible learned KV pairs
        P = p["pk"].shape[0]
        pk = jnp.broadcast_to(p["pk"].astype(k.dtype), (B, P, KV, dh))
        pv = jnp.broadcast_to(p["pv"].astype(v.dtype), (B, P, KV, dh))
        kf = jnp.concatenate([pk, k], axis=1)
        vf = jnp.concatenate([pv, v], axis=1)
        o = flash_attention(q, kf, vf, causal=True, q_offset=pos, k_offset=-P,
                            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
        new_cache = None
    else:
        o = flash_attention(q, k, v, causal=True, q_offset=pos,
                            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
        new_cache = None
        if mode == "prefill":
            Smax = cache["k"].shape[1]
            pad = [(0, 0), (0, Smax - S), (0, 0), (0, 0)]
            new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    out = mm(o.reshape(B, S, H * dh), p["wo"], "wo")
    return out.astype(x.dtype), new_cache


# --------------------------------------------------------------- MLA block
def mla_params(cfg, key):
    D, H = cfg.d_model, cfg.n_heads
    dn, dr, lora = cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora
    ks = jax.random.split(key, 5)
    std = D ** -0.5
    dt = jnp.dtype(cfg.dtype)
    return {
        "norm": norm_params(cfg, D),
        "wq": jax.random.normal(ks[0], (D, H * (dn + dr)), dt) * std,
        "wdkv": jax.random.normal(ks[1], (D, lora + dr), dt) * std,
        "kv_norm": {"scale": jnp.ones((lora,), jnp.float32)},
        "wuk": jax.random.normal(ks[2], (lora, H * dn), dt) * lora ** -0.5,
        "wuv": jax.random.normal(ks[3], (lora, H * dn), dt) * lora ** -0.5,
        "wo": jax.random.normal(ks[4], (H * dn, D), dt) * (H * dn) ** -0.5,
    }


def mla_fwd(cfg, p, x, *, mode, cache=None, pos=0):
    """DeepSeek-V2 multi-head latent attention.

    Cache holds only (c_kv, k_rope): (lora + rope_dim) per token.  Decode
    uses the weight-absorbed latent form — scores and values are computed
    directly against the latent cache, never materializing per-head K/V.
    """
    B, S, D = x.shape
    H, dn, dr, lora = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim, cfg.kv_lora
    h = apply_norm(cfg, p["norm"], x)
    q = (h @ p["wq"]).reshape(B, S, H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    dkv = h @ p["wdkv"]
    ckv = rms_norm(dkv[..., :lora], p["kv_norm"]["scale"])   # (B,S,lora)
    kr = dkv[..., lora:].reshape(B, S, 1, dr)
    positions = pos + jnp.arange(S)
    qr = rope(qr, positions, cfg.rope_theta)
    kr = rope(kr, positions, cfg.rope_theta)
    scale_fix = (dn + dr) ** -0.5  # flash/decode divide by per-part dims

    if mode == "decode":
        ckv_c = lax.dynamic_update_slice(cache["ckv"], ckv, (0, pos, 0))
        kr_c = lax.dynamic_update_slice(cache["kr"], kr[:, :, 0], (0, pos, 0))
        # absorbed: q_lat[b,h,l] = sum_d qn[b,h,d] * wuk[l, h*dn+d]
        wuk = p["wuk"].reshape(lora, H, dn)
        q_lat = jnp.einsum("bqhd,lhd->bqhl", qn, wuk, preferred_element_type=F32)
        s = jnp.einsum("bqhl,bsl->bhqs", q_lat, ckv_c.astype(F32),
                       preferred_element_type=F32)
        s += jnp.einsum("bqhd,bsd->bhqs", qr.astype(F32), kr_c.astype(F32),
                        preferred_element_type=F32)
        s *= scale_fix
        valid = jnp.arange(ckv_c.shape[1]) < pos + 1
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhqs,bsl->bqhl", pr, ckv_c.astype(F32),
                           preferred_element_type=F32)
        wuv = p["wuv"].reshape(lora, H, dn)
        o = jnp.einsum("bqhl,lhd->bqhd", o_lat, wuv, preferred_element_type=F32)
        o = o.astype(x.dtype)
        new_cache = {"ckv": ckv_c, "kr": kr_c}
    else:
        kn = jnp.einsum("bsl,lhd->bshd", ckv, p["wuk"].reshape(lora, H, dn))
        vv = jnp.einsum("bsl,lhd->bshd", ckv, p["wuv"].reshape(lora, H, dn))
        kfull = jnp.concatenate([kn, jnp.broadcast_to(kr, (B, S, H, dr))], -1)
        qfull = jnp.concatenate([qn, qr], -1).reshape(B, S, H, 1, dn + dr)
        vpad = jnp.pad(vv, [(0, 0), (0, 0), (0, 0), (0, dr)])
        o = flash_attention(qfull, kfull, vpad, causal=True, q_offset=pos,
                            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
        o = o.reshape(B, S, H, dn + dr)[..., :dn]
        new_cache = None
        if mode == "prefill":
            Smax = cache["ckv"].shape[1]
            new_cache = {
                "ckv": jnp.pad(ckv, [(0, 0), (0, Smax - S), (0, 0)]),
                "kr": jnp.pad(kr[:, :, 0], [(0, 0), (0, Smax - S), (0, 0)]),
            }
    out = o.reshape(B, S, H * dn) @ p["wo"]
    return out.astype(x.dtype), new_cache


# --------------------------------------------------------------- dense FFN
def ffn_params(cfg, key, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    p = {"norm": norm_params(cfg, D)}
    if cfg.act == "silu":  # swiglu
        p["wg"] = jax.random.normal(ks[0], (D, F), dt) * D ** -0.5
        p["wu"] = jax.random.normal(ks[1], (D, F), dt) * D ** -0.5
    else:
        p["wi"] = jax.random.normal(ks[0], (D, F), dt) * D ** -0.5
    p["wd"] = jax.random.normal(ks[2], (F, D), dt) * F ** -0.5
    return p


def ffn_fwd(cfg, p, x, d_ff=None, pc=None):
    mm = (lambda a, w, name: a @ w) if pc is None else pc.matmul
    h = (apply_norm(cfg, p["norm"], x) if pc is None
         else pc.apply_norm(cfg, p["norm"], x, "norm"))
    if cfg.act == "silu":
        a = (jax.nn.silu(mm(h, p["wg"], "wg").astype(F32)).astype(x.dtype)
             * mm(h, p["wu"], "wu"))
    elif cfg.act == "gelu":
        a = jax.nn.gelu(mm(h, p["wi"], "wi").astype(F32)).astype(x.dtype)
    else:
        a = jax.nn.relu(mm(h, p["wi"], "wi"))
    return mm(a, p["wd"], "wd").astype(x.dtype)
