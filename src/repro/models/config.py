"""Model configuration schema.

A model is a token embedding + a sequence of *stages*; each stage is a
block pattern repeated ``repeat`` times (executed interleaved, i.e.
stage = lax.scan over ``repeat`` of its pattern).  This expresses every
assigned architecture exactly:

  * dense LMs:        1 stage, pattern = [attn+dense], repeat = L
  * granite-moe:      1 stage, pattern = [attn+moe],   repeat = L
  * deepseek-v2-lite: stage0 = [attn(mla)+dense] x1, stage1 = [mla+moe] x26
  * jamba:            1 stage, pattern = 8 blocks (mamba/attn x {dense,moe}),
                      repeat = 4
  * xlstm:            1 stage, pattern = [mlstm x7, slstm], repeat = 3

Every (stage, pattern position) is a ZO layer *group* whose parameters are
stacked over ``repeat``; the global LeZO layer index space enumerates all
``sum(repeat * len(pattern))`` blocks.

Model stack / zoo (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    kind: str          # attn | mla | mamba | mlstm | slstm
    ffn: str = "dense"  # dense | moe | none
    d_ff: int = 0       # override cfg.d_ff for this block (0 = default)


@dataclasses.dataclass(frozen=True)
class StageCfg:
    repeat: int
    pattern: Tuple[BlockCfg, ...]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    stages: Tuple[StageCfg, ...]
    d_head: int = 0                  # 0 -> d_model // n_heads
    # attention flavor
    qk_norm: bool = False
    attn_q_chunk: int = 512       # flash attention q tile
    attn_k_chunk: int = 2048      # flash attention kv tile (acc-carry HBM
                                  # traffic ~ 1/attn_k_chunk; hillclimbed)
    pos_emb: str = "rope"            # rope | learned | none
    rope_theta: float = 10000.0
    act: str = "silu"                # silu(=swiglu) | gelu | relu
    norm: str = "rms"                # rms | ln
    # MLA (deepseek)
    kv_lora: int = 0
    rope_head_dim: int = 64
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_dense_d_ff: int = 0        # deepseek: layer-0 dense FFN width
    # mamba
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    # xlstm
    lstm_pf: int = 2                 # mLSTM projection factor
    # misc
    tie_embeddings: bool = True
    frontend: str = "none"           # none | audio | vision
    frontend_dim: int = 0            # stub embedding dim (== d_model)
    max_seq: int = 4096
    dtype: str = "bfloat16"
    subquadratic: bool = False       # eligible for long_500k decode
    min_active_layers: int = 1       # forbid rho=1 (paper Fig.3 collapse)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def num_layers(self) -> int:
        return sum(s.repeat * len(s.pattern) for s in self.stages)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    @property
    def lstm_d_inner(self) -> int:
        return self.lstm_pf * self.d_model

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def dense_lm(name, L, d_model, n_heads, n_kv_heads, d_ff, vocab, **kw) -> ModelConfig:
    """Helper for standard dense decoder-only LMs."""
    return ModelConfig(
        name=name, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv_heads,
        d_ff=d_ff, vocab=vocab,
        stages=(StageCfg(L, (BlockCfg("attn", "dense"),)),), **kw)
