"""Mixture-of-Experts FFN with sort-based static-shape dispatch.

Routing is DeepSeek/Granite-style: softmax over experts, top-k, renormalize.
Dispatch uses the production sort-based scheme (static shapes, capacity
drop): token-expert assignments are sorted by expert id, each expert gets a
contiguous capacity-C slab of a (E*C, D) buffer, expert FFNs run as one
batched einsum, and outputs scatter back weighted.  All shapes are static
-> jit/pjit friendly.

Sharding note (see distributed/sharding.py): expert weights are sharded
over the *d_ff* axis (tensor parallelism inside every expert) rather than
over the expert axis.  Router + dispatch then stay device-local (no
all-to-all); the only collective is the usual TP reduce of the FFN output.
An expert-sharded (EP) layout is the classic alternative — for ZO
fine-tuning the TP layout wins because perturbation touches all experts
uniformly and the dispatch buffers never cross devices.

Model stack / zoo (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.models import layers

F32 = jnp.float32


def capacity(cfg, tokens: int) -> int:
    """Per-dispatch-group expert capacity.

    Never exceeds ``tokens`` (a token contributes each expert at most one
    assignment since top-k picks are distinct), so single-token decode
    groups get C=1."""
    c = -(-int(tokens * cfg.top_k * cfg.capacity_factor) // cfg.n_experts)
    return max(1, min(tokens, -(-c // 4) * 4 if tokens >= 4 else c))


def moe_params(cfg, key):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "norm": layers.norm_params(cfg, D),
        "router": jax.random.normal(ks[0], (D, E), jnp.float32) * D ** -0.5,
        "we_g": jax.random.normal(ks[1], (E, D, F), dt) * D ** -0.5,
        "we_u": jax.random.normal(ks[2], (E, D, F), dt) * D ** -0.5,
        "we_d": jax.random.normal(ks[3], (E, F, D), dt) * F ** -0.5,
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * F
        kss = jax.random.split(ks[4], 3)
        p["ws_g"] = jax.random.normal(kss[0], (D, Fs), dt) * D ** -0.5
        p["ws_u"] = jax.random.normal(kss[1], (D, Fs), dt) * D ** -0.5
        p["ws_d"] = jax.random.normal(kss[2], (Fs, D), dt) * Fs ** -0.5
    return p


def moe_fwd(cfg, p, x):
    """x: (B, S, D) -> (y, aux).

    Dispatch groups = batch rows: capacity is per-row, so every sort /
    cumsum / scatter is row-local and stays on the owning data shard.
    Written batched (explicit B dim, not vmap) so the big intermediates
    can carry sharding constraints — without them the SPMD partitioner
    all-gathers the dispatch buffers globally.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    h = ctx.constrain(layers.apply_norm(cfg, p["norm"], x),
                      "batch", None, None)                     # (B, S, D)

    logits = jnp.einsum("bsd,de->bse", h.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                     # (B, S, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # Switch-style load-balance loss
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((B, E), F32).at[
        jnp.arange(B)[:, None], top_e.reshape(B, -1)].add(1.0) / (S * k)
    aux = E * jnp.sum(me * jnp.mean(ce, axis=0))

    # ---- per-row sort-based dispatch (all ops row-local) ----------------
    row = lambda a, *ax: ctx.constrain(a, "batch", *ax)
    e_flat = top_e.reshape(B, S * k)
    w_flat = top_w.reshape(B, S * k)
    tok_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S), k)[None], (B, S * k))
    order = jnp.argsort(e_flat, axis=-1)
    take = lambda a: jnp.take_along_axis(a, order, axis=-1)
    e_s, w_s, tok_s = (row(take(e_flat), None), row(take(w_flat), None),
                       row(take(tok_flat), None))
    counts = jax.vmap(lambda e: jnp.zeros((E,), jnp.int32).at[e].add(1))(e_s)
    starts = jnp.cumsum(counts, axis=-1) - counts
    pos = jnp.arange(S * k)[None] - jnp.take_along_axis(starts, e_s, axis=-1)
    keep = pos < C
    slot = row(jnp.where(keep, e_s * C + pos, E * C), None)    # E*C = trash

    h_s = row(jnp.take_along_axis(h, tok_s[..., None], axis=1),
              None, None)                                      # (B, S*k, D)
    # vmapped scatter => batched scatter HLO: partitions on the batch dim
    # (explicit arange(B) row indices would force a global gather).
    buf = jax.vmap(lambda s, u: jnp.zeros((E * C + 1, D), x.dtype)
                   .at[s].set(u))(slot, h_s)
    buf = row(buf, None, None)
    eb = ctx.constrain(buf[:, :-1].reshape(B, E, C, D),
                       "batch", None, None, None)

    # ---- expert FFN (batched swiglu, TP on d_ff) ------------------------
    g = jnp.einsum("becd,edf->becf", eb, p["we_g"], preferred_element_type=F32)
    u = jnp.einsum("becd,edf->becf", eb, p["we_u"], preferred_element_type=F32)
    a = ctx.constrain((jax.nn.silu(g) * u).astype(x.dtype),
                      "batch", None, None, "model")
    o = jnp.einsum("becf,efd->becd", a, p["we_d"], preferred_element_type=F32)
    o = ctx.constrain(o, "batch", None, None, None).reshape(B, E * C, D)

    # ---- combine ---------------------------------------------------------
    o_s = row(jnp.take_along_axis(o, jnp.clip(slot, 0, E * C - 1)[..., None],
                                  axis=1), None, None)
    contrib = row(o_s * jnp.where(keep, w_s, 0.0)[..., None], None, None)
    y = jax.vmap(lambda t, c: jnp.zeros((S, D), F32).at[t].add(c))(
        tok_s, contrib)
    y = ctx.constrain(y, "batch", None, None)

    if cfg.n_shared_experts:
        sg = jax.nn.silu((h @ p["ws_g"]).astype(F32))
        su = (h @ p["ws_u"]).astype(F32)
        y = y + jnp.einsum("bsf,fd->bsd", (sg * su).astype(x.dtype),
                           p["ws_d"], preferred_element_type=F32)

    return y.astype(x.dtype), aux
