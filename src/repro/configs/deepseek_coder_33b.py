"""deepseek-coder-33b [dense] — llama-arch. 62L d_model=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256.  [arXiv:2401.14196; hf]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import ModelConfig, dense_lm


def full() -> ModelConfig:
    return dense_lm("deepseek-coder-33b", 62, 7168, 56, 8, 19200, 32256,
                    tie_embeddings=False, max_seq=32768)


def smoke() -> ModelConfig:
    return dense_lm("deepseek-coder-smoke", 3, 64, 8, 2, 160, 512,
                    tie_embeddings=False, dtype="float32", max_seq=128)
