"""OPT family (the paper's models): relu, LayerNorm, learned positions,
MHA, tied embeddings.  [arXiv:2205.01068]

The paper fine-tunes OPT-1.3b / 13b / 30b with MeZO/LeZO; we reproduce the
configs for cost analysis and provide reduced variants for CPU-scale
convergence experiments (benchmarks/accuracy.py).

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import ModelConfig, dense_lm

_COMMON = dict(act="relu", norm="ln", pos_emb="learned", tie_embeddings=True,
               max_seq=2048)


def opt_1_3b() -> ModelConfig:
    return dense_lm("opt-1.3b", 24, 2048, 32, 32, 8192, 50272, **_COMMON)


def opt_13b() -> ModelConfig:
    return dense_lm("opt-13b", 40, 5120, 40, 40, 20480, 50272, **_COMMON)


def opt_30b() -> ModelConfig:
    return dense_lm("opt-30b", 48, 7168, 56, 56, 28672, 50272, **_COMMON)


def full() -> ModelConfig:  # registry default: the paper's main model
    return opt_13b()


def smoke() -> ModelConfig:
    return dense_lm("opt-smoke", 2, 64, 4, 4, 128, 512, dtype="float32",
                    **{**_COMMON, "max_seq": 128})


def opt_tiny(layers=4, d_model=128, vocab=512) -> ModelConfig:
    """CPU-trainable OPT-shaped model for convergence benchmarks."""
    return dense_lm(f"opt-tiny-{layers}L{d_model}", layers, d_model, 4, 4,
                    4 * d_model, vocab, dtype="float32",
                    **{**_COMMON, "max_seq": 256})


def tiny() -> ModelConfig:
    """Registry variant for the fast-tier fixtures (``model.variant``)."""
    return opt_tiny()


def bench() -> ModelConfig:
    """Registry variant at the benchmark suite's perturb-heavy
    params/token ratio (benchmarks/common.bench_model)."""
    return opt_tiny(layers=4, d_model=512, vocab=2048)
