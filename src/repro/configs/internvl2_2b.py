"""internvl2-2b [vlm] — InternViT (stub) + InternLM2-1.8B backbone.
24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92553; input_specs provides
patch embeddings.  [arXiv:2404.16821; hf]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import ModelConfig, dense_lm


def full() -> ModelConfig:
    return dense_lm("internvl2-2b", 24, 2048, 16, 8, 8192, 92553,
                    frontend="vision", tie_embeddings=False, max_seq=32768)


def smoke() -> ModelConfig:
    return dense_lm("internvl2-smoke", 2, 64, 4, 2, 128, 512,
                    frontend="vision", tie_embeddings=False, dtype="float32",
                    max_seq=128)
