"""jamba-v0.1-52b [hybrid] — Mamba+attention 7:1, MoE 16e top-2 every other
layer.  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Period-8 pattern (attention at offset 4, MoE at odd offsets), repeated 4x.
[arXiv:2403.19887; hf]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import BlockCfg, ModelConfig, StageCfg


def _pattern(attn_offset=4):
    out = []
    for i in range(8):
        kind = "attn" if i == attn_offset else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        out.append(BlockCfg(kind, ffn))
    return tuple(out)


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=65536, stages=(StageCfg(4, _pattern()),),
        n_experts=16, top_k=2, moe_d_ff=14336,
        mamba_d_state=16, mamba_expand=2, mamba_conv=4,
        tie_embeddings=False, max_seq=524288, subquadratic=True,
    )


def smoke() -> ModelConfig:
    pat = (BlockCfg("mamba", "dense"), BlockCfg("mamba", "moe"),
           BlockCfg("attn", "dense"), BlockCfg("mamba", "moe"))
    return ModelConfig(
        name="jamba-smoke", d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, stages=(StageCfg(2, pat),),
        n_experts=4, top_k=2, moe_d_ff=64, dtype="float32", max_seq=128,
        subquadratic=True,
    )
