"""qwen3-14b [dense] — qk_norm, GQA. 40L d_model=5120 40H (kv=8) d_head=128
d_ff=17408 vocab=151936.  [hf:Qwen/Qwen3-8B; hf]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import ModelConfig, dense_lm


def full() -> ModelConfig:
    return dense_lm("qwen3-14b", 40, 5120, 40, 8, 17408, 151936,
                    d_head=128, qk_norm=True, tie_embeddings=False,
                    max_seq=32768)


def smoke() -> ModelConfig:
    return dense_lm("qwen3-smoke", 2, 64, 4, 2, 160, 512, d_head=16,
                    qk_norm=True, tie_embeddings=False, dtype="float32",
                    max_seq=128)
