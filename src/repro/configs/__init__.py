"""Architecture registry: --arch <id> resolves here.

Model-zoo config (DESIGN.md §8).
"""
from __future__ import annotations

import importlib

ARCHS = {
    "xlstm-350m": "xlstm_350m",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "internlm2-1.8b": "internlm2_1_8b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen3-14b": "qwen3_14b",
    "musicgen-large": "musicgen_large",
    "internvl2-2b": "internvl2_2b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "opt-13b": "opt",
}

# Accepted spellings that resolve to a registry id but stay out of
# list_archs() so sweeps/dry-run grids don't run the same config twice.
ALIASES = {
    "opt": "opt-13b",      # family alias: full() is the 13b paper model
}


def get(arch: str, variant: str = "full"):
    arch = ALIASES.get(arch, arch)
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return getattr(mod, variant)()


def list_archs():
    return sorted(ARCHS)
