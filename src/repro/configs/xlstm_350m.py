"""xlstm-350m [ssm] — sLSTM + mLSTM blocks, xLSTM[7:1] interleave.

24L d_model=1024 4H d_ff=0 (the mLSTM block carries its own 2x projection)
vocab=50304.  [arXiv:2405.04517; unverified]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import BlockCfg, ModelConfig, StageCfg

_PATTERN = tuple([BlockCfg("mlstm", "none")] * 7 + [BlockCfg("slstm", "none")])


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab=50304, stages=(StageCfg(3, _PATTERN),), lstm_pf=2,
        tie_embeddings=True, max_seq=524288, subquadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke", d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab=512, stages=(StageCfg(1, (BlockCfg("mlstm", "none"),
                                        BlockCfg("slstm", "none"))),),
        lstm_pf=2, dtype="float32", max_seq=128, subquadratic=True,
    )
