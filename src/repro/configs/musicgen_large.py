"""musicgen-large [audio] — decoder-only over EnCodec tokens; frontend
stubbed (input_specs provides frame embeddings).  48L d_model=2048 32H
(kv=32) d_ff=8192 vocab=2048.  [arXiv:2306.05284; hf]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import ModelConfig, dense_lm


def full() -> ModelConfig:
    return dense_lm("musicgen-large", 48, 2048, 32, 32, 8192, 2048,
                    act="gelu", norm="ln", pos_emb="learned",
                    frontend="audio", tie_embeddings=False, max_seq=32768)


def smoke() -> ModelConfig:
    return dense_lm("musicgen-smoke", 2, 64, 4, 4, 128, 256,
                    act="gelu", norm="ln", pos_emb="learned",
                    frontend="audio", tie_embeddings=False, dtype="float32",
                    max_seq=128)
