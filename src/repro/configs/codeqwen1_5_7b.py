"""codeqwen1.5-7b [dense] — qwen1.5-arch (MHA). 32L d_model=4096 32H (kv=32)
d_ff=13440 vocab=92416.  [hf:Qwen/CodeQwen1.5-7B; hf]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import ModelConfig, dense_lm


def full() -> ModelConfig:
    return dense_lm("codeqwen1.5-7b", 32, 4096, 32, 32, 13440, 92416,
                    tie_embeddings=False, max_seq=32768)


def smoke() -> ModelConfig:
    return dense_lm("codeqwen-smoke", 2, 64, 4, 4, 192, 512,
                    tie_embeddings=False, dtype="float32", max_seq=128)
