"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 2 shared / 64 routed top-6.

27L d_model=2048 16H d_head=128(+64 rope) moe d_ff=1408 vocab=102400;
layer 0 uses a dense FFN (width 10944).  [arXiv:2405.04434; hf]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import BlockCfg, ModelConfig, StageCfg


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", d_model=2048, n_heads=16, n_kv_heads=16,
        d_head=128, d_ff=1408, vocab=102400,
        stages=(StageCfg(1, (BlockCfg("mla", "dense", d_ff=10944),)),
                StageCfg(26, (BlockCfg("mla", "moe"),))),
        kv_lora=512, rope_head_dim=64,
        n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
        tie_embeddings=False, max_seq=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", d_model=64, n_heads=4, n_kv_heads=4,
        d_head=16, d_ff=48, vocab=512,
        stages=(StageCfg(1, (BlockCfg("mla", "dense", d_ff=96),)),
                StageCfg(2, (BlockCfg("mla", "moe"),))),
        kv_lora=32, rope_head_dim=8,
        n_experts=4, n_shared_experts=2, top_k=2, moe_d_ff=48,
        tie_embeddings=False, dtype="float32", max_seq=128,
    )
