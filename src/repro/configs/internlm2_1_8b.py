"""internlm2-1.8b [dense] — GQA. 24L d_model=2048 16H (kv=8) d_ff=8192
vocab=92544.  [arXiv:2403.17297; hf]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import ModelConfig, dense_lm


def full() -> ModelConfig:
    return dense_lm("internlm2-1.8b", 24, 2048, 16, 8, 8192, 92544,
                    tie_embeddings=False, max_seq=32768)


def smoke() -> ModelConfig:
    return dense_lm("internlm2-smoke", 2, 64, 4, 2, 128, 512,
                    tie_embeddings=False, dtype="float32", max_seq=128)
