"""granite-moe-1b-a400m [moe] — 32 experts, top-8.

24L d_model=1024 16H (GQA kv=8) expert d_ff=512 vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Model-zoo config (DESIGN.md §8).
"""
from repro.models.config import BlockCfg, ModelConfig, StageCfg


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab=49155,
        stages=(StageCfg(24, (BlockCfg("attn", "moe"),)),),
        n_experts=32, top_k=8, moe_d_ff=512,
        tie_embeddings=True, max_seq=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=512, stages=(StageCfg(2, (BlockCfg("attn", "moe"),)),),
        n_experts=4, top_k=2, moe_d_ff=32, dtype="float32", max_seq=128,
    )
