"""Assigned input-shape grid (LM-family transformers).

``train_*`` shapes lower ``train_step`` (a full LeZO/MeZO optimization
step); ``decode_*`` / ``long_*`` lower ``serve_step`` (one new token
against a KV/state cache of ``seq_len``); ``prefill_*`` lowers the cache
build over the full prompt.

``long_500k`` requires sub-quadratic sequence handling — it only runs for
configs with ``subquadratic=True`` (xlstm, jamba); pure full-attention
archs skip it (recorded in DESIGN.md §4 and EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def shapes_for(cfg) -> list:
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        out.append(SHAPES["long_500k"])
    return out
