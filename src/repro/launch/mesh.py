"""Production mesh construction.

Single pod: 16x16 = 256 chips (TPU v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the ``pod``
axis carries pure data parallelism, which for ZO fine-tuning costs one
scalar all-reduce per forward (see DESIGN.md §3): the DCN between pods is
effectively idle, which is the property that lets LeZO scale to
arbitrarily many pods.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state; the dry-run sets
``xla_force_host_platform_device_count=512`` *before* any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
