"""One spec-driven launch CLI for every command (DESIGN.md §11)::

    python -m repro.launch train    --preset lezo-opt13b --set optimizer.lr=1e-4
    python -m repro.launch evaluate --task sst2 --mode train
    python -m repro.launch dryrun   --arch deepseek-coder-33b --shape train_4k
    python -m repro.launch hillclimb --arch opt-13b --shape train_4k --cfg attn_k_chunk=1024
    python -m repro.launch serve    --arch xlstm-350m --gen 16
    python -m repro.launch specs    --out artifacts/specs
    python -m repro.launch report   [RUN]           # health report (markdown)
    python -m repro.launch replay   [RUN] --step 7  # bitwise replay verifier

Every shared flag is *generated* from the ``repro.api`` spec schema —
``--<section>.<field>`` for each field, plus the short aliases below —
so no command re-declares (or drifts on) a default: they all start from
the same preset and differ only by spec overrides.  Precedence:
preset < generated/alias flags < command implications (e.g.
``train --optimizer mezo``, which always means n_drop=0 — the legacy
semantics) < ``--set section.field=value``.

The legacy module entrypoints (``python -m repro.launch.train`` etc.)
are thin shims that forward here.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, Optional

from repro import api
from repro.api import presets as presets_mod
from repro.api import spec as spec_mod

# Short ergonomic spellings (also the legacy CLI surface) — one table,
# shared by every command; the long generated form always exists too.
ALIASES = {
    "--arch": "model.arch",
    "--variant": "model.variant",
    "--seq-len": "model.seq_len",
    "--task": "task.name",
    "--lr": "optimizer.lr",
    "--eps": "optimizer.eps",
    "--sparsity": "optimizer.sparsity",
    "--estimator": "estimator.name",
    "--q": "estimator.q",
    "--backend": "runtime.backend",
    "--forward-backend": "runtime.forward_backend",
    "--peft": "runtime.peft",
    "--quorum": "runtime.quorum",
    "--loss-shards": "runtime.n_loss_shards",
    "--steps": "run.steps",
    "--batch-size": "run.batch_size",
    "--seed": "run.seed",
    "--ckpt-dir": "run.ckpt_dir",
    "--ckpt-every": "run.ckpt_every",
    "--telemetry": "telemetry.enabled",
    "--trace-jsonl": "telemetry.jsonl",
    "--profile-dir": "telemetry.profile_dir",
    "--runs-dir": "telemetry.runs_dir",
}

# commands that operate on an existing run directory — they take no
# experiment-spec flags (the spec is the run's embedded spec.json)
_NO_SPEC_CMDS = {"report", "replay"}

_SPEC_DEST = "spec_overrides"


class _SpecFlag(argparse.Action):
    """Collects any generated/alias spec flag into one ordered dict."""

    def __call__(self, parser, ns, value, option_string=None):
        store = getattr(ns, _SPEC_DEST, None)
        if store is None:
            store = {}
            setattr(ns, _SPEC_DEST, store)
        store[self.metavar] = value   # metavar carries the spec path


def add_spec_flags(ap: argparse.ArgumentParser):
    """Generate ``--section.field`` flags from the spec schema + the
    alias table.  Values are raw strings; ``api.coerce`` (the same parser
    behind ``--set``) types them, so every surface agrees."""
    g = ap.add_argument_group("experiment spec (generated from repro.api)")
    for path in spec_mod.field_paths():
        sec, _, name = path.partition(".")
        default = getattr(getattr(api.Experiment(), sec), name)
        g.add_argument(f"--{path}", action=_SpecFlag, metavar=path,
                       help=f"(default from preset; base {default!r})")
    for flag, path in sorted(ALIASES.items()):
        g.add_argument(flag, action=_SpecFlag, metavar=path,
                       help=f"alias for --{path}")
    ap.add_argument("--preset", default="default",
                    help=f"base spec; one of {presets_mod.names()}")
    ap.add_argument("--set", action="append", default=[], metavar="PATH=VAL",
                    help="spec override, e.g. --set optimizer.lr=1e-4 "
                         "(highest precedence, repeatable)")


def build_spec(ns, implied: Optional[Dict] = None) -> api.Experiment:
    """preset -> flags -> command implications -> --set.

    Command implications (e.g. ``train --optimizer mezo`` forcing
    sparsity 0) intentionally beat the generated flags — that is the
    legacy semantics (``--optimizer mezo --sparsity X`` always meant
    n_drop=0) — while an explicit ``--set`` still wins over everything.
    """
    spec = presets_mod.get(ns.preset)
    flags = getattr(ns, _SPEC_DEST, None) or {}
    if flags:
        spec = api.with_overrides(spec, flags)
    if implied:
        spec = api.with_overrides(spec, implied)
    sets = {}
    for kv in ns.set:
        path, eq, val = kv.partition("=")
        if not eq:
            raise spec_mod.SpecError(path, "--set expects PATH=VALUE")
        sets[path] = val
    if sets:
        spec = api.with_overrides(spec, sets)
    return spec


def _clean_history(hist: Dict) -> Dict:
    return {k: v for k, v in hist.items() if not k.endswith("params")}


def _write_json(path: str, payload):
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)


# ---------------------------------------------------------------- commands
def _cmd_train(ns):
    from repro.obs import runlog

    implied = {}
    if ns.optimizer == "mezo":
        implied = {"optimizer.sparsity": 0.0, "optimizer.n_drop": None}
    elif ns.optimizer == "fo":
        implied = {"optimizer.mode": "fo"}
    # every launch train writes a run directory by default; an explicit
    # flag wins (implications beat generated flags, so check first) and
    # --no-runlog turns the registry off entirely
    flags = getattr(ns, _SPEC_DEST, None) or {}
    user_set = {kv.partition("=")[0] for kv in ns.set}
    if (not ns.no_runlog and "telemetry.runs_dir" not in flags
            and "telemetry.runs_dir" not in user_set):
        implied["telemetry.runs_dir"] = runlog.DEFAULT_RUNS_DIR
    spec = build_spec(ns, implied)
    result = api.run(spec)
    print(json.dumps(result["summary"], indent=1))
    if ns.out:
        _write_json(ns.out, {"spec": result["spec"],
                             "summary": result["summary"],
                             "history": _clean_history(result["history"])})
    return result


def _cmd_evaluate(ns):
    from repro import tasks
    spec = build_spec(ns)
    raw = spec.task.name
    names = tasks.names() if raw in (None, "all") else [raw]
    reports = [api.evaluate(api.with_overrides(spec, {"task.name": n}),
                            mode=ns.mode, n_examples=ns.n_examples)
               for n in names]
    print(json.dumps(reports, indent=1))
    if ns.out:
        _write_json(ns.out, reports)
    return reports


def _cmd_dryrun(ns):
    from repro import configs
    from repro.configs.shapes import SHAPES, shapes_for

    spec = build_spec(ns)
    api.validate(spec)
    archs = ([a for a in configs.list_archs() if a != "opt-13b"]
             if ns.all else [spec.model.arch])
    cells = []
    for arch in archs:
        cfg = configs.get(arch)
        shapes = [SHAPES[ns.shape]] if ns.shape else shapes_for(cfg)
        for sh in shapes:
            meshes = ([False, True] if (ns.both_meshes or ns.all)
                      else [ns.multi_pod or spec.runtime.mesh == "multi_pod"])
            for mp in meshes:
                cells.append((arch, sh.name, mp))

    os.makedirs(ns.out, exist_ok=True)
    results, failures = [], []
    for arch, shape_name, mp in cells:
        try:
            rec = api.dryrun_cell(spec, shape_name, arch=arch,
                                  multi_pod=mp, lowering=ns.lowering,
                                  save_hlo=ns.save_hlo)
            results.append(rec)
            tag = f"{arch}_{shape_name}_{'mp' if mp else 'sp'}_{ns.lowering}"
            _write_json(os.path.join(ns.out, tag + ".json"), rec)
        except Exception as e:  # noqa: BLE001 — report every cell
            failures.append((arch, shape_name, mp, repr(e)[:300]))
            print(f"FAIL [{arch} x {shape_name} x "
                  f"{'mp' if mp else 'sp'}]: {e!r}"[:400])
    print(f"\n{len(results)} cells passed, {len(failures)} failed")
    for f in failures:
        print("  FAIL:", f)
    return {"cells": results, "failures": failures}


def _cmd_hillclimb(ns):
    from repro.launch import analysis
    from repro.launch import dryrun as dryrun_mod

    spec = build_spec(ns)
    api.validate(spec)
    overrides = {}
    for kv in ns.cfg:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    multi_pod = ns.multi_pod or spec.runtime.mesh == "multi_pod"
    cfg, shape, mesh, lowered, compiled = dryrun_mod.lower_cell(
        spec.model.arch, ns.shape, multi_pod, ns.lowering, overrides)
    txt = compiled.as_text()
    cost = analysis.HloCost(txt).total()
    ma = compiled.memory_analysis()
    terms = dryrun_mod.roofline_terms(
        {"flops": cost.flops, "bytes accessed": cost.bytes}, ma, cost.coll,
        mesh.devices.size)
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    print(f"\n=== {spec.model.arch} x {ns.shape} x "
          f"{'mp' if multi_pod else 'sp'} x {ns.lowering} "
          f"{overrides or ''} ===")
    print(f"compute={terms['compute_s']*1e3:10.2f} ms")
    print(f"memory ={terms['memory_s']*1e3:10.2f} ms")
    print(f"coll   ={terms['collective_s']*1e3:10.2f} ms   dominant: {dom}")
    if ma:
        print(f"temp   ={ma.temp_size_in_bytes/2**30:10.2f} GiB  "
              f"args={ma.argument_size_in_bytes/2**30:.2f} GiB")
    proj = None
    est, q = spec.estimator.name, spec.estimator.q
    fwd_backend = spec.runtime.forward_backend
    if est != "two_point" or q != 1 or fwd_backend != "materialized":
        proj = analysis.estimator_step_cost(
            terms, est, q=q, forward_backend=fwd_backend,
            param_bytes=ma.argument_size_in_bytes if ma else None)
        print(f"\nprojected for estimator={est} q={q} "
              f"({proj['forwards']} forwards, {proj['axpy_sweeps']} sweeps):")
        print(f"compute={proj['compute_s']*1e3:10.2f} ms  "
              f"memory={proj['memory_s']*1e3:10.2f} ms  "
              f"coll={proj['collective_s']*1e3:10.2f} ms")
    print("\ntop collectives (GiB wire/device/step):")
    for k, v in sorted(cost.detail.items(), key=lambda x: -x[1])[:ns.top]:
        print(f"  {v/2**30:9.3f}  {k[:110]}")
    rec = {"spec": api.to_dict(spec), "overrides": overrides, "terms": terms,
           "estimator_projection": proj,
           "detail": dict(sorted(cost.detail.items(),
                                 key=lambda x: -x[1])[:30])}
    if ns.tag:
        _write_json(f"artifacts/hillclimb/{ns.tag}.json", rec)
    return rec


def _cmd_serve(ns):
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import configs
    from repro.launch import serve as serve_mod
    from repro.models import frontends, lm

    spec = build_spec(ns)
    api.validate(spec)
    cfg = configs.get(spec.model.arch, spec.model.variant)
    engine_mode = ns.engine
    if engine_mode == "auto":
        engine_mode = "paged" if lm.supports_paged(cfg) else "lockstep"
    if engine_mode == "paged" and not lm.supports_paged(cfg):
        raise SystemExit(
            f"{spec.model.arch} has non-attn mixers or a stub frontend; "
            "the paged engine does not cover it — use --engine lockstep")
    if engine_mode == "lockstep" and frontends.uses_embeds(cfg):
        raise SystemExit(f"{spec.model.arch} takes stub embeddings; use "
                         "the decode dry-run cell for it instead")
    params = lm.init_params(cfg, jax.random.PRNGKey(spec.run.seed))
    rng = np.random.default_rng(spec.run.seed)
    tokens = rng.integers(0, cfg.vocab, (ns.batch, ns.prompt_len))

    if engine_mode == "paged":
        from repro import obs
        from repro import serving as serving_mod
        sess = obs.session(spec.telemetry)
        engine = serving_mod.Engine(cfg, params, spec.serving, obs=sess)
        reqs = [serving_mod.Request(rid=i, tokens=row.tolist(),
                                    max_new_tokens=ns.gen,
                                    seed=spec.run.seed + i)
                for i, row in enumerate(tokens)]
        t0 = time.perf_counter()
        with sess.profile():
            results = engine.run(reqs)
        dt = time.perf_counter() - t0
        sess.close()
        if sess.enabled and not spec.telemetry.prometheus:
            print(engine.metrics_text())
        out = [r.tokens for r in sorted(results, key=lambda r: r.rid)]
        print(f"arch={cfg.name} engine=paged lanes="
              f"{spec.serving.max_lanes} batch={ns.batch} "
              f"prompt={ns.prompt_len} gen={ns.gen}: {dt:.2f}s "
              f"({ns.batch * ns.gen / dt:.1f} tok/s incl. compile; "
              f"{engine.n_prefill_calls} prefill calls, "
              f"{engine.n_decode_steps} decode steps, "
              f"{engine.n_compiles()} compiles)")
        sched = engine.sched
        if spec.serving.prefix_cache or sched.preemptions:
            print(f"sharing: page hit rate {sched.page_hit_rate:.2f} "
                  f"({sched.prefix_hits}/{sched.prefix_lookups} pages), "
                  f"{sched.cow_copies} COW copies, "
                  f"{sched.trie_evictions} trie evictions, "
                  f"{sched.preemptions} preemptions")
        print("sample:", np.asarray(out[0])[:12])
        return {"spec": api.to_dict(spec), "seconds": dt, "tokens": out,
                "engine": {"mode": "paged",
                           "prefill_calls": engine.n_prefill_calls,
                           "decode_steps": engine.n_decode_steps,
                           "compiles": engine.n_compiles(),
                           "page_hit_rate": sched.page_hit_rate,
                           "cow_copies": sched.cow_copies,
                           "preemptions": sched.preemptions}}

    toks = jnp.asarray(tokens, jnp.int32)
    t0 = time.perf_counter()
    out = serve_mod.generate(cfg, params, toks, ns.gen,
                             max_seq=ns.prompt_len + ns.gen + 1)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} engine=lockstep batch={ns.batch} "
          f"prompt={ns.prompt_len} gen={ns.gen}: {dt:.2f}s "
          f"({ns.batch * ns.gen / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0])[:12])
    return {"spec": api.to_dict(spec), "seconds": dt,
            "tokens": np.asarray(out).tolist(),
            "engine": {"mode": "lockstep"}}


def _cmd_report(ns):
    from repro.launch import report as report_mod

    rep = report_mod.report_run(ns.run, runs_root=ns.runs_root, out=ns.out)
    print(rep["markdown"])
    return rep


def _cmd_replay(ns):
    from repro.launch import replay as replay_mod

    rep = replay_mod.replay_run(ns.run, step=ns.step,
                                runs_root=ns.runs_root)
    print(json.dumps(rep, indent=1))
    return rep


def _cmd_swarm(ns):
    from repro.obs import runlog
    from repro.swarm import driver

    if ns.attach:
        result = driver.run_attached(ns.attach)
        print(json.dumps(result, indent=1))
        return result
    # like train: every coordinator writes a run directory by default —
    # the (seed, g) log is both the recovery substrate and the replay
    # evidence, so a swarm without one defeats the point
    implied = {}
    flags = getattr(ns, _SPEC_DEST, None) or {}
    user_set = {kv.partition("=")[0] for kv in ns.set}
    if (not ns.no_runlog and "telemetry.runs_dir" not in flags
            and "telemetry.runs_dir" not in user_set):
        implied["telemetry.runs_dir"] = runlog.DEFAULT_RUNS_DIR
    if ("swarm.workers" not in flags and "swarm.workers" not in user_set
            and "swarm.n_shards" not in flags
            and "swarm.n_shards" not in user_set):
        implied["swarm.workers"] = 2
    spec = build_spec(ns, implied)
    summary = driver.run_swarm(spec, respawn=not ns.no_respawn)
    print(json.dumps(summary, indent=1))
    if ns.out:
        _write_json(ns.out, {"spec": api.to_dict(spec), "summary": summary})
    return summary


def _cmd_specs(ns):
    os.makedirs(ns.out, exist_ok=True)
    written = {}
    for name in presets_mod.names():
        path = os.path.join(ns.out, f"{name}.json")
        with open(path, "w") as f:
            f.write(api.to_json(presets_mod.get(name)))
        written[name] = path
    if ns.markdown:
        from repro.launch import docgen
        for path in docgen.write_docs(ns.markdown):
            written[os.path.basename(path)] = path
    print(json.dumps(written, indent=1))
    return written


# ------------------------------------------------------------------ parser
def _add_extras(cmd: str, ap: argparse.ArgumentParser):
    """Command-specific flags only — nothing here may shadow a spec field."""
    if cmd == "train":
        ap.add_argument("--optimizer", default="lezo",
                        choices=["lezo", "mezo", "fo"],
                        help="lezo (spec sparsity) | mezo (sparsity=0) | fo")
        ap.add_argument("--out", default=None, help="write history JSON here")
        ap.add_argument("--no-runlog", action="store_true",
                        help="do not write a run directory (default: "
                             "artifacts/runs/<run_id>/ per train)")
    elif cmd == "evaluate":
        ap.add_argument("--mode", default="zeroshot",
                        choices=["zeroshot", "train"])
        ap.add_argument("--n-examples", type=int, default=256)
        ap.add_argument("--out", default=None, help="also write JSON here")
    elif cmd == "dryrun":
        ap.add_argument("--shape", default=None)
        ap.add_argument("--lowering", default="optimized",
                        choices=["optimized", "faithful", "mezo"])
        ap.add_argument("--multi-pod", action="store_true")
        ap.add_argument("--both-meshes", action="store_true")
        ap.add_argument("--all", action="store_true",
                        help="every (arch x shape) cell")
        ap.add_argument("--out", default="artifacts/dryrun")
        ap.add_argument("--save-hlo", default=None,
                        help="dir for gzipped HLO")
    elif cmd == "hillclimb":
        ap.add_argument("--shape", required=True)
        ap.add_argument("--lowering", default="optimized",
                        choices=["optimized", "faithful", "mezo"])
        ap.add_argument("--multi-pod", action="store_true")
        ap.add_argument("--cfg", action="append", default=[],
                        metavar="KEY=VAL",
                        help="model-config override (int/float/str)")
        ap.add_argument("--top", type=int, default=10)
        ap.add_argument("--tag", default=None,
                        help="save json under this tag")
    elif cmd == "serve":
        ap.add_argument("--batch", type=int, default=4,
                        help="number of synthetic requests")
        ap.add_argument("--prompt-len", type=int, default=32)
        ap.add_argument("--gen", type=int, default=16,
                        help="tokens generated per request")
        ap.add_argument("--engine", default="auto",
                        choices=["auto", "paged", "lockstep"],
                        help="auto: continuous-batching engine when the "
                             "arch supports it (attn mixers), else the "
                             "legacy lockstep loop")
    elif cmd == "swarm":
        ap.add_argument("--attach", default=None, metavar="HOST:PORT",
                        help="join an existing swarm as a worker instead "
                             "of starting a coordinator (the spec ships "
                             "over the wire)")
        ap.add_argument("--no-respawn", action="store_true",
                        help="do not respawn workers that die mid-run")
        ap.add_argument("--no-runlog", action="store_true",
                        help="do not write a run directory")
        ap.add_argument("--out", default=None,
                        help="write the summary JSON here")
    elif cmd == "specs":
        ap.add_argument("--out", default="artifacts/specs",
                        help="dump every preset spec JSON here")
        ap.add_argument("--markdown", default=None, metavar="DIR",
                        help="also regenerate the generated docs "
                             "(docs/cli.md + the serving spec table) "
                             "under DIR — `make docs`")
    elif cmd in ("report", "replay"):
        ap.add_argument("run", nargs="?", default=None,
                        help="run id or run-dir path (default: the "
                             "latest run under --runs-root)")
        ap.add_argument("--runs-root", default="artifacts/runs",
                        help="run registry root (launch train default)")
        if cmd == "replay":
            ap.add_argument("--step", type=int, default=None,
                            help="step to verify through (default: last "
                                 "recorded)")
        else:
            ap.add_argument("--out", default=None,
                            help="also write the markdown here (default: "
                                 "<run_dir>/report.md only)")


COMMANDS = {
    "train": _cmd_train, "evaluate": _cmd_evaluate, "dryrun": _cmd_dryrun,
    "hillclimb": _cmd_hillclimb, "serve": _cmd_serve, "swarm": _cmd_swarm,
    "specs": _cmd_specs, "report": _cmd_report, "replay": _cmd_replay,
}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="repro.launch", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    for cmd in COMMANDS:
        p = sub.add_parser(cmd)
        if cmd not in _NO_SPEC_CMDS:
            add_spec_flags(p)
        _add_extras(cmd, p)
    return ap


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] in ("dryrun", "hillclimb"):
        # MUST precede any jax import: jax locks the host device count on
        # first init, and these commands lower onto the 512-way mesh
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count"
                                     "=512")
    ns = build_parser().parse_args(argv)
    return COMMANDS[ns.cmd](ns)


def console(argv=None) -> int:
    result = main(argv)
    if isinstance(result, dict) and result.get("failures"):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(console())
