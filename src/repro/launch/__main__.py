"""``python -m repro.launch <cmd>`` — the unified spec-driven CLI.

Part of the unified launch surface (DESIGN.md §11).
"""
from repro.launch import cli

if __name__ == "__main__":
    raise SystemExit(cli.console())
