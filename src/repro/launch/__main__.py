"""``python -m repro.launch <cmd>`` — the unified spec-driven CLI."""
from repro.launch import cli

if __name__ == "__main__":
    raise SystemExit(cli.console())
