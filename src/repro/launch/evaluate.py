"""Task evaluation CLI: ``python -m repro.launch.evaluate --task sst2
--arch opt-13b --variant smoke``.

Modes:
  * ``--mode zeroshot``  score freshly-initialized params (the baseline
    every fine-tuning number in the paper is reported against);
  * ``--mode train``     run a ZO/FO fine-tune first, then report both
    zero-shot and post-train metrics (best-checkpoint params, selected
    on the task metric — the SuperGLUE protocol);
  * ``--ckpt-dir <d>``   restore the latest checkpoint from a previous
    ``launch.train`` run and score it (post-train without re-training).

``--task all`` sweeps every registered task into one report.  The report
is JSON on stdout (and ``--out <path>``): one record per task with the
metric protocol name, zero-shot / post-train values, and val loss.
"""
from __future__ import annotations

import argparse
import json
from typing import Optional

from repro import configs, tasks
from repro.core import zo
from repro.train.trainer import Trainer, TrainConfig


def evaluate_task(task_name: str, arch: str = "opt-13b",
                  variant: str = "smoke", mode: str = "zeroshot",
                  steps: int = 300, batch_size: int = 32, lr: float = 1e-3,
                  eps: float = 1e-3, sparsity: float = 0.5,
                  estimator: str = "two_point", q: int = 1,
                  seq_len: int = 48, n_examples: int = 256, seed: int = 0,
                  ckpt_dir: Optional[str] = None) -> dict:
    """One task's metric report dict (the CLI emits a list of these)."""
    if ckpt_dir is not None and mode == "train":
        # Trainer auto-resumes from ckpt_dir, which would silently turn
        # "fine-tune then score" into "restore then maybe-train"
        raise ValueError("--ckpt-dir scores an existing checkpoint; "
                         "combine it with --mode zeroshot, not train")
    mcfg = configs.get(arch, variant)
    task = tasks.build(task_name, vocab=mcfg.vocab, seq_len=seq_len, seed=seed)
    n_drop = int(sparsity * mcfg.num_layers)
    tcfg = TrainConfig(steps=steps, batch_size=batch_size,
                       eval_every=max(1, steps // 2), log_every=0,
                       seed=seed, estimator=estimator, est_q=q,
                       ckpt_dir=ckpt_dir)
    trainer = Trainer(mcfg, task, tcfg,
                      zo_cfg=zo.ZOConfig(eps=eps, lr=lr, n_drop=n_drop,
                                         backend="scan"))
    val = trainer.make_dataset(n_examples, seed_shift=1)

    report = {"task": task.name, "kind": task.kind, "metric": task.metric,
              "arch": arch, "variant": variant, "n_examples": n_examples,
              "mode": mode}
    zs_loss, zs_metric = trainer.evaluate(trainer.trainable, val,
                                          max_examples=n_examples)
    report["zeroshot"] = zs_metric
    report["zeroshot_val_loss"] = zs_loss

    if ckpt_dir is not None and mode != "train":
        # score a previously trained checkpoint (restore into the template)
        params, step, _, _ = trainer.ckpt.restore(trainer.trainable)
        vl, metric = trainer.evaluate(params, val, max_examples=n_examples)
        report.update(trained=metric, trained_val_loss=vl, ckpt_step=step)
    elif mode == "train":
        hist = trainer.train(val_data=val)
        params = hist.get("best_params", hist["final_params"])
        vl, metric = trainer.evaluate(params, val, max_examples=n_examples)
        report.update(trained=metric, trained_val_loss=vl,
                      best_step=hist.get("best_step", -1),
                      val_metric_curve=hist["val_acc"])
    return report


def main(argv=None) -> list:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--task", default="all",
                    help="registered task name, or 'all' (see repro.tasks)")
    ap.add_argument("--arch", default="opt-13b")
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--mode", default="zeroshot", choices=["zeroshot", "train"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--estimator", default="two_point")
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=48)
    ap.add_argument("--n-examples", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="score this checkpoint dir instead of fresh params")
    ap.add_argument("--out", default=None, help="also write the JSON here")
    args = ap.parse_args(argv)

    names = tasks.names() if args.task == "all" else [args.task]
    reports = [evaluate_task(
        n, arch=args.arch, variant=args.variant, mode=args.mode,
        steps=args.steps, batch_size=args.batch_size, lr=args.lr,
        eps=args.eps, sparsity=args.sparsity, estimator=args.estimator,
        q=args.q, seq_len=args.seq_len, n_examples=args.n_examples,
        seed=args.seed, ckpt_dir=args.ckpt_dir) for n in names]
    print(json.dumps(reports, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports, f, indent=1)
    return reports


if __name__ == "__main__":
    main()
