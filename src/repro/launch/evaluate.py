"""Task evaluation CLI — legacy entrypoint, now a shim over the unified
spec CLI (``python -m repro.launch evaluate``, see launch/cli.py).

Modes:
  * ``--mode zeroshot``  score freshly-initialized params (the baseline
    every fine-tuning number in the paper is reported against);
  * ``--mode train``     run a ZO/FO fine-tune first, then report both
    zero-shot and post-train metrics (best-checkpoint params, selected
    on the task metric — the SuperGLUE protocol);
  * ``--ckpt-dir <d>``   restore the latest checkpoint from a previous
    train run and score it (post-train without re-training).

``--task all`` sweeps every registered task into one report.  The
report is JSON on stdout (and ``--out <path>``): one record per task
with the metric protocol name, zero-shot / post-train values, val loss,
and the full experiment spec that produced it.

Task evaluation surface (DESIGN.md §9, §11).
"""
from __future__ import annotations

import sys
from typing import Optional

from repro import api
from repro.launch import cli


def evaluate_task(task_name: str, arch: str = "opt-13b",
                  variant: str = "smoke", mode: str = "zeroshot",
                  steps: Optional[int] = None,
                  batch_size: Optional[int] = None,
                  lr: Optional[float] = None, eps: Optional[float] = None,
                  sparsity: Optional[float] = None,
                  estimator: Optional[str] = None, q: Optional[int] = None,
                  seq_len: Optional[int] = None, n_examples: int = 256,
                  seed: int = 0, ckpt_dir: Optional[str] = None) -> dict:
    """One task's metric report dict (the CLI emits a list of these).

    Library-compatible wrapper over ``api.evaluate``: ``None`` arguments
    fall through to the shared ``default`` preset, so this function can
    no longer disagree with the train CLI about defaults.
    """
    overrides = {
        "task.name": task_name, "model.arch": arch,
        "model.variant": variant,
        "run.seed": seed, "run.ckpt_dir": ckpt_dir,
    }
    for path, val in (("model.seq_len", seq_len),
                      ("run.steps", steps), ("run.batch_size", batch_size),
                      ("optimizer.lr", lr), ("optimizer.eps", eps),
                      ("optimizer.sparsity", sparsity),
                      ("estimator.name", estimator), ("estimator.q", q)):
        if val is not None:
            overrides[path] = val
    spec = api.with_overrides(api.presets.get("default"), overrides)
    return api.evaluate(spec, mode=mode, n_examples=n_examples)


def main(argv=None) -> list:
    argv = list(sys.argv[1:] if argv is None else argv)
    return cli.main(["evaluate"] + argv)


if __name__ == "__main__":
    main()
