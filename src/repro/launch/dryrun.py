"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first lines — before any other import — since jax locks
the device count on first init:

Production-mesh lowering (DESIGN.md §3).
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import re         # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                      # noqa: E402
from repro.configs.shapes import SHAPES        # noqa: E402
from repro.launch import analysis, specs       # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import frontends, lm         # noqa: E402

# ------------------------------------------------------------- roofline
# TPU v5e per-chip constants (assignment-specified).
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2, "c64": 8, "tuple": 0,
                "token": 0, "bf8": 1}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(\w+)\[([\d,]*)\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str):
    """Sum result sizes of collective ops, per op kind (per-device view)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype is None:
            continue
        out[kind] = out.get(kind, 0) + _shape_bytes(dtype, dims)
    for m in _TUPLE_RE.finditer(hlo_text):
        inner, kind = m.group(1), m.group(2)
        for sm in _SHAPE_RE.finditer(inner):
            out[kind] = out.get(kind, 0) + _shape_bytes(sm.group(1), sm.group(2))
    return out


def roofline_terms(cost, mem, coll, n_chips):
    """Three roofline terms in seconds (per-step, per-chip)."""
    flops = cost.get("flops", 0.0)
    bytes_hbm = cost.get("bytes accessed", 0.0)
    bytes_coll = float(sum(coll.values()))
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": bytes_coll / ICI_BW,
        "hlo_flops": flops,
        "hlo_bytes": bytes_hbm,
        "collective_bytes": bytes_coll,
        "collective_breakdown": coll,
        "n_chips": n_chips,
    }


def model_flops(cfg, shape, estimator: str = "two_point", q: int = 1) -> float:
    """Analytic training FLOPs: forwards_per_step * 2*N_active*D tokens.

    The forward count comes from the estimator cost model
    (``repro.estimators.costs``): 2 for the paper's two-point SPSA, q+1
    for FZOO-style one_sided, 2q for averaged — ZO has no backward pass
    under any of them.  For decode, one token per sequence."""
    from repro.estimators import costs as est_costs

    pshapes = specs.param_specs(cfg)
    n_active = lm.count_active_params(cfg, pshapes)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        fwd = est_costs.step_counts(estimator, q=q)["forwards"]
        return fwd * 2.0 * n_active * tokens    # SPSA forwards, no bwd
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/seq


# ------------------------------------------------------------- lowering
def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "optimized", overrides: dict = None):
    cfg = configs.get(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ins = specs.input_specs(cfg, shape)
    with mesh:
        if shape.kind == "train":
            shard_fn, pshapes = specs.build_train_step(cfg, mesh, variant)
            fn = shard_fn(ins["batch"])
            lowered = fn.lower(pshapes, ins["batch"],
                               jax.ShapeDtypeStruct((), jnp.int32),
                               jax.ShapeDtypeStruct((), jnp.uint32))
        elif shape.kind == "prefill":
            shard_fn, pshapes = specs.build_prefill_step(cfg, mesh,
                                                         shape.seq_len)
            fn = shard_fn(shape.global_batch)
            data = ins.get("tokens", ins.get("embeds"))
            lowered = fn.lower(pshapes, data)
        else:  # decode
            fn, pshapes, cshapes = specs.build_serve_step(
                cfg, mesh, shape.seq_len, shape.global_batch)
            data = ins.get("token", ins.get("embeds"))
            lowered = fn.lower(pshapes, ins["caches"], data, ins["pos"])
        compiled = lowered.compile()
    return cfg, shape, mesh, lowered, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "optimized", verbose: bool = True,
             hlo_dir: str = None, overrides: dict = None,
             estimator: str = "two_point", q: int = 1,
             forward_backend: str = "materialized"):
    t0 = time.time()
    cfg, shape, mesh, lowered, compiled = lower_cell(
        arch, shape_name, multi_pod, variant, overrides)
    n_chips = mesh.devices.size
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}_{variant}"
        with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
            f.write(compiled.as_text())
    ca_xla = compiled.cost_analysis()
    ca_xla = ca_xla[0] if isinstance(ca_xla, (list, tuple)) else (ca_xla or {})
    ma = compiled.memory_analysis()
    # scan-aware analysis (XLA's cost_analysis counts while bodies once)
    acc = analysis.analyze(compiled.as_text())
    ca = {"flops": acc["flops"], "bytes accessed": acc["bytes"]}
    coll = acc["collectives"]
    terms = roofline_terms(ca, ma, coll, n_chips)
    terms["xla_raw_flops"] = ca_xla.get("flops")
    terms["xla_raw_bytes"] = ca_xla.get("bytes accessed")
    mf = model_flops(cfg, shape, estimator=estimator, q=q)
    # the lowered graph is always a two_point step, so utilization is
    # computed estimator-invariantly (both sides scale with forwards)
    mf_base = mf if estimator == "two_point" else model_flops(cfg, shape)
    mem = {}
    if ma is not None:
        mem = {"argument_bytes": ma.argument_size_in_bytes,
               "output_bytes": ma.output_size_in_bytes,
               "temp_bytes": ma.temp_size_in_bytes,
               "alias_bytes": ma.alias_size_in_bytes}
    from repro.estimators import costs as est_costs
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "estimator": estimator, "q": q,
        "forward_backend": forward_backend,
        # analytic sweep/forward counts for the configured step (the
        # lowered graph itself is always the materialized two_point
        # baseline; see analysis.estimator_step_cost for projection)
        "step_counts": est_costs.step_counts(
            estimator, q=q, forward_backend=forward_backend),
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "compile_s": round(time.time() - t0, 1),
        "memory": mem,
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flop_ratio": (mf_base / n_chips) / terms["hlo_flops"]
        if terms["hlo_flops"] else None,
    }
    if verbose:
        dom = max(("compute_s", "memory_s", "collective_s"),
                  key=lambda k: terms[k])
        print(f"[{arch} x {shape_name} x {rec['mesh']} x {variant}] "
              f"compile={rec['compile_s']}s "
              f"compute={terms['compute_s']*1e3:.2f}ms "
              f"memory={terms['memory_s']*1e3:.2f}ms "
              f"coll={terms['collective_s']*1e3:.2f}ms "
              f"dom={dom} temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB "
              f"useful={rec['useful_flop_ratio'] and round(rec['useful_flop_ratio'], 3)}")
    return rec


def _translate_legacy(argv):
    """Legacy flag spellings -> unified spec CLI: ``--variant`` here
    always meant the *lowering* variant (optimized|faithful|mezo)."""
    out = []
    for a in argv:
        if a == "--variant":
            out.append("--lowering")
        elif a.startswith("--variant="):
            out.append("--lowering=" + a.split("=", 1)[1])
        else:
            out.append(a)
    return out


def main(argv=None):
    """Shim over ``python -m repro.launch dryrun`` (launch/cli.py)."""
    import sys

    from repro.launch import cli
    argv = list(sys.argv[1:] if argv is None else argv)
    result = cli.main(["dryrun"] + _translate_legacy(argv))
    return 1 if result["failures"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
