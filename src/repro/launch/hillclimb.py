"""Hillclimb driver — legacy entrypoint, now a shim over the unified
spec CLI (``python -m repro.launch hillclimb``, see launch/cli.py).

    PYTHONPATH=src python -m repro.launch hillclimb \
        --arch deepseek-coder-33b --shape train_4k \
        --cfg attn_k_chunk=2048 --lowering optimized

Legacy spellings still work here: ``--set key=val`` (model-config
override) forwards as ``--cfg``, ``--variant`` as ``--lowering`` — in
the unified CLI ``--set`` is reserved for *spec* overrides.

Roofline hillclimbing (DESIGN.md §5).
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import sys  # noqa: E402

from repro.launch import cli  # noqa: E402


def _translate_legacy(argv):
    out = []
    for a in argv:
        if a == "--set":
            out.append("--cfg")
        elif a.startswith("--set="):
            out.append("--cfg=" + a.split("=", 1)[1])
        elif a == "--variant":
            out.append("--lowering")
        elif a.startswith("--variant="):
            out.append("--lowering=" + a.split("=", 1)[1])
        else:
            out.append(a)
    return out


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    return cli.main(["hillclimb"] + _translate_legacy(argv))


if __name__ == "__main__":
    main()
