"""Hillclimb driver: measure one cell with optional config overrides and
dump the dominant-term breakdown (top collectives + analyzer detail).

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch deepseek-coder-33b --shape train_4k \
        --set attn_k_chunk=2048 --variant optimized
"""
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import json      # noqa: E402

from repro.launch import analysis, dryrun  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="optimized")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (int/float/str)")
    ap.add_argument("--estimator", default="two_point",
                    choices=["two_point", "one_sided", "averaged",
                             "importance"],
                    help="project the measured cell onto this estimator")
    ap.add_argument("--q", type=int, default=1,
                    help="directions per step for one_sided / averaged")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--tag", default=None, help="save json under this tag")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    cfg, shape, mesh, lowered, compiled = dryrun.lower_cell(
        args.arch, args.shape, args.multi_pod, args.variant, overrides)
    txt = compiled.as_text()
    cost = analysis.HloCost(txt).total()
    ma = compiled.memory_analysis()
    terms = dryrun.roofline_terms(
        {"flops": cost.flops, "bytes accessed": cost.bytes}, ma, cost.coll,
        mesh.devices.size)
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    print(f"\n=== {args.arch} x {args.shape} x "
          f"{'mp' if args.multi_pod else 'sp'} x {args.variant} "
          f"{overrides or ''} ===")
    print(f"compute={terms['compute_s']*1e3:10.2f} ms")
    print(f"memory ={terms['memory_s']*1e3:10.2f} ms")
    print(f"coll   ={terms['collective_s']*1e3:10.2f} ms   dominant: {dom}")
    if ma:
        print(f"temp   ={ma.temp_size_in_bytes/2**30:10.2f} GiB  "
              f"args={ma.argument_size_in_bytes/2**30:.2f} GiB")
    proj = None
    if args.estimator != "two_point" or args.q != 1:
        proj = analysis.estimator_step_cost(
            terms, args.estimator, q=args.q,
            param_bytes=ma.argument_size_in_bytes if ma else None)
        print(f"\nprojected for estimator={args.estimator} q={args.q} "
              f"({proj['forwards']} forwards, {proj['axpy_sweeps']} sweeps):")
        print(f"compute={proj['compute_s']*1e3:10.2f} ms  "
              f"memory={proj['memory_s']*1e3:10.2f} ms  "
              f"coll={proj['collective_s']*1e3:10.2f} ms")
    print(f"\ntop collectives (GiB wire/device/step):")
    for k, v in sorted(cost.detail.items(), key=lambda x: -x[1])[:args.top]:
        print(f"  {v/2**30:9.3f}  {k[:110]}")
    if args.tag:
        os.makedirs("artifacts/hillclimb", exist_ok=True)
        with open(f"artifacts/hillclimb/{args.tag}.json", "w") as f:
            json.dump({"overrides": overrides, "terms": terms,
                       "estimator_projection": proj,
                       "detail": dict(sorted(cost.detail.items(),
                                             key=lambda x: -x[1])[:30])},
                      f, indent=1)


if __name__ == "__main__":
    main()
