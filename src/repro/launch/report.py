"""``launch report`` — markdown convergence/health report for a run dir.

Renders the optimizer-health stream a ``launch train`` run recorded
(repro.obs.health via repro.obs.runlog) into one human-readable
markdown page (DESIGN.md §13):

  * run header + the spec fields that shape ZO convergence;
  * loss trajectory and projected-gradient g mean/variance trend —
    the MeZO/LeZO health signal (a diverging g-variance means ε or lr
    is mis-set long before the loss shows it);
  * LeZO layer-coverage histogram + staleness (steps since each layer
    was last selected) from the run summary;
  * update magnitudes: the exact RNG-stream norm ‖lr·g·z‖ when the run
    recorded it (telemetry.health_norms) and the E‖z‖² = N estimate;
  * stage timings aggregated from the run's ``trace.jsonl`` when the
    PR 6 tracer was enabled (telemetry.enabled), joined by span name.

Pure text generation — no jax import; a report renders anywhere the
run directory is readable.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional

from repro.obs import runlog
from repro.obs.sinks import spans_from_jsonl

REPORT_FILE = "report.md"

_BAR = "#"
_BAR_WIDTH = 40


def _bar(value: float, peak: float, width: int = _BAR_WIDTH) -> str:
    if peak <= 0:
        return ""
    n = int(round(width * value / peak))
    return _BAR * max(n, 1 if value > 0 else 0)


def _fmt(v: Any, digits: int = 6) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{digits}g}"
    return str(v)


def _spec_highlights(spec: Optional[Dict]) -> List[str]:
    if not spec:
        return ["(run dir carries no spec.json)"]
    get = lambda sec, key: spec.get(sec, {}).get(key)  # noqa: E731
    rows = [
        ("model", f"{get('model', 'arch')} ({get('model', 'variant')}), "
                  f"seq_len {get('model', 'seq_len')}"),
        ("estimator", f"{get('estimator', 'name')} q={get('estimator', 'q')} "
                      f"on {get('runtime', 'forward_backend')} forwards, "
                      f"{get('runtime', 'backend')} axpy"),
        ("optimizer", f"mode {get('optimizer', 'mode')}, "
                      f"lr {_fmt(get('optimizer', 'lr'))}, "
                      f"eps {_fmt(get('optimizer', 'eps'))}, "
                      f"sparsity {_fmt(get('optimizer', 'sparsity'))}"),
        ("run", f"steps {get('run', 'steps')}, "
                f"batch {get('run', 'batch_size')}, "
                f"seed {get('run', 'seed')}"),
    ]
    return [f"- **{k}**: {v}" for k, v in rows]


def _series(rows: List[Dict], key: str) -> List[tuple]:
    return [(r["step"], r[key]) for r in rows if r.get(key) is not None]


def _trend_table(rows: List[Dict], keys: List[str],
                 max_rows: int = 12) -> List[str]:
    """A step-indexed markdown table, thinned to ~max_rows rows."""
    present = [k for k in keys if any(k in r for r in rows)]
    if not present:
        return ["(no health scalars recorded)"]
    stride = max(1, (len(rows) + max_rows - 1) // max_rows)
    picked = rows[::stride]
    if rows and picked[-1] is not rows[-1]:
        picked.append(rows[-1])
    out = ["| step | " + " | ".join(present) + " |",
           "|---" * (len(present) + 1) + "|"]
    for r in picked:
        cells = [_fmt(r.get(k)) for k in present]
        out.append(f"| {r['step']} | " + " | ".join(cells) + " |")
    return out


def _coverage_section(summary: Optional[Dict]) -> List[str]:
    if not summary or "layer_counts" not in summary:
        return ["(no per-layer selection data — flat parameter tree or "
                "no summary.json)"]
    counts = summary["layer_counts"]
    stale = summary.get("layer_staleness", [None] * len(counts))
    peak = max(counts) if counts else 0
    out = ["| layer | selected | staleness | coverage |",
           "|---|---|---|---|"]
    for i, (c, s) in enumerate(zip(counts, stale)):
        st = "never" if s is None or s < 0 else str(s)
        out.append(f"| {i} | {c} | {st} | `{_bar(c, peak)}` |")
    never = summary.get("layers_never_selected")
    if never:
        out.append("")
        out.append(f"**{never} layer(s) never selected** — at this run "
                   "length the LeZO drop schedule left them untouched.")
    return out


def _timing_section(trace_path: str) -> List[str]:
    if not os.path.exists(trace_path):
        return ["(no trace.jsonl — run with `--telemetry` / "
                "`telemetry.enabled=true` to record stage timings)"]
    spans = spans_from_jsonl(trace_path)
    if not spans:
        return ["(trace.jsonl holds no spans)"]
    agg: Dict[str, List[float]] = {}
    for sp in spans:
        agg.setdefault(sp.name, []).append(sp.dt)
    total = sum(sum(v) for v in agg.values())
    out = ["| stage | calls | total s | mean ms | share |",
           "|---|---|---|---|---|"]
    for name, durs in sorted(agg.items(), key=lambda kv: -sum(kv[1])):
        tot = sum(durs)
        share = 100.0 * tot / total if total else 0.0
        out.append(f"| {name} | {len(durs)} | {tot:.4f} | "
                   f"{1e3 * tot / len(durs):.3f} | {share:.1f}% |")
    return out


def render_report(rd: runlog.RunDir) -> str:
    rows = rd.steps
    summary = rd.summary or {}
    lines = [f"# Run report — `{rd.run_id}`", "",
             f"Run directory: `{rd.dir}`", ""]
    lines += ["## Spec", ""] + _spec_highlights(rd.spec) + [""]

    lines += ["## Convergence", ""]
    if rows:
        losses = _series(rows, "loss")
        gvars = _series(rows, "g_var")
        lines += [f"- steps recorded: **{len(rows)}** "
                  f"({rd.first_step}..{rd.last_step})"]
        if losses:
            lines += [f"- loss: {_fmt(losses[0][1])} -> "
                      f"{_fmt(losses[-1][1])}"]
        if "g_mean" in (summary or {}):
            lines += [f"- projected-gradient g: mean {_fmt(summary['g_mean'])}"
                      f", variance {_fmt(summary.get('g_var'))} over "
                      f"{summary.get('g_count')} probes"]
        if gvars:
            first_nz = next((v for _, v in gvars if v), None)
            trend = ("rising" if first_nz and gvars[-1][1] > 2 * first_nz
                     else "stable/decaying")
            lines += [f"- g-variance trend: **{trend}** "
                      f"(last {_fmt(gvars[-1][1])})"]
        lines += [""]
        lines += _trend_table(rows, ["loss", "projected_grad", "g_mean",
                                     "g_var", "update_norm",
                                     "update_norm_est", "active_layers"])
    else:
        lines += ["(steps.jsonl is empty)"]
    lines += [""]

    lines += ["## Applied hyperparameters", ""]
    if rows:
        last = rows[-1]
        lines += [f"- eps actually applied: {_fmt(last.get('eps'))}",
                  f"- lr actually applied: {_fmt(last.get('lr'))}"]
        if last.get("update_norm") is not None:
            lines += [f"- last update magnitude (exact RNG-stream norm): "
                      f"{_fmt(last['update_norm'])}"]
        if last.get("update_norm_est") is not None:
            lines += [f"- last update magnitude (E||z||^2 = N estimate): "
                      f"{_fmt(last['update_norm_est'])}"]
    lines += [""]

    lines += ["## LeZO layer coverage", ""]
    lines += _coverage_section(summary)
    lines += [""]

    lines += ["## Stage timings", ""]
    lines += _timing_section(os.path.join(rd.dir, runlog.TRACE_FILE))
    lines += [""]
    return "\n".join(lines)


def report_run(run: Optional[str] = None,
               runs_root: str = runlog.DEFAULT_RUNS_DIR,
               out: Optional[str] = None) -> Dict[str, Any]:
    """Render the report for ``run`` (default: the latest under
    ``runs_root``), write it to ``<run_dir>/report.md`` (and ``out``
    when given), and return {run_id, run_dir, path, markdown}."""
    rd = runlog.load_run(run, runs_root)
    text = render_report(rd)
    path = os.path.join(rd.dir, REPORT_FILE)
    with open(path, "w") as f:
        f.write(text)
    if out:
        d = os.path.dirname(out)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(out, "w") as f:
            f.write(text)
        path = out
    return {"run_id": rd.run_id, "run_dir": rd.dir, "path": path,
            "markdown": text}
