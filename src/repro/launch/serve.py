"""Legacy serving shim + the lockstep generate loop (DESIGN.md §12).

``python -m repro.launch serve`` is the real surface: it drives the
continuous-batching paged engine (``repro.serving``) when the arch
supports it and falls back to the lockstep ``generate`` below (one
prompt batch in, all lanes decode in step) otherwise — which also
exercises the prefill/serve_step code paths the dry-run lowers for the
decode_32k / long_500k cells, at CPU-runnable sizes.  ``benchmarks/
serving.py`` measures the two against each other.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm


def generate(cfg, params, tokens, gen_steps: int, max_seq: int):
    B, S = tokens.shape
    logits, caches = lm.prefill(cfg, params, tokens, max_seq=max_seq)
    out = [jnp.argmax(logits, -1)[:, None]]

    @jax.jit
    def step(params, caches, tok, pos):
        lg, caches = lm.serve_step(cfg, params, caches, tok, pos)
        return jnp.argmax(lg, -1)[:, None].astype(jnp.int32), caches

    tok = out[0].astype(jnp.int32)
    for i in range(gen_steps - 1):
        tok, caches = step(params, caches, tok, jnp.int32(S + i))
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    """Shim over ``python -m repro.launch serve`` (launch/cli.py):
    spec flags (--arch/--variant/--seed and any --set) plus the serve
    extras --batch/--prompt-len/--gen.  The default arch moves with the
    legacy surface via the implied override below."""
    import sys

    from repro.launch import cli
    argv = list(sys.argv[1:] if argv is None else argv)
    if not any(a == "--arch" or a.startswith("--arch=")
               or a == "--model.arch" or a.startswith("--model.arch=")
               for a in argv):
        argv = ["--arch", "xlstm-350m"] + argv
    return cli.main(["serve"] + argv)


if __name__ == "__main__":
    main()
