"""Serving launcher: prefill a batch of prompts, then batched greedy decode.

``python -m repro.launch.serve --arch xlstm-350m --variant smoke
--prompt-len 32 --gen 16``

Exercises the same prefill/serve_step code paths the dry-run lowers for
the decode_32k / long_500k cells, at CPU-runnable sizes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import frontends, lm


def generate(cfg, params, tokens, gen_steps: int, max_seq: int):
    B, S = tokens.shape
    logits, caches = lm.prefill(cfg, params, tokens, max_seq=max_seq)
    out = [jnp.argmax(logits, -1)[:, None]]

    @jax.jit
    def step(params, caches, tok, pos):
        lg, caches = lm.serve_step(cfg, params, caches, tok, pos)
        return jnp.argmax(lg, -1)[:, None].astype(jnp.int32), caches

    tok = out[0].astype(jnp.int32)
    for i in range(gen_steps - 1):
        tok, caches = step(params, caches, tok, jnp.int32(S + i))
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, args.variant)
    if frontends.uses_embeds(cfg):
        raise SystemExit(f"{args.arch} takes stub embeddings; use the "
                         "decode dry-run cell for it instead")
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab,
                                      (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.perf_counter()
    out = generate(cfg, params, tokens, args.gen,
                   max_seq=args.prompt_len + args.gen + 1)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}: {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0])[:12])


if __name__ == "__main__":
    main()
