"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs real optimization steps (CPU-sized models by default) with the full
production stack: LeZO/MeZO/FO, PEFT, checkpointing, resume, straggler
quorum.  ``--dry`` switches to lower+compile only (see dryrun.py for the
full grid).
"""
from __future__ import annotations

import argparse
import json

from repro import configs
from repro import tasks as tasks_mod
from repro.core import zo
from repro.estimators import costs as est_costs
from repro.data import synthetic
from repro.train.trainer import Trainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-13b")
    ap.add_argument("--variant", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--task", default=None,
                    help="registry task name (repro.tasks); default: the "
                         "legacy synthetic classification stream")
    ap.add_argument("--optimizer", default="lezo",
                    choices=["lezo", "mezo", "fo"])
    ap.add_argument("--estimator", default="two_point",
                    choices=["two_point", "one_sided", "averaged",
                             "importance"],
                    help="ZO gradient estimator (repro.estimators)")
    ap.add_argument("--q", type=int, default=1,
                    help="directions per step for one_sided / averaged")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--sparsity", type=float, default=0.75,
                    help="LeZO fraction of layers dropped per step")
    ap.add_argument("--backend", default="scan",
                    choices=["dense", "scan", "gather", "pallas"])
    ap.add_argument("--forward-backend", default="materialized",
                    choices=list(est_costs.FORWARD_BACKENDS),
                    help="materialized = classic perturb/restore sweeps; "
                         "virtual = fused forward regenerates z in-kernel "
                         "(Pallas; virtual_ref = pure-JAX oracle), so a ZO "
                         "step writes params once (repro.fused)")
    ap.add_argument("--peft", default=None, choices=[None, "lora", "prefix"])
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--quorum", type=float, default=1.0)
    ap.add_argument("--loss-shards", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write history JSON here")
    args = ap.parse_args()

    mcfg = configs.get(args.arch, args.variant)
    if args.task:
        task = tasks_mod.build(args.task, vocab=mcfg.vocab,
                               seq_len=args.seq_len, seed=args.seed)
    else:
        task = synthetic.TaskConfig(vocab=mcfg.vocab, seq_len=args.seq_len,
                                    n_classes=2, seed=args.seed)
    n_layers = mcfg.num_layers
    n_drop = 0 if args.optimizer == "mezo" else int(args.sparsity * n_layers)
    tcfg = TrainConfig(
        steps=args.steps, batch_size=args.batch_size,
        mode="fo" if args.optimizer == "fo" else "zo",
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        quorum=args.quorum, n_loss_shards=args.loss_shards,
        peft=args.peft, seed=args.seed, eval_every=max(1, args.steps // 4),
        estimator=args.estimator, est_q=args.q,
        forward_backend=args.forward_backend)
    zcfg = zo.ZOConfig(eps=args.eps, lr=args.lr, n_drop=n_drop,
                       backend=args.backend,
                       forward_backend=args.forward_backend)
    trainer = Trainer(mcfg, task, tcfg, zo_cfg=zcfg)
    hist = trainer.train()
    summary = {
        "arch": args.arch, "optimizer": args.optimizer,
        "estimator": args.estimator, "q": args.q,
        "forward_backend": args.forward_backend,
        "task": args.task or "synthetic",
        "metric": hist.get("metric_name", "val_loss"),
        "n_layers": n_layers, "n_drop": n_drop,
        "final_loss": hist["loss"][-1] if hist["loss"] else None,
        "val_loss": hist["val_loss"], "val_acc": hist["val_acc"],
        "best_step": hist.get("best_step"),
    }
    print(json.dumps(summary, indent=1))
    if args.out:
        hist2 = {k: v for k, v in hist.items() if not k.endswith("params")}
        with open(args.out, "w") as f:
            json.dump({"summary": summary, "history": hist2}, f, indent=1)


if __name__ == "__main__":
    main()
