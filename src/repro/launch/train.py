"""Training launcher — legacy entrypoint, now a shim over the unified
spec CLI (``python -m repro.launch train``, see launch/cli.py).

Every historical flag (``--arch --optimizer --estimator --q --lr --eps
--sparsity --backend --forward-backend --peft --task --seq-len
--ckpt-dir --ckpt-every --quorum --loss-shards --seed --steps
--batch-size --out``) is accepted unchanged: they are exactly the
generated alias flags of the spec CLI, so there is no per-command
argparse here anymore and the defaults cannot drift from evaluate's.

Part of the unified launch surface (DESIGN.md §11).
"""
from __future__ import annotations

import sys

from repro.launch import cli


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    return cli.main(["train"] + argv)


if __name__ == "__main__":
    main()
