"""Seed-lineage replay verifier: re-execute a recorded ZO run, bitwise.

A MeZO/LeZO step is fully determined by scalars — (base_seed, step
index, projected gradient g, ε, lr) — because z and the LeZO layer
selection regenerate from the counter RNG.  The run directories written
by ``launch train`` (repro.obs.runlog) record exactly those scalars, so
a recorded run can be re-executed and checked *bit for bit*
(DESIGN.md §13).  This turns the replay property the checkpoint
manager's docstring only documents into an executable verifier:

  1. rebuild the trainer from the run's embedded ``spec.json``;
  2. verify the recorded seed lineage (``seed_t = fold(base_seed, t)``);
  3. re-execute steps through the trainer's own jitted step — starting
     from the newest usable checkpoint (or the initial params when the
     run's rows start at 0), regenerating each step's batch through the
     exact data path ``train()`` uses — and compare every recorded
     scalar of every step up to ``k``: loss, g per probe, coefficients,
     ε, lr, layer selection, all as f32 bit equality;
  4. wherever a checkpoint falls inside the replayed range, compare the
     re-executed parameters against it bitwise too.

Re-execution goes through ``trainer._step`` — the very jit graph the
run used — rather than re-applying the recorded axpys in a standalone
graph: XLA contracts multiply-adds (FMA) differently depending on the
surrounding graph, so a scalar-only replay graph reproduces the update
only to ~1 ULP, not bit-exactly.  Same graph + same inputs is exact by
construction; the recorded (seed, g) stream is what gets *verified*, at
every step.

Any corruption of the run log (a flipped g bit, an edited loss) or any
nondeterminism in the step pipeline surfaces as a loud mismatch report.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import rng
from repro.obs import runlog

# metric keys compared f32-bitwise between the recorded row and the
# re-executed step (missing on either side = skipped, e.g. layer_sel on
# a flat tree)
_COMPARE_SCALARS = ("loss", "projected_grad", "eps", "lr")
_COMPARE_VECTORS = ("probe_grads", "coeffs", "n_active_params")


def _f32(v) -> np.ndarray:
    return np.asarray(v, np.float32)


def _compare_row(t: int, row: Dict, metrics: Dict,
                 failures: List[str]) -> Dict[str, Any]:
    """f32-bitwise compare of one recorded row vs re-executed metrics."""
    matched: Dict[str, Any] = {}
    for key in _COMPARE_SCALARS:
        if key in row and key in metrics:
            rec, new = _f32(row[key]), _f32(metrics[key])
            matched[key] = float(new)
            if rec != new:
                failures.append(
                    f"step {t} {key}: recorded {float(rec)!r} != "
                    f"re-executed {float(new)!r}")
    for key in _COMPARE_VECTORS:
        if key in row and key in metrics:
            rec = _f32(row[key]).reshape(-1)
            new = _f32(metrics[key]).reshape(-1)
            matched[key] = [float(x) for x in new]
            if rec.shape != new.shape or not np.array_equal(rec, new):
                failures.append(
                    f"step {t} {key}: recorded {rec.tolist()!r} != "
                    f"re-executed {new.tolist()!r}")
    if "layer_sel" in row and "layer_sel" in metrics:
        rec = np.asarray(row["layer_sel"], np.int32)
        new = np.asarray(metrics["layer_sel"], np.int32)
        matched["layer_sel"] = new.tolist()
        if not np.array_equal(rec, new):
            failures.append(f"step {t} layer_sel: recorded {rec.tolist()!r}"
                            f" != re-executed {new.tolist()!r}")
    if "active_layers" in row and "active_layers" in metrics:
        rec_n, new_n = int(row["active_layers"]), int(metrics["active_layers"])
        matched["active_layers"] = new_n
        if rec_n != new_n:
            failures.append(f"step {t} active_layers: recorded {rec_n} != "
                            f"re-executed {new_n}")
    # swarm rows (DESIGN.md §14): the quorum mask and the per-shard ±εz
    # losses the commit was reduced over — a degraded step replays with
    # the recorded mask, so the shard sets match exactly
    if "arrived" in row and "arrived" in metrics:
        rec = np.asarray(row["arrived"], np.int32)
        new = np.asarray(metrics["arrived"], np.int32)
        matched["arrived"] = new.tolist()
        if not np.array_equal(rec, new):
            failures.append(f"step {t} arrived: recorded {rec.tolist()!r}"
                            f" != re-executed {new.tolist()!r}")
    if "shard_losses" in row and "shard_losses" in metrics:
        rec_sl = {str(kk): _f32(v) for kk, v in row["shard_losses"].items()}
        new_sl = {str(kk): _f32(v)
                  for kk, v in metrics["shard_losses"].items()}
        matched["shard_losses"] = {kk: [float(x) for x in v]
                                   for kk, v in new_sl.items()}
        if sorted(rec_sl) != sorted(new_sl):
            failures.append(
                f"step {t} shard_losses: recorded shards "
                f"{sorted(rec_sl)} != re-executed {sorted(new_sl)}")
        else:
            for kk in sorted(rec_sl):
                if not np.array_equal(rec_sl[kk], new_sl[kk]):
                    failures.append(
                        f"step {t} shard_losses[{kk}]: recorded "
                        f"{rec_sl[kk].tolist()!r} != re-executed "
                        f"{new_sl[kk].tolist()!r}")
    return matched


def replay_run(run: Optional[str] = None, step: Optional[int] = None,
               runs_root: str = runlog.DEFAULT_RUNS_DIR) -> Dict[str, Any]:
    """Verify ``run`` through step ``step`` (default: last recorded).

    Returns a report dict; ``report["failures"]`` is empty iff every
    recorded scalar of every replayed step matched the re-execution bit
    for bit (and re-executed params matched every checkpoint in range).
    """
    import jax
    import jax.numpy as jnp
    from repro import api
    from repro import tasks as tasks_mod
    from repro.data import synthetic
    from repro.train.trainer import Trainer

    rd = runlog.load_run(run, runs_root)
    if rd.spec is None:
        raise FileNotFoundError(f"{rd.dir}: no spec.json — cannot replay")
    if not rd.steps:
        raise ValueError(f"{rd.dir}: no recorded steps in steps.jsonl")
    spec = api.from_dict(rd.spec)
    if spec.optimizer.mode != "zo":
        raise ValueError(
            f"replay covers optimizer.mode='zo' runs; this run used "
            f"{spec.optimizer.mode!r} (momentum/adam state is not part of "
            "the recorded scalar stream)")
    # replaying must not write a fresh run dir or trace
    spec = dataclasses.replace(spec, telemetry=api.Telemetry())

    k = rd.last_step if step is None else int(step)
    rows = {r["step"]: r for r in rd.steps}
    if k not in rows:
        raise KeyError(f"run {rd.run_id!r} has no recorded step {k} "
                       f"(steps {rd.first_step}..{rd.last_step})")

    failures: List[str] = []
    checks: List[str] = []

    trainer = Trainer.from_spec(spec)
    tcfg = trainer.tcfg
    base_seed = int(np.uint32(rng.fold_py(tcfg.seed, 0xC0FFEE)))

    # ---- seed lineage: every recorded seed must be fold(base_seed, t)
    for t in sorted(rows):
        want = int(np.uint32(rng.fold_py(base_seed, t)))
        got = rows[t].get("seed")
        if got != want:
            failures.append(
                f"seed lineage broken at step {t}: recorded {got}, "
                f"fold(base_seed={base_seed}, {t}) = {want}")
    checks.append(f"seed lineage over {len(rows)} recorded steps")

    # ---- pick the start point.  Stateless estimators (everything but
    # the importance wrapper's EMA scores) can fast-forward to the
    # newest checkpoint <= k; a stateful estimator must re-warm its
    # state from the run's first recorded step, exactly like the run
    # itself did (estimator state is never checkpointed).
    first = rd.first_step
    stateless = trainer.est_state == {}
    ckpt_steps = (set(trainer.ckpt.all_steps())
                  if trainer.ckpt is not None
                  and trainer.ckpt.latest() is not None else set())
    usable = [s for s in ckpt_steps if first <= s <= k]
    if stateless and usable:
        start_t = max(usable)
    elif first in ckpt_steps | {0}:
        start_t = first
    else:
        raise ValueError(
            f"run {rd.run_id!r} records steps {first}..{rd.last_step} "
            f"but no usable checkpoint exists under {tcfg.ckpt_dir!r} — "
            f"cannot reconstruct parameters at step {first}")
    if start_t == 0:
        params = trainer.trainable
    else:
        params, _, _, _ = trainer.ckpt.restore(trainer.trainable,
                                               step=start_t)
        params = jax.tree.map(jnp.asarray, params)
    missing = [t for t in range(start_t, k + 1) if t not in rows]
    if missing:
        raise ValueError(f"run {rd.run_id!r}: steps {missing} missing from "
                         "the recorded stream — cannot replay through them")

    # ---- re-execute steps start_t..k through the trainer's jitted step
    # over the regenerated data stream, verifying each recorded row
    train_data = trainer.make_dataset(4096)
    stream_data = {kk: v for kk, v in train_data.items()
                   if kk in tasks_mod.MODEL_BATCH_KEYS}
    stream = synthetic.batches(stream_data, tcfg.batch_size, tcfg.steps,
                               seed=tcfg.seed + 7)
    state = trainer.est_state
    matched: Dict[str, Any] = {}
    ckpt_hits = []
    done = False
    for t, np_batch in enumerate(stream):
        if t < start_t:
            continue
        if t > k:
            done = True
            break
        batch = trainer._model_batch(np_batch)
        if getattr(trainer._step, "sharded", False):
            # swarm runs re-execute with the recorded quorum mask, so a
            # short-handed commit reduces the very same shard subset
            params, state, metrics = trainer._step(
                params, state, batch, jnp.int32(t), jnp.uint32(base_seed),
                arrived=rows[t].get("arrived"))
        else:
            params, state, metrics = trainer._step(
                params, state, batch, jnp.int32(t), jnp.uint32(base_seed))
        matched = _compare_row(t, rows[t], jax.device_get(metrics), failures)
        # a checkpoint inside the replayed range pins the parameter bits
        if (t + 1) in ckpt_steps and (t + 1) <= k:
            ck, _, _, _ = trainer.ckpt.restore(trainer.trainable,
                                               step=t + 1)
            leaves_a = jax.tree_util.tree_leaves(
                jax.tree.map(np.asarray, params))
            leaves_b = jax.tree_util.tree_leaves(
                jax.tree.map(np.asarray, ck))
            bad = sum(0 if np.array_equal(a, b) else 1
                      for a, b in zip(leaves_a, leaves_b))
            if bad:
                failures.append(
                    f"re-executed params at step {t + 1} differ from "
                    f"checkpoint {t + 1} on {bad} leaves")
            else:
                ckpt_hits.append(t + 1)
        if t == k:
            done = True
            break
    if not done:
        raise ValueError(f"step {k} beyond the run's {tcfg.steps}-step "
                         "data stream")
    checks.append(
        f"re-executed steps {start_t}..{k} through the trainer's jitted "
        "step (regenerated batches) and compared every recorded scalar "
        "f32-bitwise")
    if ckpt_hits:
        checks.append("re-executed params bitwise equal checkpoints "
                      f"{ckpt_hits}")

    return {
        "run_id": rd.run_id,
        "run_dir": rd.dir,
        "step": k,
        "estimator": spec.estimator.name,
        "forward_backend": spec.runtime.forward_backend,
        "param_start": start_t,
        "checks": checks,
        "matched": matched,
        "failures": failures,
        "ok": not failures,
    }
