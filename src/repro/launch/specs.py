"""ShapeDtypeStruct stand-ins + sharded step builders for the dry-run.

No device memory is ever allocated here: parameters, batches and caches
are ``jax.ShapeDtypeStruct`` trees produced with ``jax.eval_shape``; the
launcher lowers against them and compiles for the production mesh.

Dry-run stand-ins for the production mesh (DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import zo
from repro.distributed import ctx, sharding
from repro.models import frontends, lm
from repro.models.config import ModelConfig


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, max_seq))


def input_specs(cfg: ModelConfig, shape) -> Dict[str, Any]:
    """Model inputs for one grid cell (see configs.shapes)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {"labels": jax.ShapeDtypeStruct((B, S), i32),
                 "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32)}
        if frontends.uses_embeds(cfg):
            batch["embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        return {"batch": batch}
    if shape.kind == "prefill":
        if frontends.uses_embeds(cfg):
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.dtype(cfg.dtype))}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a seq_len cache
    out = {"caches": cache_specs(cfg, B, S),
           "pos": jax.ShapeDtypeStruct((), i32)}
    if frontends.uses_embeds(cfg):
        out["embeds"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                             jnp.dtype(cfg.dtype))
    else:
        out["token"] = jax.ShapeDtypeStruct((B, 1), i32)
    return out


def zo_variant(cfg: ModelConfig, variant: str) -> zo.ZOConfig:
    """faithful = the paper's MeZO-style LeZO (dense masked passes,
    separate restore+update, uniform policy); optimized = beyond-paper
    (static-gather active subset, fused restore+update)."""
    n_drop = int(0.75 * cfg.num_layers)
    if variant == "faithful":
        return zo.ZOConfig(n_drop=n_drop, policy="uniform", backend="dense",
                           fused_update=False)
    if variant == "optimized":
        return zo.ZOConfig(n_drop=n_drop, policy="stratified",
                           backend="gather", fused_update=True)
    if variant == "mezo":
        return zo.ZOConfig(n_drop=0, policy="uniform", backend="dense",
                           fused_update=False)
    raise ValueError(variant)


def build_train_step(cfg: ModelConfig, mesh, variant: str = "optimized"):
    """jit'd LeZO train step with explicit shardings, ready to lower."""
    ctx.set_mesh(mesh)
    zcfg = zo_variant(cfg, variant)
    spec = zo.build_spec(param_specs(cfg), lm.zo_group_fn)
    loss_fn = functools.partial(lm.lm_loss, cfg)
    step = zo.make_zo_step(loss_fn, spec, zcfg)

    pshapes = param_specs(cfg)
    p_shard = sharding.params_sharding(cfg, pshapes, mesh)
    scalar = NamedSharding(mesh, P())

    def wrapped(params, batch, step_idx, base_seed):
        return step(params, batch, step_idx, base_seed)

    def shard_fn(batch_specs):
        b_shard = sharding.batch_sharding(batch_specs, mesh)
        return jax.jit(
            wrapped,
            in_shardings=(p_shard, b_shard, scalar, scalar),
            out_shardings=(p_shard, None),
            donate_argnums=(0,),
        )
    return shard_fn, pshapes


def build_prefill_step(cfg: ModelConfig, mesh, max_seq: int):
    ctx.set_mesh(mesh)
    pshapes = param_specs(cfg)
    p_shard = sharding.params_sharding(cfg, pshapes, mesh)

    if frontends.uses_embeds(cfg):
        def prefill_fn(params, embeds):
            return lm.prefill(cfg, params, None, max_seq=max_seq,
                              embeds=embeds)
    else:
        def prefill_fn(params, tokens):
            return lm.prefill(cfg, params, tokens, max_seq=max_seq)

    def shard_fn(B):
        c_shard = sharding.cache_sharding(cache_specs(cfg, B, max_seq), mesh)
        logits_shard = NamedSharding(
            mesh, P(sharding.batch_axes(mesh) if B % _nbatch(mesh) == 0
                    else None, None))
        data_shard = NamedSharding(
            mesh, P(sharding.batch_axes(mesh) if B % _nbatch(mesh) == 0
                    else None, *([None, None] if frontends.uses_embeds(cfg)
                                 else [None])))
        return jax.jit(prefill_fn, in_shardings=(p_shard, data_shard),
                       out_shardings=(logits_shard, c_shard))
    return shard_fn, pshapes


def _nbatch(mesh):
    n = 1
    for a in sharding.batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def build_serve_step(cfg: ModelConfig, mesh, cache_len: int, batch: int):
    ctx.set_mesh(mesh)
    pshapes = param_specs(cfg)
    p_shard = sharding.params_sharding(cfg, pshapes, mesh)
    cshapes = cache_specs(cfg, batch, cache_len)
    c_shard = sharding.cache_sharding(cshapes, mesh)
    scalar = NamedSharding(mesh, P())
    B = batch
    tok_shard = NamedSharding(
        mesh, P(sharding.batch_axes(mesh) if B % _nbatch(mesh) == 0 else None,
                None))
    logits_shard = tok_shard

    if frontends.uses_embeds(cfg):
        emb_shard = NamedSharding(
            mesh, P(sharding.batch_axes(mesh) if B % _nbatch(mesh) == 0
                    else None, None, None))

        def serve_fn(params, caches, embeds, pos):
            return lm.serve_step(cfg, params, caches, None, pos,
                                 embeds=embeds)
        fn = jax.jit(serve_fn,
                     in_shardings=(p_shard, c_shard, emb_shard, scalar),
                     out_shardings=(logits_shard, c_shard),
                     donate_argnums=(1,))
    else:
        def serve_fn(params, caches, token, pos):
            return lm.serve_step(cfg, params, caches, token, pos)
        fn = jax.jit(serve_fn,
                     in_shardings=(p_shard, c_shard, tok_shard, scalar),
                     out_shardings=(logits_shard, c_shard),
                     donate_argnums=(1,))
    return fn, pshapes, cshapes
