"""Scan-aware HLO cost analysis for the roofline.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**,
ignoring ``known_trip_count`` — for a 62-layer scanned transformer that
under-counts FLOPs, HBM bytes *and* the per-layer TP collectives by 62x.
This module parses the optimized HLO text and accumulates costs
recursively through the call graph with loop multipliers:

  * flops: dot ops = 2 * numel(result) * prod(contracting dims); element
    -wise arithmetic (incl. the ZO perturbation RNG) = numel per op;
    reduces = numel(operand).
  * hbm bytes: per *top-level* op in each computation: operands + result
    (internal ops of a fusion stay in registers, matching
    HloCostAnalysis' model).
  * collective bytes per kind, with trip-count multipliers; all-reduce
    counted 2x (ring reduce + broadcast), all-gather / all-to-all /
    collective-permute / reduce-scatter counted at result size.
  * conditional: max over branches (conservative for LeZO's scan+cond
    backend; the gather backend needs no conditionals).

Shapes are post-SPMD-partitioning, so everything is per-device.

Benchmark/paper-artifact analysis (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "sqrt", "rsqrt", "cbrt", "power", "tanh", "sine", "cosine", "atan2",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "logistic", "erf", "remainder", "and", "or", "xor", "not", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "select", "clamp",
    "compare", "convert", "is-finite",
}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_CALLS_RE = re.compile(r"(?:calls|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[="\s:{]+n["\s:]+"?(\d+)')
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Total (elements, bytes) over all array shapes in ``text``."""
    elems = tot = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        tot += n * _DTYPE_BYTES[dt]
    return elems, tot


@dataclasses.dataclass
class Op:
    name: str
    shape: str           # result type text
    opcode: str
    rest: str            # remainder of the line (operands + attrs)
    is_root: bool = False


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    detail: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        for k, v in other.detail.items():
            self.detail[k] = self.detail.get(k, 0.0) + v * mult


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Op]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _OP_RE.match(line)
            if m:
                name, shape, opcode, rest = m.groups()
                self.comps[cur].append(
                    Op(name, shape, opcode, rest,
                       is_root=line.lstrip().startswith("ROOT")))

    # ------------------------------------------------------------- costs
    def comp_cost(self, comp: str, fused: bool = False) -> Cost:
        key = f"{comp}|{int(fused)}"
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        symtab = {op.name: op.shape for op in self.comps.get(comp, [])}
        for op in self.comps.get(comp, []):
            total.add(self._op_cost(op, symtab, fused))
        self._memo[key] = total
        return total

    def _operand_bytes(self, op: Op, symtab) -> float:
        b = 0
        # operands are leading %refs before any attr keywords
        args = op.rest.split("),")[0]
        for m in _OPERAND_RE.finditer(args):
            ref = m.group(1)
            if ref in symtab:
                b += _shape_elems_bytes(symtab[ref])[1]
        return b

    def _op_cost(self, op: Op, symtab, fused: bool) -> Cost:
        c = Cost()
        res_elems, res_bytes = _shape_elems_bytes(op.shape)
        code = op.opcode

        if code == "while":
            m = _TRIP_RE.search(op.rest)
            trip = int(m.group(1)) if m else 1
            body = _CALLS_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            if body:
                c.add(self.comp_cost(body.group(1)), trip)
            if cond:
                c.add(self.comp_cost(cond.group(1)), trip)
            return c
        if code == "conditional":
            m = _BRANCHES_RE.search(op.rest)
            branches = []
            if m:
                branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
            else:
                branches = [x.group(1) for x in re.finditer(
                    r"(?:true_computation|false_computation)=%?([\w\.\-]+)",
                    op.rest)]
            best = Cost()
            for b in branches:
                bc = self.comp_cost(b)
                if bc.flops + bc.bytes >= best.flops + best.bytes:
                    best = bc
            c.add(best)
            c.bytes += res_bytes
            return c
        if code == "fusion":
            m = _CALLS_RE.search(op.rest)
            slice_root = None
            if m:
                inner = self.comp_cost(m.group(1), fused=True)
                c.flops += inner.flops
                c.add(Cost(coll=inner.coll))
                slice_root = self._slice_root_bytes(m.group(1))
            if slice_root is not None:
                # root is an in-place / slicing op: traffic is proportional
                # to the slice, not the whole buffer (XLA aliases it).
                c.bytes += slice_root
            else:
                c.bytes += res_bytes + self._fusion_operand_bytes(op, symtab)
            return c
        if code in ("call", "custom-call", "async-start"):
            m = _CALLS_RE.search(op.rest)
            if m and m.group(1) in self.comps:
                c.add(self.comp_cost(m.group(1)))
            c.bytes += res_bytes + self._operand_bytes(op, symtab)
            return c
        if code in _COLLECTIVES or any(code == k + "-start" for k in _COLLECTIVES):
            kind = code.replace("-start", "")
            wire = res_bytes * (2.0 if kind == "all-reduce" else 1.0)
            c.coll[kind] = wire
            c.detail[f"{kind} {op.shape[:60]}"] = wire
            c.bytes += res_bytes + self._operand_bytes(op, symtab)
            return c
        if code == "dot":
            m = _CONTRACT_RE.search(op.rest)
            lhs_ref = _OPERAND_RE.search(op.rest)
            contract = 1
            if m and lhs_ref and lhs_ref.group(1) in symtab:
                lhs_shape = _SHAPE_RE.search(symtab[lhs_ref.group(1)])
                if lhs_shape:
                    dims = [int(d) for d in lhs_shape.group(2).split(",") if d]
                    for ci in m.group(1).split(","):
                        if ci:
                            contract *= dims[int(ci)]
            c.flops += 2.0 * res_elems * contract
            if not fused:
                c.bytes += res_bytes + self._operand_bytes(op, symtab)
            return c
        if code in ("reduce", "reduce-window"):
            c.flops += self._operand_bytes(op, symtab) / 4.0  # ~elems
            if not fused:
                c.bytes += res_bytes + self._operand_bytes(op, symtab)
            return c
        if code in _ELEMENTWISE:
            c.flops += res_elems
            if not fused:
                c.bytes += res_bytes + self._operand_bytes(op, symtab)
            return c
        if code in ("parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "after-all", "iota", "partition-id"):
            return c
        if code in ("dynamic-update-slice", "scatter", "dynamic-slice",
                    "gather"):
            if not fused:
                c.bytes += self._slice_op_bytes(op, symtab, res_bytes)
            return c
        # data movement ops (copy, sort, pad, broadcast, transpose,
        # reshape, concatenate, slice, ...)
        if not fused:
            c.bytes += res_bytes + self._operand_bytes(op, symtab)
        return c

    def _fusion_operand_bytes(self, op: Op, symtab) -> float:
        """Operand traffic of a fusion, slice-aware.

        If the fused computation dynamic-slices / gathers one of its
        *parameters* (the classic scan pattern: read this layer's slice of
        a stacked tensor, or this chunk of a loop-invariant buffer), the
        fusion touches only the slice — charge 2x slice bytes instead of
        the full outer operand.
        """
        full = self._operand_bytes(op, symtab)
        m = _CALLS_RE.search(op.rest)
        if not m or m.group(1) not in self.comps:
            return full
        inner_ops = self.comps[m.group(1)]
        param_order = {}
        for o in inner_ops:
            if o.opcode == "parameter":
                pm = re.match(r"\s*(\d+)\s*\)", o.rest)
                if pm:
                    param_order[o.name] = int(pm.group(1))
        outer_refs = _OPERAND_RE.findall(op.rest.split("),")[0])
        adjust = 0.0
        seen = set()
        for o in inner_ops:
            if o.opcode not in ("dynamic-slice", "gather"):
                continue
            refs = _OPERAND_RE.findall(o.rest.split("),")[0])
            if not refs or refs[0] not in param_order or refs[0] in seen:
                continue
            seen.add(refs[0])
            idx = param_order[refs[0]]
            if idx < len(outer_refs) and outer_refs[idx] in symtab:
                outer_bytes = _shape_elems_bytes(symtab[outer_refs[idx]])[1]
                adjust += 2.0 * _shape_elems_bytes(o.shape)[1] - outer_bytes
        return max(0.0, full + adjust)

    # ------------------------------------------------- slice-proportional
    def _slice_op_bytes(self, op: Op, symtab, res_bytes: float) -> float:
        """Traffic for in-place update / slicing ops: ~2x the moved slice."""
        refs = _OPERAND_RE.findall(op.rest.split("),")[0])
        if op.opcode in ("dynamic-update-slice", "scatter"):
            upd = refs[1] if op.opcode == "dynamic-update-slice" else (
                refs[2] if len(refs) > 2 else None)
            if upd and upd in symtab:
                return 2.0 * _shape_elems_bytes(symtab[upd])[1]
            return res_bytes  # fallback
        # dynamic-slice / gather: read+write proportional to the result
        return 2.0 * res_bytes

    def _slice_root_bytes(self, comp: str) -> Optional[float]:
        """If ``comp``'s ROOT is a slice-ish op, its slice-proportional
        bytes; else None."""
        ops = self.comps.get(comp, [])
        root = next((o for o in ops if o.is_root), ops[-1] if ops else None)
        if root is None:
            return None
        if root.opcode in ("dynamic-update-slice", "scatter", "dynamic-slice",
                           "gather"):
            symtab = {o.name: o.shape for o in ops}
            res_bytes = _shape_elems_bytes(root.shape)[1]
            return self._slice_op_bytes(root, symtab, res_bytes)
        return None

    def total(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Dict:
    cost = HloCost(hlo_text).total()
    return {"flops": cost.flops, "bytes": cost.bytes,
            "collectives": cost.coll}


# --------------------------------------------------- estimator cost model
_HBM_BW_DEFAULT = 819e9  # TPU v5e bytes/s — matches launch/dryrun.py


def estimator_step_cost(terms: Dict, name: str, q: int = 1,
                        param_bytes: Optional[float] = None,
                        fused_update: bool = True,
                        hbm_bw: float = _HBM_BW_DEFAULT,
                        forward_backend: str = "materialized") -> Dict:
    """Project lowered-step roofline terms onto a different ZO estimator.

    The train graph we lower and cost (launch/specs.py) is a fused
    two-point step — ``repro.estimators.costs.BASELINE``: 2 forwards + 3
    parameter axpy sweeps.  Other estimators change only the *counts* of
    those two primitives, so their step time projects from the measured
    terms without recompiling per estimator:

      * forward-scaling work (flops, activation HBM traffic, per-layer TP
        collectives) scales with the estimator's forward count;
      * when ``param_bytes`` (per-device) is known, axpy sweeps are
        re-priced exactly: each sweep moves ~2x the active parameter
        bytes through HBM.  Without it, memory scales with forwards and
        the sweep counts are still reported for the caller.

    ``forward_backend="virtual"``/``"virtual_ref"`` prices the fused
    runtime (DESIGN.md §10): probe sweeps vanish from the counts, so with
    ``param_bytes`` the perturb+update share of memory time collapses to
    the single update sweep.
    """
    from repro.estimators import costs  # pure-python counts, no jax

    base = costs.step_counts(costs.BASELINE, fused_update=True)
    est = costs.step_counts(name, q=q, fused_update=fused_update,
                            forward_backend=forward_backend)
    f = est["forwards"] / base["forwards"]
    # scaled times + counts only: copying the raw hlo_flops/bytes fields
    # through unscaled would contradict the scaled *_s terms
    out = {"estimator": name, "q": q, "forwards": est["forwards"],
           "axpy_sweeps": est["axpy_sweeps"],
           "forward_backend": forward_backend}
    out["compute_s"] = terms["compute_s"] * f
    out["collective_s"] = terms["collective_s"] * f
    if param_bytes:
        sweep_s = 2.0 * param_bytes / hbm_bw
        fwd_mem = max(0.0, terms["memory_s"] - base["axpy_sweeps"] * sweep_s)
        out["memory_s"] = fwd_mem * f + est["axpy_sweeps"] * sweep_s
    else:
        out["memory_s"] = terms["memory_s"] * f
    return out
