"""Spec consumers: derive() legacy configs, and the top-level entrypoints
``run`` / ``evaluate`` / ``dryrun`` / ``sweep``.

``derive`` materializes today's ``ZOConfig`` / ``EstimatorConfig`` /
``TrainConfig`` / ``FOConfig`` / ``LoRAConfig`` / ``PrefixConfig`` from
the spec, so ``Trainer``, ``estimators.make_step`` and the fused runtime
stay bit-identical underneath — the equivalence suite in
tests/test_api.py holds that line for every estimator x forward backend.

This module is imported lazily by ``repro.api`` (it pulls jax via the
trainer); spec/validate/presets stay import-light for the CLI.

Part of the unified experiment-spec surface (DESIGN.md §11).
"""
import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional

from repro import estimators
from repro import tasks as tasks_mod
from repro.api import presets as presets_mod
from repro.api.spec import Experiment, SpecError, to_dict, with_overrides
from repro.api.validate import n_drop_for, resolve_model
from repro.api.validate import validate as validate_spec
from repro.core import fo, zo
from repro.data import synthetic
from repro.peft import lora as lora_mod
from repro.peft import prefix as prefix_mod


class Derived(NamedTuple):
    """The legacy config tree a spec materializes to."""
    model_cfg: Any
    task: Any                     # synthetic.TaskConfig | tasks.CompiledTask
    tcfg: Any                     # train.trainer.TrainConfig
    zo_cfg: zo.ZOConfig
    fo_cfg: fo.FOConfig
    est_cfg: estimators.EstimatorConfig
    lora_cfg: lora_mod.LoRAConfig
    prefix_cfg: prefix_mod.PrefixConfig
    n_drop: int


def derive(spec: Experiment) -> Derived:
    """Validate ``spec`` and materialize the legacy configs it implies."""
    from repro.train.trainer import TrainConfig  # trainer imports repro.api

    mcfg = validate_spec(spec)
    m, t, o, e, rt, r = (spec.model, spec.task, spec.optimizer,
                         spec.estimator, spec.runtime, spec.run)
    if t.name is not None:
        task = tasks_mod.build(t.name, vocab=mcfg.vocab, seq_len=m.seq_len,
                               seed=r.seed)
    else:
        task = synthetic.TaskConfig(vocab=mcfg.vocab, seq_len=m.seq_len,
                                    n_classes=t.n_classes,
                                    signal_rate=t.signal_rate, seed=r.seed)
    n_drop = n_drop_for(spec, mcfg.num_layers)
    eval_every = (max(1, r.steps // 4) if r.eval_every is None
                  else r.eval_every)
    tcfg = TrainConfig(
        steps=r.steps, batch_size=r.batch_size, eval_every=eval_every,
        log_every=r.log_every, seed=r.seed, mode=o.mode,
        estimator=e.name, est_q=e.q,
        ckpt_dir=r.ckpt_dir, ckpt_every=r.ckpt_every,
        keep_ckpts=r.keep_ckpts,
        n_loss_shards=rt.n_loss_shards, quorum=rt.quorum,
        peft=rt.peft, forward_backend=rt.forward_backend)
    zo_cfg = zo.ZOConfig(
        eps=o.eps, lr=o.lr, n_drop=n_drop, policy=o.policy,
        backend=rt.backend, fused_update=o.fused_update,
        weight_decay=o.weight_decay, interpret=rt.interpret,
        forward_backend=rt.forward_backend,
        paired_probes=rt.paired_probes)
    est_cfg = estimators.from_zo(zo_cfg, name=e.name, q=e.q,
                                 q_chunk=e.q_chunk, inner=e.inner,
                                 importance_decay=e.importance_decay)
    fo_cfg = fo.FOConfig(optimizer=o.fo_optimizer, lr=o.lr,
                         weight_decay=o.weight_decay, grad_clip=o.grad_clip)
    lora_cfg = lora_mod.LoRAConfig(rank=rt.lora_rank, alpha=rt.lora_alpha,
                                   targets=tuple(rt.lora_targets))
    prefix_cfg = prefix_mod.PrefixConfig(n_prefix=rt.prefix_tokens)
    return Derived(mcfg, task, tcfg, zo_cfg, fo_cfg, est_cfg, lora_cfg,
                   prefix_cfg, n_drop)


def preset(name: str) -> Experiment:
    return presets_mod.get(name)


def _summary(spec: Experiment, d: Derived, hist: Dict) -> Dict:
    return {
        "arch": spec.model.arch,
        "mode": spec.optimizer.mode,
        "estimator": spec.estimator.name, "q": spec.estimator.q,
        "forward_backend": spec.runtime.forward_backend,
        "task": spec.task.name or "synthetic",
        "metric": hist.get("metric_name", "val_loss"),
        "n_layers": d.model_cfg.num_layers, "n_drop": d.n_drop,
        "final_loss": hist["loss"][-1] if hist["loss"] else None,
        "val_loss": hist["val_loss"], "val_acc": hist["val_acc"],
        "best_step": hist.get("best_step"),
        "run_id": hist.get("run_id"), "run_dir": hist.get("run_dir"),
    }


def run(spec: Experiment, train_data=None, val_data=None) -> Dict:
    """Train per the spec.  Returns ``{"spec", "summary", "history"}`` —
    the spec dict is embedded so every result artifact is replayable."""
    from repro.train.trainer import Trainer

    trainer = Trainer.from_spec(spec)
    hist = trainer.train(train_data=train_data, val_data=val_data)
    d = trainer.derived
    return {"spec": to_dict(spec), "summary": _summary(spec, d, hist),
            "history": hist}


def evaluate(spec: Experiment, mode: str = "zeroshot",
             n_examples: int = 256) -> Dict:
    """One task's metric report (the SuperGLUE protocol; DESIGN.md §9).

    ``mode="zeroshot"`` scores fresh params (or, when ``run.ckpt_dir``
    is set, the latest checkpoint there); ``mode="train"`` fine-tunes
    first and reports both numbers.
    """
    from repro.train.trainer import Trainer

    if spec.task.name is None:
        raise SpecError("task.name", "evaluate requires a registry task")
    if mode not in ("zeroshot", "train"):
        raise SpecError("<mode>", f"unknown evaluate mode {mode!r}")
    ckpt_dir = spec.run.ckpt_dir
    if ckpt_dir is not None and mode == "train":
        # Trainer auto-resumes from ckpt_dir, which would silently turn
        # "fine-tune then score" into "restore then maybe-train"
        raise SpecError("run.ckpt_dir", "scores an existing checkpoint; "
                        "combine it with mode=zeroshot, not train")
    trainer = Trainer.from_spec(spec)
    task = trainer.registry_task
    val = trainer.make_dataset(n_examples, seed_shift=1)
    report = {"task": task.name, "kind": task.kind, "metric": task.metric,
              "arch": spec.model.arch, "variant": spec.model.variant,
              "n_examples": n_examples, "mode": mode,
              "spec": to_dict(spec)}
    zs_loss, zs_metric = trainer.evaluate(trainer.trainable, val,
                                          max_examples=n_examples)
    report["zeroshot"] = zs_metric
    report["zeroshot_val_loss"] = zs_loss
    if ckpt_dir is not None and mode != "train":
        params, step, _, _ = trainer.ckpt.restore(trainer.trainable)
        vl, metric = trainer.evaluate(params, val, max_examples=n_examples)
        report.update(trained=metric, trained_val_loss=vl, ckpt_step=step)
    elif mode == "train":
        hist = trainer.train(val_data=val)
        params = hist.get("best_params", hist["final_params"])
        vl, metric = trainer.evaluate(params, val, max_examples=n_examples)
        report.update(trained=metric, trained_val_loss=vl,
                      best_step=hist.get("best_step", -1),
                      val_metric_curve=hist["val_acc"])
    return report


def dryrun_cell(spec: Experiment, shape: str, arch: Optional[str] = None,
                multi_pod: Optional[bool] = None,
                lowering: str = "optimized", save_hlo: Optional[str] = None,
                overrides: Optional[Dict] = None) -> Dict:
    """One lower+compile roofline cell.  The single implementation both
    ``api.dryrun`` and the CLI grid loop share: the record embeds the
    spec *of the cell* (arch/mesh substituted when the grid varies
    them), so every artifact stays replayable."""
    from repro.launch import dryrun as dryrun_mod

    arch = spec.model.arch if arch is None else arch
    mp = (spec.runtime.mesh == "multi_pod") if multi_pod is None \
        else multi_pod
    rec = dryrun_mod.run_cell(
        arch, shape, mp, lowering, hlo_dir=save_hlo, overrides=overrides,
        estimator=spec.estimator.name, q=spec.estimator.q,
        forward_backend=spec.runtime.forward_backend)
    rec["spec"] = to_dict(with_overrides(spec, {
        "model.arch": arch,
        "runtime.mesh": "multi_pod" if mp else "single"}))
    return rec


def dryrun(spec: Experiment, shape: Optional[str] = None,
           lowering: str = "optimized", save_hlo: Optional[str] = None,
           overrides: Optional[Dict] = None) -> List[Dict]:
    """Lower + compile the spec's arch on the production mesh and
    return the roofline records (one per shape cell).

    Must run before jax initializes real devices — the dry-run pins
    ``xla_force_host_platform_device_count`` at import (the unified CLI
    and the legacy ``launch.dryrun`` entrypoint both guarantee this).
    """
    validate_spec(spec)
    from repro.configs.shapes import SHAPES, shapes_for

    mcfg = resolve_model(
        with_overrides(spec, {"model.variant": "full"}))
    shapes = [SHAPES[shape]] if shape else shapes_for(mcfg)
    return [dryrun_cell(spec, sh.name, lowering=lowering,
                        save_hlo=save_hlo, overrides=overrides)
            for sh in shapes]


def sweep(spec: Experiment, overrides: List[Dict[str, Any]],
          train_data=None, val_data=None) -> List[Dict]:
    """Run ``spec`` once per override set (dotted-path dicts), returning
    ``[{"overrides", "result"}, ...]`` — every scenario is a spec diff."""
    out = []
    for ov in overrides:
        varied = with_overrides(spec, dict(ov))
        out.append({"overrides": dict(ov),
                    "result": run(varied, train_data=train_data,
                                  val_data=val_data)})
    return out
