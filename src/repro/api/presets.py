"""Named experiment presets — the single source of truth for CLI and CI
defaults.  ``python -m repro.launch specs`` dumps every preset to
``artifacts/specs/`` (the ``make specs`` target); the golden-spec test
pins the serialized schema byte-for-byte.

Part of the unified experiment-spec surface (DESIGN.md §11).
"""
import dataclasses
from typing import Dict

from repro.api.spec import (Experiment, Estimator, Model, Optimizer, Run,
                            Runtime, SpecError, Swarm)

# The paper's headline recipe at CPU-runnable scale: LeZO (75% of layers
# dropped per step) + two-point SPSA on the OPT stack.  This preset IS
# the legacy ``launch/train`` default surface — the bit-identity
# acceptance gate compares the two.
_LEZO_OPT13B = Experiment()

PRESETS: Dict[str, Experiment] = {
    # ``default`` is what every CLI command starts from when no --preset
    # is given; train and evaluate therefore agree on every shared field.
    "default": _LEZO_OPT13B,
    "lezo-opt13b": _LEZO_OPT13B,
    "mezo-opt13b": dataclasses.replace(
        _LEZO_OPT13B, optimizer=dataclasses.replace(
            _LEZO_OPT13B.optimizer, sparsity=0.0)),
    "fo-opt13b": dataclasses.replace(
        _LEZO_OPT13B, optimizer=dataclasses.replace(
            _LEZO_OPT13B.optimizer, mode="fo")),
    # fused virtual-perturbation runtime (DESIGN.md §10); virtual_ref is
    # the pure-JAX oracle so the preset runs on the CPU container too
    "lezo-opt13b-virtual": dataclasses.replace(
        _LEZO_OPT13B, runtime=dataclasses.replace(
            _LEZO_OPT13B.runtime, forward_backend="virtual_ref")),
    # FZOO-style batched multi-query estimator (DESIGN.md §6)
    "fzoo-opt13b-q16": dataclasses.replace(
        _LEZO_OPT13B, estimator=Estimator(name="one_sided", q=16)),
    "lezo-opt13b-lora": dataclasses.replace(
        _LEZO_OPT13B,
        optimizer=dataclasses.replace(_LEZO_OPT13B.optimizer,
                                      lr=3e-3, eps=1e-2),
        runtime=dataclasses.replace(_LEZO_OPT13B.runtime, peft="lora")),
    # CI bench-smoke: the benchmark-sized OPT variant at the sweep's
    # perturb-heavy params/token ratio (benchmarks/estimator_sweep.py)
    "bench-smoke": Experiment(
        model=Model(arch="opt-13b", variant="bench", seq_len=32),
        optimizer=Optimizer(lr=1e-4),
        # dense axpy backend: the benchmark suite's historical baseline
        runtime=Runtime(backend="dense"),
        run=Run(steps=120, batch_size=8, eval_every=0, log_every=0)),
    # fast-tier fixture: the 4L/128d CPU model, a handful of steps
    "tiny-smoke": Experiment(
        model=Model(arch="opt-13b", variant="tiny", seq_len=32),
        run=Run(steps=8, batch_size=8, eval_every=0, log_every=1)),
    # CI swarm-smoke: 2 local workers on the tiny model, enough steps
    # to cross a checkpoint so crash/rejoin is exercised (DESIGN.md §14)
    "swarm-smoke": Experiment(
        model=Model(arch="opt-13b", variant="tiny", seq_len=32),
        swarm=Swarm(workers=2),
        run=Run(steps=12, batch_size=8, eval_every=0, log_every=1)),
}


def names():
    return sorted(PRESETS)


def get(name: str) -> Experiment:
    if name not in PRESETS:
        raise SpecError("<preset>", f"unknown preset {name!r}; "
                                    f"known: {names()}")
    return PRESETS[name]
