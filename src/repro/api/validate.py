"""The one build-time validation site for experiment specs.

Every invariant that used to live deep in ``Trainer.__init__``,
``core/zo.py`` and ``estimators.build_estimator`` is checked here,
against the spec, before any parameter is allocated — with the offending
field path in every message.  The deep checks remain as defensive
assertions for legacy (non-spec) constructions, but a spec-built run can
only fail here.

Import-light on purpose: no jax at module scope, so the CLI can validate
specs before the dry-run path pins XLA host-device flags.

Part of the unified experiment-spec surface (DESIGN.md §11).
"""
from typing import List, Optional

from repro import configs
from repro import tasks as tasks_mod
from repro.api.spec import Experiment, SpecError, UnknownTaskError

MODES = ("zo", "zo_momentum", "fo")
POLICIES = ("stratified", "uniform")
BACKENDS = ("dense", "scan", "gather", "pallas")
FO_OPTIMIZERS = ("sgd", "momentum", "adamw")
PEFTS = (None, "lora", "prefix")
MESHES = ("single", "multi_pod")
SCHEDULES = ("constant",)


def _require(cond: bool, path: str, message: str):
    if not cond:
        raise SpecError(path, message)


def resolve_model(spec: Experiment):
    """``configs.get`` with spec-path errors instead of KeyError."""
    try:
        return configs.get(spec.model.arch, spec.model.variant)
    except KeyError:
        raise SpecError("model.arch",
                        f"unknown arch {spec.model.arch!r}; known: "
                        f"{configs.list_archs()}") from None
    except AttributeError:
        raise SpecError("model.variant",
                        f"config module for {spec.model.arch!r} has no "
                        f"variant {spec.model.variant!r}") from None


def virtual_block_errors(model_cfg) -> List[str]:
    """Block kinds the fused virtual forward cannot cover (DESIGN.md §10)."""
    return sorted({f"{b.kind}+{b.ffn}" for s in model_cfg.stages
                   for b in s.pattern if b.kind != "attn" or b.ffn == "moe"})


def validate(spec: Experiment):
    """Raise :class:`SpecError` on the first invalid field / combination;
    return the resolved ``ModelConfig`` on success."""
    # estimator cost tables are the name registry of record; the import is
    # deferred so validate stays jax-free until a spec actually needs it
    from repro.estimators import costs

    m, t, o, e, rt, sw, sv, tel, r = (spec.model, spec.task, spec.optimizer,
                                      spec.estimator, spec.runtime,
                                      spec.swarm, spec.serving,
                                      spec.telemetry, spec.run)
    mcfg = resolve_model(spec)

    _require(m.seq_len >= 2, "model.seq_len", f"must be >= 2, got {m.seq_len}")

    if t.name is not None and t.name not in tasks_mod.names():
        raise UnknownTaskError(
            "task.name", f"unknown task {t.name!r}; registered: "
                         f"{tasks_mod.names()}")
    _require(t.n_classes >= 2, "task.n_classes",
             f"must be >= 2, got {t.n_classes}")
    _require(0.0 < t.signal_rate <= 1.0, "task.signal_rate",
             f"must be in (0, 1], got {t.signal_rate}")

    _require(o.mode in MODES, "optimizer.mode",
             f"unknown mode {o.mode!r}; pick from {MODES}")
    _require(o.eps > 0, "optimizer.eps", f"must be > 0, got {o.eps}")
    _require(o.lr >= 0, "optimizer.lr", f"must be >= 0, got {o.lr}")
    _require(o.schedule in SCHEDULES, "optimizer.schedule",
             f"unknown schedule {o.schedule!r}; pick from {SCHEDULES}")
    _require(o.weight_decay >= 0, "optimizer.weight_decay",
             f"must be >= 0, got {o.weight_decay}")
    _require(0.0 <= o.sparsity < 1.0, "optimizer.sparsity",
             f"must be in [0, 1), got {o.sparsity} (rho=1 would drop every "
             "layer — the paper's Fig.3 collapse)")
    if o.n_drop is not None:
        _require(0 <= o.n_drop < mcfg.num_layers, "optimizer.n_drop",
                 f"must be in [0, {mcfg.num_layers}) for "
                 f"{mcfg.name} ({mcfg.num_layers} layers), got {o.n_drop}")
    _require(o.policy in POLICIES, "optimizer.policy",
             f"unknown policy {o.policy!r}; pick from {POLICIES}")
    _require(o.fo_optimizer in FO_OPTIMIZERS, "optimizer.fo_optimizer",
             f"unknown FO optimizer {o.fo_optimizer!r}; pick from "
             f"{FO_OPTIMIZERS}")
    if o.grad_clip is not None:
        _require(o.grad_clip > 0, "optimizer.grad_clip",
                 f"must be > 0 or none, got {o.grad_clip}")

    _require(e.name in costs.ESTIMATORS, "estimator.name",
             f"unknown estimator {e.name!r}; pick from {costs.ESTIMATORS}")
    _require(e.q >= 1, "estimator.q", f"must be >= 1, got {e.q}")
    _require(e.q_chunk >= 0, "estimator.q_chunk",
             f"must be >= 0 (0 = one widened forward), got {e.q_chunk}")
    _require(e.inner in costs.ESTIMATORS and e.inner != "importance",
             "estimator.inner",
             f"must be a non-importance estimator, got {e.inner!r}")
    _require(0.0 < e.importance_decay <= 1.0, "estimator.importance_decay",
             f"must be in (0, 1], got {e.importance_decay}")

    _require(rt.backend in BACKENDS, "runtime.backend",
             f"unknown kernel backend {rt.backend!r}; pick from {BACKENDS}")
    _require(rt.forward_backend in costs.FORWARD_BACKENDS,
             "runtime.forward_backend",
             f"unknown forward_backend {rt.forward_backend!r}; pick from "
             f"{costs.FORWARD_BACKENDS}")
    _require(rt.mesh in MESHES, "runtime.mesh",
             f"unknown mesh {rt.mesh!r}; pick from {MESHES}")
    _require(rt.peft in PEFTS, "runtime.peft",
             f"unknown peft {rt.peft!r}; pick from {PEFTS}")
    _require(rt.lora_rank >= 1, "runtime.lora_rank",
             f"must be >= 1, got {rt.lora_rank}")
    _require(rt.prefix_tokens >= 1, "runtime.prefix_tokens",
             f"must be >= 1, got {rt.prefix_tokens}")
    _require(rt.n_loss_shards >= 1, "runtime.n_loss_shards",
             f"must be >= 1, got {rt.n_loss_shards}")
    _require(0.0 < rt.quorum <= 1.0, "runtime.quorum",
             f"must be in (0, 1], got {rt.quorum}")

    # the hoisted cross-section invariants (formerly trainer.py / zo.py)
    if rt.backend == "gather":
        _require(o.policy == "stratified", "optimizer.policy",
                 "runtime.backend='gather' requires the stratified policy "
                 "(its compact active buffers need static per-group sizes)")
    if rt.forward_backend != "materialized":
        _require(rt.peft is None, "runtime.peft",
                 "forward_backend='virtual' covers full-parameter ZO only "
                 "(no PEFT merge)")
        _require(o.mode == "zo", "optimizer.mode",
                 "forward_backend='virtual' requires mode='zo'")
        bad = virtual_block_errors(mcfg)
        _require(not bad, "runtime.forward_backend",
                 "'virtual' covers attn + dense blocks; "
                 f"model.arch={m.arch!r} has {bad}")

    # serving engine node (DESIGN.md §12): the pool/bucket arithmetic
    # must close before any arena is allocated
    _require(sv.page_size >= 1, "serving.page_size",
             f"must be >= 1, got {sv.page_size}")
    _require(sv.n_pages >= 2, "serving.n_pages",
             f"must be >= 2 (page 0 is the reserved trash page), "
             f"got {sv.n_pages}")
    _require(sv.max_lanes >= 1, "serving.max_lanes",
             f"must be >= 1, got {sv.max_lanes}")
    _require(sv.prefill_chunk >= 1
             and sv.prefill_chunk % sv.page_size == 0,
             "serving.prefill_chunk",
             f"must be a positive multiple of serving.page_size="
             f"{sv.page_size}, got {sv.prefill_chunk}")
    _require(sv.max_seq >= sv.prefill_chunk
             and sv.max_seq % sv.page_size == 0,
             "serving.max_seq",
             f"must be a multiple of serving.page_size={sv.page_size} "
             f">= prefill_chunk={sv.prefill_chunk}, got {sv.max_seq}")
    _require(sv.max_new_tokens >= 1, "serving.max_new_tokens",
             f"must be >= 1, got {sv.max_new_tokens}")
    _require(sv.max_new_tokens < sv.max_seq, "serving.max_new_tokens",
             f"must leave room for a prompt inside serving.max_seq="
             f"{sv.max_seq}, got {sv.max_new_tokens}")
    # the pool must cover at least the smallest default-budget request
    # (1-token prompt padded to the chunk, plus the generation budget) —
    # otherwise every Engine.submit fails and the spec can serve nothing
    min_span = max(sv.prefill_chunk, 1 + sv.max_new_tokens)
    min_pages = -(-min_span // sv.page_size)
    _require(min_pages <= sv.n_pages - 1, "serving.n_pages",
             f"pool has {sv.n_pages - 1} usable pages (page 0 is trash) "
             f"but the smallest default-budget request needs {min_pages} "
             f"({min_span} slots at page_size={sv.page_size})")
    _require(sv.priorities >= 1, "serving.priorities",
             f"must be >= 1 priority classes, got {sv.priorities}")
    if sv.preempt:
        _require(sv.priorities >= 2, "serving.preempt",
                 "preemption needs at least two priority classes "
                 f"(serving.priorities={sv.priorities}) — equal-priority "
                 "requests never evict each other")
    _require(sv.temperature >= 0.0, "serving.temperature",
             f"must be >= 0 (0 = greedy), got {sv.temperature}")
    _require(sv.top_k >= 0, "serving.top_k",
             f"must be >= 0 (0 = full vocab), got {sv.top_k}")
    if sv.eos_id is not None:
        _require(0 <= sv.eos_id < mcfg.vocab, "serving.eos_id",
                 f"must be a {mcfg.name} vocab id in [0, {mcfg.vocab}), "
                 f"got {sv.eos_id}")

    # telemetry node (DESIGN.md §13): sinks only make sense on an
    # enabled tracer — a configured-but-dark sink is a silent data loss
    # bug waiting to be "discovered" after a week-long run
    _require(tel.ring >= 0, "telemetry.ring",
             f"must be >= 0 (0 = no ring buffer), got {tel.ring}")
    if not tel.enabled:
        for path, val in (("telemetry.fence", tel.fence),
                          ("telemetry.jsonl", tel.jsonl),
                          ("telemetry.prometheus", tel.prometheus),
                          ("telemetry.profile_dir", tel.profile_dir)):
            _require(not val, path,
                     "configured while telemetry.enabled=false — the "
                     "sink would silently record nothing; set "
                     "telemetry.enabled=true (or clear this field)")
    if tel.enabled:
        _require(tel.ring > 0 or bool(tel.jsonl), "telemetry.ring",
                 "telemetry.enabled=true needs at least one span sink: "
                 "a ring capacity > 0 or a telemetry.jsonl path")
    # the health run log is independent of the tracer (`enabled`), but
    # its sub-knobs make no sense without a run directory to write to
    if tel.runs_dir is None:
        for path, val in (("telemetry.run_id", tel.run_id),
                          ("telemetry.health_norms", tel.health_norms)):
            _require(not val, path,
                     "configured while telemetry.runs_dir is unset — no "
                     "run directory would be written; set "
                     "telemetry.runs_dir (or clear this field)")

    _require(r.steps >= 1, "run.steps", f"must be >= 1, got {r.steps}")
    _require(r.batch_size >= 1, "run.batch_size",
             f"must be >= 1, got {r.batch_size}")
    if rt.n_loss_shards > 1:
        _require(r.batch_size % rt.n_loss_shards == 0, "run.batch_size",
                 f"must divide into runtime.n_loss_shards="
                 f"{rt.n_loss_shards} loss shards, got {r.batch_size}")
    if r.eval_every is not None:
        _require(r.eval_every >= 0, "run.eval_every",
                 f"must be >= 0 (0 = no eval, none = auto), got "
                 f"{r.eval_every}")
    _require(r.log_every >= 0, "run.log_every",
             f"must be >= 0, got {r.log_every}")
    _require(r.ckpt_every >= 0, "run.ckpt_every",
             f"must be >= 0, got {r.ckpt_every}")
    if r.ckpt_every > 0:
        _require(r.ckpt_dir is not None, "run.ckpt_dir",
                 "required when run.ckpt_every > 0")
    _require(r.keep_ckpts >= 1, "run.keep_ckpts",
             f"must be >= 1, got {r.keep_ckpts}")

    # swarm node (DESIGN.md §14): the scalar-sync topology must close
    # before any process is spawned — a worker that dies on a bad spec
    # after attach is a much worse failure mode than a SpecError here
    from repro.swarm import chaos as chaos_mod  # stdlib-only, kept lazy

    _require(sw.workers >= 0, "swarm.workers",
             f"must be >= 0 (0 = swarm off), got {sw.workers}")
    _require(sw.n_shards >= 0, "swarm.n_shards",
             f"must be >= 0 (0 = auto: one shard per worker), "
             f"got {sw.n_shards}")
    _require(0.0 < sw.quorum <= 1.0, "swarm.quorum",
             f"must be in (0, 1], got {sw.quorum}")
    _require(sw.step_deadline_s > 0, "swarm.step_deadline_s",
             f"must be > 0, got {sw.step_deadline_s}")
    _require(0 <= sw.port <= 65535, "swarm.port",
             f"must be a TCP port in [0, 65535] (0 = ephemeral), "
             f"got {sw.port}")
    _require(0.0 <= sw.chaos_drop < 1.0, "swarm.chaos_drop",
             f"must be in [0, 1) — dropping every message forever "
             f"deadlocks the run, got {sw.chaos_drop}")
    _require(sw.chaos_delay_ms >= 0, "swarm.chaos_delay_ms",
             f"must be >= 0, got {sw.chaos_delay_ms}")
    try:
        chaos_mod.parse_crashes(sw.chaos_crash)
    except ValueError as ex:
        raise SpecError("swarm.chaos_crash", str(ex)) from None
    try:
        chaos_mod.parse_partitions(sw.chaos_partition)
    except ValueError as ex:
        raise SpecError("swarm.chaos_partition", str(ex)) from None

    if swarm_active(spec):
        shards = swarm_shards(spec)
        _require(o.mode == "zo", "optimizer.mode",
                 "the swarm StepCommit carries one projected-gradient "
                 "scalar — mode='zo' only (momentum/fo state cannot be "
                 "reconstructed from the (seed, g) log)")
        _require(e.name == "two_point", "estimator.name",
                 "swarm shard contributions are (l+, l-) pairs reduced "
                 "to a single g — estimator='two_point' only")
        _require(rt.n_loss_shards == 1, "runtime.n_loss_shards",
                 "the swarm shards the loss itself (swarm.n_shards); "
                 "disable the in-trainer quorum simulation")
        _require(r.batch_size % shards == 0, "run.batch_size",
                 f"must divide into the swarm's {shards} loss shards, "
                 f"got {r.batch_size}")
        _require(sw.workers <= shards, "swarm.workers",
                 f"more workers than loss shards would leave "
                 f"{sw.workers - shards} workers permanently idle; "
                 f"raise swarm.n_shards (= {shards}) or drop workers")
    return mcfg


def swarm_active(spec: Experiment) -> bool:
    """True when the spec selects the decomposed sharded step
    (``repro.swarm.shardstep``) — any workers, or explicit shards."""
    return spec.swarm.workers > 0 or spec.swarm.n_shards > 0


def swarm_shards(spec: Experiment) -> int:
    """Resolved loss-shard count: explicit ``swarm.n_shards`` wins, else
    one shard per worker.  Fixed by the spec — NOT by how many processes
    actually show up — so commits are worker-count-invariant."""
    sw = spec.swarm
    return sw.n_shards if sw.n_shards > 0 else max(sw.workers, 1)


def n_drop_for(spec: Experiment, num_layers: int) -> int:
    """The LeZO drop count the spec implies for an ``num_layers`` model:
    explicit ``optimizer.n_drop`` wins, else ``int(sparsity * L)``."""
    o = spec.optimizer
    if o.mode == "fo":
        return 0
    if o.n_drop is not None:
        return o.n_drop
    return int(o.sparsity * num_layers)
