"""Unified experiment API (DESIGN.md §11).

One serializable config tree, one validation site, one CLI surface::

    from repro import api

    spec = api.preset("lezo-opt13b")
    spec = api.with_overrides(spec, {"optimizer.lr": 1e-4,
                                     "estimator.name": "one_sided",
                                     "estimator.q": 16})
    api.validate(spec)              # every invariant, at build time
    result = api.run(spec)          # {"spec", "summary", "history"}

The spec round-trips through JSON byte-stably (``to_json`` /
``from_json``), is embedded in every checkpoint manifest and result
artifact, and drives the single generated-flag CLI::

    python -m repro.launch train --preset lezo-opt13b \
        --set optimizer.lr=1e-4 --set estimator.q=16

``spec`` / ``validate`` / ``presets`` are import-light (no jax); the
runners (``run`` / ``evaluate`` / ``dryrun`` / ``sweep`` / ``derive``)
load lazily since they pull the full training stack.
"""
from repro.api import presets, validate as _validate_mod
from repro.api.presets import PRESETS
from repro.api.spec import (Experiment, Estimator, Model, Optimizer, Run,
                            Runtime, Serving, SpecError, Swarm, Task,
                            Telemetry, UnknownTaskError, check_resume_spec,
                            coerce, field_of, field_paths, from_dict,
                            from_json, spec_diff, to_dict, to_json,
                            with_overrides)

validate = _validate_mod.validate

_LAZY = ("run", "evaluate", "dryrun", "dryrun_cell", "sweep", "derive",
         "preset", "Derived")

__all__ = ["Experiment", "Estimator", "Model", "Optimizer", "PRESETS",
           "Run", "Runtime", "Serving", "SpecError", "Swarm", "Task",
           "Telemetry", "UnknownTaskError",
           "check_resume_spec", "coerce", "field_of", "field_paths",
           "from_dict", "from_json", "presets", "spec_diff", "to_dict",
           "to_json", "validate", "with_overrides", *_LAZY]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        runners = importlib.import_module("repro.api.runners")
        return getattr(runners, name)
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
