"""The unified experiment spec: one frozen, JSON-round-trippable tree.

Every entrypoint (train, evaluate, dryrun, hillclimb, benchmarks, tests)
consumes an :class:`Experiment` instead of hand-wiring ``ZOConfig`` /
``EstimatorConfig`` / ``TrainConfig`` — those legacy dataclasses are now
*derived* views (see ``repro.api.derive``), so the optimizer recipe is
stated exactly once and a new scenario is a spec diff, not a plumbing PR
(DESIGN.md §11).

Sections:

  * ``model``     — registered architecture + variant + sequence shape
  * ``task``      — registry task name, or the synthetic stream's knobs
  * ``optimizer`` — the step recipe: mode, eps, lr, sparsity, policy
  * ``estimator`` — ZO gradient estimator and its direction budget
  * ``runtime``   — kernel/forward backends, mesh, quorum, PEFT
  * ``swarm``     — multi-process scalar-sync topology (DESIGN.md §14)
  * ``run``       — steps, batch, seed, eval cadence, checkpoint policy

Serialization is byte-stable: ``from_json(to_json(s))`` round-trips and
``to_json(from_json(txt)) == txt`` for any ``to_json``-produced text —
the golden-spec CI test pins this.
"""
import dataclasses
import json
import typing
from typing import Any, Dict, Optional, Tuple


class SpecError(ValueError):
    """A spec field is invalid.  ``path`` names the offending field
    (e.g. ``"optimizer.lr"``) and always appears in the message."""

    def __init__(self, path: str, message: str):
        self.path = path
        super().__init__(f"{path}: {message}")


class UnknownTaskError(SpecError, KeyError):
    """Unknown ``task.name``.  Also a KeyError so legacy callers that
    caught the registry's KeyError keep working."""

    def __str__(self):  # KeyError repr()s its args; keep the message
        return ValueError.__str__(self)


# --------------------------------------------------------------- sections
@dataclasses.dataclass(frozen=True)
class Model:
    arch: str = "opt-13b"         # registry id (repro.configs)
    variant: str = "smoke"        # config-module variant function
    seq_len: int = 64


@dataclasses.dataclass(frozen=True)
class Task:
    # registry task name (repro.tasks); None = legacy synthetic stream
    name: Optional[str] = None
    # synthetic-stream knobs (ignored for registry tasks)
    n_classes: int = 2
    signal_rate: float = 0.25


@dataclasses.dataclass(frozen=True)
class Optimizer:
    mode: str = "zo"              # zo | zo_momentum | fo
    eps: float = 1e-3
    lr: float = 1e-4
    schedule: str = "constant"
    weight_decay: float = 0.0
    # LeZO layer sparsity: fraction of layers dropped per step (0 = MeZO).
    # ``n_drop`` overrides the fraction with an explicit layer count.
    sparsity: float = 0.75
    n_drop: Optional[int] = None
    policy: str = "stratified"    # stratified | uniform
    fused_update: bool = True
    # FO baseline only
    fo_optimizer: str = "adamw"   # sgd | momentum | adamw
    grad_clip: Optional[float] = 1.0


@dataclasses.dataclass(frozen=True)
class Estimator:
    name: str = "two_point"       # two_point | one_sided | averaged | importance
    q: int = 1
    q_chunk: int = 0
    inner: str = "two_point"      # estimator the importance wrapper drives
    importance_decay: float = 0.99


@dataclasses.dataclass(frozen=True)
class Runtime:
    backend: str = "scan"         # axpy kernel: dense | scan | gather | pallas
    forward_backend: str = "materialized"   # | virtual | virtual_ref
    # stack the virtual ±εz pair (and one_sided's q-chunks) onto one
    # paired fused forward — bit-identical floats, half the W-tile loads
    paired_probes: bool = True
    interpret: bool = True        # axpy pallas interpret mode (CPU container)
    mesh: str = "single"          # single | multi_pod (dryrun/sharded lowering)
    n_loss_shards: int = 1
    quorum: float = 1.0
    peft: Optional[str] = None    # None | lora | prefix
    lora_rank: int = 8
    lora_alpha: int = 16
    lora_targets: Tuple[str, ...] = ("wq", "wv")
    prefix_tokens: int = 5


@dataclasses.dataclass(frozen=True)
class Swarm:
    """Seed-synchronized multi-process data-parallel ZO (DESIGN.md §14).

    ``workers > 0`` (or an explicit ``n_shards``) switches the step to
    the decomposed sharded execution path (``repro.swarm.shardstep``):
    the global batch splits into ``n_shards`` fixed loss shards, each
    shard's ±εz probe losses are evaluated independently, and the commit
    reduces them host-side in fixed shard order — so the committed step
    is bit-identical whether 1, 2 or 4 processes evaluated the shards.
    ``launch swarm`` runs the real coordinator + worker processes; a
    plain ``launch train`` on the same spec runs the identical sharded
    step in one process.  The ``chaos_*`` schedule deterministically
    injects transport faults for straggler / crash / partition testing.
    """
    workers: int = 0              # worker processes; 0 = swarm off
    n_shards: int = 0             # loss shards per step; 0 = auto (=workers)
    quorum: float = 1.0           # commit at >= round(quorum*n_shards) shards
    step_deadline_s: float = 5.0  # straggler deadline before quorum fallback
    host: str = "127.0.0.1"
    port: int = 0                 # coordinator TCP port; 0 = ephemeral
    chaos_seed: int = 0           # seeds the deterministic fault schedule
    chaos_drop: float = 0.0      # P(drop) per contribution/commit message
    chaos_delay_ms: float = 0.0  # injected delay upper bound per message
    chaos_crash: str = ""        # "worker:step[,...]" hard-exit points
    chaos_partition: str = ""    # "worker:start-end[,...]" drop-all windows


@dataclasses.dataclass(frozen=True)
class Serving:
    """Continuous-batching inference engine knobs (DESIGN.md §12).
    Pages are the cache allocation unit; buckets (``max_lanes`` decode
    lanes, ``prefill_chunk``-token prefill calls) fix every compiled
    shape, so the engine compiles exactly once per bucket."""
    page_size: int = 16           # cache slots per page
    n_pages: int = 64             # arena pages (page 0 = trash, reserved)
    max_lanes: int = 4            # decode batch bucket (concurrent requests)
    prefill_chunk: int = 32       # tokens per prefill call (page multiple)
    max_seq: int = 256            # per-request cap: prompt + generation
    max_new_tokens: int = 16      # default generation budget per request
    temperature: float = 0.0      # 0 = greedy
    top_k: int = 0                # 0 = full-vocab sampling
    eos_id: Optional[int] = None  # None = stop on max_new_tokens only
    # prefix-cache page sharing + preemptive scheduling (DESIGN.md §12)
    prefix_cache: bool = False    # share full-page prompt prefixes (COW)
    priorities: int = 1           # priority classes; FIFO within a class
    preempt: bool = False         # evict lower-priority decoding lanes


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """``repro.obs`` wiring (DESIGN.md §13): stage-level step tracing,
    serving metrics, and the optional jax profiler hook.  Disabled by
    default — the hot paths then pay the zero-allocation null tracer.
    Every field is resume-mutable: turning telemetry on (or moving a
    sink) is not a training-recipe change."""
    enabled: bool = False
    ring: int = 4096              # in-memory span ring capacity (0 = off)
    fence: bool = False           # block_until_ready at span exit (true
                                  # stage timings; serializes dispatch)
    jsonl: Optional[str] = None   # JSONL span/event log path
    prometheus: Optional[str] = None  # metrics text-dump path
    profile_dir: Optional[str] = None  # jax.profiler trace dir
    # --- optimizer-health run log (repro.obs.health / .runlog): write a
    # structured run directory <runs_dir>/<run_id>/ (spec + per-step
    # scalar JSONL + summary) that `launch report` renders and `launch
    # replay` re-executes bit-identically.  Independent of `enabled` —
    # the health stream needs no tracer.  None = no run log.
    runs_dir: Optional[str] = None
    run_id: Optional[str] = None  # None = auto (timestamp + seed)
    # exact per-step ‖lr·g·z‖ via tree_z_norm (regenerates every active
    # z at drain time — accurate but costs ~1 axpy-equivalent per
    # logged step; the free E‖z‖²=N estimate is always recorded)
    health_norms: bool = False


@dataclasses.dataclass(frozen=True)
class Run:
    steps: int = 300
    batch_size: int = 16
    seed: int = 0
    # None = auto (max(1, steps // 4)); 0 = no eval
    eval_every: Optional[int] = None
    log_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    keep_ckpts: int = 2


@dataclasses.dataclass(frozen=True)
class Experiment:
    model: Model = Model()
    task: Task = Task()
    optimizer: Optimizer = Optimizer()
    estimator: Estimator = Estimator()
    runtime: Runtime = Runtime()
    swarm: Swarm = Swarm()
    serving: Serving = Serving()
    telemetry: Telemetry = Telemetry()
    run: Run = Run()


SECTIONS: Dict[str, type] = {
    "model": Model, "task": Task, "optimizer": Optimizer,
    "estimator": Estimator, "runtime": Runtime, "swarm": Swarm,
    "serving": Serving, "telemetry": Telemetry, "run": Run,
}

# Fields a resumed run may legitimately change relative to the spec
# embedded in its checkpoint (extend the schedule, move the ckpt dir).
# Every serving.* field is mutable too: serving a checkpoint under a
# different engine shape is not a training-recipe change.  Likewise
# every telemetry.* field — observing a run differently never changes
# what the run computes (the obs no-interference rule, DESIGN.md §13).
RESUME_MUTABLE = frozenset({
    "run.steps", "run.eval_every", "run.log_every",
    "run.ckpt_dir", "run.ckpt_every", "run.keep_ckpts",
    # swarm topology/transport knobs a resumed run may move freely —
    # the committed bits depend only on (n_shards, quorum, workers when
    # n_shards is auto), which therefore stay recipe fields
    "swarm.step_deadline_s", "swarm.host", "swarm.port",
    "swarm.chaos_seed", "swarm.chaos_drop", "swarm.chaos_delay_ms",
    "swarm.chaos_crash", "swarm.chaos_partition",
}) | {f"serving.{f.name}" for f in dataclasses.fields(Serving)} \
  | {f"telemetry.{f.name}" for f in dataclasses.fields(Telemetry)}


# ------------------------------------------------------------ field access
def field_of(path: str) -> dataclasses.Field:
    """Resolve ``"section.field"`` to its dataclass field, or raise."""
    sec, _, name = path.partition(".")
    cls = SECTIONS.get(sec)
    if cls is None:
        raise SpecError(path, f"unknown spec section {sec!r}; "
                              f"sections: {sorted(SECTIONS)}")
    for f in dataclasses.fields(cls):
        if f.name == name:
            return f
    known = [f.name for f in dataclasses.fields(cls)]
    raise SpecError(path, f"unknown field in section {sec!r}; "
                          f"fields: {known}")


def field_paths() -> Tuple[str, ...]:
    """Every ``section.field`` path, in schema order."""
    return tuple(f"{sec}.{f.name}" for sec, cls in SECTIONS.items()
                 for f in dataclasses.fields(cls))


_TRUE, _FALSE = {"1", "true", "yes", "on"}, {"0", "false", "no", "off"}
_NONE = {"none", "null", ""}


def coerce(path: str, raw: Any) -> Any:
    """Coerce a raw (usually CLI string) value to the field's type.
    The one parsing site shared by ``--set``, generated flags, and
    ``with_overrides`` — so every surface agrees on spellings."""
    f = field_of(path)
    t = f.type
    origin = typing.get_origin(t)
    if origin is typing.Union:                   # Optional[inner]
        inner = [a for a in typing.get_args(t) if a is not type(None)][0]
        if raw is None or (isinstance(raw, str) and raw.lower() in _NONE):
            return None
        t, origin = inner, typing.get_origin(inner)
    if origin is tuple:                          # Tuple[str, ...]
        if isinstance(raw, str):
            return tuple(s.strip() for s in raw.split(",") if s.strip())
        return tuple(raw)
    if not isinstance(raw, str):
        return raw
    if t is bool:
        low = raw.lower()
        if low in _TRUE:
            return True
        if low in _FALSE:
            return False
        raise SpecError(path, f"expected a boolean, got {raw!r}")
    try:
        if t is int:
            return int(raw)
        if t is float:
            return float(raw)
    except ValueError:
        raise SpecError(path, f"expected {t.__name__}, got {raw!r}") from None
    return raw


def with_overrides(spec: Experiment, overrides: Dict[str, Any]) -> Experiment:
    """Return ``spec`` with dotted-path overrides applied
    (``{"optimizer.lr": "1e-4", "estimator.q": 16}``).  String values are
    coerced to the field type; unknown paths raise :class:`SpecError`."""
    by_sec: Dict[str, Dict[str, Any]] = {}
    for path, raw in overrides.items():
        sec, _, name = path.partition(".")
        by_sec.setdefault(sec, {})[name] = coerce(path, raw)
    return dataclasses.replace(spec, **{
        sec: dataclasses.replace(getattr(spec, sec), **kv)
        for sec, kv in by_sec.items()})


def get(spec: Experiment, path: str) -> Any:
    field_of(path)
    sec, _, name = path.partition(".")
    return getattr(getattr(spec, sec), name)


# ----------------------------------------------------------- serialization
def to_dict(spec: Experiment) -> Dict[str, Dict[str, Any]]:
    """Nested plain dict, field order preserved, tuples as lists."""
    out: Dict[str, Dict[str, Any]] = {}
    for sec, cls in SECTIONS.items():
        node = getattr(spec, sec)
        out[sec] = {f.name: (list(v) if isinstance(
            v := getattr(node, f.name), tuple) else v)
            for f in dataclasses.fields(cls)}
    return out


def from_dict(d: Dict[str, Any]) -> Experiment:
    """Inverse of :func:`to_dict`.  Missing sections/fields take their
    defaults; unknown keys raise :class:`SpecError` with the path."""
    if not isinstance(d, dict):
        raise SpecError("<root>", f"expected a dict, got {type(d).__name__}")
    sections = {}
    for sec, payload in d.items():
        cls = SECTIONS.get(sec)
        if cls is None:
            raise SpecError(sec, f"unknown spec section; "
                                 f"sections: {sorted(SECTIONS)}")
        if not isinstance(payload, dict):
            raise SpecError(sec, "expected a mapping of fields")
        kv = {}
        for name, val in payload.items():
            kv[name] = coerce(f"{sec}.{name}",
                              tuple(val) if isinstance(val, list) else val)
        sections[sec] = cls(**kv)
    return Experiment(**sections)


def to_json(spec: Experiment) -> str:
    return json.dumps(to_dict(spec), indent=1) + "\n"


def from_json(text: str) -> Experiment:
    return from_dict(json.loads(text))


# ------------------------------------------------------------------- diff
def spec_diff(a: Dict[str, Any], b: Dict[str, Any],
              ignore=RESUME_MUTABLE) -> Tuple[str, ...]:
    """Human-readable field-level differences between two spec dicts,
    as ``"path: <a> != <b>"`` lines.  Paths in ``ignore`` are skipped."""
    lines = []
    for path in field_paths():
        if path in ignore:
            continue
        sec, _, name = path.partition(".")
        default = getattr(SECTIONS[sec](), name)
        default = list(default) if isinstance(default, tuple) else default
        va = a.get(sec, {}).get(name, default)
        vb = b.get(sec, {}).get(name, default)
        if va != vb:
            lines.append(f"{path}: {va!r} != {vb!r}")
    return tuple(lines)


def check_resume_spec(saved: Dict[str, Any], spec: Experiment):
    """Fail loudly when a checkpoint's embedded spec disagrees with the
    resuming run's spec on anything beyond the RESUME_MUTABLE fields."""
    diff = spec_diff(saved, to_dict(spec))
    if diff:
        raise SpecError("<resume>", "checkpoint spec does not match the "
                        "resuming experiment spec:\n  " + "\n  ".join(diff))
