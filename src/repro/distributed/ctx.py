"""Mesh context for in-model sharding constraints.

Model code is mesh-agnostic; the launcher registers the active mesh here
and layers call :func:`constrain` on large intermediates where XLA's SPMD
propagation needs a nudge (the MoE dispatch buffers are the canonical
case: without a constraint the partitioner all-gathers the scatter
operand globally — 80 GiB per step on granite-moe).

``constrain`` is a no-op when no mesh is registered (CPU tests,
single-device training), and silently drops axes that don't divide, so
the same model code serves every cell of the grid.

Distributed topology context (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]):
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x, *axes):
    """axes: one of None | 'model' | 'batch' per dim of x."""
    if _MESH is None:
        return x
    mesh = _MESH
    spec = []
    for d, a in enumerate(axes):
        if a == "batch":
            ba = _batch_axes(mesh)
            n = 1
            for ax in ba:
                n *= mesh.shape[ax]
            spec.append(ba if (ba and x.shape[d] % n == 0 and x.shape[d] >= n)
                        else None)
        elif a == "model":
            n = mesh.shape.get("model", 1)
            spec.append("model" if (x.shape[d] % n == 0 and x.shape[d] >= n)
                        else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
