"""Sharding rules: parameter, activation and cache placement per arch.

Layout summary (Megatron-style TP on the ``model`` axis, batch on
``data`` and, multi-pod, ``pod``):

  * embeddings: vocab-sharded; LM head: vocab-sharded output.
  * attention/MLA: column-parallel QKV (heads on model), row-parallel
    output projection.
  * FFN / MoE experts: column-parallel up/gate, row-parallel down.
    Experts are TP-sharded on d_ff, NOT expert-sharded — router and
    dispatch stay device-local (see models.moe docstring).
  * mamba: column-parallel in_proj (d_inner on model), channel-sharded
    conv/ssm params, row-parallel out_proj.
  * mLSTM/sLSTM: replicated block weights.  The q/k/v maps contract the
    full d_inner (cross-head mixing), which TP cannot split without
    changing the math; at xlstm-350m scale replication costs <1 GiB per
    device.  Recorded as an accepted trade-off (DESIGN.md §4, roofline
    notes the replicated perturbation work).
  * KV caches: batch on data(+pod), *sequence* on model — always
    divisible (unlike kv_heads=8 on a 16-way axis) and it is what makes
    32k/500k caches fit; decode attention becomes flash-decode style
    (partial scores + small collectives), which XLA SPMD derives.

Every rule is divisibility-checked against the mesh: a dimension that
does not divide falls back to replication rather than failing, so the
same rule table serves every (arch x mesh) cell.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf name -> which dim (negative, from the end) is sharded on `model`
_COL = {"wq": -1, "wk": -1, "wv": -1, "wg": -1, "wu": -1, "wi": -1,
        "ws_g": -1, "ws_u": -1, "in_proj": -1, "dt_w": -1, "conv_w": -1,
        "conv_b": -1, "Dskip": -1, "wuk": -1, "wuv": -1, "we_g": -1,
        "we_u": -1, "dt_b": -1}
_ROW = {"wo": -2, "wd": -2, "ws_d": -2, "out_proj": -2, "x_proj": -2,
        "A_log": -2, "we_d": -2}
_REPL = {"norm", "scale", "bias", "router", "wdkv", "kv_norm", "q_norm",
         "k_norm", "b_i", "b_f", "b", "rh", "out_norm", "A", "B"}


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(shape, dim, mesh, axis="model"):
    n = mesh.shape[axis]
    d = dim if dim >= 0 else len(shape) + dim
    return 0 <= d < len(shape) and shape[d] % n == 0 and shape[d] >= n


def _block_kind(cfg, path_parts):
    si = int(path_parts[1][1:])
    bj = int(path_parts[2][1:])
    return cfg.stages[si].pattern[bj].kind


def param_pspec(cfg, path: str, shape, mesh: Mesh) -> P:
    parts = path.split("/")
    name = parts[-1]
    nd = len(shape)
    repl = P(*([None] * nd))
    if parts[0] == "embed":
        if name == "tok" and _div(shape, 0, mesh):
            return P("model", *([None] * (nd - 1)))
        return repl
    if parts[0] == "head":
        if _div(shape, -1, mesh):
            return P(*([None] * (nd - 1)), "model")
        return repl
    if parts[0] != "stages":
        return repl
    kind = _block_kind(cfg, parts)
    if kind in ("mlstm", "slstm") and parts[3] == "mix":
        return repl                       # replicated recurrent blocks
    if name in ("pk", "pv"):              # prefix KV: heads dim is -2
        if _div(shape, -2, mesh):
            return P(*([None] * (nd - 2)), "model", None)
        return repl
    if name in _COL and _div(shape, _COL[name], mesh):
        d = nd + _COL[name]
        return P(*[("model" if i == d else None) for i in range(nd)])
    if name in _ROW and _div(shape, _ROW[name], mesh):
        d = nd + _ROW[name]
        return P(*[("model" if i == d else None) for i in range(nd)])
    return repl


def cache_pspec(path: str, shape, mesh: Mesh) -> P:
    """Decode/prefill cache leaves: (R, B, ...) — see module docstring."""
    name = path.split("/")[-1]
    nd = len(shape)
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    b_ax = ba if (shape[1] % nb == 0 and shape[1] >= nb) else None
    spec = [None, b_ax] + [None] * (nd - 2)
    if name in ("k", "v", "ckv", "kr") and _div(shape, 2, mesh):
        spec[2] = "model"                       # sequence dim
    elif name == "conv" and _div(shape, 3, mesh):
        spec[3] = "model"                       # channels
    elif name == "ssm" and _div(shape, 2, mesh):
        spec[2] = "model"                       # d_inner
    elif name in ("C", "n", "c", "h", "m") and nd >= 3 and _div(shape, -1, mesh):
        spec[-1] = "model"                      # head dim of lstm states
    return P(*spec)


def arena_pspec(path: str, shape, mesh: Mesh) -> P:
    """Serving KV-arena leaves (R, n_pages, page_size, KV, dh): shard the
    *page* dim on ``model`` — the flash-decode analog of the sequence
    rule above (pages are position-order sequence slabs), and page counts
    are operator-chosen so divisibility is the common case.  No batch
    axis: the arena is one shared slab every lane's page table indexes
    into (DESIGN.md §12).  Falls back to replication like every rule
    here."""
    name = path.split("/")[-1]
    nd = len(shape)
    spec = [None] * nd
    if name in ("k", "v") and _div(shape, 1, mesh):
        spec[1] = "model"
    return P(*spec)


def arena_sharding(arena_shapes, mesh: Mesh):
    return _tree_map_with_path(
        lambda ps, leaf: NamedSharding(mesh, arena_pspec(ps, leaf.shape, mesh)),
        arena_shapes)


def data_pspec(shape, mesh: Mesh) -> P:
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    lead = ba if (shape[0] % nb == 0 and shape[0] >= nb) else None
    return P(lead, *([None] * (len(shape) - 1)))


def _tree_map_with_path(fn, tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)
        out.append(fn(ps, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def params_sharding(cfg, params_shapes, mesh: Mesh):
    return _tree_map_with_path(
        lambda ps, leaf: NamedSharding(mesh, param_pspec(cfg, ps, leaf.shape,
                                                         mesh)),
        params_shapes)


def cache_sharding(cache_shapes, mesh: Mesh):
    return _tree_map_with_path(
        lambda ps, leaf: NamedSharding(mesh, cache_pspec(ps, leaf.shape, mesh)),
        cache_shapes)


def batch_sharding(batch_shapes, mesh: Mesh):
    return _tree_map_with_path(
        lambda ps, leaf: NamedSharding(mesh, data_pspec(leaf.shape, mesh)),
        batch_shapes)
