"""Prefix tuning composed with ZO (paper Table 4: MeZO/LeZO (prefix)).

Trainable state: ``n_prefix`` learned key/value pairs per attention
layer (stacked over layers).  They are *injected* into the base params as
``pk``/``pv`` leaves, which ``layers.attn_fwd`` prepends as always-visible
positions.  ZO perturbs only the prefix tree; LeZO's layer groups apply
via the same stage/block paths.

PEFT trainable subtrees (DESIGN.md §1 subsystem map).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class PrefixConfig:
    n_prefix: int = 5
    init_std: float = 0.02


def init_prefix(cfg: ModelConfig, key, pcfg: PrefixConfig = PrefixConfig()
                ) -> Dict[str, Any]:
    """One (pk, pv) pair per attention block position, stacked over repeat."""
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    P = pcfg.n_prefix
    out = {}
    for si, st in enumerate(cfg.stages):
        for bj, b in enumerate(st.pattern):
            if b.kind != "attn":
                continue
            key, k1, k2 = jax.random.split(key, 3)
            base = f"stages/s{si}/b{bj}/mix"
            out[f"{base}/pk"] = jax.random.normal(
                k1, (st.repeat, P, KV, dh), jnp.dtype(cfg.dtype)) * 0.02
            out[f"{base}/pv"] = jax.random.normal(
                k2, (st.repeat, P, KV, dh), jnp.dtype(cfg.dtype)) * 0.02
    if not out:
        raise ValueError("model has no attention blocks for prefix tuning")
    return out


def inject(params, prefix: Dict[str, Any]):
    """Return params with pk/pv leaves grafted into the matching blocks."""
    params = jax.tree.map(lambda x: x, params)  # shallow-ish copy
    for path, leaf in prefix.items():
        parts = path.split("/")
        node = params
        for p in parts[:-1]:
            node = node.setdefault(p, {}) if isinstance(node, dict) else node
        node[parts[-1]] = leaf
    return params


def prefix_group_fn(path: str):
    if path.startswith("stages/"):
        parts = path.split("/")
        return f"{parts[1]}.{parts[2]}"
    return None
