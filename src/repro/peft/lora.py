"""LoRA composed with ZO (paper Table 4: MeZO/LeZO (LoRA)).

Trainable state is *only* the LoRA tree (A, B per target projection,
stacked over layers exactly like the base weights), so the ZO machinery —
including LeZO's layer groups — applies unchanged: ``zo.build_spec`` on
the LoRA tree with the same group_fn.

``merge`` produces effective weights W + (alpha/r) * A @ B.  For ZO this
costs one small matmul per target per pass; no optimizer state exists
either way (ZO stores nothing), so LoRA's benefit under ZO is *fewer
perturbed dimensions* (lower SPSA variance), not memory.

PEFT trainable subtrees (DESIGN.md §1 subsystem map).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 8
    alpha: int = 16
    targets: Tuple[str, ...] = ("wq", "wv")   # leaf names inside block mix


def _is_target(path_str: str, targets) -> bool:
    leafname = path_str.rsplit("/", 1)[-1]
    return path_str.startswith("stages/") and leafname in targets


def init_lora(params, cfg: LoRAConfig, key) -> Dict[str, Any]:
    """Build the LoRA tree mirroring targeted leaves of ``params``."""
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if not _is_target(ps, cfg.targets) or leaf.ndim != 3:
            continue  # (R, din, dout) stacked projections only
        R, din, dout = leaf.shape
        key, k1 = jax.random.split(key)
        out[ps] = {
            "A": jax.random.normal(k1, (R, din, cfg.rank), leaf.dtype) * din ** -0.5,
            "B": jnp.zeros((R, cfg.rank, dout), leaf.dtype),
        }
    if not out:
        raise ValueError(f"no LoRA targets matched {cfg.targets}")
    return out


def merge(params, lora: Dict[str, Any], cfg: LoRAConfig):
    """Return params with W <- W + (alpha/rank) * A @ B for each target."""
    scale = cfg.alpha / cfg.rank
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if ps in lora:
            ab = jnp.einsum("rik,rkj->rij", lora[ps]["A"], lora[ps]["B"])
            leaf = leaf + (scale * ab).astype(leaf.dtype)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def lora_group_fn(path: str):
    """ZO layer-group labels for the LoRA tree: the dict key IS the base
    path, so reuse its stage/block prefix ('stages/s0/b0/...')."""
    if path.startswith("stages/"):
        parts = path.split("/")
        return f"{parts[1]}.{parts[2]}"
    return None
