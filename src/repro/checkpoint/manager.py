"""Fault-tolerant checkpointing for ZO training.

ZO optimizer state is (params, step, base_seed) — no moments — so a
checkpoint is the parameter tree plus a tiny manifest.  Design points for
1000+ node runs (see DESIGN.md §7):

  * atomic: write to ``<dir>/tmp.<step>`` then ``os.rename`` — a crash
    mid-write never corrupts the latest checkpoint;
  * sharded: each host saves only the leaves (or leaf shards) it owns via
    ``shard_filter``; the manifest records the tree structure so restore
    validates shapes before touching device memory;
  * async: ``save(..., blocking=False)`` hands the host-side write to a
    daemon thread — the train loop continues (the arrays are already
    fetched, so there is no race with donation);
  * keep-k GC, newest-first ``latest()`` resolution, and deterministic
    *replay*: because every LeZO update derives from (base_seed, step), a
    restore reproduces the exact update stream that would have followed.
  * elastic: ``remesh`` re-places a restored tree onto any new mesh —
    legal at any step boundary because ZO state is mesh-free.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np


def _flatten(params) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[ps] = np.asarray(leaf)
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, params, base_seed: int, extra: Optional[dict] = None,
             blocking: bool = True,
             shard_filter: Optional[Callable[[str], bool]] = None):
        flat = _flatten(params)
        if shard_filter is not None:
            flat = {k: v for k, v in flat.items() if shard_filter(k)}
        manifest = {
            "step": int(step),
            "base_seed": int(base_seed),
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }

        def _write():
            tmp = os.path.join(self.dir, f"tmp.{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self.wait()
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        ckpts = self.all_steps()
        for s in ckpts[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The manifest alone — lets a resume validate the embedded
        experiment spec (``extra["spec"]``, see DESIGN.md §11) before
        any array bytes are read."""
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)

    def restore(self, template, step: Optional[int] = None):
        """Restore into the structure of ``template`` (validates shapes).

        Returns (params, step, base_seed, extra)."""
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat:
            ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path)
            if ps not in data:
                raise KeyError(f"checkpoint {d} missing leaf {ps}")
            arr = data[ps]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {ps}: ckpt {arr.shape} vs {leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
        params = jax.tree_util.tree_unflatten(treedef, leaves)
        return (params, manifest["step"], manifest["base_seed"],
                manifest["extra"])


def remesh(params, mesh, shardings):
    """Re-place a (restored) tree onto a new mesh — elastic rescale.

    ``shardings`` is a pytree of NamedSharding matching ``params``; works
    for grown/shrunk meshes since host arrays carry no placement."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), params, shardings)
