"""Synthetic fine-tuning streams (SuperGLUE stand-ins, see DESIGN.md §8).

This module defines the repo's canonical batch format — ``{tokens,
labels, loss_mask, class_labels}`` — which the SuperGLUE-style task
registry (``repro/tasks/``, DESIGN.md §9) also compiles down to; prefer
``--task <name>`` registry tasks for anything metric-bearing, and these
streams for raw convergence/throughput work.

Offline container => no SST-2/BoolQ/SQuAD.  These tasks exercise the same
code paths and difficulty *structure*:

  * classification  — SST-2/BoolQ-like: a prompt whose token statistics
    carry a class signal, followed by a query position; the model must
    emit the class verbalizer token.  Loss masked to the answer position
    (the MeZO prompt-based fine-tuning setup).
  * multiple_choice — Copa-like: the signal selects among k verbalizers.
  * generation      — SQuAD-like copy task: the answer is a span that
    occurred earlier in the prompt; loss over the answer tokens.

Difficulty is controlled by signal density; all generators are
numpy-seeded and deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskConfig:
    name: str = "classification"
    kind: str = "classification"   # classification | multiple_choice | generation
    vocab: int = 512
    seq_len: int = 64
    n_classes: int = 2
    signal_rate: float = 0.25      # fraction of context positions carrying signal
    answer_len: int = 8            # generation only
    seed: int = 0

    @property
    def verbalizers(self) -> np.ndarray:
        # reserve the top token ids as class verbalizers / query marker
        return np.arange(self.vocab - 1 - self.n_classes, self.vocab - 1)

    @property
    def query_token(self) -> int:
        return self.vocab - 1


def make_dataset(task: TaskConfig, n: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(task.seed)
    V, S = task.vocab, task.seq_len
    base_vocab = V - 1 - task.n_classes          # ids usable as filler
    tokens = rng.integers(0, base_vocab // 2, size=(n, S))
    labels_cls = rng.integers(0, task.n_classes, size=(n,))
    loss_mask = np.zeros((n, S - 1), np.float32)

    if task.kind in ("classification", "multiple_choice"):
        # class-conditional signal tokens scattered through the context
        for c in range(task.n_classes):
            rows = labels_cls == c
            sig = rng.random((rows.sum(), S)) < task.signal_rate
            sig_tokens = base_vocab // 2 + c * (base_vocab // (2 * task.n_classes)) \
                + rng.integers(0, base_vocab // (2 * task.n_classes),
                               size=(rows.sum(), S))
            tokens[rows] = np.where(sig, sig_tokens, tokens[rows])
        tokens[:, -2] = task.query_token
        tokens[:, -1] = task.verbalizers[labels_cls]
        # labels[t] = tokens[t+1]: the verbalizer (position S-1) is
        # predicted at label index S-2 — the last one.
        loss_mask[:, -1] = 1.0
    elif task.kind == "generation":
        A = task.answer_len
        span_start = rng.integers(4, S - 3 * A, size=(n,))
        for i in range(n):
            span = tokens[i, span_start[i]:span_start[i] + A]
            tokens[i, -A - 1] = task.query_token
            tokens[i, -A:] = span
        loss_mask[:, -A:] = 1.0                    # predict the copied span
    else:
        raise ValueError(task.kind)

    inputs = tokens[:, :-1].astype(np.int32)
    labels = tokens[:, 1:].astype(np.int32)
    return {"tokens": inputs, "labels": labels, "loss_mask": loss_mask,
            "class_labels": labels_cls.astype(np.int32)}


def batches(dataset: Dict[str, np.ndarray], batch_size: int, steps: int,
            seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite shuffled batch stream (with-replacement epochs)."""
    n = dataset["tokens"].shape[0]
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, n, size=(batch_size,))
        yield {k: v[idx] for k, v in dataset.items()}


def classification_accuracy(cfg_model, params, dataset, task: TaskConfig,
                            lm_module, max_examples: int = 256) -> float:
    """Argmax-over-verbalizers accuracy at the answer position."""
    import jax.numpy as jnp
    n = min(max_examples, dataset["tokens"].shape[0])
    toks = jnp.asarray(dataset["tokens"][:n])
    hidden, _, _ = lm_module.forward(cfg_model, params, toks, mode="train")
    logits = lm_module.logits_fn(cfg_model, params, hidden[:, -1])  # answer pos
    verb = jnp.asarray(task.verbalizers)
    pred = jnp.argmax(logits[:, verb], axis=-1)
    return float(jnp.mean(pred == jnp.asarray(dataset["class_labels"][:n])))
