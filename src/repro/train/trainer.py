"""Training driver: ZO (MeZO/LeZO) and FO (the paper's FT baseline).

Handles: jit + buffer donation, eval/validation cadence, best-checkpoint
selection on validation loss (the paper's protocol), resume-from-latest,
and the loss-quorum straggler simulation (DESIGN.md §7): the global batch
is split into ``n_loss_shards`` (stand-ins for data-parallel replica
groups) and each SPSA forward averages only the shards that "arrived" —
a deterministic per-step subset when ``quorum < 1``.  SPSA only needs *a*
mini-batch loss, so stragglers cost variance, not correctness; the test
suite checks convergence still holds at quorum=0.75.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import estimators
from repro import obs as obs_mod
from repro import tasks as tasks_mod
from repro.core import fo, rng, zo, zo_adaptive
from repro.data import synthetic
from repro.models import frontends, lm
from repro.peft import lora as lora_mod
from repro.peft import prefix as prefix_mod
from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 500
    batch_size: int = 16
    eval_every: int = 100
    log_every: int = 50
    seed: int = 0
    mode: str = "zo"              # zo | zo_momentum | fo
    # gradient estimator for mode="zo" (see repro.estimators):
    # two_point | one_sided | averaged | importance
    estimator: str = "two_point"
    est_q: int = 1                # directions/step for one_sided & averaged
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    keep_ckpts: int = 2
    # straggler simulation
    n_loss_shards: int = 1
    quorum: float = 1.0
    # peft
    peft: Optional[str] = None    # None | lora | prefix
    # materialized | virtual | virtual_ref — virtual runs probe forwards
    # against in-kernel-regenerated perturbed weights (repro.fused):
    # a ZO step writes parameters exactly once (the update axpy)
    forward_backend: str = "materialized"


class Trainer:
    """``task`` is either a legacy ``synthetic.TaskConfig`` or a registry
    ``tasks.CompiledTask``.  Registry tasks switch validation to the
    task's metric protocol and best-checkpoint selection to highest
    metric (the SuperGLUE protocol); synthetic tasks keep lowest
    validation loss, the paper's protocol.

    Preferred construction is :meth:`from_spec` on a ``repro.api``
    :class:`Experiment` — the legacy direct construction keeps working
    bit-identically but soft-warns (DESIGN.md §11).
    """

    @classmethod
    def from_spec(cls, spec) -> "Trainer":
        """Build from a validated ``repro.api.Experiment``.  The derived
        legacy configs are exactly what the old hand-wired construction
        produced, so the step stream is bit-identical; the spec rides
        along into every checkpoint manifest this trainer writes."""
        from repro import api
        d = api.derive(spec)
        return cls(d.model_cfg, d.task, d.tcfg, zo_cfg=d.zo_cfg,
                   fo_cfg=d.fo_cfg, lora_cfg=d.lora_cfg,
                   prefix_cfg=d.prefix_cfg, est_cfg=d.est_cfg,
                   _spec=spec, _derived=d)

    def __init__(self, model_cfg, task,
                 tcfg: TrainConfig,
                 zo_cfg: zo.ZOConfig = zo.ZOConfig(),
                 fo_cfg: fo.FOConfig = fo.FOConfig(),
                 lora_cfg: lora_mod.LoRAConfig = lora_mod.LoRAConfig(),
                 prefix_cfg: prefix_mod.PrefixConfig = prefix_mod.PrefixConfig(),
                 est_cfg: Optional[estimators.EstimatorConfig] = None,
                 _spec=None, _derived=None):
        if _spec is None:
            warnings.warn(
                "legacy Trainer(model_cfg, task, tcfg, ...) construction; "
                "prefer Trainer.from_spec(repro.api.Experiment(...)) — the "
                "spec validates every config combination at build time and "
                "rides along into checkpoints (DESIGN.md §11)",
                DeprecationWarning, stacklevel=2)
        self.experiment = _spec
        self.derived = _derived
        # optimizer-health run log (DESIGN.md §13): telemetry.runs_dir
        # makes every train() write <runs_dir>/<run_id>/ (spec + per-step
        # scalar stream + summary) — the substrate of `launch report` and
        # the bit-identity verifier `launch replay`
        tel = getattr(_spec, "telemetry", None)
        self.runlog = None
        self.health = None
        self.run_id = None
        if tel is not None and tel.runs_dir:
            from repro import api
            self.run_id = tel.run_id or obs_mod.make_run_id(
                tel.runs_dir, seed=tcfg.seed)
            self.runlog = obs_mod.RunLog(tel.runs_dir, self.run_id,
                                         spec=api.to_dict(_spec))
            if tel.enabled and not tel.jsonl:
                # no explicit span sink: the PR 6 stage trace joins the
                # run dir, so `launch report` can merge stage timings
                tel = dataclasses.replace(tel, jsonl=self.runlog.trace_path)
        # telemetry: NULL_SESSION unless the spec's telemetry node asked
        # for it — drivers hold a Session unconditionally (DESIGN.md §13)
        self.obs = obs_mod.session(tel)
        self.mcfg, self.task, self.tcfg = model_cfg, task, tcfg
        if tcfg.forward_backend != "materialized":
            zo_cfg = dataclasses.replace(zo_cfg,
                                         forward_backend=tcfg.forward_backend)
        self.zo_cfg, self.fo_cfg = zo_cfg, fo_cfg
        self.registry_task = (task if isinstance(task, tasks_mod.CompiledTask)
                              else None)
        # explicit est_cfg wins; else lift zo_cfg + TrainConfig plumbing
        self.est_cfg = est_cfg or estimators.from_zo(
            zo_cfg, name=tcfg.estimator, q=tcfg.est_q)
        if self.est_cfg.forward_backend != "materialized":
            from repro.api.validate import virtual_block_errors
            if tcfg.peft:
                raise ValueError("forward_backend='virtual' covers "
                                 "full-parameter ZO only (no PEFT merge)")
            if tcfg.mode != "zo":
                raise ValueError("forward_backend='virtual' requires "
                                 "mode='zo'")
            bad = virtual_block_errors(model_cfg)
            if bad:
                raise ValueError(
                    "forward_backend='virtual' covers attn + dense blocks; "
                    f"model has {bad}")
        key = jax.random.PRNGKey(tcfg.seed)
        self.base_params = lm.init_params(model_cfg, key)

        # trainable tree + loss over it
        if tcfg.peft == "lora":
            self.trainable = lora_mod.init_lora(self.base_params, lora_cfg,
                                                jax.random.fold_in(key, 1))
            group_fn = lora_mod.lora_group_fn
            self._to_model = lambda tr: lora_mod.merge(self.base_params, tr,
                                                       lora_cfg)
        elif tcfg.peft == "prefix":
            self.trainable = prefix_mod.init_prefix(model_cfg,
                                                    jax.random.fold_in(key, 2),
                                                    prefix_cfg)
            group_fn = prefix_mod.prefix_group_fn
            self._to_model = lambda tr: prefix_mod.inject(self.base_params, tr)
        else:
            self.trainable = self.base_params
            group_fn = lm.zo_group_fn
            self._to_model = lambda tr: tr

        self.spec = zo.build_spec(self.trainable, group_fn)
        self._build_loss()
        self._build_step()
        if self.runlog is not None:
            norm_fn = None
            if getattr(_spec.telemetry, "health_norms", False) \
                    and tcfg.mode == "zo" and self.spec.num_layers:
                norm_fn = self._make_norm_fn()
            self.health = obs_mod.HealthAccumulator(self.spec.num_layers,
                                                    norm_fn=norm_fn)
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
                     if tcfg.ckpt_dir else None)

    def _make_norm_fn(self):
        """Exact ‖z(seed)‖ on the recorded layer selection — evaluated at
        drain time (off the hot path), jitted once per mask dtype."""
        spec = self.spec
        shapes = zo.leaf_shapes(self.trainable)

        @jax.jit
        def znorm(seed, gmask):
            return zo.tree_z_norm(spec, shapes, seed, spec.split_mask(gmask))

        def norm_fn(seed, layer_sel):
            gmask = jnp.asarray(np.asarray(layer_sel) > 0)
            return float(znorm(jnp.uint32(seed), gmask))

        return norm_fn

    # ------------------------------------------------------------- loss
    def _build_loss(self):
        mcfg, tcfg = self.mcfg, self.tcfg

        def base_loss(trainable, batch, perturb=None):
            return lm.lm_loss(mcfg, self._to_model(trainable), batch,
                              perturb=perturb)

        if tcfg.n_loss_shards <= 1 or tcfg.quorum >= 1.0:
            self.loss_fn = base_loss
            return

        n_sh = tcfg.n_loss_shards
        n_ok = max(1, int(round(tcfg.quorum * n_sh)))

        def quorum_loss(trainable, batch, perturb=None):
            # deterministic straggler subset per batch content
            tag = jnp.sum(batch["labels"][:, -1]).astype(jnp.uint32)
            bits = rng.mix32(jnp.arange(n_sh, dtype=jnp.uint32) * jnp.uint32(
                0x9E3779B9) + rng.fold(tag, jnp.uint32(0xFA11)))
            arrived = jnp.argsort(bits) < n_ok          # n_ok shards arrive
            shards = jax.tree.map(
                lambda x: x.reshape(n_sh, x.shape[0] // n_sh, *x.shape[1:]),
                batch)
            losses = jax.vmap(
                lambda b: base_loss(trainable, b, perturb=perturb))(shards)
            w = arrived.astype(jnp.float32)
            return jnp.sum(losses * w) / jnp.sum(w)

        self.loss_fn = quorum_loss

    # ------------------------------------------------------------- step
    def _build_step(self):
        if self.experiment is not None:
            import importlib
            # repro.api binds the name "validate" to the function; the
            # module itself has to come from importlib
            api_validate = importlib.import_module("repro.api.validate")
            if api_validate.swarm_active(self.experiment):
                # swarm spec (DESIGN.md §14): run the decomposed sharded
                # step — the same probe/reduce/commit programs a swarm
                # worker runs, so a lone process and an N-worker swarm
                # commit bit-identical steps on this spec.  Stateless
                # (est_state == {}), so replay's ckpt fast-forward works.
                from repro.swarm import shardstep
                self._step = shardstep.from_trainer(
                    self, api_validate.swarm_shards(self.experiment))
                self.est_state = {}
                self.fo_state = None
                self._eval_loss = jax.jit(self.loss_fn)
                return
        if self.tcfg.mode == "zo":
            step, init = estimators.make_step(self.loss_fn, self.spec,
                                              self.est_cfg)
            self._step = jax.jit(step, donate_argnums=(0, 1))
            self.est_state = init()
            self.fo_state = None
        elif self.tcfg.mode == "zo_momentum":
            mcfg = zo_adaptive.ZOMomentumConfig(
                eps=self.zo_cfg.eps, lr=self.zo_cfg.lr,
                n_drop=self.zo_cfg.n_drop, backend=self.zo_cfg.backend)
            step, init = zo_adaptive.make_zo_momentum_step(
                self.loss_fn, self.spec, mcfg)
            self._mom_step = jax.jit(step, donate_argnums=(0, 1))
            self.mom_state = init()
            self._step = None
            self.fo_state = None
        else:
            step = fo.make_fo_step(self.loss_fn, self.fo_cfg)
            self._step = jax.jit(step, donate_argnums=(0, 1))
            self.fo_state = fo.init_state(self.trainable, self.fo_cfg)
        self._eval_loss = jax.jit(self.loss_fn)

    # ------------------------------------------------------------- data
    def make_dataset(self, n: int, seed_shift: int = 0):
        """Dataset in the synthetic batch format, from either task type."""
        if self.registry_task is not None:
            t = self.registry_task
            return t.make_dataset(n, seed=t.seed + seed_shift)
        return synthetic.make_dataset(
            dataclasses.replace(self.task, seed=self.task.seed + seed_shift)
            if seed_shift else self.task, n)

    @staticmethod
    def _model_batch(np_batch, n=None):
        """Strip eval-only keys; the loss/model sees only token arrays."""
        return {k: jnp.asarray(v if n is None else v[:n])
                for k, v in np_batch.items() if k in tasks_mod.MODEL_BATCH_KEYS}

    def _ckpt_extra(self) -> Optional[Dict[str, Any]]:
        """Spec-built trainers embed their spec in every manifest so a
        resume can verify it is replaying the same experiment."""
        if self.experiment is None:
            return None
        from repro import api
        extra = {"spec": api.to_dict(self.experiment)}
        if self.run_id is not None:
            extra["run_id"] = self.run_id
        return extra

    # ------------------------------------------------------------ train
    def train(self, train_data=None, val_data=None) -> Dict[str, Any]:
        tcfg = self.tcfg
        if train_data is None:
            train_data = self.make_dataset(4096)
        if val_data is None:
            val_data = self.make_dataset(512, seed_shift=1)
        base_seed = np.uint32(rng.fold_py(tcfg.seed, 0xC0FFEE))

        start = 0
        params = self.trainable
        if self.ckpt and self.ckpt.latest() is not None:
            if self.experiment is not None:
                from repro import api
                saved = self.ckpt.read_manifest().get(
                    "extra", {}).get("spec")
                if saved is not None:
                    # loud failure with a field diff when the checkpoint
                    # was written under a different experiment spec
                    api.check_resume_spec(saved, self.experiment)
            params, start, _, _ = self.ckpt.restore(params)
            params = jax.tree.map(jnp.asarray, params)
            # estimator state (O(scalars), e.g. importance EMA scores) is
            # not checkpointed: after resume it re-warms from init within
            # ~1/(1-decay) steps (DESIGN.md §7)

        history = {"step": [], "loss": [], "val_loss": [], "val_step": [],
                   "val_acc": [], "wall": [], "wall_compute": []}
        if self.registry_task is not None:
            history["metric_name"] = self.registry_task.metric
        # best-checkpoint score, maximized: task metric for registry tasks
        # (SuperGLUE protocol), -val_loss otherwise (the paper's protocol)
        best = (-np.inf, None, -1)
        tr = self.obs.tracer
        overhead = 0.0   # eval + checkpoint seconds, excluded from wall_compute
        t0 = time.perf_counter()
        # eval-only arrays (e.g. multiple-choice candidates) would be
        # fancy-indexed every step just to be dropped by _model_batch
        stream_data = {k: v for k, v in train_data.items()
                       if k in tasks_mod.MODEL_BATCH_KEYS}
        stream = synthetic.batches(stream_data, tcfg.batch_size, tcfg.steps,
                                   seed=tcfg.seed + 7)
        with self.obs.profile():
            for t, np_batch in enumerate(stream):
                if t < start:
                    continue
                batch = self._model_batch(np_batch)
                with tr.span(obs_mod.TRAIN_STEP) as sp:
                    if self.tcfg.mode == "zo":
                        params, self.est_state, metrics = self._step(
                            params, self.est_state, batch, jnp.int32(t),
                            base_seed)
                    elif self.tcfg.mode == "zo_momentum":
                        params, self.mom_state, metrics = self._mom_step(
                            params, self.mom_state, batch, jnp.int32(t),
                            base_seed)
                    else:
                        params, self.fo_state, metrics = self._step(
                            params, self.fo_state, batch, jnp.int32(t))
                    sp.fence(metrics["loss"])
                if tr.enabled and "active_layers" in metrics:
                    tr.gauge(obs_mod.GAUGE_ACTIVE,
                             int(metrics["active_layers"]))
                if self.health is not None:
                    # buffers device values only — no sync until drain
                    self.health.record(t, metrics,
                                       seed=rng.fold_py(int(base_seed), t))
                # the final step always logs, even off the log_every grid —
                # a truncated tail made short runs look like they never ran
                if tcfg.log_every and (t % tcfg.log_every == 0
                                       or t == tcfg.steps - 1):
                    now = time.perf_counter()
                    history["step"].append(t)
                    history["loss"].append(float(metrics["loss"]))
                    history["wall"].append(now - t0)
                    history["wall_compute"].append(now - t0 - overhead)
                    if self.runlog is not None:
                        # the float() above already synced this step; the
                        # batched device_get rides the same drain point
                        self.runlog.append(self.health.drain())
                if tcfg.eval_every and (t + 1) % tcfg.eval_every == 0:
                    te = time.perf_counter()
                    vl, va = self.evaluate(params, val_data)
                    history["val_step"].append(t + 1)
                    history["val_loss"].append(vl)
                    history["val_acc"].append(va)
                    score = va if self.registry_task is not None else -vl
                    if score > best[0]:
                        best = (score, jax.tree.map(np.asarray, params), t + 1)
                    overhead += time.perf_counter() - te
                if (self.ckpt and tcfg.ckpt_every
                        and (t + 1) % tcfg.ckpt_every == 0):
                    te = time.perf_counter()
                    self.ckpt.save(t + 1, params, int(base_seed),
                                   extra=self._ckpt_extra(), blocking=False)
                    overhead += time.perf_counter() - te
        if self.ckpt:
            self.ckpt.wait()
        history["final_params"] = params
        if best[1] is not None:
            history["best_params"] = best[1]
            history["best_step"] = best[2]
        if self.runlog is not None:
            self.runlog.append(self.health.drain())
            self.runlog.finalize(self.health.summary())
            history["run_id"] = self.run_id
            history["run_dir"] = self.runlog.dir
        self.obs.flush()
        return history

    def evaluate(self, params, val_data, max_examples=256):
        """Returns (val_loss, metric): the registry task's primary metric,
        or verbalizer accuracy for legacy synthetic tasks (-1 if n/a)."""
        n = min(max_examples, val_data["tokens"].shape[0])
        vl = float(self._eval_loss(params, self._model_batch(val_data, n)))
        if self.registry_task is not None:
            va = self.registry_task.evaluate(
                self.mcfg, self._to_model(params), val_data, lm,
                max_examples=n)
        elif self.task.kind in ("classification", "multiple_choice"):
            va = synthetic.classification_accuracy(
                self.mcfg, self._to_model(params), val_data, self.task, lm,
                max_examples=n)
        else:
            va = -1.0
        return vl, va
