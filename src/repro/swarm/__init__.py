"""repro.swarm — seed-synchronized multi-process ZO training
(DESIGN.md §14).

A MeZO/LeZO step is fully reproducible from ``(seed, projected-gradient
scalar)``: the perturbation z and the LeZO layer selection regenerate
from the counter RNG.  So a data-parallel swarm needs no gradient
all-reduce — each worker probes ±εz on its shard of the global batch
and ships two floats per shard; the coordinator reduces them in fixed
shard order and broadcasts ``(seed, g)`` back.  Per-step wire traffic
is a few hundred bytes regardless of model size, against ``4·|θ|``
for a first-order gradient exchange.

Modules:

* :mod:`~repro.swarm.proto`       — length-prefixed JSON wire protocol
* :mod:`~repro.swarm.commit`      — fixed-order host-side commit math
* :mod:`~repro.swarm.shardstep`   — the decomposed sharded ZO step both
  the swarm and the single-process trainer execute on swarm specs
* :mod:`~repro.swarm.coordinator` — shard assignment, quorum deadline,
  membership epochs, run-registry rows
* :mod:`~repro.swarm.worker`      — elastic worker (join mid-run by
  folding the committed ``(seed, g)`` log — no weight transfer)
* :mod:`~repro.swarm.chaos`       — deterministic delay/drop/crash/
  partition schedules for fault testing
* :mod:`~repro.swarm.driver`      — ``launch swarm`` process supervisor
"""
from repro.swarm.chaos import Chaos, ChaosConfig
from repro.swarm.commit import (commit_scalars, quorum_count, reduce_losses,
                                shard_losses_dict)
from repro.swarm.proto import Conn, StepCommit, StepContribution

__all__ = ["Chaos", "ChaosConfig", "Conn", "StepCommit", "StepContribution",
           "commit_scalars", "quorum_count", "reduce_losses",
           "shard_losses_dict"]
