"""Swarm process driver: spawn, supervise, respawn (DESIGN.md §14).

``run_swarm(spec)`` runs the coordinator in-process and launches
``swarm.workers`` local worker processes (``python -m repro.launch
swarm --attach host:port``).  A supervisor thread watches them: a
worker that dies mid-run — injected ``chaos_crash`` or otherwise — is
respawned (unless ``respawn=False``), and the replacement demonstrates
the elastic-join path: it attaches with nothing but the address,
rebuilds from the wire-shipped spec, and folds the committed
``(seed, g)`` log forward to the live step.

``attach`` mode is the worker half: connect to an existing coordinator
and serve until the run completes.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading
import time
from typing import Any, Dict, List, Optional

_MAX_RESPAWNS_PER_SLOT = 3


def _src_root() -> str:
    import repro
    # namespace package: __file__ is None, __path__ still points at src/repro
    pkg_dir = (os.path.dirname(repro.__file__) if repro.__file__
               else list(repro.__path__)[0])
    return os.path.dirname(os.path.abspath(pkg_dir))


def _worker_cmd(host: str, port: int) -> List[str]:
    return [sys.executable, "-m", "repro.launch", "swarm",
            "--attach", f"{host}:{port}"]


def _worker_env() -> Dict[str, str]:
    env = dict(os.environ)
    src = _src_root()
    prev = env.get("PYTHONPATH", "")
    if src not in prev.split(os.pathsep):
        env["PYTHONPATH"] = src + (os.pathsep + prev if prev else "")
    return env


def spawn_worker(host: str, port: int) -> subprocess.Popen:
    return subprocess.Popen(_worker_cmd(host, port), env=_worker_env())


def run_swarm(spec, *, respawn: bool = True,
              runs_root: Optional[str] = None) -> Dict[str, Any]:
    """Coordinator + ``spec.swarm.workers`` supervised local workers.

    Returns the coordinator's summary dict (run_id, epochs, straggler
    steps, wire bytes/step, worker exit codes).
    """
    from repro.swarm.coordinator import Coordinator

    if spec.swarm.workers < 1:
        raise ValueError("run_swarm needs swarm.workers >= 1 "
                         "(use --attach to join an existing swarm)")
    coord = Coordinator(spec, runs_root=runs_root)
    procs: List[Optional[subprocess.Popen]] = []
    respawns = [0] * spec.swarm.workers
    exits: List[int] = []
    done = threading.Event()

    def supervise():
        while not done.is_set():
            for slot, p in enumerate(procs):
                if p is None or p.poll() is None:
                    continue
                exits.append(p.returncode)
                procs[slot] = None
                if (respawn and not done.is_set()
                        and respawns[slot] < _MAX_RESPAWNS_PER_SLOT):
                    respawns[slot] += 1
                    procs[slot] = spawn_worker(coord.host, coord.port)
            time.sleep(0.1)

    sup = threading.Thread(target=supervise, daemon=True)
    try:
        for _ in range(spec.swarm.workers):
            procs.append(spawn_worker(coord.host, coord.port))
        sup.start()
        summary = coord.serve()
    finally:
        done.set()
        if sup.is_alive():
            sup.join(timeout=2.0)
        for p in procs:
            if p is None:
                continue
            try:
                p.wait(timeout=15.0)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=5.0)
            exits.append(p.returncode)
    summary["worker_exits"] = exits
    summary["respawns"] = sum(respawns)
    return summary


def run_attached(address: str) -> Dict[str, Any]:
    """Worker half of ``launch swarm``: join the swarm at ``address``."""
    from repro.swarm import worker
    return worker.attach(address)
