"""Swarm worker: probe your shards, ship two floats, apply the commit
(DESIGN.md §14).

A worker attaches to a coordinator address with nothing but the
address: the ``welcome`` carries the full experiment spec, so the
worker builds the same :class:`~repro.swarm.shardstep.ShardedZOStep`
a single-process trainer would, regenerates the batch stream
deterministically from the spec (zero data bytes on the wire), and
per step sends one :class:`~repro.swarm.proto.StepContribution` with
the ``(l+, l-)`` pair of each shard it owns.

**Elastic join without weight transfer**: because probes never mutate
parameters, the trajectory is a pure fold of ``commit(seed, g)`` over
the committed log.  A worker joining mid-run initializes params
deterministically from the spec (or restores the newest checkpoint),
fetches the committed ``(seed, g)`` backlog, and folds it forward —
arriving bit-identical to workers that were present from step 0.

The fault-injection hooks (:mod:`repro.swarm.chaos`) live at this edge:
contributions can be dropped/delayed, commits ignored (recovered via
``fetch`` resync), whole step windows partitioned, and ``chaos_crash``
hard-exits the process at a scheduled step so the coordinator's
death/reassignment path is deterministically exercised.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import rng
from repro.swarm import chaos as chaos_mod
from repro.swarm import proto
from repro.swarm import shardstep


class Worker:
    """One swarm worker process.  ``Worker(host, port).run()``."""

    def __init__(self, host: str, port: int):
        self.conn = proto.connect(host, port)
        self.wid = -1
        self.epoch = -1
        self.shards: List[int] = []
        self.run_id = ""
        self._commit_backlog: Dict[int, proto.StepCommit] = {}
        self._done: Optional[dict] = None
        self._commit_dropped: set = set()
        # per-step resend counter: bumped by a nudge/assign or a local
        # timeout, so resends pass a fresh chaos attempt and a dropped
        # contribution is not dropped identically forever
        self._attempt = 0
        self._attempt_step = -1

    # ------------------------------------------------------------ set-up
    def _handshake(self) -> bool:
        from repro import api
        self.conn.send({"type": "hello", "last_step": -1})
        msg = self.conn.recv(timeout=60.0)
        if msg is None or msg.get("type") == "done":
            # raced the end of the run — nothing to do
            return False
        if msg["type"] != "welcome":
            raise proto.ProtocolError(f"expected welcome, got {msg!r}")
        self.wid = int(msg["worker_id"])
        self.epoch = int(msg["membership_epoch"])
        self.run_id = msg.get("run_id", "")
        self.base_seed = int(msg["base_seed"])
        self.next_step = int(msg["next_step"])
        spec = api.from_dict(msg["spec"])
        # workers keep the ckpt config (commit messages may designate
        # this worker to write one) but never open their own run dir
        self.spec = dataclasses.replace(spec, telemetry=api.Telemetry())
        self.chaos = chaos_mod.Chaos(
            chaos_mod.ChaosConfig.from_spec(spec.swarm), self.wid)
        return True

    def _build(self):
        import jax
        import jax.numpy as jnp
        from repro import tasks as tasks_mod
        from repro.data import synthetic
        from repro.train.trainer import Trainer

        self.trainer = Trainer.from_spec(self.spec)
        assert getattr(self.trainer._step, "sharded", False)
        self.step: shardstep.ShardedZOStep = self.trainer._step
        tcfg = self.trainer.tcfg
        want = int(np.uint32(rng.fold_py(tcfg.seed, 0xC0FFEE)))
        if want != self.base_seed:
            raise proto.ProtocolError(
                f"seed lineage mismatch: spec folds to {want}, "
                f"coordinator announced {self.base_seed}")
        self.params = self.trainer.trainable
        self.t = 0
        # newest checkpoint <= next_step fast-forwards for free
        ck = self.trainer.ckpt
        if ck is not None and ck.latest() is not None:
            usable = [s for s in ck.all_steps() if s <= self.next_step]
            if usable:
                self.params, self.t, _, _ = ck.restore(self.params,
                                                       step=max(usable))
                self.params = jax.tree.map(jnp.asarray, self.params)
        train_data = self.trainer.make_dataset(4096)
        stream_data = {k: v for k, v in train_data.items()
                       if k in tasks_mod.MODEL_BATCH_KEYS}
        self._stream = enumerate(synthetic.batches(
            stream_data, tcfg.batch_size, tcfg.steps, seed=tcfg.seed + 7))
        self._batch_t = -1
        self._batch = None

    def _batch_for(self, t: int):
        """Advance the deterministic batch stream to step ``t`` — the
        iterator stays in lockstep, so fast-forward just consumes it."""
        while self._batch_t < t:
            self._batch_t, np_batch = next(self._stream)
            self._batch = self.trainer._model_batch(np_batch)
        return self._batch

    def _fast_forward(self):
        """Fold the committed ``(seed, g)`` backlog from ``self.t`` up
        to the coordinator's ``next_step`` — elastic join, no weights
        on the wire."""
        if self.t >= self.next_step:
            return
        self.conn.send({"type": "fetch", "from_step": self.t})
        while self.t < self.next_step:
            msg = self.conn.recv(timeout=60.0)
            if msg is None:
                raise proto.ProtocolError("coordinator hung up mid-resync")
            self._ingest(msg)
            self._apply_backlog()

    # --------------------------------------------------------- messaging
    def _ingest(self, msg: dict):
        kind = msg["type"]
        if kind == "assign":
            self.epoch = int(msg["membership_epoch"])
            self.shards = [int(s) for s in msg["shards"]]
            self._attempt += 1   # re-probe/resend for the named step
        elif kind == "commit":
            cm = proto.StepCommit.from_wire(msg)
            key = ("commit", cm.step)
            if (cm.step >= self.t and key not in self._commit_dropped
                    and self.chaos.drop("commit", cm.step)):
                # chaos eats this broadcast exactly once; the worker
                # recovers through the fetch/resync path
                self._commit_dropped.add(key)
                return
            self._commit_backlog[cm.step] = cm
        elif kind == "commits":
            for raw in msg["commits"]:
                cm = proto.StepCommit.from_wire(raw)
                self._commit_backlog[cm.step] = cm
        elif kind == "done":
            self._done = msg

    def _apply_backlog(self):
        """Apply every contiguous pending commit at ``self.t``."""
        while self.t in self._commit_backlog:
            cm = self._commit_backlog.pop(self.t)
            want = int(np.uint32(rng.fold_py(self.base_seed, self.t)))
            if cm.seed != want:
                raise proto.ProtocolError(
                    f"commit step {cm.step} carries seed {cm.seed}, "
                    f"lineage says {want}")
            self.params = self.step.apply_commit(self.params, cm.seed, cm.g)
            if cm.ckpt_worker == self.wid and self.trainer.ckpt is not None:
                self.trainer.ckpt.save(
                    self.t + 1, self.params, int(self.base_seed),
                    extra=self.trainer._ckpt_extra(), blocking=True)
            self.t += 1
            self._commit_backlog = {s: c for s, c
                                    in self._commit_backlog.items()
                                    if s >= self.t}

    def _contribute(self, t: int, seed: int, attempt: int = 0):
        """Probe my shards for step ``t`` and send the contribution —
        unless chaos drops/partitions it (the coordinator's deadline
        machinery takes over)."""
        if not self.shards:
            return
        batch = self._batch_for(t)
        shards_all = shardstep.shard_batch(batch, self.step.n_shards)
        pairs = {str(s): [float(v) for v in
                          self.step.probe_shard(self.params, shards_all[s],
                                                seed)]
                 for s in self.shards}
        c = proto.StepContribution(
            run_id=self.run_id, membership_epoch=self.epoch, step=t,
            seed=seed, shard_losses=pairs, worker_id=self.wid)
        self.chaos.sleep("contribution", t, attempt)
        if self.chaos.drop("contribution", t, attempt):
            return
        self.conn.send(c.to_wire())

    # --------------------------------------------------------------- run
    def run(self) -> dict:
        if not self._handshake():
            self.conn.close()
            return {"worker_id": -1, "steps_applied": 0, "joined": False}
        self._build()
        self._fast_forward()
        deadline_s = self.spec.swarm.step_deadline_s
        contributed_for = None
        while self._done is None:
            self._apply_backlog()
            if self._done is not None:
                break
            t = self.t
            if t >= self.spec.run.steps:
                break
            if t != self._attempt_step:
                self._attempt_step, self._attempt = t, 0
            self.chaos.maybe_crash(t)
            seed = int(np.uint32(rng.fold_py(self.base_seed, t)))
            key = (t, self.epoch, self._attempt)
            if contributed_for != key:
                self._contribute(t, seed, self._attempt)
                contributed_for = key
            try:
                msg = self.conn.recv(timeout=deadline_s * 2)
            except TimeoutError:
                # our contribution or the commit was lost — resync the
                # committed backlog and recontribute with a fresh attempt
                self._attempt += 1
                try:
                    self.conn.send({"type": "fetch", "from_step": self.t})
                except OSError:
                    raise proto.ProtocolError("coordinator unreachable")
                continue
            if msg is None:
                raise proto.ProtocolError("coordinator hung up")
            self._ingest(msg)
        self._apply_backlog()
        try:
            self.conn.send({"type": "bye"})
        except OSError:
            pass
        self.conn.close()
        return {"worker_id": self.wid, "steps_applied": self.t,
                "epoch": self.epoch,
                "bytes_sent": self.conn.bytes_sent,
                "bytes_recv": self.conn.bytes_recv,
                "summary": (self._done or {}).get("summary")}


def attach(address: str) -> dict:
    """``launch swarm --attach host:port`` entry point."""
    host, port = address.rsplit(":", 1)
    return Worker(host, int(port)).run()
