"""Deterministic fault injection for the swarm transport (DESIGN.md §14).

Straggler-timeout, worker-death and partition-recovery paths are the
hard-to-hit 1% of a distributed trainer; this module makes them the
repeatable 100%.  Every decision (drop this message? delay it how long?
die here?) is a pure hash of ``(chaos_seed, worker, kind, step,
attempt)`` — two runs with the same spec inject byte-identical fault
schedules, so a chaos run is as replayable as a clean one.

Faults are applied at the *worker's* edge of the transport (the
coordinator stays honest — a lying coordinator is a different failure
model than the quorum machinery defends against):

* ``drop``      — an outgoing contribution or incoming commit vanishes.
* ``delay``     — a message is held up to ``delay_ms`` before sending.
* ``crash``     — ``worker:step`` hard-exits (``os._exit``) at the top
                  of that step, before contributing: the reader-side EOF
                  is the coordinator's death signal.
* ``partition`` — ``worker:start-end`` (inclusive) drops *everything*
                  in the window, both directions; the worker recovers
                  through the fetch/resync path afterwards.

Resends pass a fresh ``attempt`` counter into the hash, so a dropped
message is not dropped identically forever — schedules with
``drop < 1`` always make progress.  Stdlib-only: imported by
``api.validate`` (which must stay jax-free) to parse the schedules.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional, Tuple

_M = 0xFFFFFFFF
# exit code for an injected crash — distinguishable from real tracebacks
CRASH_EXIT = 43


def _mix(x: int) -> int:
    """Murmur3-style 32-bit avalanche (python-int twin of rng.mix32)."""
    x &= _M
    x ^= x >> 16
    x = (x * 0x7FEB352D) & _M
    x ^= x >> 15
    x = (x * 0x846CA68B) & _M
    x ^= x >> 16
    return x


def _hash01(seed: int, worker: int, kind: str, step: int, attempt: int) -> float:
    """Deterministic uniform in [0, 1) for one fault decision."""
    h = _mix(seed ^ 0x5EEDFA17)
    for part in (worker, step, attempt, len(kind)):
        h = _mix(h * 0x9E3779B9 + (part & _M))
    for ch in kind.encode():
        h = _mix(h ^ ch)
    return h / 4294967296.0


def parse_crashes(text: str) -> Tuple[Tuple[int, int], ...]:
    """``"worker:step[,worker:step...]"`` -> ((worker, step), ...)."""
    out = []
    for item in filter(None, (s.strip() for s in (text or "").split(","))):
        try:
            w, s = item.split(":")
            w, s = int(w), int(s)
        except ValueError:
            raise ValueError(
                f"expected 'worker:step[,...]' with integer fields, "
                f"got {item!r}") from None
        if w < 0 or s < 0:
            raise ValueError(f"worker and step must be >= 0, got {item!r}")
        out.append((w, s))
    return tuple(out)


def parse_partitions(text: str) -> Tuple[Tuple[int, int, int], ...]:
    """``"worker:start-end[,...]"`` -> ((worker, start, end), ...);
    the window is inclusive on both ends."""
    out = []
    for item in filter(None, (s.strip() for s in (text or "").split(","))):
        try:
            w, span = item.split(":")
            start, end = span.split("-")
            w, start, end = int(w), int(start), int(end)
        except ValueError:
            raise ValueError(
                f"expected 'worker:start-end[,...]' with integer fields, "
                f"got {item!r}") from None
        if w < 0 or start < 0 or end < start:
            raise ValueError(
                f"need worker >= 0 and 0 <= start <= end, got {item!r}")
        out.append((w, start, end))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Parsed, hashable form of the spec's ``swarm.chaos_*`` fields."""
    seed: int = 0
    drop: float = 0.0
    delay_ms: float = 0.0
    crashes: Tuple[Tuple[int, int], ...] = ()
    partitions: Tuple[Tuple[int, int, int], ...] = ()

    @classmethod
    def from_spec(cls, sw) -> "ChaosConfig":
        return cls(seed=sw.chaos_seed, drop=sw.chaos_drop,
                   delay_ms=sw.chaos_delay_ms,
                   crashes=parse_crashes(sw.chaos_crash),
                   partitions=parse_partitions(sw.chaos_partition))

    @property
    def enabled(self) -> bool:
        return bool(self.drop or self.delay_ms or self.crashes
                    or self.partitions)


class Chaos:
    """One worker's view of the fault schedule.

    ``worker_id`` is the coordinator-assigned id; a respawned worker
    gets a fresh id, so a ``crash`` entry fires exactly once per id.
    """

    def __init__(self, cfg: ChaosConfig, worker_id: int):
        self.cfg = cfg
        self.wid = worker_id

    def partitioned(self, step: int) -> bool:
        return any(w == self.wid and start <= step <= end
                   for w, start, end in self.cfg.partitions)

    def drop(self, kind: str, step: int, attempt: int = 0) -> bool:
        """Drop this message?  Partition windows drop unconditionally."""
        if self.partitioned(step):
            return True
        if self.cfg.drop <= 0.0:
            return False
        return _hash01(self.cfg.seed, self.wid, kind, step,
                       attempt) < self.cfg.drop

    def delay_s(self, kind: str, step: int, attempt: int = 0) -> float:
        if self.cfg.delay_ms <= 0.0:
            return 0.0
        u = _hash01(self.cfg.seed, self.wid, "delay:" + kind, step, attempt)
        return u * self.cfg.delay_ms / 1000.0

    def sleep(self, kind: str, step: int, attempt: int = 0) -> None:
        d = self.delay_s(kind, step, attempt)
        if d > 0.0:
            time.sleep(d)

    def crash_point(self, step: int) -> bool:
        return (self.wid, step) in self.cfg.crashes

    def maybe_crash(self, step: int) -> None:
        """Hard-exit at an injected ``worker:step`` crash point.

        ``os._exit`` (not ``sys.exit``): no atexit, no flushing, no
        socket shutdown handshake — the closest a test harness gets to
        a host losing power.
        """
        if self.crash_point(step):
            os._exit(CRASH_EXIT)
