"""Host-side commit arithmetic for the swarm (DESIGN.md §14).

The whole bit-identity story funnels through this file: every shard's
``(l+, l-)`` pair is reduced to the committed step scalars **in fixed
shard order, in float32, on the host** — by the coordinator, by every
worker checking a commit, and by the single-process sharded trainer.
Contributions are keyed by shard index, so the reduction literally
cannot see arrival order; two swarms (or a swarm and a lone process)
that saw the same shard losses commit the same bits.

The quorum fallback reuses the in-trainer quorum math
(``models/lm.quorum_loss``): the same ``n_ok = max(1, round(q·n))``
threshold and the same arrived-weighted mean ``Σ wᵢlᵢ / Σ wᵢ`` —
evaluated here with a left-to-right float32 loop instead of an XLA
reduction, which is what makes the result a function of the shard set
alone.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

f32 = np.float32


def quorum_count(n_shards: int, quorum: float) -> int:
    """Shards required to commit — the trainer's quorum_loss threshold."""
    return max(1, int(round(quorum * n_shards)))


def reduce_losses(pairs: Sequence[Optional[Sequence[float]]]
                  ) -> Tuple[np.float32, np.float32, List[int]]:
    """Arrived-weighted mean of the ±εz shard losses, fixed shard order.

    ``pairs[i]`` is shard i's ``(l+, l-)`` or ``None`` if it never
    arrived.  Returns ``(L+, L-, arrived)`` with the mean accumulated
    left-to-right in float32 — the committed bits depend only on which
    shards arrived, never on when.
    """
    lp = f32(0.0)
    lm = f32(0.0)
    w = f32(0.0)
    arrived = []
    for pair in pairs:
        if pair is None:
            arrived.append(0)
            continue
        arrived.append(1)
        lp = f32(lp + f32(pair[0]))
        lm = f32(lm + f32(pair[1]))
        w = f32(w + f32(1.0))
    if w == 0.0:
        raise ValueError("cannot commit a step with zero arrived shards")
    return f32(lp / w), f32(lm / w), arrived


def commit_scalars(pairs: Sequence[Optional[Sequence[float]]],
                   eps: float) -> Dict[str, object]:
    """The scalars a :class:`~repro.swarm.proto.StepCommit` carries,
    from the per-shard loss pairs: two-point projected gradient
    ``g = (L+ − L−) / 2ε`` and the recorded loss ``(L+ + L−) / 2``."""
    lp, lm, arrived = reduce_losses(pairs)
    e = f32(eps)
    g = f32(f32(lp - lm) / f32(f32(2.0) * e))
    loss = f32(f32(0.5) * f32(lp + lm))
    return {"l_plus": lp, "l_minus": lm, "loss": loss,
            "projected_grad": g, "arrived": arrived}


def shard_losses_dict(pairs: Sequence[Optional[Sequence[float]]]
                      ) -> Dict[str, List[float]]:
    """JSON-row form: ``{shard_index: [l+, l-]}`` for arrived shards
    only (a quorum-degraded step records exactly what it reduced)."""
    return {str(i): [float(f32(p[0])), float(f32(p[1]))]
            for i, p in enumerate(pairs) if p is not None}
