"""Swarm coordinator: shard assignment, quorum commits, membership
epochs (DESIGN.md §14).

The coordinator owns the *decision*, never the parameters: it assigns
the spec-fixed loss shards round-robin over live workers, collects
``StepContribution``s, and — when the step completes or the deadline
passes with ≥ quorum of shards — reduces the shard losses through the
same fixed-order host math as every replica (:mod:`repro.swarm.commit`)
and broadcasts the ``StepCommit``.  Selection health metrics come from
a ``jax.eval_shape`` abstract parameter tree (layer selection is a pure
function of the seed and the tree's *shapes*), so the coordinator
writes the exact same run-registry rows as a single-process sharded
trainer — which is what lets ``launch replay`` verify a swarm run
bit-for-bit.

Membership is epoch-numbered: every join, leave or death bumps
``membership_epoch``, reassigns shards, and broadcasts ``assign``;
contributions stamped with an older epoch are rejected (the worker
recomputes under its new assignment and resends).  A worker death
mid-step reassigns its shards immediately, so even a quorum=1.0 run
survives a crash; checkpoint writes are delegated per commit to the
lowest live worker id.
"""
from __future__ import annotations

import queue
import socket
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro import obs as obs_mod
from repro.core import rng
from repro.swarm import commit as commit_mod
from repro.swarm import proto

_JOIN_GRACE_S = 120.0   # max wait for the first worker to attach
_POLL_S = 0.05


class _Peer:
    """One connected worker, as the coordinator sees it."""

    def __init__(self, conn: proto.Conn):
        self.conn = conn
        self.wid: Optional[int] = None
        self.alive = True

    def send(self, msg: dict) -> None:
        try:
            self.conn.send(msg)
        except OSError:
            self.alive = False


class StepLedger:
    """Pure contribution gate for one step — shard-keyed, so the commit
    literally cannot depend on arrival order.  Socket-free on purpose:
    the determinism properties are tested against this class directly.
    """

    def __init__(self, run_id: str, step: int, seed: int, epoch: int,
                 n_shards: int):
        self.run_id, self.step, self.seed = run_id, step, seed
        self.epoch = epoch
        self.n_shards = n_shards
        self.pairs: List[Optional[List[float]]] = [None] * n_shards
        self.rejected = {"stale_epoch": 0, "stale_step": 0, "run_id": 0,
                         "bad_shard": 0}

    def add(self, c: proto.StepContribution, epoch: int) -> str:
        """Admit one contribution; returns the disposition.  ``epoch``
        is the coordinator's *current* epoch (it may have advanced past
        ``self.epoch`` after a mid-step membership change)."""
        if c.run_id != self.run_id:
            self.rejected["run_id"] += 1
            return "run_id"
        if c.membership_epoch < epoch:
            self.rejected["stale_epoch"] += 1
            return "stale_epoch"
        if c.step != self.step:
            self.rejected["stale_step"] += 1
            return "stale_step"
        ok = False
        for key, pair in c.shard_losses.items():
            i = int(key)
            if not 0 <= i < self.n_shards:
                self.rejected["bad_shard"] += 1
                continue
            # duplicate shards overwrite bit-identically: every replica
            # runs the same jitted probe program on the same slice
            self.pairs[i] = [float(pair[0]), float(pair[1])]
            ok = True
        return "ok" if ok else "bad_shard"

    @property
    def n_arrived(self) -> int:
        return sum(p is not None for p in self.pairs)

    @property
    def complete(self) -> bool:
        return self.n_arrived == self.n_shards

    def missing(self) -> List[int]:
        return [i for i, p in enumerate(self.pairs) if p is None]

    def commit(self, eps: float) -> Dict[str, Any]:
        """The committed scalars (fixed-order f32 reduction)."""
        return commit_mod.commit_scalars(self.pairs, eps)


class Coordinator:
    """Run one swarm training loop; see :meth:`serve`."""

    def __init__(self, experiment, runs_root: Optional[str] = None):
        from repro import api
        from repro.api import spec as spec_mod
        import importlib
        api_validate = importlib.import_module("repro.api.validate")
        from repro.swarm import shardstep

        api.validate(experiment)
        if not api_validate.swarm_active(experiment):
            raise ValueError("spec has no active swarm node "
                             "(set swarm.workers or swarm.n_shards)")
        self.experiment = experiment
        sw, r, tel = experiment.swarm, experiment.run, experiment.telemetry
        self.n_shards = api_validate.swarm_shards(experiment)
        self.n_ok = commit_mod.quorum_count(self.n_shards, sw.quorum)
        self.deadline_s = sw.step_deadline_s
        self.steps = r.steps
        self.log_every = r.log_every
        self.ckpt_every = r.ckpt_every if r.ckpt_dir else 0
        self.eps = experiment.optimizer.eps
        self.lr = experiment.optimizer.lr
        # the trainer folds TrainConfig.seed (= run.seed) — mirror that
        self.base_seed = int(np.uint32(rng.fold_py(r.seed, 0xC0FFEE)))
        self.spec_dict = spec_mod.to_dict(experiment)

        # run registry (DESIGN.md §13): the swarm's (seed, g) log is the
        # recovery substrate AND the replay evidence
        self.runlog = None
        self.run_id = None
        self.health = None
        runs_dir = runs_root or tel.runs_dir
        self.oracle = shardstep.SelectionOracle(experiment)
        if runs_dir:
            self.run_id = tel.run_id or obs_mod.make_run_id(runs_dir,
                                                            seed=r.seed)
            self.runlog = obs_mod.RunLog(runs_dir, self.run_id,
                                         spec=self.spec_dict)
            norm_fn = (self.oracle.norm_fn
                       if getattr(tel, "health_norms", False) else None)
            self.health = obs_mod.HealthAccumulator(self.oracle.num_layers,
                                                    norm_fn=norm_fn)
        self.obs = obs_mod.session(tel)
        reg = self.obs.registry
        self._g_live = reg.gauge("swarm_live_workers",
                                 "workers currently attached")
        self._g_epoch = reg.gauge("swarm_epoch", "membership epoch")
        self._g_straggler = reg.gauge("swarm_straggler_steps",
                                      "steps committed below full strength")
        self._g_bytes = reg.gauge("swarm_bytes_per_step",
                                  "mean wire bytes per committed step")

        # ---- transport
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((sw.host, sw.port))
        self._sock.listen(16)
        self.host, self.port = self._sock.getsockname()[:2]
        self._events: "queue.Queue" = queue.Queue()
        self._stop = threading.Event()
        self._accept_thread = threading.Thread(target=self._accept_loop,
                                               daemon=True)
        self._accept_thread.start()

        # ---- state
        self.epoch = 0
        self.peers: Dict[int, _Peer] = {}
        self._joiners: List[_Peer] = []
        self._closed_peers: List[_Peer] = []
        self._next_wid = 0
        self.commit_log: List[dict] = []
        self.straggler_steps = 0
        self.stale_rejections = 0

    # ----------------------------------------------------------- threads
    def _accept_loop(self):
        while not self._stop.is_set():
            try:
                sock, _ = self._sock.accept()
            except OSError:
                return
            peer = _Peer(proto.Conn(sock))
            threading.Thread(target=self._reader_loop, args=(peer,),
                             daemon=True).start()

    def _reader_loop(self, peer: _Peer):
        while not self._stop.is_set():
            try:
                msg = peer.conn.recv()
            except (OSError, proto.ProtocolError):
                msg = None
            if msg is None:
                self._events.put(("dead", peer, None))
                return
            self._events.put((msg["type"], peer, msg))

    # -------------------------------------------------------- membership
    def _live_wids(self) -> List[int]:
        return sorted(w for w, p in self.peers.items() if p.alive)

    def _shards_of(self, wid: int) -> List[int]:
        live = self._live_wids()
        if wid not in live:
            return []
        k = live.index(wid)
        return [s for s in range(self.n_shards)
                if s % len(live) == k]

    def _assignment_msg(self, wid: int, step: int) -> dict:
        return {"type": "assign", "membership_epoch": self.epoch,
                "step": step, "shards": self._shards_of(wid),
                "n_live": len(self._live_wids())}

    def _bump_epoch(self, step: int, *, welcome_new: bool = True):
        """Advance the membership epoch and rebroadcast assignments for
        ``step`` — contributions from the previous epoch are now stale."""
        self.epoch += 1
        for wid in self._live_wids():
            self.peers[wid].send(self._assignment_msg(wid, step))
        self._g_epoch.set(self.epoch)
        self._g_live.set(len(self._live_wids()))

    def _admit(self, peer: _Peer, step: int):
        from repro.api import spec as spec_mod
        wid = self._next_wid
        self._next_wid += 1
        peer.wid = wid
        self.peers[wid] = peer
        self.epoch += 1
        peer.send({"type": "welcome", "worker_id": wid,
                   "membership_epoch": self.epoch,
                   "spec": self.spec_dict, "run_id": self.run_id or "",
                   "base_seed": self.base_seed, "next_step": step,
                   "n_shards": self.n_shards,
                   "shards": []})  # real shards follow in the assign
        for w in self._live_wids():
            self.peers[w].send(self._assignment_msg(w, step))
        self._g_epoch.set(self.epoch)
        self._g_live.set(len(self._live_wids()))

    def _drop_peer(self, peer: _Peer, step: int):
        if peer.wid is not None and peer.wid in self.peers:
            del self.peers[peer.wid]
            peer.alive = False
            self._closed_peers.append(peer)
            if self._live_wids():
                self._bump_epoch(step)
        peer.alive = False

    def _process_boundary(self, step: int):
        """Admit queued joiners at a step boundary."""
        while self._joiners:
            self._admit(self._joiners.pop(0), step)

    # ------------------------------------------------------------- serve
    def _handle(self, kind: str, peer: _Peer, msg: Optional[dict],
                ledger: Optional[StepLedger], step: int) -> None:
        if kind == "hello":
            if msg is not None and peer.wid is None:
                self._joiners.append(peer)
        elif kind == "dead" or kind == "bye":
            self._drop_peer(peer, step)
        elif kind == "fetch" and msg is not None:
            start = max(0, int(msg.get("from_step", 0)))
            peer.send({"type": "commits",
                       "commits": self.commit_log[start:]})
        elif kind == "contribution" and msg is not None and ledger:
            c = proto.StepContribution.from_wire(msg)
            if ledger.add(c, self.epoch) == "stale_epoch":
                self.stale_rejections += 1

    def _await_quorum(self, ledger: StepLedger, step: int) -> None:
        """Block until the step can commit: complete, or deadline passed
        with ≥ quorum shards.  Death mid-step reassigns immediately."""
        deadline = time.monotonic() + self.deadline_s
        nudge_attempt = 0
        while True:
            # admit joiners even mid-step: they fast-forward from the
            # commit log and pick up shards at the next epoch bump
            if self._joiners:
                self._process_boundary(step)
            if ledger.complete:
                return
            now = time.monotonic()
            if now >= deadline:
                if ledger.n_arrived >= self.n_ok:
                    return
                # below quorum: nudge the workers owning missing shards
                # (resends pass a fresh chaos attempt counter) and re-arm
                for wid in self._live_wids():
                    self.peers[wid].send(self._assignment_msg(wid, step))
                nudge_attempt += 1
                deadline = time.monotonic() + self.deadline_s
            try:
                kind, peer, msg = self._events.get(
                    timeout=min(_POLL_S * 4, max(0.0, deadline - now)))
            except queue.Empty:
                continue
            self._handle(kind, peer, msg, ledger, step)

    def _wait_for_workers(self, step: int):
        t0 = time.monotonic()
        while not self._live_wids():
            if self._joiners:
                self._process_boundary(step)
                continue
            if time.monotonic() - t0 > _JOIN_GRACE_S:
                raise TimeoutError("no worker attached within "
                                   f"{_JOIN_GRACE_S}s")
            try:
                kind, peer, msg = self._events.get(timeout=_POLL_S * 4)
            except queue.Empty:
                continue
            self._handle(kind, peer, msg, None, step)

    def _record_step(self, t: int, seed: int, scal: Dict[str, Any],
                     pairs) -> None:
        if self.health is None:
            return
        metrics = {
            "loss": scal["loss"],
            "projected_grad": scal["projected_grad"],
            "probe_grads": np.asarray([scal["projected_grad"]], np.float32),
            "coeffs": np.asarray([scal["projected_grad"]], np.float32),
            "eps": np.float32(self.eps),
            "lr": float(self.lr),
            "arrived": np.asarray(scal["arrived"], np.int32),
            "shard_losses": commit_mod.shard_losses_dict(pairs),
        }
        metrics.update(self.oracle.metrics(seed))
        self.health.record(t, metrics, seed=seed)
        if self.log_every and (t % self.log_every == 0
                               or t == self.steps - 1):
            self.runlog.append(self.health.drain())

    def _wire_bytes(self) -> int:
        peers = list(self.peers.values()) + self._closed_peers
        return sum(p.conn.bytes_sent + p.conn.bytes_recv for p in peers)

    def serve(self) -> Dict[str, Any]:
        """Drive the run to completion; returns (and writes, when a run
        dir is configured) the summary."""
        try:
            return self._serve()
        finally:
            self.close()

    def _serve(self) -> Dict[str, Any]:
        t0 = time.time()
        step_bytes: List[int] = []
        bytes_before = self._wire_bytes()
        for t in range(self.steps):
            self._process_boundary(t)
            if not self._live_wids():
                self._wait_for_workers(t)
            seed = int(np.uint32(rng.fold_py(self.base_seed, t)))
            ledger = StepLedger(self.run_id or "", t, seed, self.epoch,
                                self.n_shards)
            # drain anything already queued (e.g. eager contributions)
            while True:
                try:
                    kind, peer, msg = self._events.get_nowait()
                except queue.Empty:
                    break
                self._handle(kind, peer, msg, ledger, t)
            self._await_quorum(ledger, t)

            scal = ledger.commit(self.eps)
            if 0 in scal["arrived"]:
                self.straggler_steps += 1
                self._g_straggler.set(self.straggler_steps)
            self.stale_rejections += sum(ledger.rejected.values())
            ckpt_wid = -1
            if self.ckpt_every and (t + 1) % self.ckpt_every == 0:
                live = self._live_wids()
                ckpt_wid = live[0] if live else -1
            cm = proto.StepCommit(
                step=t, seed=seed, g=float(scal["projected_grad"]),
                loss=float(scal["loss"]),
                active_layers=int(self.oracle.metrics(seed)["active_layers"]),
                membership_epoch=self.epoch, arrived=scal["arrived"],
                ckpt_worker=ckpt_wid).to_wire()
            self.commit_log.append(cm)
            for wid in self._live_wids():
                self.peers[wid].send(cm)
            self._record_step(t, seed, scal, ledger.pairs)
            now_bytes = self._wire_bytes()
            step_bytes.append(now_bytes - bytes_before)
            bytes_before = now_bytes
            self._g_bytes.set(now_bytes / (t + 1))

        summary = {
            "run_id": self.run_id, "steps": self.steps,
            "n_shards": self.n_shards, "quorum_n": self.n_ok,
            "membership_epochs": self.epoch,
            "workers_seen": self._next_wid,
            "straggler_steps": self.straggler_steps,
            "stale_rejections": self.stale_rejections,
            "wire_bytes": self._wire_bytes(),
            "bytes_per_step": self._wire_bytes() / max(1, self.steps),
            # join handshakes ship the spec dict once; the median step
            # delta is the steady-state scalar-only figure
            "steady_bytes_per_step": float(np.median(step_bytes))
            if step_bytes else 0.0,
            "wall_s": time.time() - t0,
        }
        done = {"type": "done", "summary": {k: v for k, v in summary.items()
                                            if k != "run_id"}}
        for wid in self._live_wids():
            self.peers[wid].send(done)
        # give workers a moment to checkpoint/exit cleanly
        t_end = time.monotonic() + 10.0
        while self._live_wids() and time.monotonic() < t_end:
            try:
                kind, peer, msg = self._events.get(timeout=_POLL_S * 4)
            except queue.Empty:
                continue
            if kind in ("dead", "bye"):
                peer.alive = False
                if peer.wid in self.peers:
                    del self.peers[peer.wid]
        if self.runlog is not None:
            self.runlog.append(self.health.drain())
            full = dict(self.health.summary())
            full.update(summary)
            self.runlog.finalize(full)
        return summary

    def close(self):
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        for p in list(self.peers.values()) + self._joiners:
            p.conn.close()
        self.obs.flush()
        self.obs.close()
