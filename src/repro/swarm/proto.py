"""Swarm wire protocol: length-prefixed JSON frames (DESIGN.md §14).

A frame is a 4-byte big-endian length followed by a UTF-8 JSON object
with a ``"type"`` tag.  JSON because the payloads are a handful of
scalars — the protocol's entire point is that a ZO step commits from
``(seed, g)`` alone, so the per-step traffic is hundreds of *bytes*
against the ``4·|θ|`` of a first-order gradient all-reduce (the
``BENCH_dist.json`` tripwire pins it under 1 KB).  Floats survive the
trip exactly: ``float(np.float32(x))`` is the shortest round-tripping
repr, so ``np.float32(json.loads(...))`` restores identical bits.

Message types:

==============  ===========================================================
``hello``       worker → coordinator: join request (``last_step`` when
                reconnecting)
``welcome``     coordinator → worker: assigned ``worker_id``, the full
                experiment spec (workers need only an address), run_id,
                base_seed, membership epoch, shard ids, next step
``assign``      coordinator → worker: shard reassignment at an epoch bump
                (mid-step when a peer died, boundary on join/leave)
``contribution``worker → coordinator: :class:`StepContribution`
``commit``      coordinator → worker: :class:`StepCommit` (broadcast)
``fetch``       worker → coordinator: resync request for committed steps
                ``>= from_step`` (elastic join, partition recovery)
``commits``     coordinator → worker: the requested commit backlog
``done``        coordinator → worker: run complete, summary attached
``bye``         worker → coordinator: clean leave
==============  ===========================================================
"""
from __future__ import annotations

import dataclasses
import json
import socket
import struct
import threading
from typing import Dict, List, Optional

_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 24  # 16 MiB — the spec-carrying welcome is the ceiling

MESSAGE_TYPES = ("hello", "welcome", "assign", "contribution", "commit",
                 "fetch", "commits", "done", "bye")


class ProtocolError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class StepContribution:
    """One worker's shard losses for one step.

    ``shard_losses`` maps shard index (a string — it travels as a JSON
    object key) to the ``[l+, l-]`` pair for that shard.  Contributions
    carrying a stale ``membership_epoch`` or a foreign ``run_id`` are
    rejected by the coordinator's ledger.
    """
    run_id: str
    membership_epoch: int
    step: int
    seed: int
    shard_losses: Dict[str, List[float]]
    worker_id: int = -1

    def to_wire(self) -> dict:
        return {"type": "contribution", **dataclasses.asdict(self)}

    @classmethod
    def from_wire(cls, msg: dict) -> "StepContribution":
        return cls(run_id=msg["run_id"],
                   membership_epoch=int(msg["membership_epoch"]),
                   step=int(msg["step"]), seed=int(msg["seed"]),
                   shard_losses={str(k): [float(v[0]), float(v[1])]
                                 for k, v in msg["shard_losses"].items()},
                   worker_id=int(msg.get("worker_id", -1)))


@dataclasses.dataclass(frozen=True)
class StepCommit:
    """The committed step — everything a replica needs to apply it.

    ``(seed, g)`` alone reconstructs the parameter update (z and the
    layer selection regenerate from the counter RNG); the rest is
    bookkeeping: ``arrived`` records the quorum mask the loss was
    reduced over, ``ckpt_worker`` designates at most one worker to
    write the checkpoint for ``step + 1``.
    """
    step: int
    seed: int
    g: float
    loss: float
    active_layers: int
    membership_epoch: int
    arrived: List[int]
    ckpt_worker: int = -1

    def to_wire(self) -> dict:
        return {"type": "commit", **dataclasses.asdict(self)}

    @classmethod
    def from_wire(cls, msg: dict) -> "StepCommit":
        return cls(step=int(msg["step"]), seed=int(msg["seed"]),
                   g=float(msg["g"]), loss=float(msg["loss"]),
                   active_layers=int(msg["active_layers"]),
                   membership_epoch=int(msg["membership_epoch"]),
                   arrived=[int(x) for x in msg["arrived"]],
                   ckpt_worker=int(msg.get("ckpt_worker", -1)))


def encode(msg: dict) -> bytes:
    if msg.get("type") not in MESSAGE_TYPES:
        raise ProtocolError(f"unknown message type {msg.get('type')!r}")
    body = json.dumps(msg, separators=(",", ":"), sort_keys=True).encode()
    if len(body) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(body)} bytes exceeds MAX_FRAME")
    return _LEN.pack(len(body)) + body


class Conn:
    """A framed connection with send/recv byte counters.

    ``send`` is locked (the coordinator broadcasts from its step loop
    while reader threads live elsewhere); ``recv`` assumes a single
    reader.  ``recv`` returns ``None`` on clean EOF and raises
    ``socket.timeout`` on a deadline.
    """

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._slock = threading.Lock()
        self._rbuf = b""
        self.bytes_sent = 0
        self.bytes_recv = 0
        self.msgs_sent = 0
        self.msgs_recv = 0

    def send(self, msg: dict) -> int:
        frame = encode(msg)
        with self._slock:
            self.sock.sendall(frame)
            self.bytes_sent += len(frame)
            self.msgs_sent += 1
        return len(frame)

    def _read(self, n: int, timeout: Optional[float]) -> Optional[bytes]:
        self.sock.settimeout(timeout)
        while len(self._rbuf) < n:
            chunk = self.sock.recv(65536)
            if not chunk:
                return None
            self._rbuf += chunk
            self.bytes_recv += len(chunk)
        out, self._rbuf = self._rbuf[:n], self._rbuf[n:]
        return out

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        header = self._read(_LEN.size, timeout)
        if header is None:
            return None
        (n,) = _LEN.unpack(header)
        if n > MAX_FRAME:
            raise ProtocolError(f"frame of {n} bytes exceeds MAX_FRAME")
        body = self._read(n, timeout)
        if body is None:
            return None
        self.msgs_recv += 1
        msg = json.loads(body.decode())
        if msg.get("type") not in MESSAGE_TYPES:
            raise ProtocolError(f"unknown message type {msg.get('type')!r}")
        return msg

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def connect(host: str, port: int, timeout: float = 10.0) -> Conn:
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.settimeout(None)
    return Conn(sock)
