"""Decomposed sharded ZO step — the swarm's unit of execution
(DESIGN.md §14).

The monolithic jitted trainer step cannot be bit-reproduced by a
multi-process swarm: XLA fuses the probe/reduce/update into one graph
whose FMA contraction depends on the graph's shape (see
``launch/replay.py`` — even a standalone update axpy differs by ~1 ULP).
So when the spec's ``swarm`` node is active, **both** the single-process
trainer and every swarm worker run this decomposed step instead:

1. ``probe(params, shard_batch, seed) -> (l+, l-)`` — one jitted ±εz
   two-point probe per loss shard.  Never mutates ``params`` (the
   materialized path perturbs, probes and discards inside the jit), so
   the parameter trajectory is a pure fold of commits over the
   ``(seed, g)`` log — which is exactly what lets a replacement worker
   reconstruct params from ``steps.jsonl`` without weight transfer.
2. a host-side float32 reduction in fixed shard order
   (:mod:`repro.swarm.commit`) — identical bits no matter which process
   evaluated which shard, or in what order contributions arrived.
3. ``commit(params, seed, g)`` — one jitted, donated update axpy.

The shard count is fixed by the *spec* (``api.validate.swarm_shards``),
not by live membership, so a 1-, 2- and 4-worker swarm — and a lone
``launch train`` — commit byte-identical steps on the same spec.
``arrived`` (quorum fallback) is an explicit input, recorded per step
and replayed from the run log.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import estimators
from repro.core import rng, zo
from repro.swarm import commit as commit_mod


def shard_batch(batch, n_shards: int) -> List[dict]:
    """Split a batch dict into ``n_shards`` contiguous equal slices
    along axis 0 — shard i is rows ``[i·B/n, (i+1)·B/n)``, the same
    fixed assignment everywhere."""
    n = next(iter(batch.values())).shape[0]
    if n % n_shards:
        raise ValueError(f"batch of {n} does not divide into "
                         f"{n_shards} shards")
    per = n // n_shards
    return [{k: v[i * per:(i + 1) * per] for k, v in batch.items()}
            for i in range(n_shards)]


class ShardedZOStep:
    """Drop-in for the trainer's jitted ``_step`` on swarm specs.

    ``__call__(params, state, batch, step_idx, base_seed, arrived=None)
    -> (params, state, metrics)`` — the trainer's step interface, plus
    the quorum mask.  ``state`` is the empty dict (two_point is
    stateless), which keeps ``launch replay``'s stateless fast-forward
    path working.  Metrics come back as host numpy scalars plus the
    selection metrics as device arrays; rows gain ``arrived`` and
    ``shard_losses`` so a quorum-degraded commit replays exactly.
    """

    sharded = True

    def __init__(self, loss_fn, zspec: zo.ZOSpec,
                 cfg: estimators.EstimatorConfig, n_shards: int,
                 shapes: Sequence):
        if cfg.name != "two_point":
            raise ValueError("the sharded step carries one (l+, l-) pair "
                             f"per shard — two_point only, got {cfg.name!r}")
        self.n_shards = int(n_shards)
        self.cfg = cfg
        self.zspec = zspec
        est = estimators.build_estimator(zspec, cfg)
        self.est = est

        def probe(params, shard, seed):
            masks, idxs, _ = est.select(seed, {})
            if est.virtual and cfg.paired_probes:
                losses = est._vloss_pair(loss_fn, params, shard, seed,
                                         cfg.eps, masks)
                return jnp.stack([losses[0], losses[1]])
            if est.virtual:
                lp = est._vloss(loss_fn, params, shard, seed, cfg.eps, masks)
                lm = est._vloss(loss_fn, params, shard, seed, -cfg.eps, masks)
                return jnp.stack([lp, lm])
            p = est._ax(params, cfg.eps, seed, masks, idxs)
            lp = loss_fn(p, shard)
            p = est._ax(p, -2.0 * cfg.eps, seed, masks, idxs)
            lm = loss_fn(p, shard)
            # p (= params - eps*z) dies here: probes never mutate params
            return jnp.stack([lp, lm])

        def commit(params, seed, g):
            masks, idxs, _ = est.select(seed, {})
            decay = 1.0 - cfg.lr * cfg.weight_decay
            return est._ax(params, -jnp.float32(cfg.lr) * g, seed, masks,
                           idxs, decay)

        def sel_metrics(seed):
            masks, _, n_active = est.select(seed, {})
            out = {
                "active_layers": jnp.asarray(n_active, jnp.int32),
                "n_active_params": jnp.stack(
                    [zo.active_param_count(zspec, tuple(shapes), masks)]),
            }
            if zspec.num_layers:
                out["layer_sel"] = zo.global_layer_mask(
                    zspec, masks).astype(jnp.int32)
            return out

        self._probe = jax.jit(probe)
        self._commit = jax.jit(commit, donate_argnums=(0,))
        self._sel_metrics = jax.jit(sel_metrics)

    # ------------------------------------------------------ shard-level
    def probe_shard(self, params, shard, seed: int) -> np.ndarray:
        """(l+, l-) for one shard as host float32 — what a worker puts
        in its :class:`~repro.swarm.proto.StepContribution`."""
        return np.asarray(self._probe(params, shard, jnp.uint32(seed)),
                          np.float32)

    def apply_commit(self, params, seed: int, g: float):
        """Fold one committed ``(seed, g)`` into params — the elastic
        fast-forward primitive (donates the old params)."""
        return self._commit(params, jnp.uint32(seed), jnp.float32(g))

    def selection_metrics(self, seed: int) -> Dict:
        """The layer-selection health scalars for a committed seed;
        pure function of the seed — no parameters involved."""
        return dict(self._sel_metrics(jnp.uint32(seed)))

    # ------------------------------------------------------- trainer API
    def __call__(self, params, state, batch, step_idx, base_seed,
                 arrived: Optional[Sequence[int]] = None):
        t = int(step_idx)
        seed = rng.fold_py(int(base_seed), t)
        shards = shard_batch(batch, self.n_shards)
        if arrived is None:
            arrived = [1] * self.n_shards
        if len(arrived) != self.n_shards:
            raise ValueError(f"arrived mask of {len(arrived)} for "
                             f"{self.n_shards} shards")
        # dispatch every arrived probe before fetching any — the host
        # reduction then drains them in fixed shard order
        pending = {i: self._probe(params, shards[i], jnp.uint32(seed))
                   for i in range(self.n_shards) if arrived[i]}
        pairs = [np.asarray(pending[i], np.float32) if i in pending else None
                 for i in range(self.n_shards)]
        scal = commit_mod.commit_scalars(pairs, self.cfg.eps)
        g = scal["projected_grad"]
        params = self.apply_commit(params, seed, g)
        metrics = {
            "loss": scal["loss"],
            "projected_grad": g,
            "probe_grads": np.asarray([g], np.float32),
            "coeffs": np.asarray([g], np.float32),
            "eps": np.float32(self.cfg.eps),
            "lr": float(self.cfg.lr),
            "arrived": np.asarray(scal["arrived"], np.int32),
            "shard_losses": commit_mod.shard_losses_dict(pairs),
        }
        metrics.update(self.selection_metrics(seed))
        return params, state, metrics


def from_trainer(trainer, n_shards: int) -> ShardedZOStep:
    """The trainer hook: build the sharded step from an already-built
    Trainer's loss/spec/config (``Trainer._build_step`` calls this when
    the experiment's swarm node is active)."""
    return ShardedZOStep(trainer.loss_fn, trainer.spec, trainer.est_cfg,
                         n_shards, zo.leaf_shapes(trainer.trainable))


# --------------------------------------------------- paramless builders
def abstract_trainable(experiment):
    """The trainable pytree as ShapeDtypeStructs + its ZO group_fn —
    via ``jax.eval_shape``, so the coordinator (which never holds
    parameters) can build selection metrics and z-norms for free."""
    from repro import api
    from repro.models import lm
    from repro.peft import lora as lora_mod
    from repro.peft import prefix as prefix_mod

    d = api.derive(experiment)
    tcfg, mcfg = d.tcfg, d.model_cfg

    if tcfg.peft == "lora":
        def init(seed0):
            key = jax.random.PRNGKey(seed0)
            return lora_mod.init_lora(lm.init_params(mcfg, key), d.lora_cfg,
                                      jax.random.fold_in(key, 1))
        group_fn = lora_mod.lora_group_fn
    elif tcfg.peft == "prefix":
        def init(seed0):
            key = jax.random.PRNGKey(seed0)
            return prefix_mod.init_prefix(mcfg, jax.random.fold_in(key, 2),
                                          d.prefix_cfg)
        group_fn = prefix_mod.prefix_group_fn
    else:
        def init(seed0):
            return lm.init_params(mcfg, jax.random.PRNGKey(seed0))
        group_fn = lm.zo_group_fn

    tr = jax.eval_shape(init, jnp.int32(tcfg.seed))
    return tr, group_fn, d


def trainable_param_count(experiment) -> int:
    """Total trainable parameters — the FO all-reduce baseline is
    ``4 · this`` bytes per step (float32 gradients)."""
    tr, _, _ = abstract_trainable(experiment)
    return int(sum(int(np.prod(s)) for s in zo.leaf_shapes(tr)))


class SelectionOracle:
    """Coordinator-side seed -> health metrics, built without params.

    Wraps the same jitted selection program as :class:`ShardedZOStep`
    plus (optionally) the exact ‖z‖ norm fn the trainer uses for
    ``telemetry.health_norms`` — all shape-only, from the abstract
    trainable.
    """

    def __init__(self, experiment):
        tr, group_fn, d = abstract_trainable(experiment)
        self.zspec = zo.build_spec(tr, group_fn)
        self.shapes = zo.leaf_shapes(tr)
        self.est_cfg = d.est_cfg
        est = estimators.build_estimator(self.zspec, d.est_cfg)
        zspec, shapes = self.zspec, self.shapes

        def sel_metrics(seed):
            masks, _, n_active = est.select(seed, {})
            out = {
                "active_layers": jnp.asarray(n_active, jnp.int32),
                "n_active_params": jnp.stack(
                    [zo.active_param_count(zspec, tuple(shapes), masks)]),
            }
            if zspec.num_layers:
                out["layer_sel"] = zo.global_layer_mask(
                    zspec, masks).astype(jnp.int32)
            return out

        self._sel_metrics = jax.jit(sel_metrics)

        @jax.jit
        def znorm(seed, gmask):
            return zo.tree_z_norm(zspec, shapes, seed,
                                  zspec.split_mask(gmask))

        def norm_fn(seed, layer_sel):
            gmask = jnp.asarray(np.asarray(layer_sel) > 0)
            return float(znorm(jnp.uint32(seed), gmask))

        self.norm_fn = norm_fn if self.zspec.num_layers else None

    @property
    def num_layers(self) -> int:
        return self.zspec.num_layers or 0

    def metrics(self, seed: int) -> Dict:
        return dict(self._sel_metrics(jnp.uint32(seed)))
