"""Per-shard invocation of the fused kernel under a mesh.

The counter RNG makes z a pure function of the *global* (row, col) index
of each weight element, so a shard can generate exactly its slice of z
by offsetting the kernel's counter window — no communication, no
bookkeeping, the same shard-invariance ``kernels/ref.py`` gives the axpy
sweeps.  These wrappers bind that contract to the two layouts
``distributed/sharding.py`` assigns the dense projections:

  * column-parallel (wq/wk/wv/wg/wu/wi): W sharded on its last dim, x
    replicated on ``model`` — each shard passes ``col_off`` and the full
    stored row length ``ld=N``; outputs concatenate along N.
  * row-parallel (wo/wd): W sharded on its first dim, x sharded on its
    last — each shard passes ``row_off``; partial products all-reduce.

The ``virtual_ref`` forward backend needs none of this: the oracle is
plain XLA ops whose iota counters partition under pjit automatically.
These wrappers exist for running the *kernel* per shard via shard_map on
real TPUs.

Fused virtual-perturbation runtime (DESIGN.md §10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.fused.matmul import pmatmul


def _rep(ndim: int) -> P:
    return P(*([None] * ndim))


def pmatmul_col_sharded(mesh, x, w, seed, scale, active, *, axis="model",
                        interpret=None):
    """Column-parallel fused matmul: w (K, N) sharded on N over ``axis``,
    x replicated, output sharded on its last dim."""
    N = w.shape[1]
    shard_n = N // mesh.shape[axis]

    def local(x_, w_, seed_, scale_, active_):
        c0 = (jax.lax.axis_index(axis) * shard_n).astype(jnp.uint32)
        return pmatmul(x_, w_, seed_, scale_, active_, col_off=c0, ld=N,
                       interpret=interpret)

    return shard_map(
        local, mesh=mesh,
        in_specs=(_rep(x.ndim), P(None, axis), P(), P(), P()),
        out_specs=P(*([None] * (x.ndim - 1)), axis),
        check_rep=False,
    )(x, w, jnp.asarray(seed, jnp.uint32), jnp.asarray(scale, jnp.float32),
      jnp.asarray(active, jnp.bool_))


def pmatmul_row_sharded(mesh, x, w, seed, scale, active, *, axis="model",
                        interpret=None):
    """Row-parallel fused matmul: w (K, N) sharded on K over ``axis``,
    x sharded on its last dim, partial products all-reduced."""
    K, N = w.shape
    shard_k = K // mesh.shape[axis]

    def local(x_, w_, seed_, scale_, active_):
        r0 = (jax.lax.axis_index(axis) * shard_k).astype(jnp.uint32)
        part = pmatmul(x_, w_, seed_, scale_, active_, row_off=r0, ld=N,
                       interpret=interpret)
        return jax.lax.psum(part, axis)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(*([None] * (x.ndim - 1)), axis), P(axis, None),
                  P(), P(), P()),
        out_specs=_rep(x.ndim),
        check_rep=False,
    )(x, w, jnp.asarray(seed, jnp.uint32), jnp.asarray(scale, jnp.float32),
      jnp.asarray(active, jnp.bool_))
