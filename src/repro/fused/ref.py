"""Pure-JAX oracle for the virtual-perturbation fused forward.

Virtual perturbation evaluates ``loss(theta + s*eps*z)`` without ever
writing ``theta + s*eps*z`` into the parameter buffers: every weight
consumer regenerates its slice of ``z`` on the fly from the same counter
RNG the perturb/restore/update axpy sweeps use (``kernels/ops.py``), so a
virtual probe loss is made of the *same float ops* as the materialized
perturb -> forward -> restore sequence — only the two parameter sweeps
around the forward disappear.

z-consistency contract (shared bit-for-bit with ``kernels.ops.zo_axpy``):

    leaf_seed  = fold(step_seed, leaf_uid(path))    # path = tree-path str
    layer_seed = fold(leaf_seed, l)                 # l = 0 for unstacked
    z[i, ...]  = counter_normal(layer_seed, flat_index_within_layer)

Everything here is element-wise jnp over broadcasted iotas plus the
model's own matmul, so the oracle lowers anywhere, shards under pjit with
zero communication (each device generates exactly its shard of z — the
property ``kernels/ref.py`` established for the axpy), and serves as the
numerical reference the Pallas kernels in ``fused/matmul.py`` are
property-tested against.

Fused virtual-perturbation runtime (DESIGN.md §10).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import rng
from repro.kernels import ref as kref

F32 = jnp.float32


def layer_seed(step_seed, path: str, layer=0):
    """Per-(leaf, layer) RNG seed under the z-consistency contract."""
    ls = rng.fold(jnp.asarray(step_seed, jnp.uint32),
                  jnp.uint32(rng.leaf_uid(path)))
    return rng.fold(ls, jnp.asarray(layer, jnp.uint32))


def zmat(seed, m: int, n: int, *, row0=0, col0=0, ld=None, trans=False):
    """z for an (m, n) window of a stored weight matrix.

    Counters follow the *stored* leaf layout so shards and views agree
    with the axpy sweeps: window element (i, j) has counter
    ``(row0+i)*ld + (col0+j)``.  ``trans=True`` means the window is read
    through a transpose of the stored leaf (the tied LM head consuming
    ``embed/tok.T``): counter = ``(col0+j)*ld + (row0+i)``.  ``ld`` is
    the stored row length (defaults to the window's own: n, or m when
    trans).  ``row0``/``col0`` may be traced (shard offsets).
    """
    rows = (jnp.asarray(row0, jnp.uint32)
            + lax.broadcasted_iota(jnp.uint32, (m, n), 0))
    cols = (jnp.asarray(col0, jnp.uint32)
            + lax.broadcasted_iota(jnp.uint32, (m, n), 1))
    if trans:
        idx = cols * jnp.uint32(m if ld is None else ld) + rows
    else:
        idx = rows * jnp.uint32(n if ld is None else ld) + cols
    return rng.counter_normal(seed, idx)


def _eff_scale(scale, active):
    """Fold the LeZO predicate into the scalar scale: inactive layers add
    ``0 * z`` (exact — z is finite), a scalar select instead of a
    weight-sized one, so XLA never runs a full select pass per matmul."""
    s = jnp.asarray(scale, F32)
    if active is None:
        return s
    return jnp.where(active, s, jnp.zeros((), F32))


def pvec(w, seed, scale, active=None):
    """Virtually perturbed small leaf (norm scale/bias, any shape).

    Returns ``(w + scale*z)`` rounded to ``w.dtype`` — the identical
    floats the materialized axpy writes — as an O(w.size) temp, never a
    parameter-buffer write.  ``active`` (scalar bool) is the LeZO
    per-layer predicate.
    """
    idx = kref._within_layer_index((1,) + w.shape)[0]
    z = rng.counter_normal(seed, idx)
    return (w.astype(F32) + _eff_scale(scale, active) * z).astype(w.dtype)


def pmatmul(x, w, seed, scale, active=None, *, trans=False, ld=None,
            row0=0, col0=0):
    """``x @ (w + scale*z)`` with z regenerated — the oracle for the
    Pallas kernel.  ``w``: (K, N); ``x``: (..., K)."""
    z = zmat(seed, w.shape[0], w.shape[1], row0=row0, col0=col0, ld=ld,
             trans=trans)
    weff = (w.astype(F32) + _eff_scale(scale, active) * z).astype(w.dtype)
    return x @ weff


def _stack_scales(scales, active):
    """(P,) effective scales with per-probe LeZO predicates folded in
    (``0 * z`` is exact — see :func:`_eff_scale`)."""
    s = jnp.asarray(scales, F32)
    if active is None:
        return s
    return jnp.where(jnp.asarray(active, jnp.bool_), s, jnp.zeros((), F32))


def pmatmul_stack(x, w, seeds, scales, active=None, *, trans=False, ld=None,
                  row0=0, col0=0):
    """P stacked probes ``x[p] @ (w + scales[p]*z(seeds[p]))`` — the
    oracle for ``fused.matmul.pmatmul_stack``.  x: (P, ..., K); seeds/
    scales/active: (P,).  The per-probe floats are exactly what P
    separate :func:`pmatmul` calls produce (a batched dot over the probe
    axis evaluates each slice with the same contraction)."""
    P = x.shape[0]
    z = zmat(jnp.asarray(seeds, jnp.uint32).reshape(P, 1, 1),
             w.shape[0], w.shape[1], row0=row0, col0=col0, ld=ld,
             trans=trans)                                    # (P, K, N)
    eff = _stack_scales(scales, active).reshape(P, 1, 1)
    weff = (w[None].astype(F32) + eff * z).astype(w.dtype)
    lead = x.shape[1:-1]
    x2 = x.reshape(P, -1, x.shape[-1])
    out = jnp.einsum("pmk,pkn->pmn", x2, weff)
    return out.reshape(P, *lead, w.shape[1])


def pvec_stack(w, seeds, scales, active=None):
    """P stacked perturbed views of a vector-sized leaf: (P, *w.shape)."""
    P = jnp.asarray(seeds).shape[0]
    idx = kref._within_layer_index((1,) + w.shape)[0]
    z = rng.counter_normal(
        jnp.asarray(seeds, jnp.uint32).reshape((P,) + (1,) * w.ndim),
        idx[None])
    eff = _stack_scales(scales, active).reshape((P,) + (1,) * w.ndim)
    return (w[None].astype(F32) + eff * z).astype(w.dtype)


def pembed(tok_w, tokens, seed, scale):
    """Perturbed embedding lookup: gather first, then add z only for the
    looked-up rows — the z slice is activation-sized, never (V, D)."""
    D = tok_w.shape[-1]
    rows = tok_w[tokens]
    idx = (tokens.astype(jnp.uint32)[..., None] * jnp.uint32(D)
           + jnp.arange(D, dtype=jnp.uint32))
    z = rng.counter_normal(seed, idx)
    return (rows.astype(F32) + jnp.asarray(scale, F32) * z).astype(
        tok_w.dtype)


def ppos(pos_w, pos, S: int, seed, scale):
    """Perturbed learned-position rows ``pos_w[pos:pos+S]``."""
    D = pos_w.shape[-1]
    rows = lax.dynamic_slice_in_dim(pos_w, pos, S, 0)
    r = jnp.asarray(pos, jnp.uint32) + jnp.arange(S, dtype=jnp.uint32)
    idx = r[:, None] * jnp.uint32(D) + jnp.arange(D, dtype=jnp.uint32)
    z = rng.counter_normal(seed, idx)
    return (rows.astype(F32) + jnp.asarray(scale, F32) * z).astype(
        pos_w.dtype)


def pembed_stack(tok_w, tokens, seeds, scales):
    """P stacked perturbed embedding lookups: one gather serves every
    probe; z is regenerated per probe seed (once when all seeds equal —
    XLA CSEs the identical broadcast).  Returns (P, B, S, D)."""
    P = jnp.asarray(seeds).shape[0]
    D = tok_w.shape[-1]
    rows = tok_w[tokens]                                     # (B, S, D)
    idx = (tokens.astype(jnp.uint32)[..., None] * jnp.uint32(D)
           + jnp.arange(D, dtype=jnp.uint32))
    z = rng.counter_normal(
        jnp.asarray(seeds, jnp.uint32).reshape((P,) + (1,) * idx.ndim),
        idx[None])                                           # (P, B, S, D)
    eff = jnp.asarray(scales, F32).reshape((P,) + (1,) * idx.ndim)
    return (rows[None].astype(F32) + eff * z).astype(tok_w.dtype)


def ppos_stack(pos_w, pos, S: int, seeds, scales):
    """P stacked perturbed learned-position windows: (P, S, D)."""
    P = jnp.asarray(seeds).shape[0]
    D = pos_w.shape[-1]
    rows = lax.dynamic_slice_in_dim(pos_w, pos, S, 0)
    r = jnp.asarray(pos, jnp.uint32) + jnp.arange(S, dtype=jnp.uint32)
    idx = r[:, None] * jnp.uint32(D) + jnp.arange(D, dtype=jnp.uint32)
    z = rng.counter_normal(
        jnp.asarray(seeds, jnp.uint32).reshape(P, 1, 1), idx[None])
    eff = jnp.asarray(scales, F32).reshape(P, 1, 1)
    return (rows[None].astype(F32) + eff * z).astype(pos_w.dtype)
