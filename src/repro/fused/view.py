"""PerturbView lens: thread virtual perturbation through a forward pass.

A :class:`PerturbCtx` is the whole perturbation — (seed, scale, LeZO
masks) plus static impl flags — created per probe inside the estimator's
trace and handed to ``models.lm.lm_loss(..., perturb=ctx)``.  The model
derives a :class:`LayerPerturb` handle per (block, layer) as its stage
scan walks the stacked parameters; the handle knows the leaf-path prefix
(static string), the layer index within the stacked axis-0 (traced) and
the layer's active predicate (traced bool), which is everything needed to
reproduce the exact per-leaf z streams of the axpy sweeps
(fused/ref.py's z-consistency contract).

``impl="pallas"`` routes matmuls through the fused kernel
(fused/matmul.py, interpret mode on CPU); ``impl="ref"`` uses the
pure-JAX oracle — same floats, ordinary XLA ops, shards under pjit.
Vector-sized leaves (norm scale/bias) always use the oracle: an O(D)
temp is activation-sized, and a kernel launch would cost more than the
add.

Fused virtual-perturbation runtime (DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.fused import matmul as pk
from repro.fused import ref as fref

IMPLS = ("pallas", "ref")


@dataclasses.dataclass(frozen=True)
class PerturbCtx:
    """One virtual perturbation: theta + scale * z(seed) on active layers."""
    seed: Any                       # traced uint32 direction seed
    scale: Any                      # traced f32: sign * eps
    masks: Optional[Dict[str, Any]]  # group -> (L_g,) bool; None = all on
    impl: str = "pallas"            # pallas | ref      (static)
    interpret: bool = True          # pallas interpret mode (static)

    def group_mask(self, group: str, L: int):
        if self.masks is None or group not in self.masks:
            return jnp.ones((L,), jnp.bool_)
        return self.masks[group]

    def leaf(self, path: str) -> "LayerPerturb":
        """Handle for an always-perturbed unstacked leaf (embeddings,
        head, final norm — the leaves LeZO never drops)."""
        return LayerPerturb(self, path, jnp.uint32(0), jnp.bool_(True))

    def block(self, prefix: str, layer, active) -> "LayerPerturb":
        """Handle for layer ``layer`` of the stacked block at ``prefix``."""
        return LayerPerturb(self, prefix, layer, active)


@dataclasses.dataclass(frozen=True)
class LayerPerturb:
    ctx: PerturbCtx
    prefix: str                     # static leaf-path prefix
    layer: Any                      # traced uint32 index into stacked axis 0
    active: Any                     # traced bool: LeZO predicate

    def child(self, name: str) -> "LayerPerturb":
        return dataclasses.replace(self, prefix=self._p(name))

    def _p(self, name: str) -> str:
        if self.prefix and name:
            return f"{self.prefix}/{name}"
        return self.prefix or name

    def _seed(self, name: str):
        return fref.layer_seed(self.ctx.seed, self._p(name), self.layer)

    def matmul(self, x, w, name: str = "", *, trans: bool = False,
               ld: Optional[int] = None):
        """``x @ (w + scale*z)`` for the leaf at ``prefix/name``."""
        seed = self._seed(name)
        if self.ctx.impl == "ref":
            return fref.pmatmul(x, w, seed, self.ctx.scale, self.active,
                                trans=trans, ld=ld)
        return pk.pmatmul(x, w, seed, self.ctx.scale, self.active,
                          trans=trans, ld=ld, interpret=self.ctx.interpret)

    def vec(self, w, name: str = ""):
        """Virtually perturbed vector-sized leaf (norm scale/bias)."""
        return fref.pvec(w, self._seed(name), self.ctx.scale, self.active)

    def norm(self, p: Dict[str, Any], name: str = "") -> Dict[str, Any]:
        """Perturbed view of a norm param dict ({scale[, bias]})."""
        sub = self.child(name) if name else self
        return {k: sub.vec(v, k) for k, v in p.items()}
