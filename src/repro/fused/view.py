"""PerturbView lens: thread virtual perturbation through a forward pass.

A :class:`PerturbCtx` is the whole perturbation — (seed, scale, LeZO
masks) plus static impl flags — created per probe inside the estimator's
trace and handed to ``models.lm.lm_loss(..., perturb=ctx)``.  The model
derives a :class:`LayerPerturb` handle per (block, layer) as its stage
scan walks the stacked parameters; the handle knows the leaf-path prefix
(static string), the layer index within the stacked axis-0 (traced) and
the layer's active predicate (traced bool), which is everything needed to
reproduce the exact per-leaf z streams of the axpy sweeps
(fused/ref.py's z-consistency contract).

``impl="pallas"`` routes matmuls through the fused kernel
(fused/matmul.py; ``interpret=None`` auto-detects the platform);
``impl="ref"`` uses the pure-JAX oracle — same floats, ordinary XLA ops,
shards under pjit.  Vector-sized leaves (norm scale/bias) always use the
oracle: an O(D) temp is activation-sized, and a kernel launch would cost
more than the add.

Paired probes (:class:`ProbePair`): a ctx may carry P stacked probes —
per-probe (P,) seed/scale vectors riding ONE forward whose activations
fold the probe axis into the batch dim ((P·B, S, D), p-major).  Every
weight matmul then runs as a single stacked kernel pass: each W tile is
loaded once for all P probes, and with ``shared_seed`` (two_point's
antithetic ±εz pair) each z tile is regenerated once and reused for
both signs — halving weight traffic AND z-regens vs. P independent
virtual forwards, with bit-identical per-probe floats (DESIGN.md §10).

Fused virtual-perturbation runtime (DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.fused import matmul as pk
from repro.fused import ref as fref
from repro.obs import trace as obs

IMPLS = ("pallas", "ref")


@dataclasses.dataclass(frozen=True)
class ProbePair:
    """Static description of the stacked probes riding one forward.

    ``n`` is the probe count P (the batch axis is P·B, p-major);
    ``shared_seed`` asserts every probe draws the identical z stream
    (two_point's ±εz pair — seeds differ only in scale sign), letting
    the kernel regenerate each z tile once for all probes."""
    n: int
    shared_seed: bool = False


@dataclasses.dataclass(frozen=True)
class PerturbCtx:
    """One virtual perturbation: theta + scale * z(seed) on active layers.

    Unpaired (``pair is None``): ``seed``/``scale`` are traced scalars
    and ``masks`` maps group -> (L_g,) bool.  Paired: ``seed``/``scale``
    are (P,) vectors, ``masks`` maps group -> (P, L_g), and the model
    folds the probe axis into the batch dim (``lm_loss`` returns a (P,)
    loss vector)."""
    seed: Any                       # traced uint32 seed — scalar | (P,)
    scale: Any                      # traced f32 sign*eps — scalar | (P,)
    masks: Optional[Dict[str, Any]]  # group -> (L_g,) | (P, L_g) bool
    impl: str = "pallas"            # pallas | ref      (static)
    interpret: Optional[bool] = None  # pallas interpret (None = auto)
    pair: Optional[ProbePair] = None  # stacked-probe descriptor (static)

    def group_mask(self, group: str, L: int):
        """Per-layer LeZO mask with the scan's layer axis leading:
        (L,) unpaired, (L, P) paired (the stage scan slices axis 0)."""
        if self.masks is None or group not in self.masks:
            if self.pair is None:
                return jnp.ones((L,), jnp.bool_)
            return jnp.ones((L, self.pair.n), jnp.bool_)
        m = self.masks[group]
        return m if self.pair is None else m.T

    def probe(self, i: int) -> "PerturbCtx":
        """Probe ``i`` of a paired ctx as a plain unpaired ctx — the
        per-probe escape hatch for computations that must stay literally
        the same program as the single-probe path (the chunked-CE
        reductions, whose float association is not stable across batch
        shapes under XLA fusion)."""
        if self.pair is None:
            raise ValueError("probe() requires a paired ctx")
        masks = (None if self.masks is None
                 else {g: m[i] for g, m in self.masks.items()})
        return dataclasses.replace(self, seed=self.seed[i],
                                   scale=self.scale[i], masks=masks,
                                   pair=None)

    def leaf(self, path: str) -> "LayerPerturb":
        """Handle for an always-perturbed unstacked leaf (embeddings,
        head, final norm — the leaves LeZO never drops)."""
        on = (jnp.bool_(True) if self.pair is None
              else jnp.ones((self.pair.n,), jnp.bool_))
        return LayerPerturb(self, path, jnp.uint32(0), on)

    def block(self, prefix: str, layer, active) -> "LayerPerturb":
        """Handle for layer ``layer`` of the stacked block at ``prefix``."""
        return LayerPerturb(self, prefix, layer, active)


def _count_tiles(ctx: PerturbCtx, M: int, K: int, N: int):
    """Host-side structural counters for one stacked-or-not matmul call:
    W tiles entering VMEM and z tiles regenerated.  Deterministic Python
    ints from the grid arithmetic (``matmul.grid_cells``) so the claim
    is provable on CPU where wall-clock is not; no-ops under jit tracing
    like every obs counter, so the eager bench path captures them."""
    tr = obs.get_tracer()
    if not tr.enabled or obs.tracing():
        return
    cells = pk.grid_cells(M, K, N)
    if ctx.pair is None:
        tr.count(obs.CTR_WLOAD, cells)
        tr.count(obs.CTR_ZREGEN, cells)
    else:
        tr.count(obs.CTR_WLOAD, cells)      # one load serves all P probes
        tr.count(obs.CTR_ZREGEN,
                 cells if ctx.pair.shared_seed else cells * ctx.pair.n)


@dataclasses.dataclass(frozen=True)
class LayerPerturb:
    ctx: PerturbCtx
    prefix: str                     # static leaf-path prefix
    layer: Any                      # traced uint32 index into stacked axis 0
    active: Any                     # traced bool LeZO predicate — (P,) paired

    def child(self, name: str) -> "LayerPerturb":
        return dataclasses.replace(self, prefix=self._p(name))

    def _p(self, name: str) -> str:
        if self.prefix and name:
            return f"{self.prefix}/{name}"
        return self.prefix or name

    def _seed(self, name: str):
        return fref.layer_seed(self.ctx.seed, self._p(name), self.layer)

    # ------------------------------------------------------------ shapes
    @property
    def nprobes(self) -> int:
        """Probe count P (0 = unpaired scalar ctx)."""
        return 0 if self.ctx.pair is None else self.ctx.pair.n

    def _split(self, x):
        """(P·B, ..., D) -> (P, B·..., D) — the p-major batch fold."""
        return x.reshape(self.nprobes, -1, x.shape[-1])

    # ----------------------------------------------------------- matmuls
    def matmul(self, x, w, name: str = "", *, trans: bool = False,
               ld: Optional[int] = None):
        """``x @ (w + scale*z)`` for the leaf at ``prefix/name``.  Under
        a paired ctx the probe axis rides x's leading batch dim and the
        stacked kernel runs all P probes off one pass over W."""
        seed = self._seed(name)
        if self.ctx.pair is None:
            _count_tiles(self.ctx, _rows(x), w.shape[0], w.shape[1])
            if self.ctx.impl == "ref":
                return fref.pmatmul(x, w, seed, self.ctx.scale, self.active,
                                    trans=trans, ld=ld)
            return pk.pmatmul(x, w, seed, self.ctx.scale, self.active,
                              trans=trans, ld=ld,
                              interpret=self.ctx.interpret)
        lead = x.shape
        xs = self._split(x)
        _count_tiles(self.ctx, xs.shape[1], w.shape[0], w.shape[1])
        if self.ctx.impl == "ref":
            out = fref.pmatmul_stack(xs, w, seed, self.ctx.scale,
                                     self.active, trans=trans, ld=ld)
        else:
            out = pk.pmatmul_stack(xs, w, seed, self.ctx.scale, self.active,
                                   trans=trans, ld=ld,
                                   interpret=self.ctx.interpret,
                                   shared_seed=self.ctx.pair.shared_seed)
        return out.reshape(*lead[:-1], w.shape[1])

    # ------------------------------------------------------ vector leaves
    def vec(self, w, name: str = ""):
        """Virtually perturbed vector-sized leaf (norm scale/bias);
        paired ctx -> (P, *w.shape)."""
        seed = self._seed(name)
        if self.ctx.pair is None:
            return fref.pvec(w, seed, self.ctx.scale, self.active)
        return fref.pvec_stack(w, seed, self.ctx.scale, self.active)

    def norm(self, p: Dict[str, Any], name: str = "") -> Dict[str, Any]:
        """Perturbed view of a norm param dict ({scale[, bias]}).
        Unpaired only — paired call sites use :meth:`apply_norm` /
        :meth:`rms_norm`, which broadcast the (P, D) perturbed vectors
        against the probe-folded activations."""
        sub = self.child(name) if name else self
        return {k: sub.vec(v, k) for k, v in p.items()}

    def apply_norm(self, cfg, p: Dict[str, Any], x, name: str = ""):
        """``layers.apply_norm`` against the perturbed norm leaves.
        Paired: x is (P·B, ..., D); each probe normalizes against its
        own perturbed (D,) vector via a (P, 1, ..., D) broadcast —
        bit-identical per probe to the unpaired path (elementwise)."""
        from repro.models import layers  # local: avoid import cycle
        if self.ctx.pair is None:
            return layers.apply_norm(cfg, self.norm(p, name), x)
        sub = self.child(name) if name else self
        shp = x.shape
        xs = x.reshape(self.nprobes, -1, shp[-1])
        bc = lambda v: v[:, None, :]                  # (P, D) -> (P, 1, D)
        if cfg.norm == "rms":
            y = layers.rms_norm(xs, bc(sub.vec(p["scale"], "scale")))
        else:
            y = layers.layer_norm(xs, bc(sub.vec(p["scale"], "scale")),
                                  bc(sub.vec(p["bias"], "bias")))
        return y.reshape(shp)

    def rms_norm(self, x, w, name: str = ""):
        """``layers.rms_norm(x, w + scale*z)`` for a bare vector leaf
        (qk-norm).  Paired: per-probe perturbed vectors broadcast over
        the probe-folded leading dim."""
        from repro.models import layers
        if self.ctx.pair is None:
            return layers.rms_norm(x, self.vec(w, name))
        shp = x.shape
        xs = x.reshape(self.nprobes, -1, shp[-1])
        y = layers.rms_norm(xs, self.vec(w, name)[:, None, :])
        return y.reshape(shp)


def _rows(x) -> int:
    """Product of x's leading (non-contraction) dims."""
    m = 1
    for d in x.shape[:-1]:
        m *= d
    return m
