"""Virtual-perturbation fused forward runtime (DESIGN.md §10).

MeZO/LeZO spend >50% of step time sweeping parameters: perturb(+eps),
perturb(-2eps), restore, update.  But with a counter-based RNG, z is a
pure function of (seed, leaf, layer, element) — so the perturbed weights
``theta + s*eps*z`` never need to exist in HBM: the forward pass can
regenerate z inside its matmul tiles and compute ``x @ (W + s*eps*z)``
on the fly.  A two-point ZO step becomes exactly

    2 virtual forwards + 1 fused update axpy

with zero perturb/restore parameter writes, which composes
multiplicatively with LeZO's per-layer skip (the kernels carry the
active predicate) and with the batched estimators in ``repro.estimators``
(one_sided's q probes are q *seeds* of the same weights — no widened
parameter copies).

Pieces:
  * ``ref``      — pure-JAX oracle + the z-consistency contract with
                   ``kernels/ops.py`` (same streams as the axpy sweeps).
  * ``pmatmul``  — the Pallas TPU kernel (interpret-mode CPU fallback).
  * ``view``     — PerturbCtx / LayerPerturb lens the model forward
                   consumes (``lm.lm_loss(..., perturb=ctx)``).
  * ``sharded``  — shard_map wrappers with global counter offsets.

Select it with ``forward_backend="virtual"`` (Pallas) or
``"virtual_ref"`` (oracle, pjit-shardable) on ZOConfig / EstimatorConfig
/ TrainConfig; ``"materialized"`` is the classic perturb-restore path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.estimators.costs import FORWARD_BACKENDS
from repro.fused import ref
from repro.fused.matmul import pmatmul
from repro.fused.sharded import pmatmul_col_sharded, pmatmul_row_sharded
from repro.fused.view import IMPLS, LayerPerturb, PerturbCtx

__all__ = ["FORWARD_BACKENDS", "IMPLS", "LayerPerturb", "PerturbCtx",
           "make_ctx", "pmatmul", "pmatmul_col_sharded",
           "pmatmul_row_sharded", "ref"]


def make_ctx(seed, scale, masks, forward_backend: str,
             interpret: bool = True) -> PerturbCtx:
    """Build the perturbation lens for one probe of ``forward_backend``."""
    if forward_backend not in FORWARD_BACKENDS[1:]:
        raise ValueError(
            f"not a virtual forward backend: {forward_backend!r}; "
            f"pick from {FORWARD_BACKENDS[1:]}")
    impl = "ref" if forward_backend == "virtual_ref" else "pallas"
    return PerturbCtx(seed=jnp.asarray(seed, jnp.uint32),
                      scale=jnp.asarray(scale, jnp.float32),
                      masks=masks, impl=impl, interpret=interpret)
