"""Virtual-perturbation fused forward runtime (DESIGN.md §10).

MeZO/LeZO spend >50% of step time sweeping parameters: perturb(+eps),
perturb(-2eps), restore, update.  But with a counter-based RNG, z is a
pure function of (seed, leaf, layer, element) — so the perturbed weights
``theta + s*eps*z`` never need to exist in HBM: the forward pass can
regenerate z inside its matmul tiles and compute ``x @ (W + s*eps*z)``
on the fly.  A two-point ZO step becomes exactly

    2 virtual forwards + 1 fused update axpy

with zero perturb/restore parameter writes, which composes
multiplicatively with LeZO's per-layer skip (the kernels carry the
active predicate) and with the batched estimators in ``repro.estimators``
(one_sided's q probes are q *seeds* of the same weights — no widened
parameter copies).

Pieces:
  * ``ref``      — pure-JAX oracle + the z-consistency contract with
                   ``kernels/ops.py`` (same streams as the axpy sweeps).
  * ``pmatmul``  — the Pallas TPU kernel (interpret-mode CPU fallback).
  * ``view``     — PerturbCtx / LayerPerturb lens the model forward
                   consumes (``lm.lm_loss(..., perturb=ctx)``).
  * ``sharded``  — shard_map wrappers with global counter offsets.

Select it with ``forward_backend="virtual"`` (Pallas) or
``"virtual_ref"`` (oracle, pjit-shardable) on ZOConfig / EstimatorConfig
/ TrainConfig; ``"materialized"`` is the classic perturb-restore path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.estimators.costs import FORWARD_BACKENDS
from repro.fused import ref
from repro.fused.matmul import default_interpret, pmatmul, pmatmul_stack
from repro.fused.sharded import pmatmul_col_sharded, pmatmul_row_sharded
from repro.fused.view import IMPLS, LayerPerturb, PerturbCtx, ProbePair

__all__ = ["FORWARD_BACKENDS", "IMPLS", "LayerPerturb", "PerturbCtx",
           "ProbePair", "default_interpret", "make_ctx", "make_pair_ctx",
           "make_stack_ctx", "pmatmul", "pmatmul_col_sharded",
           "pmatmul_row_sharded", "pmatmul_stack", "ref"]


def _impl_of(forward_backend: str) -> str:
    if forward_backend not in FORWARD_BACKENDS[1:]:
        raise ValueError(
            f"not a virtual forward backend: {forward_backend!r}; "
            f"pick from {FORWARD_BACKENDS[1:]}")
    return "ref" if forward_backend == "virtual_ref" else "pallas"


def make_ctx(seed, scale, masks, forward_backend: str,
             interpret=None) -> PerturbCtx:
    """Build the perturbation lens for one probe of ``forward_backend``.
    ``interpret=None`` auto-detects the platform (compiled on TPU)."""
    return PerturbCtx(seed=jnp.asarray(seed, jnp.uint32),
                      scale=jnp.asarray(scale, jnp.float32),
                      masks=masks, impl=_impl_of(forward_backend),
                      interpret=interpret)


def make_pair_ctx(seed, eps, masks, forward_backend: str,
                  interpret=None) -> PerturbCtx:
    """The antithetic ±εz pair as ONE stacked ctx: probe 0 is +eps,
    probe 1 is -eps, both drawing the identical z stream (shared seed) —
    the fused forward loads every W tile and regenerates every z tile
    once for the pair.  ``lm_loss`` under this ctx returns a (2,) loss
    vector ``[l_plus, l_minus]``."""
    s = jnp.asarray(seed, jnp.uint32)
    e = jnp.asarray(eps, jnp.float32)
    sm = (None if masks is None else
          {g: jnp.broadcast_to(m, (2,) + m.shape) for g, m in masks.items()})
    return PerturbCtx(seed=jnp.stack([s, s]),
                      scale=jnp.stack([e, -e]),
                      masks=sm, impl=_impl_of(forward_backend),
                      interpret=interpret,
                      pair=ProbePair(n=2, shared_seed=True))


def make_stack_ctx(seeds, scales, masks, forward_backend: str,
                   interpret=None) -> PerturbCtx:
    """P independent probes stacked on one forward (one_sided's q
    probes): ``seeds``/``scales`` are (P,) vectors, ``masks`` maps group
    -> (P, L_g).  W tiles are loaded once for all P probes; z streams
    stay per-seed.  ``lm_loss`` returns a (P,) loss vector."""
    seeds = jnp.asarray(seeds, jnp.uint32)
    P = seeds.shape[0]
    return PerturbCtx(seed=seeds,
                      scale=jnp.broadcast_to(
                          jnp.asarray(scales, jnp.float32), (P,)),
                      masks=masks, impl=_impl_of(forward_backend),
                      interpret=interpret,
                      pair=ProbePair(n=P, shared_seed=False))
