"""Fused virtual-perturbation matmul Pallas kernel.

Computes ``x @ (W + scale * z)`` where ``z`` never exists in HBM: each
(block_k, block_n) tile of W is read into VMEM, its z tile is regenerated
there from the counter RNG (``core.rng`` — identical streams to the axpy
kernels, see fused/ref.py for the contract), added at f32, rounded back to
the weight dtype (so the product matches the materialized perturbed
weights bit-for-bit at the tile level), and fed straight to the MXU.

This is what deletes MeZO's perturb and restore parameter sweeps: the
perturbed weights are a property of the *dataflow*, not of memory.  Per
step the parameters are read 2x (the two probe forwards — which a
forward does anyway) and written exactly once (the update axpy).

LeZO's layer skip is a scalar ``active`` predicate in SMEM: ``pl.when``
routes inactive layers to a plain matmul with zero RNG work, composing
the paper's layer sparsity with virtual perturbation multiplicatively.

Layout: grid = (M/bm, N/bn, K/bk) with K innermost; a VMEM f32 scratch
accumulates across K tiles and flushes on the last one.  Inputs are
zero-padded up to block multiples on the host side (padded K columns of
x are zero, so garbage z in the padded region contributes nothing;
padded M/N are sliced off the output), which keeps the kernel body
branch-free and interpret-mode exact.

``row_off``/``col_off`` shift the counter window: a shard holding cols
[c0, c0+n) of W passes ``col_off=c0`` and ``ld=N`` and computes exactly
its slice of the global z with no communication (see fused/sharded.py).
``trans`` reads the counters through a transpose of the stored leaf —
the tied LM head consuming ``embed/tok.T``.

Fused virtual-perturbation runtime (DESIGN.md §10).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng

# (8, 128)-aligned f32 tiles; 3 buffers * 64 KiB leaves plenty of VMEM
# headroom double-buffered.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def default_interpret() -> bool:
    """Pallas interpret-mode default: compiled kernels on a real TPU,
    the interpreter everywhere else (CPU containers, CI)."""
    try:
        return jax.default_backend() != "tpu"
    except Exception:  # pragma: no cover - jax not initialized yet
        return True


def _resolve_interpret(interpret):
    return default_interpret() if interpret is None else bool(interpret)


def grid_cells(M: int, K: int, N: int, block_m: int = BLOCK_M,
               block_n: int = BLOCK_N, block_k: int = BLOCK_K) -> int:
    """Number of (i, j, k) grid cells a ``pmatmul`` of these dims runs —
    each cell loads one (bk, bn) W tile into VMEM and (when active)
    regenerates its z tile.  Shared with the oracle path so the
    structural W-traffic counters (obs.CTR_WLOAD / CTR_ZREGEN) report
    the same dataflow regardless of impl."""
    bm = min(block_m, _round_up(max(M, 1), 8))
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, _round_up(K, 128))
    return ((_round_up(M, bm) // bm) * (_round_up(N, bn) // bn)
            * (_round_up(K, bk) // bk))


def _kernel(seed_ref, scale_ref, active_ref, offs_ref, x_ref, w_ref, o_ref,
            acc_ref, *, nk, bk, bn, ld, trans):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active_ref[0])
    def _perturbed():
        row0 = offs_ref[0] + (k * bk).astype(jnp.uint32)
        col0 = offs_ref[1] + (j * bn).astype(jnp.uint32)
        ri = row0 + lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
        ci = col0 + lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
        idx = (ci * jnp.uint32(ld) + ri) if trans \
            else (ri * jnp.uint32(ld) + ci)
        z = rng.counter_normal(seed_ref[0], idx)
        w = w_ref[...]
        weff = (w.astype(jnp.float32) + scale_ref[0] * z).astype(w.dtype)
        acc_ref[...] += jnp.dot(x_ref[...], weff,
                                preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_not(active_ref[0]))
    def _plain():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _round_up(a: int, b: int) -> int:
    return -(-a // b) * b


@functools.partial(jax.jit, static_argnames=("trans", "ld", "block_m",
                                             "block_n", "block_k",
                                             "interpret"))
def pmatmul(x, w, seed, scale, active=None, *, trans=False, ld=None,
            row_off=0, col_off=0, block_m=BLOCK_M, block_n=BLOCK_N,
            block_k=BLOCK_K, interpret=None):
    """``x @ (w + scale*z)`` without materializing the perturbed weights.

    x: (..., K); w: (K, N); seed uint32 scalar (pre-folded per leaf and
    layer, fused/ref.layer_seed); scale f32 scalar (sign * eps); active:
    scalar bool LeZO predicate (None = always on).  ``ld``/``trans``/
    ``row_off``/``col_off`` define the counter window into the stored
    leaf (see module docstring); oracle: ``fused.ref.pmatmul``.
    ``interpret=None`` auto-detects the platform (compiled on TPU).
    """
    interpret = _resolve_interpret(interpret)
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, K)
    ld = (w.shape[0] if trans else N) if ld is None else ld

    bm = min(block_m, _round_up(max(M, 1), 8))
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, _round_up(K, 128))
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    x2 = jnp.pad(x2, [(0, Mp - M), (0, Kp - K)])
    wp = jnp.pad(w, [(0, Kp - K), (0, Np - N)])
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)

    active = jnp.bool_(True) if active is None else active
    offs = jnp.stack([jnp.asarray(row_off, jnp.uint32),
                      jnp.asarray(col_off, jnp.uint32)])
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bk=bk, bn=bn, ld=ld, trans=trans),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # seed   (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # scale  (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # active (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # offs   (2,)
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(
        jnp.asarray(seed, jnp.uint32).reshape(1),
        jnp.asarray(scale, jnp.float32).reshape(1),
        jnp.asarray(active, jnp.bool_).reshape(1),
        offs,
        x2,
        wp,
    )
    return out[:M, :N].reshape(*lead, N)


def _kernel_stack(seed_ref, scale_ref, active_ref, offs_ref, x_ref, w_ref,
                  o_ref, acc_ref, *, nk, bk, bn, ld, trans, nprobes,
                  shared_seed):
    """P-probe body: one W tile serves every probe.  ``x_ref``/``o_ref``/
    ``acc_ref`` carry a leading probe axis (P, bm, ·); seed/scale/active
    are (P,) SMEM vectors.  With ``shared_seed`` (the antithetic ±εz
    pair) the z tile is regenerated ONCE and reused for both signs —
    the W tile is loaded once either way.  Inactive probes fold the
    LeZO predicate into a zero scale: ``(w + 0*z)`` rounds back to ``w``
    exactly (z is finite), so a skipped layer's contribution is
    bit-identical to the plain matmul."""
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    any_active = active_ref[0]
    for p in range(1, nprobes):
        any_active = jnp.logical_or(any_active, active_ref[p])

    @pl.when(any_active)
    def _perturbed():
        row0 = offs_ref[0] + (k * bk).astype(jnp.uint32)
        col0 = offs_ref[1] + (j * bn).astype(jnp.uint32)
        ri = row0 + lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
        ci = col0 + lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
        idx = (ci * jnp.uint32(ld) + ri) if trans \
            else (ri * jnp.uint32(ld) + ci)
        w = w_ref[...]
        wf = w.astype(jnp.float32)
        z = rng.counter_normal(seed_ref[0], idx) if shared_seed else None
        for p in range(nprobes):
            zp = z if shared_seed else rng.counter_normal(seed_ref[p], idx)
            sp = jnp.where(active_ref[p], scale_ref[p],
                           jnp.zeros((), jnp.float32))
            weff = (wf + sp * zp).astype(w.dtype)
            acc_ref[p] += jnp.dot(x_ref[p], weff,
                                  preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_not(any_active))
    def _plain():
        w = w_ref[...]
        for p in range(nprobes):
            acc_ref[p] += jnp.dot(x_ref[p], w,
                                  preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("trans", "ld", "block_m",
                                             "block_n", "block_k",
                                             "interpret", "shared_seed"))
def pmatmul_stack(x, w, seeds, scales, active=None, *, trans=False, ld=None,
                  row_off=0, col_off=0, block_m=BLOCK_M, block_n=BLOCK_N,
                  block_k=BLOCK_K, interpret=None, shared_seed=False):
    """P stacked probes of ``x_p @ (w + scales[p] * z(seeds[p]))`` in one
    kernel pass — each (bk, bn) tile of W enters VMEM once for all P
    probes instead of once per probe.

    x: (P, ..., K); w: (K, N); seeds/scales: (P,) uint32 / f32; active:
    (P,) bool per-probe LeZO predicate (None = all on).  Returns
    (P, ..., N).  ``shared_seed=True`` asserts every probe draws the
    same z (two_point's ±εz pair: seeds[p] must all equal seeds[0]) and
    regenerates each z tile once.  Counter-window args as in
    :func:`pmatmul`; oracle: ``fused.ref.pmatmul_stack``.
    """
    interpret = _resolve_interpret(interpret)
    P = x.shape[0]
    lead = x.shape[1:-1]
    K = x.shape[-1]
    N = w.shape[1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(P, M, K)
    ld = (w.shape[0] if trans else N) if ld is None else ld

    bm = min(block_m, _round_up(max(M, 1), 8))
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, _round_up(K, 128))
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    x2 = jnp.pad(x2, [(0, 0), (0, Mp - M), (0, Kp - K)])
    wp = jnp.pad(w, [(0, Kp - K), (0, Np - N)])
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)

    active = jnp.ones((P,), jnp.bool_) if active is None else active
    offs = jnp.stack([jnp.asarray(row_off, jnp.uint32),
                      jnp.asarray(col_off, jnp.uint32)])
    out = pl.pallas_call(
        functools.partial(_kernel_stack, nk=nk, bk=bk, bn=bn, ld=ld,
                          trans=trans, nprobes=P, shared_seed=shared_seed),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # seeds  (P,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # scales (P,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # active (P,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # offs   (2,)
            pl.BlockSpec((P, bm, bk), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((P, bm, bn), lambda i, j, k: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((P, Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, bm, bn), jnp.float32)],
        interpret=interpret,
    )(
        jnp.asarray(seeds, jnp.uint32).reshape(P),
        jnp.asarray(scales, jnp.float32).reshape(P),
        jnp.asarray(active, jnp.bool_).reshape(P),
        offs,
        x2,
        wp,
    )
    return out[:, :M, :N].reshape(P, *lead, N)
