"""Fused virtual-perturbation matmul Pallas kernel.

Computes ``x @ (W + scale * z)`` where ``z`` never exists in HBM: each
(block_k, block_n) tile of W is read into VMEM, its z tile is regenerated
there from the counter RNG (``core.rng`` — identical streams to the axpy
kernels, see fused/ref.py for the contract), added at f32, rounded back to
the weight dtype (so the product matches the materialized perturbed
weights bit-for-bit at the tile level), and fed straight to the MXU.

This is what deletes MeZO's perturb and restore parameter sweeps: the
perturbed weights are a property of the *dataflow*, not of memory.  Per
step the parameters are read 2x (the two probe forwards — which a
forward does anyway) and written exactly once (the update axpy).

LeZO's layer skip is a scalar ``active`` predicate in SMEM: ``pl.when``
routes inactive layers to a plain matmul with zero RNG work, composing
the paper's layer sparsity with virtual perturbation multiplicatively.

Layout: grid = (M/bm, N/bn, K/bk) with K innermost; a VMEM f32 scratch
accumulates across K tiles and flushes on the last one.  Inputs are
zero-padded up to block multiples on the host side (padded K columns of
x are zero, so garbage z in the padded region contributes nothing;
padded M/N are sliced off the output), which keeps the kernel body
branch-free and interpret-mode exact.

``row_off``/``col_off`` shift the counter window: a shard holding cols
[c0, c0+n) of W passes ``col_off=c0`` and ``ld=N`` and computes exactly
its slice of the global z with no communication (see fused/sharded.py).
``trans`` reads the counters through a transpose of the stored leaf —
the tied LM head consuming ``embed/tok.T``.

Fused virtual-perturbation runtime (DESIGN.md §10).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng

# (8, 128)-aligned f32 tiles; 3 buffers * 64 KiB leaves plenty of VMEM
# headroom double-buffered.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _kernel(seed_ref, scale_ref, active_ref, offs_ref, x_ref, w_ref, o_ref,
            acc_ref, *, nk, bk, bn, ld, trans):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active_ref[0])
    def _perturbed():
        row0 = offs_ref[0] + (k * bk).astype(jnp.uint32)
        col0 = offs_ref[1] + (j * bn).astype(jnp.uint32)
        ri = row0 + lax.broadcasted_iota(jnp.uint32, (bk, bn), 0)
        ci = col0 + lax.broadcasted_iota(jnp.uint32, (bk, bn), 1)
        idx = (ci * jnp.uint32(ld) + ri) if trans \
            else (ri * jnp.uint32(ld) + ci)
        z = rng.counter_normal(seed_ref[0], idx)
        w = w_ref[...]
        weff = (w.astype(jnp.float32) + scale_ref[0] * z).astype(w.dtype)
        acc_ref[...] += jnp.dot(x_ref[...], weff,
                                preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_not(active_ref[0]))
    def _plain():
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _round_up(a: int, b: int) -> int:
    return -(-a // b) * b


@functools.partial(jax.jit, static_argnames=("trans", "ld", "block_m",
                                             "block_n", "block_k",
                                             "interpret"))
def pmatmul(x, w, seed, scale, active=None, *, trans=False, ld=None,
            row_off=0, col_off=0, block_m=BLOCK_M, block_n=BLOCK_N,
            block_k=BLOCK_K, interpret=True):
    """``x @ (w + scale*z)`` without materializing the perturbed weights.

    x: (..., K); w: (K, N); seed uint32 scalar (pre-folded per leaf and
    layer, fused/ref.layer_seed); scale f32 scalar (sign * eps); active:
    scalar bool LeZO predicate (None = always on).  ``ld``/``trans``/
    ``row_off``/``col_off`` define the counter window into the stored
    leaf (see module docstring); oracle: ``fused.ref.pmatmul``.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    M = 1
    for d in lead:
        M *= d
    x2 = x.reshape(M, K)
    ld = (w.shape[0] if trans else N) if ld is None else ld

    bm = min(block_m, _round_up(max(M, 1), 8))
    bn = min(block_n, _round_up(N, 128))
    bk = min(block_k, _round_up(K, 128))
    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    x2 = jnp.pad(x2, [(0, Mp - M), (0, Kp - K)])
    wp = jnp.pad(w, [(0, Kp - K), (0, Np - N)])
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)

    active = jnp.bool_(True) if active is None else active
    offs = jnp.stack([jnp.asarray(row_off, jnp.uint32),
                      jnp.asarray(col_off, jnp.uint32)])
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bk=bk, bn=bn, ld=ld, trans=trans),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # seed   (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # scale  (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # active (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # offs   (2,)
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(
        jnp.asarray(seed, jnp.uint32).reshape(1),
        jnp.asarray(scale, jnp.float32).reshape(1),
        jnp.asarray(active, jnp.bool_).reshape(1),
        offs,
        x2,
        wp,
    )
    return out[:M, :N].reshape(*lead, N)
