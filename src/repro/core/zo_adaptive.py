"""Beyond-paper: adaptive ZO optimizers — momentum and Adam-style — with
LeZO's *zero extra memory* property preserved.

Classical ZO-momentum would store a momentum pytree (doubling memory,
defeating MeZO's point).  Observation: the SPSA update direction is
``g_t * z_t`` where ``z_t`` regenerates from (seed, t).  A K-step
momentum update is therefore a *weighted sum of regenerable directions*:

    m_t = sum_{j=0..K-1} beta^j * g_{t-j} * z_{t-j}

so it suffices to keep the last K **scalars** g_{t-j} (K*4 bytes!) and
re-apply each z from its seed — K fused axpy passes instead of one.
With LeZO sparsity each pass touches only that step's active layers, so
the extra compute is K * (1-rho) element-wise passes — and memory stays
exactly (params + a few scalars).

``zo_adam`` additionally tracks a scalar second-moment v_t of the
projected gradient (Adam's per-parameter v collapses to a scalar under
SPSA, because the per-parameter gradient estimate is g * z with z ~
N(0,1): E[(g z)^2] = g^2).  This is the ZO-AdaMM idea reduced to its
memory-free special case.

Both are property-tested for equivalence against explicit-buffer
reference implementations (tests/test_zo_adaptive.py).

ZO core (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import rng, zo


@dataclasses.dataclass(frozen=True)
class ZOMomentumConfig:
    eps: float = 1e-3
    lr: float = 1e-6
    beta: float = 0.9
    history: int = 8              # K regenerated directions
    n_drop: int = 0
    backend: str = "dense"
    adam: bool = False            # scale by 1/sqrt(v) of projected grads
    adam_beta2: float = 0.999
    adam_eps: float = 1e-8
    interpret: bool = True


def make_zo_momentum_step(loss_fn: Callable, spec: zo.ZOSpec,
                          cfg: ZOMomentumConfig,
                          lr_schedule: Optional[Callable] = None):
    """State = (params, g_history (K,) f32, v_scalar) — O(K) extra bytes.

    Each step: SPSA estimate through the estimator subsystem (a two-point
    :class:`~repro.estimators.TwoPointSPSA` probe, restored immediately),
    push g_t into the ring, then apply the momentum-weighted sum of the
    last K directions, regenerating each z_{t-j} (and its layer subset)
    from (base_seed, t-j) — the same regenerate-from-seed trick the
    estimator DirectionSets are built on.
    """
    from repro import estimators  # local import: estimators builds on zo

    sched = lr_schedule or (lambda t: cfg.lr)
    K = cfg.history
    est = estimators.build_estimator(
        spec, estimators.EstimatorConfig(
            name="two_point", eps=cfg.eps, lr=cfg.lr, n_drop=cfg.n_drop,
            policy="stratified", backend=cfg.backend, fused_update=False,
            interpret=cfg.interpret))

    def init_state():
        return {"g_hist": jnp.zeros((K,), jnp.float32),
                "v": jnp.zeros((), jnp.float32),
                "count": jnp.zeros((), jnp.int32)}

    def step(params, state, batch, step_idx, base_seed):
        seed = rng.fold(jnp.asarray(base_seed, jnp.uint32),
                        jnp.asarray(step_idx, jnp.uint32))
        ax = lambda p, s, sd, m, i: zo.tree_axpy(
            p, spec, sd, s, m, i, backend=cfg.backend,
            interpret=cfg.interpret)

        # SPSA probe + immediate restore (unfused: momentum owns the update)
        p, dirs, em = est.estimate(loss_fn, params, batch, seed, state)
        p = est.restore_probe(p, dirs)
        g = dirs.coeffs[0]

        g_hist = jnp.roll(state["g_hist"], 1).at[0].set(g)
        count = state["count"] + 1
        v = cfg.adam_beta2 * state["v"] + (1 - cfg.adam_beta2) * g * g
        lr = sched(step_idx)
        if cfg.adam:
            vhat = v / (1 - cfg.adam_beta2 ** count.astype(jnp.float32))
            lr = lr / (jnp.sqrt(vhat) + cfg.adam_eps)

        # momentum: re-apply the last K directions with beta^j weights.
        # j runs over history; steps before 0 contribute g=0 (ring init).
        def apply_j(j, p):
            t_j = step_idx - j
            seed_j = rng.fold(jnp.asarray(base_seed, jnp.uint32),
                              jnp.asarray(t_j, jnp.uint32))
            masks_j, idxs_j, _ = est.select(seed_j, state)
            scale = -lr * (cfg.beta ** j.astype(jnp.float32)) * g_hist[j]
            valid = (t_j >= 0).astype(jnp.float32)
            return ax(p, scale * valid, seed_j, masks_j, idxs_j)

        p = jax.lax.fori_loop(0, K, apply_j, p)
        new_state = {"g_hist": g_hist, "v": v, "count": count}
        metrics = {"loss": em["loss"], "projected_grad": g, "lr": lr}
        return p, new_state, metrics

    return step, init_state
