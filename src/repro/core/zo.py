"""LeZO / MeZO optimizer core: layer-sparse SPSA + ZO-SGD over pytrees.

The optimizer sees parameters through a :class:`ZOSpec`, which labels each
leaf as either *always-perturbed* (embeddings, head, final norm, PEFT
vectors) or *stacked over a layer group* (axis 0 = layers of one
homogeneous block group — see models.lm).

A single ZO step (Algorithm 1 of the paper)::

    active  = select(seed_t)                       # LeZO subset
    theta  += eps * z        (on active layers)    # perturb +
    l_plus  = loss(theta)
    theta  -= 2*eps * z                            # perturb -
    l_minus = loss(theta)
    g       = (l_plus - l_minus) / (2*eps)         # projected grad (scalar!)
    theta  += (eps - lr*g) * z                     # fused restore+update

Every pass regenerates z from (base_seed, step); nothing is stored, and
under data parallelism the only cross-replica values are the two scalar
losses.  ``fused_update=False`` gives the paper-faithful separate
restore + update passes.

Since the estimator refactor (DESIGN.md §6) this module owns the ZOSpec
/ selection / axpy plumbing while the gradient estimate itself lives in
``repro.estimators`` — :func:`make_zo_step` is a compatibility shim over
the ``two_point`` estimator, and FZOO-style batched one-sided, averaged
multi-direction, and importance-weighted estimators are one config away
(``estimators.make_step``).

Layer selection
---------------
``policy="uniform"`` is the paper's policy: drop n_drop of the N global
layers uniformly.  ``policy="stratified"`` (default here) fixes a static
per-group quota (largest-remainder apportionment of n_drop over groups)
and samples uniformly *within* each group — statistically equivalent for
single-group models (i.e. all of the paper's OPT experiments) and
required by the ``gather`` backend, whose compact active buffer needs a
static size per stacked leaf.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import rng, selection
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.obs import trace as obs


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class ZOSpec:
    """Maps parameter leaves to layer groups (see build_spec)."""
    paths: Tuple[str, ...]
    groups: Tuple[Optional[str], ...]
    slices: Dict[str, Tuple[int, int]]   # group -> (start, length) globally
    num_layers: int

    def split_mask(self, active):
        return {g: jax.lax.dynamic_slice(active, (s,), (l,))
                for g, (s, l) in self.slices.items()}

    def quotas(self, n_drop: int) -> Dict[str, int]:
        """Largest-remainder apportionment of n_drop over groups."""
        if self.num_layers == 0:
            # No stacked groups (e.g. a flat toy tree): nothing to drop.
            if n_drop:
                raise ValueError("n_drop > 0 but the spec has no layer groups")
            return {}
        if not 0 <= n_drop < self.num_layers:
            raise ValueError(f"n_drop must be in [0, {self.num_layers})")
        exact = {g: n_drop * L / self.num_layers
                 for g, (_, L) in self.slices.items()}
        base = {g: min(int(e), self.slices[g][1]) for g, e in exact.items()}
        order = sorted(exact, key=lambda g: exact[g] - base[g], reverse=True)
        i = 0
        while sum(base.values()) < n_drop:
            g = order[i % len(order)]
            if base[g] < self.slices[g][1]:
                base[g] += 1
            i += 1
        return base


def build_spec(params, group_fn: Callable[[str], Optional[str]]) -> ZOSpec:
    """``group_fn(path_str)`` returns the layer-group name for a leaf
    stacked over layers on axis 0, or None for always-perturbed leaves."""
    leaves = jax.tree_util.tree_leaves_with_path(params)
    paths, groups, sizes = [], [], {}
    for path, leaf in leaves:
        ps = _path_str(path)
        g = group_fn(ps)
        paths.append(ps)
        groups.append(g)
        if g is not None:
            L = leaf.shape[0]
            if sizes.setdefault(g, L) != L:
                raise ValueError(
                    f"group {g!r}: inconsistent layer counts {sizes[g]} vs {L} at {ps}")
    slices, start = {}, 0
    for g in sorted(sizes):
        slices[g] = (start, sizes[g])
        start += sizes[g]
    return ZOSpec(tuple(paths), tuple(groups), slices, start)


# ----------------------------------------------------------- selection
def _group_rank_bits(seed, salt: str, g: str, L: int):
    """Seeded per-layer ranking bits for group ``g`` — the one hashing
    scheme shared by the uniform and weighted stratified policies."""
    gseed = rng.fold(seed, jnp.uint32(rng.leaf_uid(salt + g)))
    ids = jnp.arange(L, dtype=jnp.uint32)
    return rng.mix32(ids * jnp.uint32(0x9E3779B9) + gseed)


def _mask_from_active(act, L: int):
    return jnp.zeros((L,), jnp.bool_).at[act].set(True)


def stratified_select(spec: ZOSpec, seed, n_drop: int):
    """Per-group masks + static-size active index vectors.

    Returns (masks: {g: (L_g,) bool}, idxs: {g: (L_g - quota_g,) int32},
    n_active).
    """
    quotas = spec.quotas(n_drop)
    masks, idxs = {}, {}
    n_active = 0
    for g, (start, L) in spec.slices.items():
        q = quotas[g]
        bits = _group_rank_bits(seed, "sel/", g, L)
        order = jnp.argsort(bits)
        act = jnp.sort(order[q:]).astype(jnp.int32)      # active, ascending
        masks[g] = _mask_from_active(act, L)
        idxs[g] = act
        n_active += L - q
    return masks, idxs, n_active


def stratified_select_weighted(spec: ZOSpec, seed, n_drop: int, weights):
    """Importance-weighted LeZO selection with static per-group quotas.

    ``weights`` (num_layers,) >= 0, globally indexed like ZOSpec.slices.
    Gumbel top-k by log-weight within each group: heavier layers are kept
    more often, selection stays fully stochastic (every layer has nonzero
    keep probability), and the per-group active count is the same static
    ``L_g - quota_g`` as :func:`stratified_select`, so the gather
    backend's compact buffers keep their shapes.  Uniform weights recover
    the unweighted distribution.
    """
    quotas = spec.quotas(n_drop)
    masks, idxs = {}, {}
    n_active = 0
    for g, (start, L) in spec.slices.items():
        k = L - quotas[g]
        w = jax.lax.dynamic_slice(jnp.asarray(weights, jnp.float32),
                                  (start,), (L,))
        bits = _group_rank_bits(seed, "wsel/", g, L)
        u = jnp.clip((bits >> jnp.uint32(8)).astype(jnp.float32)
                     / jnp.float32(1 << 24), 1e-7, 1.0 - 1e-7)
        gumbel = -jnp.log(-jnp.log(u))
        score = jnp.log(jnp.clip(w, 1e-9, None)) + gumbel
        order = jnp.argsort(-score)
        act = jnp.sort(order[:k]).astype(jnp.int32)      # active, ascending
        masks[g] = _mask_from_active(act, L)
        idxs[g] = act
        n_active += k
    return masks, idxs, n_active


def uniform_select(spec: ZOSpec, seed, n_drop: int):
    """Paper policy: global uniform drop (dynamic per-group counts)."""
    active = selection.uniform_active(seed, spec.num_layers, n_drop)
    return spec.split_mask(active), None, spec.num_layers - n_drop


# ----------------------------------------------------------------- axpy
def tree_axpy(params, spec: ZOSpec, seed, scale, masks, idxs=None, *,
              decay=1.0, backend="dense", interpret=True):
    """theta <- decay*theta + scale*z on active layers, identity elsewhere."""
    obs.get_tracer().count(obs.CTR_AXPY)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    assert len(leaves) == len(spec.paths), "params tree changed since build_spec"
    out = []
    for leaf, path, group in zip(leaves, spec.paths, spec.groups):
        mask = None if group is None else masks[group]
        aidx = None if (group is None or idxs is None) else idxs[group]
        out.append(kops.zo_axpy(
            leaf, path=path, seed=seed, scale=scale, decay=decay,
            mask=mask, active_idx=aidx, backend=backend, interpret=interpret))
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------- health / norm identity
def global_layer_mask(spec: ZOSpec, masks):
    """Per-group masks -> one (num_layers,) bool at the global indices."""
    gmask = jnp.zeros((spec.num_layers,), jnp.bool_)
    for g, (start, _) in spec.slices.items():
        gmask = jax.lax.dynamic_update_slice(gmask, masks[g], (start,))
    return gmask


def leaf_shapes(params) -> Tuple[Tuple[int, ...], ...]:
    """Static leaf shapes in ``ZOSpec.paths`` order (jit-safe input to
    :func:`active_param_count` / :func:`tree_z_norm`)."""
    return tuple(tuple(leaf.shape)
                 for leaf in jax.tree_util.tree_leaves(params))


def active_param_count(spec: ZOSpec, shapes, masks):
    """f32 count of parameters one direction's z touches: full sizes for
    always-perturbed leaves + mask-selected rows of stacked leaves.
    Float because 13B-scale counts overflow int32; exact up to 2^24 per
    leaf times active layers, plenty for the E‖z‖² = N norm estimate."""
    total = jnp.float32(0.0)
    for shape, group in zip(shapes, spec.groups):
        if group is None:
            total = total + jnp.float32(math.prod(shape))
        else:
            per_layer = float(math.prod(shape[1:]))
            total = total + jnp.sum(
                masks[group].astype(jnp.float32)) * jnp.float32(per_layer)
    return total


def tree_z_norm(spec: ZOSpec, shapes, seed, masks):
    """Exact ‖z(seed)‖ over the active subset — the RNG-stream norm
    identity: z is a pure function of (seed, leaf, layer, element), so
    the magnitude of the update ``-lr·g·z`` a recorded step applied is
    ``|lr·g| * tree_z_norm(...)`` without ever materializing z alongside
    the parameters.  Regenerates each leaf's stream exactly as
    ``kernels/ops.zo_axpy`` does (same fold(seed, leaf_uid) keying,
    single pseudo-layer for ungrouped leaves)."""
    seed = jnp.asarray(seed, jnp.uint32)
    total = jnp.float32(0.0)
    for shape, path, group in zip(shapes, spec.paths, spec.groups):
        leaf_seed = rng.fold(seed, jnp.uint32(rng.leaf_uid(path)))
        if group is None:
            z = kref.leaf_normal_nd(leaf_seed, (1,) + tuple(shape))
            total = total + jnp.sum(z * z)
        else:
            z = kref.leaf_normal_nd(leaf_seed, tuple(shape))
            m = masks[group].astype(jnp.float32).reshape(
                (shape[0],) + (1,) * (len(shape) - 1))
            total = total + jnp.sum(m * z * z)
    return jnp.sqrt(total)


@dataclasses.dataclass(frozen=True)
class ZOConfig:
    eps: float = 1e-3
    lr: float = 1e-6
    n_drop: int = 0               # 0 => MeZO; >0 => LeZO
    policy: str = "stratified"    # stratified | uniform
    backend: str = "dense"        # dense | scan | gather | pallas
    fused_update: bool = True     # beyond-paper single restore+update pass
    weight_decay: float = 0.0
    interpret: bool = True        # pallas interpret mode (CPU container)
    # materialized = classic perturb/forward/restore sweeps;
    # virtual[_ref] = fused forward regenerates z in-kernel, the step is
    # 2 forwards + 1 update axpy with zero perturb/restore writes
    # (repro.fused, DESIGN.md §10)
    forward_backend: str = "materialized"
    # stack the virtual ±εz pair onto ONE paired forward (each W tile
    # loaded and each z tile regenerated once per pair) — bit-identical
    # to the per-probe virtual path; ignored when materialized
    paired_probes: bool = True


def make_zo_step(loss_fn: Callable, spec: ZOSpec, cfg: ZOConfig,
                 lr_schedule: Optional[Callable] = None):
    """Build the jit-able ZO step: step(params, batch, step_idx, base_seed)
    -> (params, metrics).  ``loss_fn(params, batch) -> scalar`` must
    average over the (possibly sharded) batch.  Donate params at jit time.

    Since the estimator refactor this is a thin shim over the two-point
    estimator in ``repro.estimators`` — the probe/update op sequence (and
    therefore every result bit) is unchanged from the original inline
    implementation; tests/test_estimators.py holds the line.  Callers who
    want a different estimator (one_sided, averaged, importance) use
    ``estimators.make_step`` directly, which also threads estimator state.
    """
    if cfg.backend == "gather" and cfg.policy != "stratified":
        raise ValueError("gather backend requires the stratified policy")
    from repro import estimators  # local import: estimators builds on zo

    ecfg = estimators.from_zo(cfg)
    estep, _ = estimators.make_step(loss_fn, spec, ecfg, lr_schedule)

    def step(params, batch, step_idx, base_seed):
        p, _state, metrics = estep(params, {}, batch, step_idx, base_seed)
        return p, metrics

    return step
