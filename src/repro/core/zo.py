"""LeZO / MeZO optimizer core: layer-sparse SPSA + ZO-SGD over pytrees.

The optimizer sees parameters through a :class:`ZOSpec`, which labels each
leaf as either *always-perturbed* (embeddings, head, final norm, PEFT
vectors) or *stacked over a layer group* (axis 0 = layers of one
homogeneous block group — see models.lm).

A single ZO step (Algorithm 1 of the paper)::

    active  = select(seed_t)                       # LeZO subset
    theta  += eps * z        (on active layers)    # perturb +
    l_plus  = loss(theta)
    theta  -= 2*eps * z                            # perturb -
    l_minus = loss(theta)
    g       = (l_plus - l_minus) / (2*eps)         # projected grad (scalar!)
    theta  += (eps - lr*g) * z                     # fused restore+update

Every pass regenerates z from (base_seed, step); nothing is stored, and
under data parallelism the only cross-replica values are the two scalar
losses.  ``fused_update=False`` gives the paper-faithful separate
restore + update passes.

Layer selection
---------------
``policy="uniform"`` is the paper's policy: drop n_drop of the N global
layers uniformly.  ``policy="stratified"`` (default here) fixes a static
per-group quota (largest-remainder apportionment of n_drop over groups)
and samples uniformly *within* each group — statistically equivalent for
single-group models (i.e. all of the paper's OPT experiments) and
required by the ``gather`` backend, whose compact active buffer needs a
static size per stacked leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import rng, selection
from repro.kernels import ops as kops


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class ZOSpec:
    """Maps parameter leaves to layer groups (see build_spec)."""
    paths: Tuple[str, ...]
    groups: Tuple[Optional[str], ...]
    slices: Dict[str, Tuple[int, int]]   # group -> (start, length) globally
    num_layers: int

    def split_mask(self, active):
        return {g: jax.lax.dynamic_slice(active, (s,), (l,))
                for g, (s, l) in self.slices.items()}

    def quotas(self, n_drop: int) -> Dict[str, int]:
        """Largest-remainder apportionment of n_drop over groups."""
        if not 0 <= n_drop < self.num_layers:
            raise ValueError(f"n_drop must be in [0, {self.num_layers})")
        exact = {g: n_drop * L / self.num_layers
                 for g, (_, L) in self.slices.items()}
        base = {g: min(int(e), self.slices[g][1]) for g, e in exact.items()}
        order = sorted(exact, key=lambda g: exact[g] - base[g], reverse=True)
        i = 0
        while sum(base.values()) < n_drop:
            g = order[i % len(order)]
            if base[g] < self.slices[g][1]:
                base[g] += 1
            i += 1
        return base


def build_spec(params, group_fn: Callable[[str], Optional[str]]) -> ZOSpec:
    """``group_fn(path_str)`` returns the layer-group name for a leaf
    stacked over layers on axis 0, or None for always-perturbed leaves."""
    leaves = jax.tree_util.tree_leaves_with_path(params)
    paths, groups, sizes = [], [], {}
    for path, leaf in leaves:
        ps = _path_str(path)
        g = group_fn(ps)
        paths.append(ps)
        groups.append(g)
        if g is not None:
            L = leaf.shape[0]
            if sizes.setdefault(g, L) != L:
                raise ValueError(
                    f"group {g!r}: inconsistent layer counts {sizes[g]} vs {L} at {ps}")
    slices, start = {}, 0
    for g in sorted(sizes):
        slices[g] = (start, sizes[g])
        start += sizes[g]
    return ZOSpec(tuple(paths), tuple(groups), slices, start)


# ----------------------------------------------------------- selection
def stratified_select(spec: ZOSpec, seed, n_drop: int):
    """Per-group masks + static-size active index vectors.

    Returns (masks: {g: (L_g,) bool}, idxs: {g: (L_g - quota_g,) int32},
    n_active).
    """
    quotas = spec.quotas(n_drop)
    masks, idxs = {}, {}
    n_active = 0
    for g, (start, L) in spec.slices.items():
        q = quotas[g]
        gseed = rng.fold(seed, jnp.uint32(rng.leaf_uid("sel/" + g)))
        ids = jnp.arange(L, dtype=jnp.uint32)
        bits = rng.mix32(ids * jnp.uint32(0x9E3779B9) + gseed)
        order = jnp.argsort(bits)
        act = jnp.sort(order[q:]).astype(jnp.int32)      # active, ascending
        masks[g] = jnp.zeros((L,), jnp.bool_).at[act].set(True)
        idxs[g] = act
        n_active += L - q
    return masks, idxs, n_active


def uniform_select(spec: ZOSpec, seed, n_drop: int):
    """Paper policy: global uniform drop (dynamic per-group counts)."""
    active = selection.uniform_active(seed, spec.num_layers, n_drop)
    return spec.split_mask(active), None, spec.num_layers - n_drop


# ----------------------------------------------------------------- axpy
def tree_axpy(params, spec: ZOSpec, seed, scale, masks, idxs=None, *,
              decay=1.0, backend="dense", interpret=True):
    """theta <- decay*theta + scale*z on active layers, identity elsewhere."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    assert len(leaves) == len(spec.paths), "params tree changed since build_spec"
    out = []
    for leaf, path, group in zip(leaves, spec.paths, spec.groups):
        mask = None if group is None else masks[group]
        aidx = None if (group is None or idxs is None) else idxs[group]
        out.append(kops.zo_axpy(
            leaf, path=path, seed=seed, scale=scale, decay=decay,
            mask=mask, active_idx=aidx, backend=backend, interpret=interpret))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass(frozen=True)
class ZOConfig:
    eps: float = 1e-3
    lr: float = 1e-6
    n_drop: int = 0               # 0 => MeZO; >0 => LeZO
    policy: str = "stratified"    # stratified | uniform
    backend: str = "dense"        # dense | scan | gather | pallas
    fused_update: bool = True     # beyond-paper single restore+update pass
    weight_decay: float = 0.0
    interpret: bool = True        # pallas interpret mode (CPU container)


def make_zo_step(loss_fn: Callable, spec: ZOSpec, cfg: ZOConfig,
                 lr_schedule: Optional[Callable] = None):
    """Build the jit-able ZO step: step(params, batch, step_idx, base_seed)
    -> (params, metrics).  ``loss_fn(params, batch) -> scalar`` must
    average over the (possibly sharded) batch.  Donate params at jit time."""
    if cfg.backend == "gather" and cfg.policy != "stratified":
        raise ValueError("gather backend requires the stratified policy")
    sched = lr_schedule or (lambda t: cfg.lr)

    def step(params, batch, step_idx, base_seed):
        seed = rng.fold(jnp.asarray(base_seed, jnp.uint32),
                        jnp.asarray(step_idx, jnp.uint32))
        if cfg.policy == "stratified":
            masks, idxs, n_active = stratified_select(spec, seed, cfg.n_drop)
        else:
            masks, idxs, n_active = uniform_select(spec, seed, cfg.n_drop)
        ax = lambda p, s, d=1.0: tree_axpy(
            p, spec, seed, s, masks, idxs, decay=d,
            backend=cfg.backend, interpret=cfg.interpret)

        p = ax(params, cfg.eps)
        l_plus = loss_fn(p, batch)
        p = ax(p, -2.0 * cfg.eps)
        l_minus = loss_fn(p, batch)
        g = (l_plus - l_minus) / (2.0 * cfg.eps)
        lr = sched(step_idx)
        decay = 1.0 - lr * cfg.weight_decay
        if cfg.fused_update:
            p = ax(p, cfg.eps - lr * g, decay)
        else:  # paper-faithful two passes
            p = ax(p, cfg.eps)               # restore
            p = ax(p, -lr * g, decay)        # ZO-SGD update
        metrics = {
            "loss": 0.5 * (l_plus + l_minus),
            "projected_grad": g,
            "lr": lr,
            "active_layers": jnp.asarray(n_active, jnp.int32),
        }
        return p, metrics

    return step
