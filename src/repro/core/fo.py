"""First-order baselines (the paper's "FT" rows): SGD / momentum / AdamW.

Self-contained pytree optimizers (no optax in the container).  Used by the
trainer for the accuracy-vs-memory comparison in benchmarks/accuracy.py:
FO needs activations + (for AdamW) 2x parameter moments — the "12x memory"
row of Table 1 — while ZO state is just (params, seed, step).

ZO core (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class FOState(NamedTuple):
    mu: Any          # first moment (or momentum buffer); None-like zeros for sgd
    nu: Any          # second moment (adamw only)
    count: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class FOConfig:
    optimizer: str = "adamw"     # sgd | momentum | adamw
    lr: float = 1e-5
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0


def init_state(params, cfg: FOConfig) -> FOState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    if cfg.optimizer == "sgd":
        z = jax.tree.map(lambda x: jnp.zeros((), x.dtype), params)  # token state
        return FOState(z, z, jnp.zeros((), jnp.int32))
    if cfg.optimizer == "momentum":
        return FOState(zeros, jax.tree.map(lambda x: jnp.zeros((), x.dtype), params),
                       jnp.zeros((), jnp.int32))
    return FOState(zeros, jax.tree.map(jnp.zeros_like, params), jnp.zeros((), jnp.int32))


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def make_fo_step(loss_fn: Callable, cfg: FOConfig,
                 lr_schedule: Optional[Callable] = None):
    sched = lr_schedule or (lambda t: cfg.lr)

    def step(params, state: FOState, batch, step_idx):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if cfg.grad_clip is not None:
            gn = _global_norm(grads)
            scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        lr = sched(step_idx)
        count = state.count + 1
        if cfg.optimizer == "sgd":
            new_params = jax.tree.map(
                lambda p, g: p - lr * (g + cfg.weight_decay * p), params, grads)
            new_state = state._replace(count=count)
        elif cfg.optimizer == "momentum":
            mu = jax.tree.map(lambda m, g: cfg.beta1 * m + g, state.mu, grads)
            new_params = jax.tree.map(
                lambda p, m: p - lr * (m + cfg.weight_decay * p), params, mu)
            new_state = state._replace(mu=mu, count=count)
        else:  # adamw
            t = count.astype(jnp.float32)
            mu = jax.tree.map(lambda m, g: cfg.beta1 * m + (1 - cfg.beta1) * g,
                              state.mu, grads)
            nu = jax.tree.map(lambda v, g: cfg.beta2 * v + (1 - cfg.beta2) * g * g,
                              state.nu, grads)
            bc1 = 1.0 - cfg.beta1 ** t
            bc2 = 1.0 - cfg.beta2 ** t
            new_params = jax.tree.map(
                lambda p, m, v: p - lr * ((m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
                                          + cfg.weight_decay * p),
                params, mu, nu)
            new_state = FOState(mu, nu, count)
        return new_params, new_state, {"loss": loss, "lr": lr}

    return step
