"""ZO core (DESIGN.md §2): counter RNG, ZOSpec + axpy plumbing,
selection policies, memory-free adaptive ZO, and the FO baseline.

Layering rule (DESIGN.md §1): this package knows nothing about models
or training — estimators build on it, train/launch consume both.
"""
