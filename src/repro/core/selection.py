"""Layer-selection policies for LeZO.

Each policy returns a boolean ``active`` mask of shape (num_layers,):
True means the layer is perturbed+updated this step, False means dropped
(the paper's "subset a").  ``n_drop = 0`` recovers MeZO exactly.

Policies are pure functions of (seed, step) so every data-parallel replica
— and a restarted job — derives the identical subset with no
communication (the same property the perturbation RNG has).

ZO core (DESIGN.md §2).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import rng

_SALT = 0x5E1EC7  # "select"

POLICIES = ("uniform", "round_robin", "weighted")


def uniform_active(seed, num_layers: int, n_drop: int):
    """Paper policy: drop ``n_drop`` layers uniformly without replacement.

    Implemented as a random ranking: hash each layer id, drop the
    ``n_drop`` smallest.  Hashes collide with probability ~N^2/2^32 —
    negligible, and a collision only slightly biases one step's subset.
    """
    if not 0 <= n_drop < num_layers:
        raise ValueError(f"n_drop must be in [0, {num_layers}), got {n_drop}")
    if n_drop == 0:
        return jnp.ones((num_layers,), jnp.bool_)
    ids = jnp.arange(num_layers, dtype=jnp.uint32)
    bits = rng.mix32(ids * jnp.uint32(0x9E3779B9) + rng.fold(seed, jnp.uint32(_SALT)))
    order = jnp.argsort(bits)  # ascending
    active = jnp.ones((num_layers,), jnp.bool_).at[order[:n_drop]].set(False)
    return active


def round_robin_active(step, num_layers: int, n_drop: int, stride: int = 1):
    """Deterministic rotation: a contiguous window of active layers walks
    the stack.  Zero RNG; useful as an ablation (and for pipeline-friendly
    schedules where the active window aligns with pipeline stages)."""
    k = num_layers - n_drop
    start = (jnp.asarray(step, jnp.int32) * stride) % num_layers
    pos = (jnp.arange(num_layers, dtype=jnp.int32) - start) % num_layers
    return pos < k


def weighted_active(seed, weights, n_drop: int):
    """Beyond-paper: importance-weighted selection via Gumbel top-k.

    ``weights`` (num_layers,) >= 0 — e.g. running |projected_grad|
    attribution per layer.  Layers with larger weight are kept more often,
    LISA-style, while remaining fully stochastic.

    Selection is an argsort top-k mask (like :func:`uniform_active`), not
    a score threshold: thresholding selects more than k layers when
    scores tie (the 24-bit Gumbel draws do collide) and indexes out of
    bounds at k == 0.  ``n_drop == num_layers`` is allowed here (empty
    mask) so callers composing with always-on leaf groups can express
    "drop every stacked layer".
    """
    num_layers = weights.shape[0]
    if not 0 <= n_drop <= num_layers:
        raise ValueError(
            f"n_drop must be in [0, {num_layers}], got {n_drop}")
    k = num_layers - n_drop
    if k == 0:
        return jnp.zeros((num_layers,), jnp.bool_)
    if n_drop == 0:
        return jnp.ones((num_layers,), jnp.bool_)
    ids = jnp.arange(num_layers, dtype=jnp.uint32)
    bits = rng.mix32(ids * jnp.uint32(0x9E3779B9) + rng.fold(seed, jnp.uint32(_SALT + 1)))
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) / jnp.float32(1 << 24)
    gumbel = -jnp.log(-jnp.log(jnp.clip(u, 1e-7, 1.0 - 1e-7)))
    score = jnp.log(jnp.clip(weights, 1e-9, None)) + gumbel
    order = jnp.argsort(-score)
    return jnp.zeros((num_layers,), jnp.bool_).at[order[:k]].set(True)


def make_policy(name: str, num_layers: int, n_drop: int):
    """Returns fn(seed, step, weights) -> active mask."""
    if name == "uniform":
        return lambda seed, step, weights=None: uniform_active(seed, num_layers, n_drop)
    if name == "round_robin":
        return lambda seed, step, weights=None: round_robin_active(step, num_layers, n_drop)
    if name == "weighted":
        return lambda seed, step, weights=None: weighted_active(seed, weights, n_drop)
    raise ValueError(f"unknown policy {name!r}; pick from {POLICIES}")
