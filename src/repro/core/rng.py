"""Counter-based RNG for zeroth-order perturbations.

MeZO/LeZO's memory trick is that the perturbation vector ``z`` is never
stored: it is regenerated from a seed for the +eps pass, the -2*eps pass,
the restore pass and the update pass.  PyTorch does this with a sequential
generator (``torch.manual_seed`` + ordered draws), which bakes in an
iteration *order* over modules and cannot be sharded without bookkeeping.

We instead make ``z`` a pure function of ``(seed, element index)``::

    z[l, i] = normal(mix(seed, leaf_uid, l), i)

so that every device holding any shard of a parameter computes exactly the
bits that correspond to its slice, with zero communication and zero state.
The same functions run inside Pallas kernel bodies (element-wise uint32 ops
only) and in the pure-jnp oracle, so kernel vs. reference comparisons are
bit-exact.

The generator is a 3-round murmur3-style finalizer over a distinct counter
per element ("lowbias32"); two decorrelated streams feed a Box-Muller
transform.  Statistical quality is validated in tests/test_rng.py (moments,
cross-correlation, uniqueness across layers/leaves).

ZO core (DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Distinct odd constants (murmur3/xxhash lineage).
_GOLDEN = np.uint32(0x9E3779B9)
_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_S2 = np.uint32(0x85EBCA6B)
_TWO_PI = np.float32(2.0 * np.pi)


def mix32(x):
    """Murmur3-style avalanche over uint32 (works on scalars and arrays)."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def fold(seed, data):
    """Derive a new uint32 seed from (seed, data) — order matters."""
    from repro.obs import trace as _obs
    _obs.get_tracer().count(_obs.CTR_RNG_FOLDS)
    seed = jnp.asarray(seed, jnp.uint32)
    data = jnp.asarray(data, jnp.uint32)
    return mix32(seed * _GOLDEN + data + _M2)


def fold_py(seed: int, data: int) -> int:
    """Python-int version of :func:`fold` for trace-time seed derivation."""
    m = 0xFFFFFFFF
    x = (seed * 0x9E3779B9 + data + 0x846CA68B) & m
    x ^= x >> 16
    x = (x * 0x7FEB352D) & m
    x ^= x >> 15
    x = (x * 0x846CA68B) & m
    x ^= x >> 16
    return x


def _uniform01(bits):
    """uint32 -> float32 uniform in (0, 1]; never 0 so log() is safe."""
    # Take the top 24 bits -> [0, 2^24), scale to (0,1].
    return (jnp.asarray(bits >> np.uint32(8), jnp.float32) + 1.0) * np.float32(
        1.0 / 16777216.0
    )


def counter_normal(seed, counters):
    """Standard normals, one per counter.

    ``seed`` is a uint32 scalar (may be traced); ``counters`` any uint32
    array of element indices.  Element-wise ops only — safe inside Pallas.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    c = jnp.asarray(counters, jnp.uint32)
    h1 = mix32(c * _GOLDEN + seed)
    h2 = mix32((c + _S2) * _GOLDEN + (seed ^ _S2))
    u1 = _uniform01(h1)
    u2 = _uniform01(h2)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(_TWO_PI * u2)


def leaf_uid(path: str) -> int:
    """Stable uint32 id for a parameter leaf from its tree path string."""
    h = 2166136261  # FNV-1a
    for ch in path.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return h
