"""Prometheus-style metrics: counters, gauges, histograms + text dump.

Aggregate metrics for long-lived processes — the serving engine exports
queue depth, lane occupancy, page-pool utilization, TTFT/latency
histograms and generated-token counts through one :class:`Registry`
(DESIGN.md §13).  ``Registry.to_text()`` renders the Prometheus text
exposition format, so the dump a run writes (``telemetry.prometheus``)
is scrapeable/diffable with standard tooling; no client library is
required or imported.

Histograms use fixed cumulative (``le``) buckets like Prometheus
proper: each bucket counts observations ``<= le``, ``+Inf`` always
exists, and ``_sum``/``_count`` ride along so consumers can derive
means.  The default buckets are latency-shaped (1ms .. 60s).
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

# 1ms .. 60s, roughly logarithmic — TTFT and request latency both fit.
LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    return repr(float(v)) if isinstance(v, float) else str(v)


class Counter:
    """Monotonically increasing count."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def inc(self, n: float = 1.0):
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) < 0")
        self.value += n

    def lines(self) -> List[str]:
        return [f"{self.name} {_fmt(self.value)}"]


class Gauge:
    """A value that goes up and down (queue depth, utilization)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def lines(self) -> List[str]:
        return [f"{self.name} {_fmt(self.value)}"]


class Histogram:
    """Cumulative-bucket histogram (Prometheus ``le`` semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = LATENCY_BUCKETS):
        self.name, self.help = name, help
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError(f"histogram {name}: empty buckets")
        self.buckets: Tuple[float, ...] = tuple(bs)
        self.counts = [0] * (len(self.buckets) + 1)   # +1 for +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        self.sum += v
        self.count += 1
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def quantile(self, q: float) -> float:
        """Approximate quantile off the bucket counts (upper edge of the
        bucket holding the q-th observation; inf if it lands in +Inf)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return float("nan")
        target = q * self.count
        seen = 0
        for i, le in enumerate(self.buckets):
            seen += self.counts[i]
            # seen > 0 guards empty leading buckets: with q == 0 (or all
            # observations past this bucket) `seen >= target` is trivially
            # true and would wrongly return the first bucket's edge.
            if seen > 0 and seen >= target:
                return le
        return float("inf")

    def lines(self) -> List[str]:
        out, cum = [], 0
        for i, le in enumerate(self.buckets):
            cum += self.counts[i]
            out.append(f'{self.name}_bucket{{le="{_fmt(le)}"}} {cum}')
        cum += self.counts[-1]
        out.append(f'{self.name}_bucket{{le="+Inf"}} {cum}')
        out.append(f"{self.name}_sum {_fmt(self.sum)}")
        out.append(f"{self.name}_count {self.count}")
        return out


class Registry:
    """Get-or-create metric store with a text exposition dump."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        kw = {} if buckets is None else {"buckets": buckets}
        return self._get(Histogram, name, help, **kw)

    def metrics(self) -> List[object]:
        return [self._metrics[k] for k in sorted(self._metrics)]

    def to_text(self) -> str:
        """Prometheus text exposition format (sorted, deterministic)."""
        out = []
        for m in self.metrics():
            if m.help:
                out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            out.extend(m.lines())
        return "\n".join(out) + ("\n" if out else "")

    def dump(self, path: str):
        import os
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_text())
