"""Optimizer-health accumulator: the ZO step's scalar vitals, sync-free.

A MeZO/LeZO training step is fully determined by a handful of scalars —
(seed, projected gradient g, ε, lr, active-layer set) — so observing the
*optimizer* (is g-variance blowing up?  is LeZO starving a layer?  how
big are the updates actually landing?) costs almost nothing: buffer the
per-step device scalars, fetch them in one batched transfer every
``log_every`` steps, and derive the running statistics host-side.

:class:`HealthAccumulator` is that buffer.  The contract (DESIGN.md §13):

  * ``record(step, metrics, seed=...)`` keeps references to the step's
    device values — **no** host sync, no ``float()``, nothing that would
    stall the async dispatch pipeline.  It runs every step.
  * ``drain()`` performs ONE batched ``jax.device_get`` over everything
    buffered since the last drain and turns it into JSON-ready step rows
    (the ``repro.obs.runlog`` stream format).  Callers put it where the
    train loop already syncs (the ``log_every`` boundary).
  * Running aggregates — Welford mean/variance of g across the
    antithetic pairs, cumulative per-layer selection counts and
    last-active step under LeZO sparsity — update at drain time.
  * The update magnitude ``‖lr·g·z‖`` comes for free from the RNG-stream
    norm identity: z regenerates from the seed, so ``‖Δθ‖ =
    |lr|·sqrt(Σ_i g_i²·N_i)`` in expectation (N_i = active parameter
    count of direction i, E‖z‖² = N) — recorded as ``update_norm_est``
    every step.  With an exact ``norm_fn`` (``core/zo.tree_z_norm``
    jitted by the trainer when ``telemetry.health_norms=true``) the
    literal ``|lr·g|·‖z(seed)‖`` is computed at drain time, off the hot
    path, as ``update_norm``.

``metrics`` keys are best-effort: a ``zo`` step emits all of them, the
``zo_momentum``/``fo`` modes only ``loss``/``lr`` — missing keys are
simply absent from the row, never an error.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

# Step-metric keys the accumulator snapshots when present.
SCALAR_KEYS = ("loss", "projected_grad", "eps", "lr", "active_layers")
VECTOR_KEYS = ("probe_grads", "coeffs", "n_active_params", "layer_sel",
               "arrived")
# swarm shard rows (DESIGN.md §14): {shard: [l+, l-]} for arrived shards
DICT_KEYS = ("shard_losses",)


def _to_float_list(v) -> List[float]:
    try:
        return [float(x) for x in v.reshape(-1)]
    except AttributeError:
        return [float(x) for x in v]


class HealthAccumulator:
    """Per-step optimizer vitals: sync-free record, batched drain."""

    def __init__(self, num_layers: int = 0, norm_fn=None):
        self.num_layers = int(num_layers)
        self.norm_fn = norm_fn      # optional (seed, layer_sel) -> ||z||
        self._pending: List = []
        self.rows: List[Dict[str, Any]] = []
        # Welford running stats over the per-step projected gradient.
        self.g_count = 0
        self.g_mean = 0.0
        self.g_m2 = 0.0
        # LeZO layer coverage: cumulative selections + last-active step.
        self.layer_counts = [0] * self.num_layers
        self.layer_last = [-1] * self.num_layers
        self.last_step = -1
        # swarm quorum accounting: steps that committed short-handed
        self.sharded_steps = 0
        self.straggler_steps = 0

    # ----------------------------------------------------------- record
    def record(self, step: int, metrics: Dict[str, Any],
               seed: Optional[int] = None):
        """Buffer the step's device values.  Never syncs: the values are
        fetched in one transfer at the next :meth:`drain`."""
        keep = {k: metrics[k]
                for k in SCALAR_KEYS + VECTOR_KEYS + DICT_KEYS
                if k in metrics}
        self._pending.append((int(step), seed, keep))

    def __len__(self):
        return len(self._pending)

    # ------------------------------------------------------------ drain
    def drain(self) -> List[Dict[str, Any]]:
        """Fetch everything buffered since the last drain (one batched
        transfer) and return the new JSON-ready step rows."""
        if not self._pending:
            return []
        import jax
        fetched = jax.device_get([m for _, _, m in self._pending])
        new_rows = []
        for (step, seed, _), vals in zip(self._pending, fetched):
            row: Dict[str, Any] = {"step": step}
            if seed is not None:
                row["seed"] = int(seed)
            for k in SCALAR_KEYS:
                if k in vals:
                    row[k] = float(vals[k])
            for k in ("probe_grads", "coeffs", "n_active_params"):
                if k in vals:
                    row[k] = _to_float_list(vals[k])
            if "layer_sel" in vals:
                row["layer_sel"] = [int(x) for x in vals["layer_sel"]]
            if "arrived" in vals:
                row["arrived"] = [int(x) for x in vals["arrived"]]
            if "shard_losses" in vals:
                row["shard_losses"] = {
                    str(k): [float(x) for x in v]
                    for k, v in vals["shard_losses"].items()}
            if "active_layers" in row:
                row["active_layers"] = int(row["active_layers"])
            self._aggregate(row)
            new_rows.append(row)
        self._pending.clear()
        self.rows.extend(new_rows)
        return new_rows

    def _aggregate(self, row: Dict[str, Any]):
        step = row["step"]
        self.last_step = max(self.last_step, step)
        g = row.get("projected_grad")
        if g is not None and math.isfinite(g):
            self.g_count += 1
            d = g - self.g_mean
            self.g_mean += d / self.g_count
            self.g_m2 += d * (g - self.g_mean)
            row["g_mean"] = self.g_mean
            row["g_var"] = self.g_var
        arrived = row.get("arrived")
        if arrived is not None:
            self.sharded_steps += 1
            if any(a == 0 for a in arrived):
                self.straggler_steps += 1
        sel = row.get("layer_sel")
        if sel is not None and len(sel) == self.num_layers:
            for i, n in enumerate(sel):
                if n > 0:
                    self.layer_counts[i] += n
                    self.layer_last[i] = step
        # update magnitude via the RNG-stream norm identity
        coeffs = row.get("coeffs")
        lr = row.get("lr")
        if coeffs is not None and lr is not None:
            n_act = row.get("n_active_params")
            if n_act is not None and len(n_act) == len(coeffs):
                row["update_norm_est"] = abs(lr) * math.sqrt(
                    sum(c * c * n for c, n in zip(coeffs, n_act)))
            if (self.norm_fn is not None and len(coeffs) == 1
                    and "seed" in row and sel is not None):
                row["update_norm"] = abs(lr * coeffs[0]) * float(
                    self.norm_fn(row["seed"], sel))
        return row

    # ---------------------------------------------------------- summary
    @property
    def g_var(self) -> float:
        return self.g_m2 / (self.g_count - 1) if self.g_count > 1 else 0.0

    def staleness(self) -> List[int]:
        """Steps since each layer was last selected (-1: never)."""
        return [-1 if last < 0 else self.last_step - last
                for last in self.layer_last]

    def summary(self) -> Dict[str, Any]:
        losses = [r["loss"] for r in self.rows if "loss" in r]
        out: Dict[str, Any] = {
            "steps_recorded": len(self.rows),
            "last_step": self.last_step,
            "g_count": self.g_count,
            "g_mean": self.g_mean,
            "g_var": self.g_var,
            "loss_first": losses[0] if losses else None,
            "loss_last": losses[-1] if losses else None,
        }
        if self.num_layers:
            out["layer_counts"] = list(self.layer_counts)
            out["layer_staleness"] = self.staleness()
            out["layers_never_selected"] = sum(
                1 for c in self.layer_counts if c == 0)
        norms = [r["update_norm_est"] for r in self.rows
                 if "update_norm_est" in r]
        if norms:
            out["update_norm_est_last"] = norms[-1]
        if self.sharded_steps:
            out["sharded_steps"] = self.sharded_steps
            out["straggler_steps"] = self.straggler_steps
        return out
