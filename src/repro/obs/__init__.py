"""``repro.obs`` — stage-level tracing + serving metrics (DESIGN.md §13).

One lightweight telemetry subsystem used by every hot path:

  * :mod:`~repro.obs.trace` — ``Span``/``Tracer`` with monotonic
    ``perf_counter`` timing, explicit ``block_until_ready`` fencing,
    nesting, a zero-allocation disabled path, and the named ZO step
    stages (``perturb`` / ``forward+εz`` / ``forward-εz`` /
    ``update_axpy``) plus counters for probes, axpy sweeps, RNG folds
    and active layers under LeZO sparsity.
  * :mod:`~repro.obs.sinks` — in-memory ring buffer + JSONL event log.
  * :mod:`~repro.obs.metrics` — Prometheus-style counters / gauges /
    histograms with a text exposition dump (the serving engine's queue
    depth, lane occupancy, page utilization, TTFT/latency, tokens/sec).
  * :mod:`~repro.obs.profiler` — optional ``jax.profiler`` region
    behind ``telemetry.profile_dir``.
  * :mod:`~repro.obs.runtime` — ``session(spec.telemetry)`` wiring.
  * :mod:`~repro.obs.health` — sync-free per-step ZO optimizer vitals
    (seed lineage, projected gradient g, ε/lr, LeZO layer coverage,
    update magnitudes) drained in one batched transfer at ``log_every``.
  * :mod:`~repro.obs.runlog` — structured ``artifacts/runs/<run_id>/``
    directories (spec + JSONL step stream + summary) that ``launch
    report`` renders and ``launch replay`` re-executes bit-identically.

Emitters call ``obs.get_tracer()`` unconditionally; the default is the
disabled :data:`NULL` tracer, whose operations are free, and spans are
automatically suppressed while jax traces a jit — so instrumentation
costs nothing on compiled steady-state paths and yields real stage
timings when the same code runs eagerly (``benchmarks/step_time.py``).
"""
from repro.obs.health import HealthAccumulator
from repro.obs.metrics import (Counter, Gauge, Histogram, LATENCY_BUCKETS,
                               Registry)
from repro.obs.profiler import profile
from repro.obs.runlog import (DEFAULT_RUNS_DIR, RunDir, RunLog, list_runs,
                              load_run, make_run_id, resolve_run)
from repro.obs.runtime import NULL_SESSION, Session, session
from repro.obs.sinks import (JSONLSink, RingSink, read_jsonl,
                             spans_from_jsonl)
from repro.obs.trace import (CTR_AXPY, CTR_PROBES, CTR_RNG_FOLDS,
                             CTR_SELECTS, CTR_WLOAD, CTR_ZREGEN, FWD_BASE,
                             FWD_MINUS, FWD_PAIR, FWD_PLUS, GAUGE_ACTIVE,
                             NULL, PERTURB, SERVE_DECODE, SERVE_PREFILL,
                             STAGES, Span, SpanRecord, TRAIN_STEP, Tracer,
                             UPDATE, get_tracer, set_tracer, tracing, use)

__all__ = [
    "CTR_AXPY", "CTR_PROBES", "CTR_RNG_FOLDS", "CTR_SELECTS", "CTR_WLOAD",
    "CTR_ZREGEN", "Counter", "DEFAULT_RUNS_DIR", "FWD_BASE", "FWD_MINUS",
    "FWD_PAIR", "FWD_PLUS", "GAUGE_ACTIVE", "Gauge", "HealthAccumulator",
    "Histogram", "JSONLSink", "LATENCY_BUCKETS", "NULL", "NULL_SESSION",
    "PERTURB", "Registry", "RingSink", "RunDir", "RunLog", "SERVE_DECODE",
    "SERVE_PREFILL", "STAGES", "Session", "Span", "SpanRecord",
    "TRAIN_STEP", "Tracer", "UPDATE", "get_tracer", "list_runs",
    "load_run", "make_run_id", "profile", "read_jsonl", "resolve_run",
    "session", "set_tracer", "spans_from_jsonl", "tracing", "use",
]
