"""Span/Tracer core: stage-level step tracing for every hot path.

The paper's headline result is an *observability* result — >50% of a
MeZO step sits in the perturb/update sweeps — so the repo carries one
shared tracing layer instead of per-benchmark stopwatch code.  A
:class:`Tracer` records nestable :class:`SpanRecord`s on a monotonic
``perf_counter`` clock, with explicit ``block_until_ready`` *fencing*
(``Span.fence``) so device-async dispatch cannot lie about where time
went, plus named counters/gauges for structural facts (probes
evaluated, axpy sweeps, RNG folds, active layers under LeZO sparsity).

Three rules keep the hot paths honest (DESIGN.md §13):

  * **Disabled means free.**  The default tracer is :data:`NULL`, whose
    ``span``/``count``/``gauge`` are no-ops returning one shared
    singleton — no record, no ``Span``, no sink call is ever allocated.
    Instrumented code calls ``obs.get_tracer()`` unconditionally.
  * **Never record under jit tracing.**  Instrumentation lives inside
    functions that callers may ``jax.jit``; a span timed at trace time
    would record compile-walk time once per cache entry.  ``span`` and
    ``count`` therefore no-op whenever jax reports an active trace, so
    jitted steps stay clean and the same code path yields real stage
    timings when run eagerly (the staged-measurement mode
    ``benchmarks/step_time.py`` uses).
  * **Fence when asked.**  ``Tracer(fence=True)`` makes ``Span.fence``
    call ``jax.block_until_ready`` on the span's result before the
    clock stops; with ``fence=False`` the same call is free, so
    steady-state pipelines keep their async dispatch.

Stage taxonomy (the ZO step's named stages) is defined here so every
emitter and every consumer agrees on the strings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

# ------------------------------------------------------- stage taxonomy
# One MeZO/LeZO step decomposes into these named stages (DESIGN.md §13).
# `perturb` appears twice per materialized two-point step (+eps, -2eps)
# and zero times under the virtual forward backend (repro.fused).
PERTURB = "perturb"
FWD_PLUS = "forward+εz"
FWD_MINUS = "forward-εz"
FWD_PAIR = "forward_pair"     # one paired ±εz forward (fused probe stack)
FWD_BASE = "forward"          # one_sided's unperturbed baseline forward
UPDATE = "update_axpy"
TRAIN_STEP = "train/step"     # the trainer's whole-step record (jit-safe)
SERVE_PREFILL = "serve/prefill"
SERVE_DECODE = "serve/decode"
STAGES: Tuple[str, ...] = (PERTURB, FWD_PLUS, FWD_MINUS, FWD_PAIR, UPDATE)

# Counter names (structural per-run facts, deterministic under a seed).
CTR_PROBES = "probes_evaluated"
CTR_AXPY = "axpy_sweeps"
CTR_RNG_FOLDS = "rng_folds"
CTR_SELECTS = "layer_selections"
# Fused-forward W-traffic counters (repro.fused): VMEM tile loads of
# weight matrices and z-tile regenerations per step — the structural
# numbers the paired ±εz probe halves (counted host-side from the same
# grid arithmetic the kernel runs, so ref and pallas impls agree).
CTR_WLOAD = "w_tile_loads"
CTR_ZREGEN = "z_regens"
GAUGE_ACTIVE = "active_layers"


def tracing() -> bool:
    """True while jax is tracing (jit/vmap/grad) — spans and counters
    must not record then.  jax is imported lazily so this module stays
    importable without it.  Public so instrumentation sites that must
    concretize a value (e.g. ``int(n_active)`` for a gauge) can skip
    the whole block under tracing."""
    try:
        import jax
        return not jax.core.trace_state_clean()
    except Exception:  # pragma: no cover - exotic/old jax
        return False


_tracing = tracing


@dataclasses.dataclass
class SpanRecord:
    """One finished span.  ``index`` is the emission sequence number
    (completion order); ``parent`` the index of the enclosing span's
    *entry* slot (-1 at top level); ``depth`` the nesting level."""
    name: str
    t0: float
    dt: float
    depth: int
    index: int
    parent: int
    meta: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        d = {"type": "span", "name": self.name, "t0": self.t0,
             "dt": self.dt, "depth": self.depth, "index": self.index,
             "parent": self.parent}
        if self.meta:
            d["meta"] = self.meta
        return d


class Span:
    """A live span; use as a context manager.  ``fence(x)`` marks ``x``
    (any pytree of jax arrays) as the span's result: when the owning
    tracer fences, the clock stops only after ``x`` is device-ready."""

    __slots__ = ("_tracer", "name", "meta", "_t0", "_result", "_entry")

    def __init__(self, tracer: "Tracer", name: str,
                 meta: Optional[Dict[str, Any]] = None):
        self._tracer = tracer
        self.name = name
        self.meta = meta
        self._result = None

    def fence(self, result):
        self._result = result
        return result

    def __enter__(self) -> "Span":
        self._entry = self._tracer._enter()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._tracer.fence and self._result is not None:
            import jax
            jax.block_until_ready(self._result)
        dt = time.perf_counter() - self._t0
        self._tracer._exit(self, dt)
        self._result = None
        return False


class _NullSpan:
    """The shared do-nothing span: one instance for the whole process,
    so a disabled tracer's hot path allocates nothing per call."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def fence(self, result):
        return result


_NULL_SPAN = _NullSpan()


class Tracer:
    """Records spans/counters into pluggable sinks (repro.obs.sinks).

    ``sinks``: objects with ``emit(record: SpanRecord)``.
    ``fence``: block on each span's fenced result before timing exit
    (true stage timings; off for steady-state pipelines).
    """

    enabled = True

    def __init__(self, sinks=(), fence: bool = False):
        self.sinks = list(sinks)
        self.fence = fence
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}
        self._depth = 0
        self._index = 0
        self._stack: List[int] = []   # entry indices of open spans

    # ------------------------------------------------------------ spans
    def span(self, name: str, meta: Optional[Dict[str, Any]] = None):
        if _tracing():
            return _NULL_SPAN
        return Span(self, name, meta)

    def _enter(self) -> int:
        entry = self._index
        self._index += 1
        self._stack.append(entry)
        self._depth += 1
        return entry

    def _exit(self, span: Span, dt: float):
        self._depth -= 1
        self._stack.pop()
        parent = self._stack[-1] if self._stack else -1
        rec = SpanRecord(name=span.name, t0=span._t0, dt=dt,
                         depth=self._depth, index=span._entry,
                         parent=parent, meta=span.meta)
        for s in self.sinks:
            s.emit(rec)

    # --------------------------------------------------------- counters
    def count(self, name: str, n: int = 1):
        if _tracing():
            return
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value):
        if _tracing():
            return
        self.gauges[name] = value

    def snapshot(self) -> Dict[str, Any]:
        """Counters + gauges as one JSON-ready event."""
        return {"type": "counters", "counters": dict(self.counters),
                "gauges": dict(self.gauges)}

    def reset(self):
        self.counters.clear()
        self.gauges.clear()


class NullTracer(Tracer):
    """The disabled tracer: every operation is a no-op and ``span``
    returns the process-wide :data:`_NULL_SPAN` singleton — the
    zero-allocation fast path the test suite pins by identity."""

    enabled = False

    def __init__(self):
        super().__init__(sinks=(), fence=False)

    def span(self, name: str, meta=None):
        return _NULL_SPAN

    def count(self, name: str, n: int = 1):
        pass

    def gauge(self, name: str, value):
        pass


NULL = NullTracer()
_CURRENT: Tracer = NULL


def get_tracer() -> Tracer:
    return _CURRENT


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install ``tracer`` (None -> NULL) globally; returns the previous
    one so callers can restore it."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tracer if tracer is not None else NULL
    return prev


class use:
    """``with obs.use(tracer): ...`` — scope the global tracer."""

    def __init__(self, tracer: Optional[Tracer]):
        self._tracer = tracer

    def __enter__(self) -> Tracer:
        self._prev = set_tracer(self._tracer)
        return _CURRENT

    def __exit__(self, exc_type, exc, tb):
        set_tracer(self._prev)
        return False
