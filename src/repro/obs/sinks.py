"""Pluggable span sinks: in-memory ring buffer and JSONL event log.

A sink is anything with ``emit(record: SpanRecord)``; a
:class:`~repro.obs.trace.Tracer` fans every finished span out to all of
its sinks.  Two implementations cover the repo's needs (DESIGN.md §13):

  * :class:`RingSink` — bounded deque; the live in-process view that
    ``benchmarks/step_time.py`` aggregates into per-stage shares and the
    trainer keeps for post-run inspection.  Old records fall off the
    back, so a week-long run cannot grow without bound.
  * :class:`JSONLSink` — one JSON object per line, append-only; the
    durable trace CI uploads as an artifact.  ``read_jsonl`` is the
    matching loader (the round-trip is pinned by tests/test_obs.py).

Prometheus-style *metrics* (counters/gauges/histograms with a text
exposition dump) live in :mod:`repro.obs.metrics` — sinks here carry
*events*, metrics there carry *aggregates*.
"""
from __future__ import annotations

import json
import os
from collections import deque
from typing import Any, Dict, List, Optional

from repro.obs.trace import SpanRecord


class RingSink:
    """Keep the most recent ``capacity`` span records in memory."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)

    def emit(self, rec: SpanRecord):
        self._buf.append(rec)

    def records(self) -> List[SpanRecord]:
        return list(self._buf)

    def spans(self, name: Optional[str] = None) -> List[SpanRecord]:
        if name is None:
            return self.records()
        return [r for r in self._buf if r.name == name]

    def clear(self):
        self._buf.clear()

    def __len__(self):
        return len(self._buf)


class JSONLSink:
    """Append span records (and arbitrary dict events) to a JSONL file.

    The file handle opens lazily on first emit and stays open — one
    ``write`` per record, no per-record open/close.  ``flush``/``close``
    make the tail durable; the sink doubles as a context manager."""

    def __init__(self, path: str):
        self.path = path
        self._fh = None

    def _handle(self):
        if self._fh is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(self.path, "a")
        return self._fh

    def emit(self, rec: SpanRecord):
        self._handle().write(json.dumps(rec.to_dict()) + "\n")

    def emit_event(self, event: Dict[str, Any]):
        """Write a non-span event line (e.g. a counter snapshot)."""
        self._handle().write(json.dumps(event) + "\n")

    def flush(self):
        if self._fh is not None:
            self._fh.flush()

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load every event from a JSONL trace (blank lines skipped).

    A truncated *final* line — the writer crashed mid-append — is
    silently dropped; corruption anywhere else still raises, since that
    indicates real damage rather than an interrupted tail write."""
    out = []
    with open(path) as f:
        lines = [ln.strip() for ln in f]
    last = max((i for i, ln in enumerate(lines) if ln), default=-1)
    for i, line in enumerate(lines):
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            if i == last:
                break
            raise
    return out


def spans_from_jsonl(path: str) -> List[SpanRecord]:
    """Reconstruct the ``SpanRecord``s from a JSONL trace — the inverse
    of ``JSONLSink.emit`` for ``type == "span"`` lines."""
    out = []
    for ev in read_jsonl(path):
        if ev.get("type") == "span":
            out.append(SpanRecord(
                name=ev["name"], t0=ev["t0"], dt=ev["dt"],
                depth=ev["depth"], index=ev["index"], parent=ev["parent"],
                meta=ev.get("meta")))
    return out
