"""Session wiring: one ``telemetry`` spec node -> tracer + sinks + metrics.

A :class:`Session` is the bundle every instrumented driver (Trainer,
serving Engine, benchmarks) accepts: a :class:`~repro.obs.trace.Tracer`
feeding the configured sinks, a Prometheus-style
:class:`~repro.obs.metrics.Registry`, and the dump/flush policy the
``telemetry`` node asked for.  :func:`session` builds one from a
validated ``api.spec.Telemetry`` node (duck-typed — obs never imports
the spec module, so the dependency points one way: api -> obs users,
never obs -> api).

``NULL_SESSION`` is the disabled bundle: its tracer is the
zero-allocation :data:`~repro.obs.trace.NULL`, ``enabled`` is False,
and ``flush``/``close`` are no-ops — drivers hold a Session
unconditionally and never branch on None (DESIGN.md §13).
"""
from __future__ import annotations

from typing import Optional

from repro.obs import metrics as metrics_mod
from repro.obs import sinks as sinks_mod
from repro.obs import trace as trace_mod


class Session:
    """Tracer + metrics registry + sink lifecycle for one run."""

    def __init__(self, tracer: trace_mod.Tracer,
                 registry: Optional[metrics_mod.Registry] = None,
                 ring: Optional[sinks_mod.RingSink] = None,
                 jsonl: Optional[sinks_mod.JSONLSink] = None,
                 prometheus_path: Optional[str] = None,
                 profile_dir: Optional[str] = None):
        self.tracer = tracer
        self.registry = registry if registry is not None \
            else metrics_mod.Registry()
        self.ring = ring
        self.jsonl = jsonl
        self.prometheus_path = prometheus_path
        self.profile_dir = profile_dir

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled

    def profile(self):
        """Context manager for the optional jax.profiler region."""
        from repro.obs import profiler
        return profiler.profile(self.profile_dir)

    def flush(self):
        """Make the run's telemetry durable: append a counter snapshot
        to the JSONL log, flush it, and (re)write the Prometheus dump.
        Safe to call repeatedly; a no-op when disabled."""
        if not self.enabled:
            return
        if self.jsonl is not None:
            self.jsonl.emit_event(self.tracer.snapshot())
            self.jsonl.flush()
        if self.prometheus_path:
            self.registry.dump(self.prometheus_path)

    def close(self):
        self.flush()
        if self.jsonl is not None:
            self.jsonl.close()


NULL_SESSION = Session(trace_mod.NULL)


def session(telemetry=None) -> Session:
    """Build a Session from an ``api.spec.Telemetry``-shaped node (any
    object with ``enabled``/``ring``/``fence``/``jsonl``/``prometheus``/
    ``profile_dir`` attributes).  ``None`` or ``enabled=False`` returns
    :data:`NULL_SESSION`."""
    if telemetry is None or not getattr(telemetry, "enabled", False):
        return NULL_SESSION
    sinks = []
    ring = None
    ring_cap = getattr(telemetry, "ring", 0)
    if ring_cap and ring_cap > 0:
        ring = sinks_mod.RingSink(ring_cap)
        sinks.append(ring)
    jsonl = None
    jsonl_path = getattr(telemetry, "jsonl", None)
    if jsonl_path:
        jsonl = sinks_mod.JSONLSink(jsonl_path)
        sinks.append(jsonl)
    tracer = trace_mod.Tracer(sinks=sinks,
                              fence=getattr(telemetry, "fence", False))
    return Session(tracer, ring=ring, jsonl=jsonl,
                   prometheus_path=getattr(telemetry, "prometheus", None),
                   profile_dir=getattr(telemetry, "profile_dir", None))
