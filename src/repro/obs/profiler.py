"""Optional ``jax.profiler`` trace hook behind ``telemetry.profile_dir``.

The obs spans answer "which *stage* is slow"; when the question drops to
"which *op* inside the stage", the real profiler takes over.  ``with
obs.profile(dir):`` wraps a region in ``jax.profiler.trace`` when a
directory is given and is a free no-op otherwise, so call sites (the
trainer loop, the serving drain, the step benchmark) carry exactly one
line regardless of configuration.  Profiler failures degrade to a
warning rather than killing a training run — a missing tensorboard
plugin must not take the experiment down with it (DESIGN.md §13).
"""
from __future__ import annotations

import contextlib
import warnings
from typing import Optional


@contextlib.contextmanager
def profile(profile_dir: Optional[str]):
    """``jax.profiler.trace(profile_dir)`` when a dir is given, else a
    no-op.  Profiler start/stop failures are demoted to warnings;
    exceptions from the wrapped body always propagate."""
    if not profile_dir:
        yield
        return
    cm, entered = None, False
    try:
        import jax
        cm = jax.profiler.trace(profile_dir)
        cm.__enter__()
        entered = True
    except Exception as e:  # pragma: no cover - env-dependent
        warnings.warn(f"obs: jax.profiler unavailable ({e!r}); "
                      "continuing without a device trace")
    try:
        yield
    finally:
        if entered:
            try:
                cm.__exit__(None, None, None)
            except Exception as e:  # pragma: no cover - env-dependent
                warnings.warn(f"obs: jax.profiler trace close failed "
                              f"({e!r})")
