"""Structured run directories: the durable form of a training run.

Every ``launch train`` with ``telemetry.runs_dir`` set writes a run
directory under ``<runs_dir>/<run_id>/`` (DESIGN.md §13):

  * ``spec.json``    — the full ``repro.api`` Experiment that produced
    the run, byte-stable (same serializer as the golden spec tests).
  * ``steps.jsonl``  — one JSON row per training step from the
    :class:`repro.obs.health.HealthAccumulator` drain: seed lineage
    (``step`` → ``seed``), loss, projected gradient(s), ε/lr actually
    applied, LeZO layer selection, update magnitudes.
  * ``summary.json`` — running aggregates written at ``finalize()``.
  * ``trace.jsonl``  — optional PR 6 stage-timing trace, when the
    tracer is enabled and no explicit ``telemetry.jsonl`` redirects it.

Because a ZO step is fully determined by its scalars, this directory is
not just a log: ``launch replay`` re-executes any recorded step from it
and asserts bit-identity, and ``launch report`` renders the
convergence/health story.  Floats survive the JSON round-trip exactly
(f32 → Python float → JSON → f32 is lossless), which is what makes
bit-identical replay from a run directory possible at all.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.obs import sinks

DEFAULT_RUNS_DIR = os.path.join("artifacts", "runs")

SPEC_FILE = "spec.json"
STEPS_FILE = "steps.jsonl"
SUMMARY_FILE = "summary.json"
TRACE_FILE = "trace.jsonl"


def _dump_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
        f.write("\n")


def make_run_id(root: str, seed: int = 0, now: Optional[float] = None) -> str:
    """Timestamped, seed-tagged, collision-free id under ``root``."""
    stamp = time.strftime("%Y%m%d-%H%M%S",
                          time.localtime(time.time() if now is None else now))
    base = f"{stamp}-s{int(seed)}"
    rid, k = base, 1
    while os.path.exists(os.path.join(root, rid)):
        k += 1
        rid = f"{base}-{k}"
    return rid


class RunLog:
    """Writer half: create the dir, stream step rows, finalize."""

    def __init__(self, root: str, run_id: str,
                 spec: Optional[Dict[str, Any]] = None):
        self.root = root
        self.run_id = run_id
        self.dir = os.path.join(root, run_id)
        os.makedirs(self.dir, exist_ok=True)
        if spec is not None:
            _dump_json(os.path.join(self.dir, SPEC_FILE), spec)
        self._sink = sinks.JSONLSink(os.path.join(self.dir, STEPS_FILE))

    @property
    def trace_path(self) -> str:
        """Where the PR 6 stage trace for this run belongs."""
        return os.path.join(self.dir, TRACE_FILE)

    def append(self, rows: List[Dict[str, Any]]) -> None:
        for row in rows:
            self._sink.emit_event(dict(row, type="step"))
        self._sink.flush()

    def finalize(self, summary: Optional[Dict[str, Any]] = None) -> None:
        if summary is not None:
            _dump_json(os.path.join(self.dir, SUMMARY_FILE), summary)
        self._sink.close()


@dataclass
class RunDir:
    """Reader half: a loaded run directory."""

    dir: str
    run_id: str
    spec: Optional[Dict[str, Any]] = None
    steps: List[Dict[str, Any]] = field(default_factory=list)
    summary: Optional[Dict[str, Any]] = None

    def step_row(self, step: int) -> Dict[str, Any]:
        for row in self.steps:
            if row.get("step") == step:
                return row
        raise KeyError(
            f"run {self.run_id!r} has no recorded step {step} "
            f"(steps {self.first_step}..{self.last_step})")

    @property
    def first_step(self) -> Optional[int]:
        return self.steps[0]["step"] if self.steps else None

    @property
    def last_step(self) -> Optional[int]:
        return self.steps[-1]["step"] if self.steps else None


def is_run_dir(path: str) -> bool:
    return os.path.isfile(os.path.join(path, SPEC_FILE)) or \
        os.path.isfile(os.path.join(path, STEPS_FILE))


def list_runs(root: str = DEFAULT_RUNS_DIR) -> List[str]:
    """Run ids under ``root``, oldest first (mtime then name)."""
    if not os.path.isdir(root):
        return []
    entries = []
    for name in os.listdir(root):
        p = os.path.join(root, name)
        if os.path.isdir(p) and is_run_dir(p):
            entries.append((os.path.getmtime(p), name))
    return [name for _, name in sorted(entries)]


def resolve_run(run: Optional[str], root: str = DEFAULT_RUNS_DIR) -> str:
    """Map a run id / path / None (= latest under root) to its dir."""
    if run is None:
        runs = list_runs(root)
        if not runs:
            raise FileNotFoundError(f"no run directories under {root!r}")
        return os.path.join(root, runs[-1])
    if os.path.isdir(run) and is_run_dir(run):
        return run
    cand = os.path.join(root, run)
    if os.path.isdir(cand) and is_run_dir(cand):
        return cand
    raise FileNotFoundError(
        f"run {run!r} not found (not a run dir, and {cand!r} "
        f"does not exist); known runs: {list_runs(root) or '[]'}")


def load_run(run: Optional[str], root: str = DEFAULT_RUNS_DIR) -> RunDir:
    """Load ``spec.json`` + step rows + ``summary.json`` if present."""
    d = resolve_run(run, root)
    rd = RunDir(dir=d, run_id=os.path.basename(os.path.normpath(d)))
    spec_path = os.path.join(d, SPEC_FILE)
    if os.path.isfile(spec_path):
        with open(spec_path) as f:
            rd.spec = json.load(f)
    steps_path = os.path.join(d, STEPS_FILE)
    if os.path.isfile(steps_path):
        rd.steps = [r for r in sinks.read_jsonl(steps_path)
                    if r.get("type") == "step"]
        rd.steps.sort(key=lambda r: r.get("step", -1))
    summary_path = os.path.join(d, SUMMARY_FILE)
    if os.path.isfile(summary_path):
        with open(summary_path) as f:
            rd.summary = json.load(f)
    return rd
