"""Pallas flash-attention forward kernel (TPU target, interpret-validated).

The forward hot spot of every assigned dense arch.  Grid =
(batch*kv_heads*q_groups, Sq/BQ); each program owns one (BQ, dh) query
tile and scans the key/value sequence in (BK, dh) tiles held in VMEM,
maintaining the usual running (m, l, acc) in f32.  Causal masking skips
fully-masked key tiles via ``pl.when`` — real predication, matching the
lax.cond skip of the jnp reference (models.layers.flash_attention, which
remains the production path under pjit; this kernel is the single-core
TPU tile schedule for it).

Layout choices: q/k/v arrive flattened to (BH, S, dh) with BH =
B*KV*G; dh padded to a multiple of 128 by the wrapper (ops-level
contract) so the MXU matmul dims are hardware-aligned.

Kernel backends of the ZO core (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, nk, causal, kv_repeat):
    qi = pl.program_id(1)
    q = q_ref[0].astype(F32)                       # (BQ, dh)
    dh = q.shape[-1]
    scale = dh ** -0.5
    m = jnp.full((bq,), NEG_INF, F32)
    l = jnp.zeros((bq,), F32)
    acc = jnp.zeros((bq, dh), F32)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]

    def body(ki, carry):
        m, l, acc = carry
        # unit slice (not int 0) on the leading axis: interpret-mode
        # discharge in current jax chokes on mixed int+Slice indexers
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(ki * bk, bk),
                            slice(None)))[0].astype(F32)   # (BK, dh)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(ki * bk, bk),
                            slice(None)))[0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale
        if causal:
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk),
                                                       1)[0]
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
        return m_new, l_new, acc_new

    if causal:
        # only key tiles up to the diagonal contribute
        nk_needed = jnp.minimum(nk, (qi + 1) * bq // bk + 1)
    else:
        nk_needed = nk
    m, l, acc = jax.lax.fori_loop(0, nk_needed, body, (m, l, acc))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, bq=128, bk=128,
                           interpret=True):
    """q: (BH, Sq, dh), k/v: (BH, Sk, dh) with q already GQA-expanded
    (BH = B*KV*G and k/v repeated per group by the caller/ops wrapper).
    dh should be a multiple of 128 for MXU alignment (any value works in
    interpret mode)."""
    BH, Sq, dh = q.shape
    Sk = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nk = Sk // bk
    grid = (BH, Sq // bq)
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          kv_repeat=1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Sk, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)


def flash_attention_ref(q, k, v, *, causal=True):
    """Pure-jnp oracle in the kernel's (BH, S, dh) layout."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(F32), k.astype(F32))
    s = s * (q.shape[-1] ** -0.5)
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        msk = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(msk[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(F32)).astype(q.dtype)
