"""Fused ZO perturb/update Pallas kernel.

The MeZO/LeZO hot spot is the element-wise pass

    theta <- decay * theta + scale * z(seed, index)

executed several times per optimization step over *every* parameter.  The
paper measures this at >50% of step time on OPT-13B.  On TPU the pass is
HBM-bandwidth-bound, so the kernel's job is to touch each parameter byte
exactly twice (read + write):

  * ``z`` is generated *inside* the kernel from a counter-based RNG
    (``core.rng``) — it never exists in HBM.  (The PyTorch original
    materializes a z tensor per module: 3x the traffic.)
  * LeZO's layer skip is a ``pl.when`` predicate on a per-layer mask held
    in SMEM: dropped layers do no RNG/FLOP work and, thanks to
    input/output aliasing, no data movement either on TPU.
  * ``decay`` folds weight decay into the same pass; ``scale`` is a
    runtime scalar (SMEM) so the *restore* (+eps) and *update* (-lr*g)
    passes fuse into one call with scale = eps - lr*g.

Layout: a parameter leaf is viewed as (L, n) — L stacked layers (L=1 for
unstacked leaves) by n flattened elements.  Grid = (L, ceil(n / BLOCK));
BlockSpec tiles (1, BLOCK) of the row into VMEM.  BLOCK is a multiple of
the 128-lane dimension; 64Ki f32 elements = 256 KiB per buffer, well under
the ~16 MiB VMEM budget even double-buffered.

Counters restart at 0 for every (leaf, layer): uniqueness across leaves
and layers comes from folding (leaf uid, layer index) into the seed, which
keeps counters within uint32 for any realistic leaf and makes the value of
z[l, i] independent of sharding.

Kernel backends of the ZO core (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng

BLOCK = 65536  # f32 elements per tile: 256 KiB in, 256 KiB out in VMEM.


def _kernel(mask_ref, seed_ref, scale_ref, decay_ref, theta_ref, out_ref, *, block):
    l = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(mask_ref[l])
    def _active():
        # Per-(leaf, layer) seed was pre-folded on the host side up to the
        # leaf uid; fold the layer index here (scalar uint32 math).
        seed_l = rng.fold(seed_ref[0], jnp.uint32(l))
        col0 = (j * block).astype(jnp.uint32)
        idx = col0 + jax.lax.broadcasted_iota(jnp.uint32, (1, block), 1)
        z = rng.counter_normal(seed_l, idx)
        x = theta_ref[...].astype(jnp.float32)
        y = decay_ref[0] * x + scale_ref[0] * z
        out_ref[...] = y.astype(out_ref.dtype)

    @pl.when(jnp.logical_not(mask_ref[l]))
    def _skipped():
        # Write-through keeps interpret-mode semantics correct; on TPU the
        # buffer is aliased so this is a VMEM-local copy, no HBM traffic
        # beyond the (already scheduled) block in/out.
        out_ref[...] = theta_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def zo_axpy_2d(theta, mask, seed, scale, decay, *, block=BLOCK, interpret=True):
    """theta: (L, n); mask: (L,) bool; seed uint32 scalar; scale/decay f32 scalars.

    Returns decay*theta + scale*z for rows where mask, theta elsewhere.
    """
    L, n = theta.shape
    block = min(block, max(128, n))
    grid = (L, pl.cdiv(n, block))
    return pl.pallas_call(
        functools.partial(_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # mask  (L,)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # seed  (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scale (1,)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # decay (1,)
            pl.BlockSpec((1, block), lambda l, j: (l, j)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda l, j: (l, j)),
        out_shape=jax.ShapeDtypeStruct(theta.shape, theta.dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(
        mask,
        jnp.asarray(seed, jnp.uint32).reshape(1),
        jnp.asarray(scale, jnp.float32).reshape(1),
        jnp.asarray(decay, jnp.float32).reshape(1),
        theta,
    )
