"""Pure-jnp oracle for the ZO axpy kernel.

Shares the counter RNG with the Pallas kernel body, so results are
bit-exact (identical element-wise float ops, just without tiling).

``leaf_normal_nd`` generates z for a leaf in its *natural* shape: the
counter of element (l, i1, ..., ik) is its flat index within layer l and
the seed is fold(seed, l).  Both are computed from broadcasted iotas —
pure element-wise ops — so under pjit every device materializes exactly
its shard of z with no communication and no reshape/reshard.

Kernel backends of the ZO core (DESIGN.md §2).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import rng


def _within_layer_index(shape):
    """uint32 flat index over dims 1.. of ``shape`` (broadcast over dim 0)."""
    idx = jnp.zeros(shape, jnp.uint32)
    stride = 1
    for d in range(len(shape) - 1, 0, -1):
        idx = idx + lax.broadcasted_iota(jnp.uint32, shape, d) * np.uint32(stride)
        stride *= shape[d]
    return idx


def leaf_normal_nd(seed, shape, layer_ids=None):
    """z ~ N(0,1) for a (L, ...) leaf: z[l, i] = f(fold(seed, lid[l]), i).

    ``layer_ids``: optional (L,) uint32 — the *global* layer id of each
    row (defaults to arange).  Lets the gather backend generate z for a
    compacted subset of layers that matches the dense full-stack values.
    """
    L = shape[0]
    if layer_ids is None:
        layer_ids = jnp.arange(L, dtype=jnp.uint32)
    seeds = rng.fold(jnp.asarray(seed, jnp.uint32), layer_ids)
    seeds = seeds.reshape((L,) + (1,) * (len(shape) - 1))
    idx = _within_layer_index(shape)
    return rng.counter_normal(seeds, idx)


def zo_axpy_nd(theta, mask, seed, scale, decay, layer_ids=None):
    """decay*theta + scale*z on rows where mask, theta elsewhere.

    theta: (L, ...); mask: (L,) bool or None (all active)."""
    z = leaf_normal_nd(seed, theta.shape, layer_ids)
    x = theta.astype(jnp.float32)
    y = (jnp.asarray(decay, jnp.float32) * x
         + jnp.asarray(scale, jnp.float32) * z).astype(theta.dtype)
    if mask is None:
        return y
    mshape = (theta.shape[0],) + (1,) * (theta.ndim - 1)
    return jnp.where(mask.reshape(mshape), y, theta)


# 2-D view kept as the direct oracle for the Pallas kernel's layout.
def leaf_normal(seed, L, n):
    seeds = rng.fold(jnp.asarray(seed, jnp.uint32), jnp.arange(L, dtype=jnp.uint32))
    idx = jnp.arange(n, dtype=jnp.uint32)
    return jax.vmap(lambda s: rng.counter_normal(s, idx))(seeds)


def zo_axpy_2d(theta, mask, seed, scale, decay):
    L, n = theta.shape
    z = leaf_normal(seed, L, n)
    x = theta.astype(jnp.float32)
    y = jnp.asarray(decay, jnp.float32) * x + jnp.asarray(scale, jnp.float32) * z
    y = y.astype(theta.dtype)
    return jnp.where(mask[:, None], y, theta)
