"""Kernel backends of the ZO core (DESIGN.md §2): the fused axpy in
four interchangeable backends (ops.py dispatch, ref.py jnp oracle,
zo_axpy.py Pallas kernel) plus the Pallas flash-attention kernel.
"""
