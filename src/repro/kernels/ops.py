"""Backend dispatch for the fused ZO axpy.

Four implementations of the same contract (oracle: ``ref.zo_axpy_nd``):

  * ``dense``  — masked element-wise pass in the leaf's natural shape.
                 Computes z for dropped layers too (what a naive port
                 does), but XLA fuses RNG+axpy into one HBM-speed loop
                 and it shards with zero communication.  MeZO (n_drop=0)
                 uses this: every layer is active anyway.
  * ``scan``   — lax.scan over the layer axis + lax.cond per layer: a
                 real runtime branch, dropped layers skip RNG + axpy
                 compute.  Paper-faithful "skip" in pure JAX.
  * ``gather`` — beyond-paper: LeZO's active set has *static* size
                 k = N - n_drop, so gather the k active rows, run the
                 dense pass on the compact (k, ...) buffer, scatter back.
                 Work is k-proportional *in the HLO itself* (visible to
                 cost_analysis, shardable on non-layer dims) at the price
                 of one extra gather+scatter stream.
  * ``pallas`` — the fused TPU kernel (``zo_axpy.zo_axpy_2d``): on-the-fly
                 RNG in VMEM, per-layer ``pl.when`` predication, buffer
                 aliasing.  Validated in interpret mode on CPU; targets
                 per-shard invocation via shard_map on real TPUs.

All backends draw identical z (same counter RNG keyed by (seed, leaf,
global layer id)) — property-tested against each other.

Kernel backends of the ZO core (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import rng
from repro.kernels import ref as kref
from repro.kernels import zo_axpy as kzo

BACKENDS = ("dense", "scan", "gather", "pallas")


def _scan_axpy(theta, mask, seed, scale, decay):
    row_shape = theta.shape[1:]
    idx = kref._within_layer_index((1,) + row_shape)[0]
    scale = jnp.asarray(scale, jnp.float32)
    decay = jnp.asarray(decay, jnp.float32)

    def active(args):
        row, l = args
        z = rng.counter_normal(rng.fold(seed, l), idx)
        return (decay * row.astype(jnp.float32) + scale * z).astype(theta.dtype)

    def body(_, inp):
        row, m, l = inp
        out = lax.cond(m, active, lambda a: a[0], (row, l))
        return None, out

    L = theta.shape[0]
    _, out = lax.scan(body, None,
                      (theta, mask, jnp.arange(L, dtype=jnp.uint32)))
    return out


def _gather_axpy(theta, active_idx, seed, scale, decay):
    """Perturb exactly the rows listed in active_idx (static length k)."""
    rows = theta[active_idx]
    rows = kref.zo_axpy_nd(rows, None, seed, scale, decay,
                           layer_ids=active_idx.astype(jnp.uint32))
    return theta.at[active_idx].set(rows)


def zo_axpy(theta, *, path, seed, scale, decay=1.0, mask=None,
            active_idx=None, backend="dense", interpret=True):
    """Apply ``decay*theta + scale*z`` to a parameter leaf.

    theta is stacked over layers on axis 0 iff ``mask``/``active_idx`` is
    given.  ``path`` (tree-path string) keys the leaf's z stream.
    ``active_idx``: static-size index vector of active layers — required
    for the gather backend, ignored otherwise.
    """
    leaf_seed = rng.fold(jnp.asarray(seed, jnp.uint32),
                         jnp.uint32(rng.leaf_uid(path)))
    if mask is None and active_idx is None:
        # whole leaf always active: single pseudo-layer, natural shape
        return kref.zo_axpy_nd(theta[None], None, leaf_seed, scale,
                               decay)[0]
    if backend == "dense":
        return kref.zo_axpy_nd(theta, mask, leaf_seed, scale, decay)
    if backend == "scan":
        return _scan_axpy(theta, mask, leaf_seed, scale, decay)
    if backend == "gather":
        if active_idx is None:
            raise ValueError("gather backend needs active_idx")
        return _gather_axpy(theta, active_idx, leaf_seed, scale, decay)
    if backend == "pallas":
        theta2d = theta.reshape(theta.shape[0], -1)
        out = kzo.zo_axpy_2d(theta2d, mask, leaf_seed, scale, decay,
                             interpret=interpret)
        return out.reshape(theta.shape)
    raise ValueError(f"unknown backend {backend!r}; pick from {BACKENDS}")
