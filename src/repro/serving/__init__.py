"""Continuous-batching serving engine (DESIGN.md §12).

``KVPool`` allocates fixed-size cache pages out of one preallocated
arena; ``Scheduler`` admits requests and tracks lanes; ``Engine`` drives
the two bucketed, jitted ``models.lm.paged_step`` shapes (one compile
per bucket) with greedy / temperature / top-k sampling off a per-request
counter RNG.  ``python -m repro.launch serve`` is the CLI surface;
``benchmarks/serving.py`` measures it against the lockstep loop.

    from repro import serving

    engine = serving.Engine(cfg, params, spec.serving)
    results = engine.run([serving.Request(rid=0, tokens=[5, 7, 11])])
"""
from repro.serving.engine import Engine, EngineUnsupported, GenResult
from repro.serving.pool import KVPool, PoolExhausted, TRASH_PAGE
from repro.serving.prefix import PrefixTrie
from repro.serving.sampling import make_sampler
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "EngineUnsupported", "GenResult", "KVPool",
           "PoolExhausted", "PrefixTrie", "Request", "Scheduler",
           "TRASH_PAGE", "make_sampler"]
