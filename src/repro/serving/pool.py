"""Paged KV-cache pool: refcounted fixed-size pages over one
preallocated arena (DESIGN.md §12).

The *arena* is the device-side slab (``models.lm.init_paged_cache``):
per stage-block, (R, n_pages, page_size, KV, dh) buffers shared by every
request.  The *pool* is the host-side allocator over page ids — pure
Python, no jax — so the scheduler's admit/finish bookkeeping is testable
without a device and the property suite can drive random traces against
the invariants directly.

Pages are **refcounted** for prefix sharing (DESIGN.md §12): a page the
prefix trie and N lanes all reference carries refcount N+1.  ``alloc``
hands out pages at refcount 1; ``incref`` registers another holder;
``decref`` (and ``free``, which is decref over a batch) drops one
reference and returns the page to the free list only when the count
reaches zero.  ``cow`` implements copy-on-write bookkeeping: a sole
owner writes in place, a shared page is swapped for a fresh private one
(the device-side content copy is the engine's job — the pool is
jax-free).

Invariants (``check_invariants`` asserts them; the stateful property
suite in tests/test_pool_properties.py hammers them):

  * free ∪ allocated == {1 .. n_pages-1}, disjoint — page 0 is reserved
    as the *trash page* (inactive lanes write there; see lm.paged_step)
    and is never handed out.
  * every allocated page has refcount >= 1; no free page has one.
  * ``decref``/``free`` of a page not currently allocated raises
    (double-free).
  * ``alloc(n)`` either returns exactly n distinct pages or raises
    :class:`PoolExhausted` leaving the pool untouched.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

TRASH_PAGE = 0


class PoolExhausted(RuntimeError):
    """alloc() could not cover the request; the pool is unchanged."""


class KVPool:
    """Host-side refcounted page allocator over ``n_pages`` fixed-size
    pages."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"n_pages must be >= 2 (page 0 is the trash "
                             f"page), got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list: recently freed pages are reused first, which
        # keeps the hot arena slice small and cache-friendly.
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._rc: Dict[int, int] = {}

    # ------------------------------------------------------------- alloc
    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._rc)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache slots."""
        return -(-n_tokens // self.page_size)

    def alloc(self, n: int) -> List[int]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages, {len(self._free)} free "
                f"({len(self._rc)} in use of {self.n_pages - 1} usable)")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._rc[p] = 1
        return pages

    # ---------------------------------------------------------- refcounts
    def refcount(self, p: int) -> int:
        """Current reference count (0 for a free page)."""
        return self._rc.get(p, 0)

    def incref(self, p: int):
        """Register another holder of an allocated page."""
        if p not in self._rc:
            raise ValueError(f"incref of unallocated page {p}")
        self._rc[p] += 1

    def decref(self, p: int) -> bool:
        """Drop one reference; returns True when the page went back to
        the free list (last holder gone)."""
        if p not in self._rc:
            raise ValueError(f"double-free or foreign page {p} "
                             f"(in_use={sorted(self._rc)})")
        self._rc[p] -= 1
        if self._rc[p] == 0:
            del self._rc[p]
            self._free.append(p)
            return True
        return False

    def free(self, pages: Sequence[int]):
        """Drop one reference per page — the retire path.  A page other
        holders (the prefix trie, another lane) still reference stays
        allocated for them."""
        for p in pages:
            self.decref(p)

    def cow(self, p: int) -> Tuple[int, bool]:
        """Copy-on-write bookkeeping for a holder about to write page
        ``p``: a sole owner keeps it (no copy); a shared page is
        exchanged for a fresh private page at refcount 1 and the
        caller's reference to ``p`` is dropped.  Returns ``(page,
        copied)`` — when ``copied`` the caller must copy the device
        content ``p -> page`` before writing.  Raises
        :class:`PoolExhausted` (pool untouched) when no page is free."""
        if p not in self._rc:
            raise ValueError(f"cow of unallocated page {p}")
        if self._rc[p] == 1:
            return p, False
        q = self.alloc(1)[0]
        self.decref(p)
        return q, True

    # -------------------------------------------------------- invariants
    def check_invariants(self):
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert not (free & self._rc.keys()), "page both free and allocated"
        assert TRASH_PAGE not in free and TRASH_PAGE not in self._rc, \
            "trash page entered circulation"
        assert free | self._rc.keys() == set(range(1, self.n_pages)), \
            "page leaked out of the pool"
        assert len(free) + len(self._rc) == self.n_pages - 1, \
            "available + in_use != usable pages"
        assert all(rc >= 1 for rc in self._rc.values()), \
            "allocated page with refcount < 1"
