"""Paged KV-cache pool: fixed-size pages over one preallocated arena
(DESIGN.md §12).

The *arena* is the device-side slab (``models.lm.init_paged_cache``):
per stage-block, (R, n_pages, page_size, KV, dh) buffers shared by every
request.  The *pool* is the host-side allocator over page ids — pure
Python, no jax — so the scheduler's admit/finish bookkeeping is testable
without a device and the property suite can drive random traces against
the invariants directly.

Invariants (``check_invariants`` asserts them; the hypothesis trace test
in tests/test_serving.py hammers them):

  * free ∪ allocated == {1 .. n_pages-1}, disjoint — page 0 is reserved
    as the *trash page* (inactive lanes write there; see lm.paged_step)
    and is never handed out.
  * ``free(p)`` of a page not currently allocated raises (double-free).
  * ``alloc(n)`` either returns exactly n distinct pages or raises
    :class:`PoolExhausted` leaving the pool untouched.
"""
from __future__ import annotations

from typing import List, Sequence

TRASH_PAGE = 0


class PoolExhausted(RuntimeError):
    """alloc() could not cover the request; the pool is unchanged."""


class KVPool:
    """Host-side page allocator over ``n_pages`` fixed-size pages."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"n_pages must be >= 2 (page 0 is the trash "
                             f"page), got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO free list: recently freed pages are reused first, which
        # keeps the hot arena slice small and cache-friendly.
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._used: set = set()

    # ------------------------------------------------------------- alloc
    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._used)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache slots."""
        return -(-n_tokens // self.page_size)

    def alloc(self, n: int) -> List[int]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages, {len(self._free)} free "
                f"({len(self._used)} in use of {self.n_pages - 1} usable)")
        pages = [self._free.pop() for _ in range(n)]
        self._used.update(pages)
        return pages

    def free(self, pages: Sequence[int]):
        for p in pages:
            if p not in self._used:
                raise ValueError(f"double-free or foreign page {p} "
                                 f"(in_use={sorted(self._used)})")
            self._used.remove(p)
            self._free.append(p)

    # -------------------------------------------------------- invariants
    def check_invariants(self):
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert not (free & self._used), "page both free and allocated"
        assert TRASH_PAGE not in free and TRASH_PAGE not in self._used, \
            "trash page entered circulation"
        assert free | self._used == set(range(1, self.n_pages)), \
            "page leaked out of the pool"
