"""Continuous-batching scheduler: admission, lanes, priorities,
preemption and prefix-cache page sharing (DESIGN.md §12).

Pure host-side bookkeeping — no jax — so the admit/preempt/finish state
machine is property-testable on its own (tests/test_serving.py drives
random priority traces and asserts the pool invariants after every
transition; tests/test_prefix.py covers the trie).

Policy (recorded trade-offs in DESIGN.md §12):

  * Priority classes, FIFO within a class: the queue is ordered by
    (priority desc, submit order), and the *head* admits only when a
    lane is free AND the pool can cover its worst case (padded prompt
    plus ``max_new_tokens``) — a blocked head blocks everything behind
    it (no skip-ahead; starvation-free within a class).
  * Reserve-ahead still holds with sharing: a lane reserves fresh pages
    for everything it may ever write — including one replacement page
    per shared page its re-run prefill chunks overlap (the COW
    reserve) — so a running request can never exhaust the pool
    mid-decode.
  * Prefix sharing (``prefix_cache=True``): the head's prompt is
    matched against the :class:`~repro.serving.prefix.PrefixTrie`;
    matched full-page prefixes attach the *same physical pages*
    (incref), prefill restarts at the first chunk past the
    chunk-aligned reuse point, and the final chunk always re-runs so
    the first token's logits are produced.  Shared pages a re-run chunk
    writes are copy-on-write swapped from the lane's reserve
    (``cow_range``); dead trie pages are evicted before admission is
    refused.
  * Preemption (``preempt=True``): when the head outranks a running
    request and admission is starved, the lowest-priority decoding lane
    is evicted — its pages are released (the trie keeps any registered
    prefix alive, so re-prefill is partial) and the request requeues at
    the front of its priority class; its regenerated tokens are
    bit-identical because sampling is a pure function of
    (seed, position).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

from repro.serving.pool import KVPool, TRASH_PAGE
from repro.serving.prefix import PrefixTrie

PREFILL, DECODE = "prefill", "decode"


@dataclasses.dataclass
class Request:
    """One generation request.  ``seed`` feeds the per-request counter
    RNG, so sampled output is reproducible no matter which lane or batch
    composition serves it.  ``max_new_tokens=None`` means "the engine's
    ``serving.max_new_tokens`` default" — resolved at ``Engine.submit``.
    ``priority``: higher admits first; a preempted request keeps its
    submit order within its class."""
    rid: int
    tokens: Sequence[int]              # prompt token ids
    max_new_tokens: Optional[int] = None
    seed: int = 0
    priority: int = 0

    def __post_init__(self):
        if len(self.tokens) < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens is not None and self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be "
                             f">= 1, got {self.max_new_tokens}")
        if self.priority < 0:
            raise ValueError(f"request {self.rid}: priority must be >= 0, "
                             f"got {self.priority}")


@dataclasses.dataclass
class Lane:
    req: Request
    pages: List[int]
    prompt_len: int
    padded_len: int                    # prompt padded to the chunk bucket
    state: str = PREFILL
    next_chunk: int = 0                # next prefill chunk index
    pos: int = 0                       # cache slots filled so far
    last_token: Optional[int] = None   # token the next decode step feeds
    out: List[int] = dataclasses.field(default_factory=list)
    t_admit: float = 0.0
    t_first: float = 0.0
    admit_seq: int = 0                 # admission order (FIFO tiebreak)
    # --- prefix sharing (DESIGN.md §12)
    shared_idx: Set[int] = dataclasses.field(default_factory=set)
    cow_reserve: List[int] = dataclasses.field(default_factory=list)
    reuse_tokens: int = 0              # cache slots attached, not recomputed


class Scheduler:
    def __init__(self, pool: KVPool, *, max_lanes: int, prefill_chunk: int,
                 max_seq: int, prefix_cache: bool = False,
                 priorities: int = 1, preempt: bool = False):
        if prefill_chunk % pool.page_size:
            raise ValueError(f"prefill_chunk={prefill_chunk} must be a "
                             f"multiple of page_size={pool.page_size}")
        if max_seq % pool.page_size:
            raise ValueError(f"max_seq={max_seq} must be a multiple of "
                             f"page_size={pool.page_size}")
        if priorities < 1:
            raise ValueError(f"priorities must be >= 1, got {priorities}")
        self.pool = pool
        self.max_lanes = max_lanes
        self.prefill_chunk = prefill_chunk
        self.max_seq = max_seq
        self.priorities = priorities
        self.preempt_enabled = preempt
        self.trie: Optional[PrefixTrie] = (PrefixTrie(pool) if prefix_cache
                                           else None)
        self.table_width = max_seq // pool.page_size
        self.lanes: List[Optional[Lane]] = [None] * max_lanes
        self.queue: Deque[Request] = deque()
        self._admit_seq = 0
        self._submit_seq = 0
        self._seq: Dict[int, int] = {}     # rid -> submit order
        # sharing / preemption telemetry (engine exports as obs gauges)
        self.prefix_hits = 0               # full prompt pages attached shared
        self.prefix_lookups = 0            # full prompt pages looked up
        self.preemptions = 0
        self.cow_copies = 0
        self.trie_evictions = 0

    # ---------------------------------------------------------- capacity
    def padded_prompt(self, prompt_len: int) -> int:
        c = self.prefill_chunk
        return -(-prompt_len // c) * c

    def span(self, req: Request) -> int:
        """Worst-case cache slots the request can touch: the padded
        prefill writes, then decode writes up to prompt+max_new."""
        return max(self.padded_prompt(len(req.tokens)),
                   len(req.tokens) + req.max_new_tokens)

    def submit(self, req: Request):
        if req.max_new_tokens is None:
            raise ValueError(f"request {req.rid}: max_new_tokens unresolved "
                             "— submit through Engine.submit, which applies "
                             "the serving.max_new_tokens default")
        if req.priority >= self.priorities:
            raise ValueError(
                f"request {req.rid}: priority {req.priority} out of range "
                f"[0, {self.priorities}) — raise serving.priorities")
        span = self.span(req)
        if span > self.max_seq:
            raise ValueError(
                f"request {req.rid}: needs {span} cache slots > "
                f"serving.max_seq={self.max_seq} (prompt {len(req.tokens)} "
                f"+ max_new {req.max_new_tokens})")
        if self.pool.pages_for(span) > self.pool.n_pages - 1:
            raise ValueError(
                f"request {req.rid}: needs {self.pool.pages_for(span)} "
                f"pages > pool capacity {self.pool.n_pages - 1}")
        self._submit_seq += 1
        self._seq[req.rid] = self._submit_seq
        self._enqueue(req)

    def _key(self, req: Request) -> Tuple[int, int]:
        return (-req.priority, self._seq[req.rid])

    def _enqueue(self, req: Request):
        """Ordered insert: priority desc, then submit order — a requeued
        (preempted) request's old seq puts it back at the front of its
        class."""
        k = self._key(req)
        for idx, queued in enumerate(self.queue):
            if self._key(queued) > k:
                self.queue.insert(idx, req)
                return
        self.queue.append(req)

    # --------------------------------------------------------- admission
    def free_lane(self) -> Optional[int]:
        for i, lane in enumerate(self.lanes):
            if lane is None:
                return i
        return None

    def _plan(self, req: Request):
        """Admission plan for ``req``: trie path to attach, fresh pages
        to allocate (table + COW reserve), and the chunk-aligned reuse
        point."""
        ps = self.pool.page_size
        c = self.prefill_chunk
        total = self.pool.pages_for(self.span(req))
        path = self.trie.match(req.tokens)[:total] if self.trie else []
        n_shared = len(path)
        padded = self.padded_prompt(len(req.tokens))
        # reuse must be chunk-aligned (prefill restarts on a chunk
        # boundary) and leave the final chunk to re-run — it produces
        # the first token's logits
        reuse_tokens = max(0, min((n_shared * ps // c) * c, padded - c))
        n_cow = n_shared - reuse_tokens // ps
        need_fresh = (total - n_shared) + n_cow
        return path, total, n_shared, reuse_tokens, n_cow, need_fresh

    def _victim(self, below: int) -> Optional[int]:
        """Lowest-priority decoding lane strictly under ``below``
        (youngest admission first within the class)."""
        best = None
        for i, lane in enumerate(self.lanes):
            if lane is None or lane.state != DECODE:
                continue
            if lane.req.priority >= below:
                continue
            if best is None or ((lane.req.priority, -lane.admit_seq)
                                < (self.lanes[best].req.priority,
                                   -self.lanes[best].admit_seq)):
                best = i
        return best

    def _reclaim(self, need_fresh: int, keep) -> None:
        if self.trie is not None and need_fresh > self.pool.available:
            self.trie_evictions += len(
                self.trie.evict(need_fresh - self.pool.available, keep=keep))

    def try_admit(self, now: float = 0.0) -> Optional[int]:
        """Admit the queue head if a lane is free and the pool covers its
        worst case.  Before refusing: reclaim dead prefix-trie pages,
        then (``preempt=True``) evict decoding lanes the head outranks.
        A still-blocked head blocks everything behind it."""
        if not self.queue:
            return None
        req = self.queue[0]
        path, total, n_shared, reuse_tokens, n_cow, need_fresh = \
            self._plan(req)
        keep = frozenset(id(n) for n in path)
        i = self.free_lane()
        self._reclaim(need_fresh, keep)
        while (self.preempt_enabled
               and (i is None or need_fresh > self.pool.available)):
            v = self._victim(req.priority)
            if v is None:
                break
            self.preempt(v)
            self._reclaim(need_fresh, keep)
            i = self.free_lane()
        if i is None or need_fresh > self.pool.available:
            return None
        self.queue.popleft()
        if self.trie is not None:
            self.prefix_lookups += len(req.tokens) // self.pool.page_size
            self.prefix_hits += n_shared
        shared = [n.page for n in path]
        for p in shared:
            self.pool.incref(p)
        fresh = self.pool.alloc(need_fresh)
        n_table_fresh = total - n_shared
        self._admit_seq += 1
        self.lanes[i] = Lane(req=req, pages=shared + fresh[:n_table_fresh],
                             prompt_len=len(req.tokens),
                             padded_len=self.padded_prompt(len(req.tokens)),
                             next_chunk=reuse_tokens // self.prefill_chunk,
                             pos=reuse_tokens,
                             t_admit=now, admit_seq=self._admit_seq,
                             shared_idx=set(range(n_shared)),
                             cow_reserve=fresh[n_table_fresh:],
                             reuse_tokens=reuse_tokens)
        return i

    # ----------------------------------------------------- prefix sharing
    def cow_range(self, lane: Lane, start: int, end: int
                  ) -> List[Tuple[int, int]]:
        """Copy-on-write every shared page overlapping cache slots
        [start, end) that a prefill chunk is about to write: swap in a
        private page from the lane's reserve (allocated at admission, so
        this can never exhaust the pool) and drop the shared reference.
        Returns (shared_page, private_page) pairs — the engine copies
        the device content before the write lands."""
        ps = self.pool.page_size
        pairs: List[Tuple[int, int]] = []
        for idx in range(start // ps, -(-end // ps)):
            if idx in lane.shared_idx and idx < len(lane.pages):
                old = lane.pages[idx]
                new = lane.cow_reserve.pop()
                self.pool.decref(old)      # trie (and peers) keep it alive
                lane.pages[idx] = new
                lane.shared_idx.discard(idx)
                self.cow_copies += 1
                pairs.append((old, new))
        return pairs

    def register_prefix(self, lane: Lane):
        """Offer a finished prefill's full prompt pages to the trie
        (engine calls this when the final chunk lands).  Already-shared
        pages match their existing nodes; the lane's fresh pages extend
        the chain and gain the trie's reference."""
        if self.trie is not None:
            self.trie.insert(lane.req.tokens, lane.pages)

    # ---------------------------------------------------------- preempt
    def preempt(self, i: int) -> Lane:
        """Evict lane ``i``: release its pages (a trie-registered prefix
        survives via the trie's references) and requeue its request at
        the front of its priority class.  Generated tokens are
        discarded — regeneration is bit-identical because sampling is a
        pure function of (seed, position)."""
        lane = self.lanes[i]
        assert lane is not None, f"preempt on empty lane {i}"
        self.pool.free(lane.pages)
        self.pool.free(lane.cow_reserve)
        self.lanes[i] = None
        self.preemptions += 1
        self._enqueue(lane.req)
        return lane

    # ------------------------------------------------------------ retire
    def finish(self, i: int) -> Lane:
        """Retire lane ``i``: drop its page references.  Pages the trie
        also references stay allocated for future prefix hits; the rest
        return to the pool immediately."""
        lane = self.lanes[i]
        assert lane is not None, f"finish on empty lane {i}"
        self.pool.free(lane.pages)
        self.pool.free(lane.cow_reserve)   # non-empty only pre-prefill-end
        self.lanes[i] = None
        self._seq.pop(lane.req.rid, None)
        return lane

    # -------------------------------------------------------- page table
    def page_row(self, lane: Lane) -> List[int]:
        """The lane's page-table row, trash-padded to ``table_width``."""
        row = list(lane.pages[:self.table_width])
        row += [TRASH_PAGE] * (self.table_width - len(row))
        return row

    def trash_row(self) -> List[int]:
        return [TRASH_PAGE] * self.table_width

    # ------------------------------------------------------------- views
    def prefilling(self) -> List[int]:
        return [i for i, l in enumerate(self.lanes)
                if l is not None and l.state == PREFILL]

    def decoding(self) -> List[int]:
        return [i for i, l in enumerate(self.lanes)
                if l is not None and l.state == DECODE]

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(l is not None for l in self.lanes)

    @property
    def page_hit_rate(self) -> float:
        """Shared prompt pages attached / full prompt pages looked up."""
        return (self.prefix_hits / self.prefix_lookups
                if self.prefix_lookups else 0.0)
