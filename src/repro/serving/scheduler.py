"""Continuous-batching scheduler: admission, lanes, page-table state
(DESIGN.md §12).

Pure host-side bookkeeping — no jax — so the admit/finish state machine
is property-testable on its own (tests/test_serving.py drives random
traces and asserts the pool invariants after every transition).

Policy (recorded trade-offs in DESIGN.md §12):

  * FIFO with head-of-line blocking: the queue head admits only when a
    lane is free AND the pool can cover its *worst case* (padded prompt
    plus ``max_new_tokens``).  Reserve-ahead means a running request can
    never exhaust the pool mid-decode, so there is no preemption path to
    get wrong — at the cost of utilization when requests finish early.
  * One lane per request; a lane is PREFILL while its prompt chunks are
    streaming in (interleaved with decode steps by the engine), DECODE
    once it has sampled its first token, and is retired on EOS /
    max-tokens, returning its pages to the pool immediately.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence

from repro.serving.pool import KVPool, TRASH_PAGE

PREFILL, DECODE = "prefill", "decode"


@dataclasses.dataclass
class Request:
    """One generation request.  ``seed`` feeds the per-request counter
    RNG, so sampled output is reproducible no matter which lane or batch
    composition serves it.  ``max_new_tokens=None`` means "the engine's
    ``serving.max_new_tokens`` default" — resolved at ``Engine.submit``."""
    rid: int
    tokens: Sequence[int]              # prompt token ids
    max_new_tokens: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        if len(self.tokens) < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens is not None and self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be "
                             f">= 1, got {self.max_new_tokens}")


@dataclasses.dataclass
class Lane:
    req: Request
    pages: List[int]
    prompt_len: int
    padded_len: int                    # prompt padded to the chunk bucket
    state: str = PREFILL
    next_chunk: int = 0                # next prefill chunk index
    pos: int = 0                       # cache slots filled so far
    last_token: Optional[int] = None   # token the next decode step feeds
    out: List[int] = dataclasses.field(default_factory=list)
    t_admit: float = 0.0
    t_first: float = 0.0
    admit_seq: int = 0                 # admission order (FIFO tiebreak)


class Scheduler:
    def __init__(self, pool: KVPool, *, max_lanes: int, prefill_chunk: int,
                 max_seq: int):
        if prefill_chunk % pool.page_size:
            raise ValueError(f"prefill_chunk={prefill_chunk} must be a "
                             f"multiple of page_size={pool.page_size}")
        if max_seq % pool.page_size:
            raise ValueError(f"max_seq={max_seq} must be a multiple of "
                             f"page_size={pool.page_size}")
        self.pool = pool
        self.max_lanes = max_lanes
        self.prefill_chunk = prefill_chunk
        self.max_seq = max_seq
        self.table_width = max_seq // pool.page_size
        self.lanes: List[Optional[Lane]] = [None] * max_lanes
        self.queue: Deque[Request] = deque()
        self._admit_seq = 0

    # ---------------------------------------------------------- capacity
    def padded_prompt(self, prompt_len: int) -> int:
        c = self.prefill_chunk
        return -(-prompt_len // c) * c

    def span(self, req: Request) -> int:
        """Worst-case cache slots the request can touch: the padded
        prefill writes, then decode writes up to prompt+max_new."""
        return max(self.padded_prompt(len(req.tokens)),
                   len(req.tokens) + req.max_new_tokens)

    def submit(self, req: Request):
        if req.max_new_tokens is None:
            raise ValueError(f"request {req.rid}: max_new_tokens unresolved "
                             "— submit through Engine.submit, which applies "
                             "the serving.max_new_tokens default")
        span = self.span(req)
        if span > self.max_seq:
            raise ValueError(
                f"request {req.rid}: needs {span} cache slots > "
                f"serving.max_seq={self.max_seq} (prompt {len(req.tokens)} "
                f"+ max_new {req.max_new_tokens})")
        if self.pool.pages_for(span) > self.pool.n_pages - 1:
            raise ValueError(
                f"request {req.rid}: needs {self.pool.pages_for(span)} "
                f"pages > pool capacity {self.pool.n_pages - 1}")
        self.queue.append(req)

    # --------------------------------------------------------- admission
    def free_lane(self) -> Optional[int]:
        for i, lane in enumerate(self.lanes):
            if lane is None:
                return i
        return None

    def try_admit(self, now: float = 0.0) -> Optional[int]:
        """Admit the queue head if a lane is free and the pool covers its
        worst case.  FIFO: a blocked head blocks everything behind it."""
        if not self.queue:
            return None
        i = self.free_lane()
        if i is None:
            return None
        req = self.queue[0]
        n = self.pool.pages_for(self.span(req))
        if n > self.pool.available:
            return None
        self.queue.popleft()
        self._admit_seq += 1
        self.lanes[i] = Lane(req=req, pages=self.pool.alloc(n),
                             prompt_len=len(req.tokens),
                             padded_len=self.padded_prompt(len(req.tokens)),
                             t_admit=now, admit_seq=self._admit_seq)
        return i

    # ------------------------------------------------------------ retire
    def finish(self, i: int) -> Lane:
        """Retire lane ``i``: its pages return to the pool immediately."""
        lane = self.lanes[i]
        assert lane is not None, f"finish on empty lane {i}"
        self.pool.free(lane.pages)
        self.lanes[i] = None
        return lane

    # -------------------------------------------------------- page table
    def page_row(self, lane: Lane) -> List[int]:
        """The lane's page-table row, trash-padded to ``table_width``."""
        row = list(lane.pages[:self.table_width])
        row += [TRASH_PAGE] * (self.table_width - len(row))
        return row

    def trash_row(self) -> List[int]:
        return [TRASH_PAGE] * self.table_width

    # ------------------------------------------------------------- views
    def prefilling(self) -> List[int]:
        return [i for i, l in enumerate(self.lanes)
                if l is not None and l.state == PREFILL]

    def decoding(self) -> List[int]:
        return [i for i, l in enumerate(self.lanes)
                if l is not None and l.state == DECODE]

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(l is not None for l in self.lanes)
