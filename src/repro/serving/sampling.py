"""Per-request counter-RNG sampling: greedy / temperature / top-k
(DESIGN.md §12).

The key for a sampled token is ``fold_in(PRNGKey(request.seed),
absolute_position)`` — a pure function of (request, position), never of
which lane or decode batch happened to serve the token.  The same
request therefore samples the same continuation whether it rode a full
batch, a lonely lane, or a re-run after preemption; the engine's
reproducibility test pins this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_sampler(temperature: float, top_k: int):
    """Build the jitted sampler for one engine: ``fn(logits (B, V) f32,
    seeds (B,) uint32, positions (B,) int32) -> (B,) int32``.

    ``temperature == 0`` is greedy (argmax; seeds unused).  ``top_k > 0``
    restricts sampling to the k highest logits.  One sampler per engine,
    so the two decode buckets stay at exactly one compile each.
    """
    if temperature < 0:
        raise ValueError(f"temperature must be >= 0, got {temperature}")
    if top_k < 0:
        raise ValueError(f"top_k must be >= 0, got {top_k}")

    if temperature == 0.0:
        @jax.jit
        def greedy(logits, seeds, positions):
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy

    @jax.jit
    def sample(logits, seeds, positions):
        if top_k > 0:
            kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
            logits = jnp.where(logits >= kth, logits, -jnp.inf)

        def one(lg, seed, position):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), position)
            return jax.random.categorical(key, lg / temperature)
        return jax.vmap(one)(logits, seeds, positions).astype(jnp.int32)
    return sample
