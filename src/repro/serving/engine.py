"""The continuous-batching engine: jitted paged steps over the KV pool
(DESIGN.md §12).

One engine owns one arena (``models.lm.init_paged_cache``), one pool
(:class:`~repro.serving.pool.KVPool`), one scheduler, and exactly two
compiled shapes of the same ``lm.paged_step`` function:

  * the *prefill bucket*:  (1, prefill_chunk) tokens, one lane's row
  * the *decode bucket*:   (max_lanes, 1) tokens, the full page table

(plus, when prefix sharing triggers a copy-on-write, one tiny
page-duplication kernel — a scalar-indexed clone compiled once and
outside the bucket promise ``n_compiles`` guards).  With
``serving.prefix_cache`` on, prompts that share a full-page token
prefix attach the same physical pages through the scheduler's radix
trie and skip the chunk-aligned part of prefill; greedy output is
bit-identical to sharing off (the correctness anchor pinned in
tests/test_serving.py).  ``serving.preempt`` lets a starved
higher-priority admission evict the lowest-priority decoding lane.

Prompts are padded to the chunk bucket and streamed in chunk-by-chunk,
interleaved with decode steps (one chunk per engine step), so a long
admission never stalls the running lanes for more than one chunk's
latency.  Inactive decode lanes ride along pointed at the trash page —
the batch shape never changes, so nothing ever recompiles after warmup.

The arena is donated through every call so XLA may update pages in
place; where the layer scan forces a fresh output buffer the cost is one
arena-sized copy per call — which is why the pool should be sized to the
workload's worst case, not padded "to be safe" (benchmarks/serving.py
measures the copy tax directly; recorded in DESIGN.md §12).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.models import lm
from repro.serving import sampling
from repro.serving.pool import KVPool
from repro.serving.scheduler import DECODE, Lane, Request, Scheduler


class EngineUnsupported(NotImplementedError):
    """The model's block family is outside the paged engine's coverage
    (SSM/MLA mixers, stub frontends) — serve it with the lockstep path."""


@dataclasses.dataclass
class GenResult:
    rid: int
    tokens: List[int]                # generated ids (prompt excluded)
    prompt_len: int
    t_submit: float
    t_admit: float
    t_first: float                   # first generated token (prefill done)
    t_done: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit

    @property
    def ttft(self) -> float:
        """Time to first token."""
        return self.t_first - self.t_submit


class Engine:
    """Drive ``spec.serving`` over a model: submit() requests, step()
    until drained (or just run())."""

    def __init__(self, cfg, params, serving, mesh=None, clock=None,
                 obs=None):
        if not lm.supports_paged(cfg):
            kinds = sorted({b.kind for s in cfg.stages for b in s.pattern})
            raise EngineUnsupported(
                f"{cfg.name}: paged serving covers attn mixers only, "
                f"got {kinds}; use the lockstep serve path")
        self.cfg = cfg
        self.params = params
        self.serving = serving
        self.clock = clock or time.perf_counter
        # learned position tables are finite: the engine cannot place a
        # token beyond them, whatever serving.max_seq asks for
        max_seq = serving.max_seq
        if cfg.pos_emb == "learned":
            max_seq = min(max_seq,
                          serving.page_size
                          * (cfg.max_seq // serving.page_size))
        self.pool = KVPool(serving.n_pages, serving.page_size)
        self.sched = Scheduler(self.pool, max_lanes=serving.max_lanes,
                               prefill_chunk=serving.prefill_chunk,
                               max_seq=max_seq,
                               prefix_cache=serving.prefix_cache,
                               priorities=serving.priorities,
                               preempt=serving.preempt)
        self.arena = lm.init_paged_cache(cfg, serving.n_pages,
                                         serving.page_size)
        sample = sampling.make_sampler(serving.temperature, serving.top_k)

        def pstep(p, a, t, pg, pos, sel, seeds, spos):
            # prefill bucket; sampling fused in so the final chunk's
            # first token comes back in the same dispatch
            logits, a2 = lm.paged_step(cfg, p, a, t, pg, pos, sel)
            return sample(logits, seeds, spos), a2

        def dstep(p, a, t, pg, pos, seeds):
            # decode bucket: token/position state stays ON DEVICE between
            # steps — the returned (toks, pos) feed the next call as-is,
            # so a steady-state decode step uploads nothing (host arrays
            # are rebuilt only when the lane set changes)
            B = pos.shape[0]
            logits, a2 = lm.paged_step(cfg, p, a, t, pg, pos,
                                       jnp.zeros((B,), jnp.int32))
            nxt = sample(logits, seeds, pos + 1)
            return nxt[:, None], pos + 1, a2

        def cstep(a, src, dst):
            # copy-on-write page duplication: clone physical page src
            # into dst across every stage-block leaf (page axis is 1)
            return jax.tree_util.tree_map(
                lambda x: x.at[:, dst].set(x[:, src]), a)
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.distributed import ctx, sharding
            ctx.set_mesh(mesh)
            a_shard = sharding.arena_sharding(
                jax.eval_shape(lambda: lm.init_paged_cache(
                    cfg, serving.n_pages, serving.page_size)), mesh)
            p_shard = sharding.params_sharding(
                cfg, jax.eval_shape(lambda: lm.init_params(
                    cfg, jax.random.PRNGKey(0))), mesh)
            repl = NamedSharding(mesh, P())
            self._pstep = jax.jit(pstep, donate_argnums=(1,),
                                  in_shardings=(p_shard, a_shard, repl,
                                                repl, repl, repl, repl,
                                                repl),
                                  out_shardings=(repl, a_shard))
            self._dstep = jax.jit(dstep, donate_argnums=(1,),
                                  in_shardings=(p_shard, a_shard, repl,
                                                repl, repl, repl),
                                  out_shardings=(repl, repl, a_shard))
            self._cstep = jax.jit(cstep, donate_argnums=(0,),
                                  in_shardings=(a_shard, repl, repl),
                                  out_shardings=a_shard)
        else:
            self._pstep = jax.jit(pstep, donate_argnums=(1,))
            self._dstep = jax.jit(dstep, donate_argnums=(1,))
            self._cstep = jax.jit(cstep, donate_argnums=(0,))
        self.n_prefill_calls = 0
        self.n_decode_steps = 0
        self._t_submit: Dict[int, float] = {}
        self._decode_dirty = True        # device lane state needs rebuild
        self._d_toks = self._d_table = self._d_pos = self._d_seeds = None
        # telemetry (DESIGN.md §13): an obs.Session, or the free
        # NULL_SESSION — the engine never branches on "is obs on"
        self.obs = obs if obs is not None else obs_mod.NULL_SESSION
        reg = self.obs.registry
        self._m_queue = reg.gauge("serving_queue_depth",
                                  "requests waiting for admission")
        self._m_lanes = reg.gauge("serving_lanes_active",
                                  "lanes prefilling or decoding")
        self._m_pages = reg.gauge("serving_pages_in_use",
                                  "KV pool pages allocated")
        self._m_util = reg.gauge("serving_page_utilization",
                                 "pages in use / usable pages")
        self._m_ttft = reg.histogram("serving_ttft_seconds",
                                     "submit -> first generated token")
        self._m_lat = reg.histogram("serving_latency_seconds",
                                    "submit -> request finished")
        self._m_toks = reg.counter("serving_tokens_generated",
                                   "generated tokens over all requests")
        self._m_reqs = reg.counter("serving_requests_completed",
                                   "requests retired")
        # prefix sharing / preemption (DESIGN.md §12)
        self._m_hit = reg.gauge("serving_page_hit_rate",
                                "shared prompt pages attached / looked up")
        self._m_preempt = reg.gauge("serving_preemptions",
                                    "decoding lanes evicted and requeued")
        self._m_cow = reg.gauge("serving_cow_copies",
                                "shared pages duplicated before a write")

    def _sample_gauges(self):
        self._m_queue.set(len(self.sched.queue))
        self._m_lanes.set(len(self.sched.prefilling())
                          + len(self.sched.decoding()))
        in_use = self.pool.in_use
        self._m_pages.set(in_use)
        usable = self.pool.n_pages - 1        # page 0 is the trash page
        self._m_util.set(in_use / usable if usable else 0.0)
        self._m_hit.set(self.sched.page_hit_rate)
        self._m_preempt.set(self.sched.preemptions)
        self._m_cow.set(self.sched.cow_copies)

    def metrics_text(self) -> str:
        """Prometheus text exposition of the engine's metrics."""
        return self.obs.registry.to_text()

    # ----------------------------------------------------------- compiles
    def n_compiles(self) -> int:
        """Compiled shapes behind the paged steps — stays at <= 2 (one
        per bucket) for the engine's whole life; the bench asserts it."""
        try:
            return self._pstep._cache_size() + self._dstep._cache_size()
        except AttributeError:  # pragma: no cover - older jax
            return -1

    # ------------------------------------------------------------- submit
    def submit(self, req: Request):
        if req.max_new_tokens is None:   # spec default for the budget
            req = dataclasses.replace(
                req, max_new_tokens=self.serving.max_new_tokens)
        self.sched.submit(req)           # validates span vs pool/table
        self._t_submit[req.rid] = self.clock()

    # --------------------------------------------------------------- step
    def step(self) -> List[GenResult]:
        """One engine iteration: admit, one prefill chunk, one batched
        decode step.  Returns the requests that finished this iteration."""
        sched = self.sched
        pre_preempt = sched.preemptions
        while sched.try_admit(now=self.clock()) is not None:
            pass
        if sched.preemptions != pre_preempt:
            self._decode_dirty = True    # a decoding lane was evicted

        # -- chunked prefill: one chunk for the oldest prefilling lane
        # (admission order, NOT lane index — a later admission into a
        # lower lane must not overtake an in-progress prefill)
        pre = sched.prefilling()
        if pre:
            i = min(pre, key=lambda j: sched.lanes[j].admit_seq)
            lane = sched.lanes[i]
            c = sched.prefill_chunk
            start = lane.next_chunk * c
            chunk = np.zeros((1, c), np.int32)
            lo = min(start + c, lane.prompt_len)
            if lo > start:
                chunk[0, :lo - start] = np.asarray(
                    lane.req.tokens[start:lo], np.int32)
            final = start + c >= lane.padded_len
            sel = (min(lane.prompt_len - 1 - start, c - 1) if final else 0)
            # copy-on-write: shared pages this chunk writes get a
            # private duplicate before the write lands (scheduler swaps
            # the page table; the device content copy happens here)
            for src, dst in sched.cow_range(lane, start, start + c):
                self.arena = self._cstep(self.arena, jnp.int32(src),
                                         jnp.int32(dst))
            with self.obs.tracer.span(obs_mod.SERVE_PREFILL) as sp:
                toks, self.arena = self._pstep(
                    self.params, self.arena, jnp.asarray(chunk),
                    jnp.asarray(np.asarray(sched.page_row(lane),
                                           np.int32)[None]),
                    jnp.asarray([start], jnp.int32),
                    jnp.asarray([sel], jnp.int32),
                    jnp.asarray([lane.req.seed], jnp.uint32),
                    jnp.asarray([lane.prompt_len], jnp.int32))
                sp.fence(toks)
            self.n_prefill_calls += 1
            lane.next_chunk += 1
            lane.pos = min(start + c, lane.padded_len)
            if final:
                sched.register_prefix(lane)   # full prompt pages -> trie
                tok = int(toks[0])
                lane.t_first = self.clock()
                lane.out.append(tok)
                lane.last_token = tok
                lane.pos = lane.prompt_len
                lane.state = DECODE
                self._decode_dirty = True

        # -- batched decode over every decoding lane (fixed bucket)
        finished: List[GenResult] = []
        dec = sched.decoding()
        live = [i for i in dec if not self._done(sched.lanes[i])]
        for i in sorted(set(dec) - set(live)):
            finished.append(self._retire(i))
        if live:
            B = sched.max_lanes
            if self._decode_dirty:
                toks = np.zeros((B, 1), np.int32)
                table = np.zeros((B, sched.table_width), np.int32)
                pos = np.zeros((B,), np.int32)
                seeds = np.zeros((B,), np.uint32)
                for i in live:
                    lane = sched.lanes[i]
                    toks[i, 0] = lane.last_token
                    table[i] = sched.page_row(lane)
                    pos[i] = lane.pos
                    seeds[i] = lane.req.seed
                self._d_toks = jnp.asarray(toks)
                self._d_table = jnp.asarray(table)
                self._d_pos = jnp.asarray(pos)
                self._d_seeds = jnp.asarray(seeds)
                self._decode_dirty = False
            with self.obs.tracer.span(obs_mod.SERVE_DECODE) as sp:
                self._d_toks, self._d_pos, self.arena = self._dstep(
                    self.params, self.arena, self._d_toks, self._d_table,
                    self._d_pos, self._d_seeds)
                sp.fence(self._d_toks)
            self.n_decode_steps += 1
            nxt = np.asarray(self._d_toks)[:, 0]
            for i in live:
                lane = sched.lanes[i]
                tok = int(nxt[i])
                lane.out.append(tok)
                lane.last_token = tok
                lane.pos += 1
                if self._done(lane):
                    finished.append(self._retire(i))
        self._sample_gauges()
        return finished

    def _done(self, lane: Lane) -> bool:
        eos = self.serving.eos_id
        return (len(lane.out) >= lane.req.max_new_tokens
                or (eos is not None and lane.out and lane.out[-1] == eos))

    def _retire(self, i: int) -> GenResult:
        self._decode_dirty = True        # lane composition changed
        lane = self.sched.finish(i)      # pages return to the pool now
        res = GenResult(rid=lane.req.rid, tokens=list(lane.out),
                        prompt_len=lane.prompt_len,
                        t_submit=self._t_submit.pop(lane.req.rid, 0.0),
                        t_admit=lane.t_admit, t_first=lane.t_first,
                        t_done=self.clock())
        self._m_reqs.inc()
        self._m_toks.inc(len(res.tokens))
        if res.t_submit:
            self._m_ttft.observe(res.ttft)
            self._m_lat.observe(res.latency)
        return res

    # ---------------------------------------------------------------- run
    def run(self, requests: Sequence[Request]) -> List[GenResult]:
        """Drain ``requests``: submit everything, step until idle.
        Results come back in finish order (not submit order).  Finished
        results are handed to the caller, never retained — a long-lived
        engine stays O(active lanes), not O(requests ever served)."""
        for r in requests:
            self.submit(r)
        results: List[GenResult] = []
        guard = 0
        t_run = self.clock()
        while self.sched.busy:
            before = (self.n_prefill_calls, self.n_decode_steps,
                      len(results), len(self.sched.queue),
                      self.sched.preemptions)
            results.extend(self.step())
            after = (self.n_prefill_calls, self.n_decode_steps,
                     len(results), len(self.sched.queue),
                     self.sched.preemptions)
            guard = guard + 1 if before == after else 0
            if guard > 2:    # admission blocked with nothing running
                raise RuntimeError(
                    "engine stalled: queue head needs "
                    "more pool pages than will ever free up")
        dt = self.clock() - t_run
        if dt > 0:
            self.obs.registry.gauge(
                "serving_tokens_per_second",
                "generated tokens / drain wall time, last run()").set(
                sum(len(r.tokens) for r in results) / dt)
        return results
