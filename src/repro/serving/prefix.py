"""Prefix-cache index: a radix trie over token-aligned KV pages
(DESIGN.md §12).

Per-tenant ZO adaptation (MeZO-style, arxiv 2305.17333) multiplies one
system prompt across every request of a tenant — at serving scale the
prompt-prefix KV work is overwhelmingly redundant.  The trie deduplicates
it at *page* granularity: each edge is exactly one page worth of prompt
tokens, each node owns one physical page of the arena whose content is
the K/V of those positions.  Because K/V at position p is a pure
function of ``tokens[0..p]`` (causal attention) and of the shared model
params, two requests agreeing on a full-page-aligned token prefix can
read the very same physical pages — vLLM-style sharing on top of
:class:`~repro.serving.pool.KVPool` refcounts.

Matching is **token-exact**: children are keyed by the page's token
tuple, so a lookup can only ever hit a true token prefix — there is no
hash-collision false-share path (tests/test_prefix.py pins this).

The trie holds one pool reference per node page.  A node whose page
refcount is exactly 1 is *dead* — no lane references it, only the trie —
and is reclaimable under pool pressure: ``evict`` releases dead nodes
leaf-first in LRU order (``Scheduler.try_admit`` calls it before
refusing admission).  Because lanes attach whole root paths, liveness is
closed upward: an attached node's ancestors are attached too, so a dead
node's descendants are all dead and leaf-first eviction never strands a
live chain.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.serving.pool import KVPool


class TrieNode:
    """One page-aligned edge of the prefix trie: ``tokens`` (exactly
    ``page_size`` ids) mapping to physical page ``page``."""

    __slots__ = ("tokens", "page", "children", "parent", "stamp")

    def __init__(self, tokens: Tuple[int, ...], page: int,
                 parent: Optional["TrieNode"], stamp: int):
        self.tokens = tokens
        self.page = page
        self.children: Dict[Tuple[int, ...], "TrieNode"] = {}
        self.parent = parent
        self.stamp = stamp          # LRU clock of the last match/insert

    def depth(self) -> int:
        d, n = 0, self.parent
        while n is not None:
            d, n = d + 1, n.parent
        return d


class PrefixTrie:
    """Radix index over token-aligned pages, backed by ``pool``."""

    def __init__(self, pool: KVPool):
        self.pool = pool
        self.page_size = pool.page_size
        self.root: Dict[Tuple[int, ...], TrieNode] = {}
        self.n_nodes = 0
        self._clock = 0

    # ------------------------------------------------------------ lookup
    def _blocks(self, tokens: Sequence[int]):
        """Full-page token blocks of ``tokens`` (the partial tail page,
        whose K/V would depend on tokens outside it, never shares)."""
        ps = self.page_size
        for i in range(len(tokens) // ps):
            yield tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])

    def match(self, tokens: Sequence[int]) -> List[TrieNode]:
        """Longest token-exact full-page prefix match: the root path of
        nodes whose concatenated tokens prefix ``tokens``.  Touches the
        LRU stamp of every node on the path."""
        self._clock += 1
        path: List[TrieNode] = []
        children = self.root
        for blk in self._blocks(tokens):
            node = children.get(blk)
            if node is None:
                break
            node.stamp = self._clock
            path.append(node)
            children = node.children
        return path

    # ------------------------------------------------------------ insert
    def insert(self, tokens: Sequence[int], pages: Sequence[int]):
        """Register a finished prefill: ``pages[i]`` holds the K/V of
        full page ``i`` of ``tokens``.  Existing nodes are kept (their
        page already carries identical content — first writer wins);
        new nodes take the lane's page and add the trie's reference."""
        self._clock += 1
        children, parent = self.root, None
        for i, blk in enumerate(self._blocks(tokens)):
            if i >= len(pages):
                break
            node = children.get(blk)
            if node is None:
                node = TrieNode(blk, pages[i], parent, self._clock)
                self.pool.incref(pages[i])
                children[blk] = node
                self.n_nodes += 1
            else:
                node.stamp = self._clock
            parent, children = node, node.children

    # ---------------------------------------------------------- eviction
    def _dead_leaves(self, keep: FrozenSet[int]) -> List[TrieNode]:
        out, stack = [], list(self.root.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if (not n.children and id(n) not in keep
                    and self.pool.refcount(n.page) == 1):
                out.append(n)
        return out

    def reclaimable(self, keep: FrozenSet[int] = frozenset()) -> int:
        """Pages ``evict`` could currently release: dead nodes (refcount
        1, trie-only) outside ``keep``.  Dead subtrees are closed
        downward, so every dead node is eventually leaf-evictable."""
        count, stack = 0, list(self.root.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if id(n) not in keep and self.pool.refcount(n.page) == 1:
                count += 1
        return count

    def evict(self, n: int, keep: FrozenSet[int] = frozenset()) -> List[int]:
        """Release up to ``n`` dead pages back to the pool, deepest and
        least-recently-used leaves first; returns the evicted page ids.
        Nodes whose ids are in ``keep`` (a path about to be attached)
        are never evicted."""
        evicted: List[int] = []
        while len(evicted) < n:
            leaves = self._dead_leaves(keep)
            if not leaves:
                break
            leaves.sort(key=lambda nd: (nd.stamp, -nd.depth()))
            for node in leaves:
                if len(evicted) >= n:
                    break
                siblings = (node.parent.children if node.parent is not None
                            else self.root)
                del siblings[node.tokens]
                self.n_nodes -= 1
                self.pool.decref(node.page)
                evicted.append(node.page)
        return evicted

    # -------------------------------------------------------- invariants
    def check_invariants(self):
        seen_pages = set()
        stack = [(None, node) for node in self.root.values()]
        count = 0
        while stack:
            parent, n = stack.pop()
            count += 1
            assert n.parent is parent, "parent link broken"
            assert len(n.tokens) == self.page_size, \
                f"edge {n.tokens!r} is not one full page"
            assert n.page not in seen_pages, \
                f"page {n.page} appears twice in the trie"
            seen_pages.add(n.page)
            assert self.pool.refcount(n.page) >= 1, \
                f"trie node page {n.page} is not allocated"
            for blk, child in n.children.items():
                assert blk == child.tokens, "child keyed by wrong tokens"
                stack.append((n, child))
        assert count == self.n_nodes, "n_nodes out of sync"
