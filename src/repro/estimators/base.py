"""Estimator protocol: regenerable update directions, never materialized.

A ZO gradient estimator probes the loss with seeded perturbations and
returns a :class:`DirectionSet` — q ``(seed, coefficient)`` pairs whose
implied parameter-space update is::

    theta <- decay * theta - lr * sum_i coeffs[i] * z(seeds[i])

Each ``z(seed_i)`` (and its LeZO layer subset) regenerates on the fly
from its seed via the counter RNG, exactly like the perturbation passes
themselves, so the optimizer state stays O(q) scalars regardless of the
model size — the invariant the whole repo is built around (DESIGN.md §6).

Implementations (see the sibling modules):

  * ``two_point``  — the paper's antithetic SPSA pair, extracted verbatim
                     from the pre-refactor ``core/zo.py`` step.
  * ``one_sided``  — FZOO-style: q one-sided probes against one shared
                     baseline loss, evaluated as a single vmapped
                     (widened) forward.
  * ``averaged``   — q independent two-point probes averaged; the update
                     replays q fused axpy passes (the ``zo_adaptive``
                     regenerate-from-seed trick).
  * ``importance`` — selection-policy wrapper: smoothed per-layer |g|
                     scores replace uniform layer drop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import rng, zo
from repro.estimators import costs
from repro.obs import trace as obs

_DIR_SALT = 0xD16E  # folds the direction index into the step seed


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    name: str = "two_point"       # two_point | one_sided | averaged | importance
    eps: float = 1e-3
    lr: float = 1e-6
    q: int = 1                    # directions per step (ignored by two_point)
    q_chunk: int = 0              # one_sided: probes vmapped per chunk
                                  # (0 = all q in one widened forward)
    n_drop: int = 0               # 0 => MeZO; >0 => LeZO layer sparsity
    policy: str = "stratified"    # stratified | uniform
    backend: str = "dense"        # dense | scan | gather | pallas
    fused_update: bool = True
    weight_decay: float = 0.0
    interpret: bool = True        # pallas interpret mode for the *axpy*
                                  # kernels (fused forwards auto-detect)
    inner: str = "two_point"      # estimator the importance wrapper drives
    importance_decay: float = 0.99  # EMA for the per-layer |g| scores
    # materialized | virtual | virtual_ref — virtual probes evaluate
    # loss(theta + s*eps*z) through the fused forward (repro.fused): the
    # loss_fn must accept a ``perturb`` kwarg (models.lm.lm_loss does)
    # and the step performs zero perturb/restore parameter writes
    forward_backend: str = "materialized"
    # stack virtual probes onto ONE fused forward: two_point's ±εz pair
    # shares each W tile load *and* each z regeneration (shared seed);
    # one_sided's q-chunks share the W loads.  Bit-identical to the
    # per-probe virtual path (DESIGN.md §10); no effect when materialized
    paired_probes: bool = True


@dataclasses.dataclass
class DirectionSet:
    """q regenerable update directions — no perturbation pytree, ever.

    ``seeds``/``coeffs``: traced uint32 / f32 scalars per direction.
    ``restore``: static per-direction scale undoing the residual probe
    perturbation still sitting in the returned params (0.0 when the probe
    already restored; +eps for two-point's ``-eps`` exit state).
    ``masks``/``idxs``: per-direction layer subsets as returned by the
    selection policy — (L,) bools / static-size int32 vectors per group,
    themselves regenerable from the direction seed.
    """
    seeds: Tuple
    coeffs: Tuple
    restore: Tuple[float, ...]
    masks: Tuple
    idxs: Tuple

    def __len__(self):
        return len(self.seeds)


def direction_seeds(seed, q: int) -> Tuple:
    """Per-direction seeds.  Direction 0 keeps the step seed itself, so
    two_point — and averaged at q=1 — draw exactly the z the paper's step
    would; further directions fold in the direction index."""
    seed = jnp.asarray(seed, jnp.uint32)
    return (seed,) + tuple(
        rng.fold(seed, jnp.uint32(_DIR_SALT + i)) for i in range(1, q))


class Estimator:
    """Shared selection / axpy / update machinery for all estimators.

    ``select_fn(seed, state)`` overrides the layer-selection policy (the
    importance wrapper injects its weighted policy into the inner
    estimator this way); default is the config's uniform/stratified one.
    """
    name = "base"

    def __init__(self, spec: zo.ZOSpec, cfg: EstimatorConfig,
                 select_fn: Optional[Callable] = None):
        if (cfg.backend == "gather" and cfg.policy != "stratified"
                and select_fn is None and cfg.name != "importance"):
            raise ValueError("gather backend requires the stratified policy")
        self.spec, self.cfg = spec, cfg
        self._select = select_fn

    # -------------------------------------------------------- selection
    def select(self, seed, state):
        """-> (masks: {g: (L_g,) bool}, idxs: {g: (k_g,) int32} | None,
        n_active)."""
        if self._select is not None:
            sel = self._select(seed, state)
        elif self.cfg.policy == "stratified":
            sel = zo.stratified_select(self.spec, seed, self.cfg.n_drop)
        else:
            sel = zo.uniform_select(self.spec, seed, self.cfg.n_drop)
        tr = obs.get_tracer()
        if tr.enabled and not obs.tracing():
            tr.count(obs.CTR_SELECTS)
            tr.gauge(obs.GAUGE_ACTIVE, int(sel[2]))
        return sel

    # ------------------------------------------------------------ state
    def init_state(self) -> Dict:
        return {}

    def update_state(self, state, dirs: DirectionSet, metrics):
        return state

    # ------------------------------------------------------------- axpy
    def _ax(self, p, scale, seed, masks, idxs, decay=1.0, backend=None):
        return zo.tree_axpy(p, self.spec, seed, scale, masks, idxs,
                            decay=decay, backend=backend or self.cfg.backend,
                            interpret=self.cfg.interpret)

    # -------------------------------------------------- virtual probing
    @property
    def virtual(self) -> bool:
        return self.cfg.forward_backend != "materialized"

    def _vloss(self, loss_fn, params, batch, seed, scale, masks):
        """Probe loss(theta + scale*z(seed)) with zero parameter writes:
        the fused forward regenerates z in its kernels (repro.fused).
        ``interpret=None`` lets the kernel auto-detect the platform
        (cfg.interpret governs only the axpy sweeps)."""
        from repro import fused  # local: fused must stay import-light here
        ctx = fused.make_ctx(seed, scale, masks, self.cfg.forward_backend,
                             interpret=None)
        return loss_fn(params, batch, perturb=ctx)

    def _vloss_pair(self, loss_fn, params, batch, seed, eps, masks):
        """The antithetic ±εz pair as ONE fused forward: returns the (2,)
        loss vector [l_plus, l_minus].  Same floats as two ``_vloss``
        calls at ±eps, but every W tile is loaded and every z tile
        regenerated once for the pair (fused.make_pair_ctx)."""
        from repro import fused
        ctx = fused.make_pair_ctx(seed, eps, masks,
                                  self.cfg.forward_backend, interpret=None)
        return loss_fn(params, batch, perturb=ctx)

    def _vloss_stack(self, loss_fn, params, batch, seeds, scales, masks):
        """P independent probes stacked onto one fused forward (one_sided's
        q-chunks): ``seeds`` (P,), ``scales`` scalar-or-(P,), ``masks``
        {g: (P, L_g)}.  Returns the (P,) loss vector — same floats as the
        vmapped per-probe path, one pass over W."""
        from repro import fused
        ctx = fused.make_stack_ctx(seeds, scales, masks,
                                   self.cfg.forward_backend, interpret=None)
        return loss_fn(params, batch, perturb=ctx)

    # --------------------------------------------------------- protocol
    def estimate(self, loss_fn, params, batch, seed, state):
        """Probe the loss.  -> (probed_params, DirectionSet, metrics).

        ``probed_params`` may still carry a residual perturbation (see
        DirectionSet.restore); callers either ``apply_update`` (which
        folds the restore into the update pass when possible) or
        ``restore_probe`` to get the unperturbed parameters back.
        """
        raise NotImplementedError

    def restore_probe(self, params, dirs: DirectionSet):
        for i, r in enumerate(dirs.restore):
            if r != 0.0:
                params = self._ax(params, r, dirs.seeds[i], dirs.masks[i],
                                  dirs.idxs[i])
        return params

    def apply_update(self, params, dirs: DirectionSet, lr, decay=1.0):
        """theta <- decay*theta - lr * sum_i coeffs[i] * z_i, as q fused
        axpy passes (restore folded into the single pass when q == 1)."""
        q = len(dirs)
        with obs.get_tracer().span(obs.UPDATE) as sp:
            if self.cfg.fused_update and q == 1 and dirs.restore[0] != 0.0:
                return sp.fence(self._ax(
                    params, dirs.restore[0] - lr * dirs.coeffs[0],
                    dirs.seeds[0], dirs.masks[0], dirs.idxs[0], decay))
            params = self.restore_probe(params, dirs)
            for i in range(q):
                params = self._ax(params, -lr * dirs.coeffs[i], dirs.seeds[i],
                                  dirs.masks[i], dirs.idxs[i],
                                  decay if i == 0 else 1.0)
            return sp.fence(params)

    def step_counts(self) -> Dict:
        """Analytic per-step cost counts (see estimators/costs.py)."""
        return costs.step_counts(self.cfg.name, q=self.cfg.q,
                                 fused_update=self.cfg.fused_update,
                                 inner=self.cfg.inner,
                                 num_layers=self.spec.num_layers,
                                 forward_backend=self.cfg.forward_backend)
