"""q independent antithetic SPSA pairs, averaged for variance reduction.

    ghat = (1/q) * sum_i g_i * z_i,   g_i = (L(+eps z_i) - L(-eps z_i)) / 2eps

Each probe perturbs, evaluates the pair, and restores before the next
direction, so a single parameter buffer is reused throughout; the update
then replays the q directions as q fused axpy passes, regenerating each
z_i from its seed (the ``zo_adaptive`` trick) — state stays q scalars.

At q=1 this is exactly two-point SPSA with an unfused restore, and
matches :class:`TwoPointSPSA` to float rounding (asserted in
tests/test_estimators.py).

Estimator subsystem (DESIGN.md §6).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.estimators.base import DirectionSet, Estimator, direction_seeds


class AveragedSPSA(Estimator):
    name = "averaged"

    def estimate(self, loss_fn, params, batch, seed, state):
        cfg = self.cfg
        q = cfg.q
        seeds = direction_seeds(seed, q)
        p = params
        coeffs, masks, idxs, gs = [], [], [], []
        loss_acc = g_acc = 0.0
        n_active = None
        for s in seeds:
            m, ix, na = self.select(s, state)
            n_active = na if n_active is None else n_active
            if self.virtual and cfg.paired_probes:
                # the ±εz pair rides one paired fused forward — W tiles
                # and z tiles each touched once per pair (DESIGN.md §10)
                ls = self._vloss_pair(loss_fn, p, batch, s, cfg.eps, m)
                l_plus, l_minus = ls[0], ls[1]
            elif self.virtual:
                # probe pair through the fused forward: no perturb, no
                # restore-before-next-probe — params never move here
                l_plus = self._vloss(loss_fn, p, batch, s, cfg.eps, m)
                l_minus = self._vloss(loss_fn, p, batch, s, -cfg.eps, m)
            else:
                p = self._ax(p, cfg.eps, s, m, ix)
                l_plus = loss_fn(p, batch)
                p = self._ax(p, -2.0 * cfg.eps, s, m, ix)
                l_minus = loss_fn(p, batch)
                p = self._ax(p, cfg.eps, s, m, ix)  # restore before next
            g = (l_plus - l_minus) / (2.0 * cfg.eps)
            coeffs.append(g / q)
            gs.append(jnp.asarray(g, jnp.float32))
            masks.append(m)
            idxs.append(ix)
            loss_acc = loss_acc + 0.5 * (l_plus + l_minus)
            g_acc = g_acc + g
        dirs = DirectionSet(seeds=seeds, coeffs=tuple(coeffs),
                            restore=(0.0,) * q, masks=tuple(masks),
                            idxs=tuple(idxs))
        metrics = {
            "loss": loss_acc / q,
            "projected_grad": g_acc / q,
            "probe_grads": jnp.stack(gs),               # per-direction g_i
            "eps": jnp.float32(cfg.eps),
            "active_layers": jnp.asarray(n_active, jnp.int32),
        }
        return p, dirs, metrics
