"""Paper-faithful antithetic SPSA pair (MeZO/LeZO Algorithm 1).

Extracted verbatim from the pre-refactor ``core/zo.py::make_zo_step``:
the op sequence (perturb +eps, loss, perturb -2eps, loss, fused
restore+update with scale ``eps - lr*g``) is unchanged, so the lowered
XLA graph — and therefore every bit of the result — is identical to the
seed implementation (asserted in tests/test_estimators.py).

Estimator subsystem (DESIGN.md §6).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.estimators.base import DirectionSet, Estimator


class TwoPointSPSA(Estimator):
    name = "two_point"

    def estimate(self, loss_fn, params, batch, seed, state):
        cfg = self.cfg
        masks, idxs, n_active = self.select(seed, state)
        if self.virtual:
            # fused forward: same z, same floats, zero parameter writes —
            # the step collapses to 2 forwards + the single update axpy
            l_plus = self._vloss(loss_fn, params, batch, seed, cfg.eps,
                                 masks)
            l_minus = self._vloss(loss_fn, params, batch, seed, -cfg.eps,
                                  masks)
            p, restore = params, 0.0
        else:
            p = self._ax(params, cfg.eps, seed, masks, idxs)
            l_plus = loss_fn(p, batch)
            p = self._ax(p, -2.0 * cfg.eps, seed, masks, idxs)
            l_minus = loss_fn(p, batch)
            restore = cfg.eps
        g = (l_plus - l_minus) / (2.0 * cfg.eps)
        dirs = DirectionSet(seeds=(jnp.asarray(seed, jnp.uint32),),
                            coeffs=(g,), restore=(restore,),
                            masks=(masks,), idxs=(idxs,))
        metrics = {
            "loss": 0.5 * (l_plus + l_minus),
            "projected_grad": g,
            "active_layers": jnp.asarray(n_active, jnp.int32),
        }
        return p, dirs, metrics
