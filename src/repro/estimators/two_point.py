"""Paper-faithful antithetic SPSA pair (MeZO/LeZO Algorithm 1).

Extracted verbatim from the pre-refactor ``core/zo.py::make_zo_step``:
the op sequence (perturb +eps, loss, perturb -2eps, loss, fused
restore+update with scale ``eps - lr*g``) is unchanged, so the lowered
XLA graph — and therefore every bit of the result — is identical to the
seed implementation (asserted in tests/test_estimators.py).

Estimator subsystem (DESIGN.md §6).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.estimators.base import DirectionSet, Estimator
from repro.obs import trace as obs


class TwoPointSPSA(Estimator):
    name = "two_point"

    def estimate(self, loss_fn, params, batch, seed, state):
        cfg = self.cfg
        tr = obs.get_tracer()
        masks, idxs, n_active = self.select(seed, state)
        if self.virtual and cfg.paired_probes:
            # ONE paired forward for the ±εz pair: each W tile loads and
            # each z tile regenerates once for both signs — the step is
            # 1 paired forward + the single update axpy (DESIGN.md §10)
            with tr.span(obs.FWD_PAIR) as sp:
                losses = sp.fence(self._vloss_pair(loss_fn, params, batch,
                                                   seed, cfg.eps, masks))
            l_plus, l_minus = losses[0], losses[1]
            p, restore = params, 0.0
        elif self.virtual:
            # fused forward: same z, same floats, zero parameter writes —
            # the step collapses to 2 forwards + the single update axpy
            with tr.span(obs.FWD_PLUS) as sp:
                l_plus = sp.fence(self._vloss(loss_fn, params, batch, seed,
                                              cfg.eps, masks))
            with tr.span(obs.FWD_MINUS) as sp:
                l_minus = sp.fence(self._vloss(loss_fn, params, batch, seed,
                                               -cfg.eps, masks))
            p, restore = params, 0.0
        else:
            with tr.span(obs.PERTURB) as sp:
                p = sp.fence(self._ax(params, cfg.eps, seed, masks, idxs))
            with tr.span(obs.FWD_PLUS) as sp:
                l_plus = sp.fence(loss_fn(p, batch))
            with tr.span(obs.PERTURB) as sp:
                p = sp.fence(self._ax(p, -2.0 * cfg.eps, seed, masks, idxs))
            with tr.span(obs.FWD_MINUS) as sp:
                l_minus = sp.fence(loss_fn(p, batch))
            restore = cfg.eps
        tr.count(obs.CTR_PROBES, 2)
        g = (l_plus - l_minus) / (2.0 * cfg.eps)
        dirs = DirectionSet(seeds=(jnp.asarray(seed, jnp.uint32),),
                            coeffs=(g,), restore=(restore,),
                            masks=(masks,), idxs=(idxs,))
        metrics = {
            "loss": 0.5 * (l_plus + l_minus),
            "projected_grad": g,
            "probe_grads": jnp.stack([jnp.asarray(g, jnp.float32)]),
            "eps": jnp.float32(cfg.eps),
            "active_layers": jnp.asarray(n_active, jnp.int32),
        }
        return p, dirs, metrics
