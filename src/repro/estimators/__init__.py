"""Pluggable ZO gradient-estimator subsystem.

The optimizer core (``core/zo.py``), the adaptive optimizers
(``core/zo_adaptive.py``), the trainer, and the launch/cost tooling all
consume ZO gradients through this package's API:

    cfg  = estimators.EstimatorConfig(name="one_sided", q=16, ...)
    step, init_state = estimators.make_step(loss_fn, spec, cfg)
    params, state, metrics = step(params, state, batch, step_idx, seed)

Estimators return :class:`DirectionSet`s — (seed, coefficient) pairs
whose perturbations regenerate from seeds and are never materialized —
so optimizer memory stays params + O(q) scalars under every estimator
and every kernel backend (dense | scan | gather | pallas).

Estimator subsystem (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.core import rng, zo
from repro.estimators import costs
from repro.estimators.averaged import AveragedSPSA
from repro.estimators.base import (DirectionSet, Estimator, EstimatorConfig,
                                   direction_seeds)
from repro.estimators.importance import ImportanceSelect
from repro.estimators.one_sided import OneSidedBatched
from repro.estimators.two_point import TwoPointSPSA

REGISTRY = {
    "two_point": TwoPointSPSA,
    "one_sided": OneSidedBatched,
    "averaged": AveragedSPSA,
    "importance": ImportanceSelect,
}
ESTIMATORS = tuple(REGISTRY)

__all__ = ["DirectionSet", "Estimator", "EstimatorConfig", "ESTIMATORS",
           "REGISTRY", "AveragedSPSA", "ImportanceSelect", "OneSidedBatched",
           "TwoPointSPSA", "build_estimator", "costs", "direction_seeds",
           "from_zo", "make_step"]


def build_estimator(spec: zo.ZOSpec, cfg: EstimatorConfig,
                    select_fn: Optional[Callable] = None) -> Estimator:
    if cfg.name not in REGISTRY:
        raise ValueError(
            f"unknown estimator {cfg.name!r}; pick from {ESTIMATORS}")
    if cfg.q < 1:
        raise ValueError(f"q must be >= 1, got {cfg.q}")
    if cfg.forward_backend not in costs.FORWARD_BACKENDS:
        raise ValueError(
            f"unknown forward_backend {cfg.forward_backend!r}; pick from "
            f"{costs.FORWARD_BACKENDS}")
    return REGISTRY[cfg.name](spec, cfg, select_fn=select_fn)


def from_zo(zo_cfg, name: str = "two_point", q: int = 1,
            **kw) -> EstimatorConfig:
    """Lift a legacy ``zo.ZOConfig`` into an EstimatorConfig."""
    return EstimatorConfig(
        name=name, eps=zo_cfg.eps, lr=zo_cfg.lr, q=q, n_drop=zo_cfg.n_drop,
        policy=zo_cfg.policy, backend=zo_cfg.backend,
        fused_update=zo_cfg.fused_update, weight_decay=zo_cfg.weight_decay,
        interpret=zo_cfg.interpret,
        forward_backend=getattr(zo_cfg, "forward_backend", "materialized"),
        paired_probes=getattr(zo_cfg, "paired_probes", True),
        **kw)


def make_step(loss_fn: Callable, spec: zo.ZOSpec, cfg: EstimatorConfig,
              lr_schedule: Optional[Callable] = None):
    """Build the jit-able estimator step and its state initializer.

    ``step(params, state, batch, step_idx, base_seed) -> (params, state,
    metrics)``.  ``state`` is the estimator's O(q)-scalar (or, for the
    importance wrapper, O(num_layers)-float) pytree; stateless estimators
    thread an empty dict.  Donate params and state at jit time.
    """
    est = build_estimator(spec, cfg)
    sched = lr_schedule or (lambda t: cfg.lr)

    def step(params, state, batch, step_idx, base_seed):
        seed = rng.fold(jnp.asarray(base_seed, jnp.uint32),
                        jnp.asarray(step_idx, jnp.uint32))
        p, dirs, metrics = est.estimate(loss_fn, params, batch, seed, state)
        lr = sched(step_idx)
        decay = 1.0 - lr * cfg.weight_decay
        p = est.apply_update(p, dirs, lr, decay)
        new_state = est.update_state(state, dirs, metrics)
        metrics = dict(metrics)
        metrics["lr"] = lr
        # optimizer-health scalars (repro.obs.health): the direction
        # coefficients, LeZO layer coverage, and per-direction active
        # parameter counts make every step auditable / replayable from
        # the run log.  Cheap (a few reductions over (L,) masks), and
        # params themselves are untouched.
        if len(dirs):
            metrics["coeffs"] = jnp.stack(
                [jnp.asarray(c, jnp.float32) for c in dirs.coeffs])
            shapes = zo.leaf_shapes(params)
            metrics["n_active_params"] = jnp.stack(
                [zo.active_param_count(spec, shapes, m) for m in dirs.masks])
            if spec.num_layers:
                metrics["layer_sel"] = sum(
                    zo.global_layer_mask(spec, m).astype(jnp.int32)
                    for m in dirs.masks)
        return p, new_state, metrics

    return step, est.init_state
