"""FZOO-style batched one-sided estimator (arXiv:2506.09034).

One unperturbed baseline forward is amortized across q one-sided probes:

    g_i = (L(theta + eps * z_i) - L(theta)) / eps
    ghat = (1/q) * sum_i g_i * z_i

The q perturbed evaluations are vmapped over a perturbation-seed axis so
XLA batches them into one widened forward (weight matmuls become batched
matmuls; the counter RNG regenerates each z_i inside the vmapped region,
so no (q, params) tree outlives the fused forward).  Compute still scales
with q — see estimators/costs.py — but per-probe overhead (dispatch,
baseline loss, non-width-scaling work) is paid once.

The probe perturbation inside the vmap always uses the dense axpy path:
a widened forward wants one fused elementwise RNG+axpy that XLA batches
across the q-axis.  The configured backend (scan/gather/pallas) governs
the q sequential update sweeps that follow, where layer skipping pays.

Memory: optimizer *state* stays O(q) scalars (the DirectionSet), but the
widened forward transiently holds up to q perturbed copies of the active
parameters as its working set (fused into the batched matmuls where XLA
can).  On memory-tight models set ``q_chunk`` to bound that: probes are
vmapped ``q_chunk`` at a time and the chunks run sequentially.

Variance of the one-sided estimate is higher per probe than antithetic
two-point (the Hessian term (eps/2) z'Hz does not cancel), but averaging
q probes for one extra forward — instead of q extra forward *pairs* —
wins on compute at equal variance for q >= 2.

Estimator subsystem (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import zo
from repro.estimators.base import DirectionSet, Estimator, direction_seeds
from repro.obs import trace as obs


class OneSidedBatched(Estimator):
    name = "one_sided"

    def estimate(self, loss_fn, params, batch, seed, state):
        cfg = self.cfg
        q = cfg.q
        seeds = direction_seeds(seed, q)
        sels = [self.select(s, state) for s in seeds]
        masks = tuple(s[0] for s in sels)
        idxs = tuple(s[1] for s in sels)
        n_active = sels[0][2]

        tr = obs.get_tracer()
        with tr.span(obs.FWD_BASE) as sp:
            l0 = sp.fence(loss_fn(params, batch))
        seeds_arr = jnp.stack([jnp.asarray(s, jnp.uint32) for s in seeds])
        stacked_masks = ({g: jnp.stack([m[g] for m in masks])
                          for g in masks[0]} if masks[0] else {})

        def probe(seed_i, masks_i):
            if self.virtual:
                # q probes are q *seeds* of the same weights: the vmapped
                # fused forward regenerates each z_i in-kernel, so no
                # widened (q, params) perturbed copies ever exist
                return self._vloss(loss_fn, params, batch, seed_i,
                                   cfg.eps, masks_i)
            p = zo.tree_axpy(params, self.spec, seed_i, cfg.eps, masks_i,
                             None, backend="dense", interpret=cfg.interpret)
            return loss_fn(p, batch)

        chunk = cfg.q_chunk if 0 < cfg.q_chunk < q else q
        # One span over all q probes: the vmapped region itself traces,
        # so per-probe spans inside it would (correctly) no-op.
        with tr.span(obs.FWD_PLUS) as sp:
            parts = []
            for c0 in range(0, q, chunk):
                sub_masks = {g: m[c0:c0 + chunk]
                             for g, m in stacked_masks.items()}
                if self.virtual and cfg.paired_probes:
                    # stacked kernel pass: the chunk's probes share one
                    # sweep over W (per-probe z streams stay intact) —
                    # same floats as the vmapped path, fewer tile loads
                    parts.append(self._vloss_stack(
                        loss_fn, params, batch, seeds_arr[c0:c0 + chunk],
                        cfg.eps, sub_masks))
                else:
                    parts.append(jax.vmap(probe)(seeds_arr[c0:c0 + chunk],
                                                 sub_masks))
            losses = sp.fence(parts[0] if len(parts) == 1
                              else jnp.concatenate(parts))
        tr.count(obs.CTR_PROBES, q)
        g = (losses - l0) / cfg.eps                     # (q,) projections
        coeffs = tuple(g[i] / q for i in range(q))
        dirs = DirectionSet(seeds=seeds, coeffs=coeffs, restore=(0.0,) * q,
                            masks=masks, idxs=idxs)
        metrics = {
            "loss": l0,                                 # unperturbed loss
            "projected_grad": jnp.mean(g),
            "probe_grads": g.astype(jnp.float32),       # per-probe g_i
            "eps": jnp.float32(cfg.eps),
            "active_layers": jnp.asarray(n_active, jnp.int32),
        }
        return params, dirs, metrics
