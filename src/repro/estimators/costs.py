"""Analytic per-step cost counts for ZO gradient estimators.

Pure Python (no jax) so the HLO cost model in ``launch/analysis.py`` and
the dry-run roofline can import it without touching an accelerator
runtime.  Counts are per optimization step:

  * ``forwards``      — model forward passes.  ``one_sided`` issues its q
                        perturbed evaluations as ONE vmapped (widened)
                        forward, but compute/HBM cost still scales with q,
                        so we count q + 1 (the +1 is the shared baseline).
  * ``axpy_sweeps``   — full parameter-sweep axpy passes (perturb /
                        restore / update).  Each sweep reads + writes every
                        *active* parameter byte once.
  * ``state_scalars`` — optimizer state beyond the parameters themselves,
                        in floats.  ``num_layers`` enters only for the
                        importance wrapper (its smoothed per-layer scores).

These counts are the contract the estimator implementations must honor
(asserted in tests/test_estimators.py) — they are what keeps the memory
story "params + O(q) scalars" auditable.

Estimator subsystem (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Dict

ESTIMATORS = ("two_point", "one_sided", "averaged", "importance")
FORWARD_BACKENDS = ("materialized", "virtual", "virtual_ref")

# Baseline the lowered train graph corresponds to (launch/specs.py lowers
# a fused two-point step: 2 forwards + 3 axpy sweeps).
BASELINE = "two_point"


def step_counts(name: str, q: int = 1, fused_update: bool = True,
                inner: str = "two_point", num_layers: int = 0,
                forward_backend: str = "materialized") -> Dict:
    """Per-step cost counts for estimator ``name`` with ``q`` directions.

    ``forward_backend="virtual"``/``"virtual_ref"`` (the fused runtime,
    DESIGN.md §10) evaluates every probe against virtually perturbed
    weights: all perturb/restore sweeps vanish and only the update axpy
    passes remain — the forward count is unchanged (probes still run).
    """
    if q < 1:
        raise ValueError(f"q must be >= 1, got {q}")
    if forward_backend not in FORWARD_BACKENDS:
        raise ValueError(f"unknown forward_backend {forward_backend!r}; "
                         f"pick from {FORWARD_BACKENDS}")
    virtual = forward_backend != "materialized"
    if name == "two_point":
        # perturb(+eps), perturb(-2eps), then fused restore+update — or
        # separate restore and update passes when unfused.  Virtual: the
        # probes are fused forwards, leaving only the single update axpy.
        sweeps = 1 if virtual else (3 if fused_update else 4)
        return {"forwards": 2, "axpy_sweeps": sweeps, "state_scalars": 0}
    if name == "one_sided":
        # 1 baseline + q perturbed forwards (one widened vmapped launch);
        # q perturb sweeps happen inside the vmap (zero when virtual:
        # the probes are q seeds of the same weights), q update sweeps.
        return {"forwards": q + 1, "axpy_sweeps": q if virtual else 2 * q,
                "state_scalars": 0}
    if name == "averaged":
        # q independent two-point probes (3 sweeps each: +eps, -2eps,
        # +eps restore; zero when virtual) + q update sweeps.
        return {"forwards": 2 * q, "axpy_sweeps": q if virtual else 4 * q,
                "state_scalars": 0}
    if name == "importance":
        if inner == "importance":
            raise ValueError("importance cannot wrap itself")
        c = dict(step_counts(inner, q=q, fused_update=fused_update,
                             forward_backend=forward_backend))
        c["state_scalars"] = c["state_scalars"] + num_layers
        return c
    raise ValueError(f"unknown estimator {name!r}; pick from {ESTIMATORS}")
