"""Importance-weighted layer selection (Sparse-MeZO / LISA direction).

Replaces LeZO's uniform layer drop with a smoothed per-layer importance
score: every step, each *active* layer's score takes an EMA step toward
the magnitude of that step's projected gradient (the only attribution a
ZO step yields without extra forwards — a layer that was active while
|g| was large is credited).  Selection is Gumbel top-k by score within
each group under the same static largest-remainder quotas as
``stratified_select``, so the gather backend's compact buffers keep
their static shapes and every backend works unchanged.

State is ``num_layers`` floats — for OPT-13B that is 40 floats next to
13B parameters, preserving the zero-extra-memory story.

This is a *wrapper*: it drives any inner estimator (``cfg.inner``,
default two_point) by injecting its weighted policy as the inner's
``select_fn``; probing, update application, and cost counts are the
inner estimator's own.

Estimator subsystem (DESIGN.md §6).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import zo
from repro.estimators.base import DirectionSet, Estimator


class ImportanceSelect(Estimator):
    name = "importance"

    def __init__(self, spec, cfg, select_fn=None):
        super().__init__(spec, cfg, select_fn=select_fn)
        from repro import estimators as _reg  # registry; safe post-import
        inner_cls = _reg.REGISTRY[cfg.inner]
        if inner_cls is ImportanceSelect:
            raise ValueError("importance cannot wrap itself")
        self.inner = inner_cls(spec, cfg,
                               select_fn=select_fn or self._weighted_select)

    # -------------------------------------------------------- selection
    def _weighted_select(self, seed, state):
        return zo.stratified_select_weighted(self.spec, seed,
                                             self.cfg.n_drop, state["imp"])

    def select(self, seed, state):
        return self.inner.select(seed, state)

    # ------------------------------------------------------------ state
    def init_state(self):
        st = dict(self.inner.init_state())
        st["imp"] = jnp.ones((self.spec.num_layers,), jnp.float32)
        return st

    def update_state(self, state, dirs: DirectionSet, metrics):
        st = dict(self.inner.update_state(state, dirs, metrics))
        imp = state["imp"]
        q = len(dirs)
        mu = self.cfg.importance_decay
        for i in range(q):
            gmask = self._global_mask(dirs.masks[i])
            # coeffs carry the 1/q averaging weight; undo it so the score
            # tracks the raw per-direction |projected grad|.
            w = jnp.abs(jnp.asarray(dirs.coeffs[i], jnp.float32)) * q
            imp = jnp.where(gmask, mu * imp + (1.0 - mu) * w, imp)
        st["imp"] = imp
        return st

    def _global_mask(self, masks):
        return zo.global_layer_mask(self.spec, masks)

    # ------------------------------------------------- delegate probing
    def estimate(self, loss_fn, params, batch, seed, state):
        return self.inner.estimate(loss_fn, params, batch, seed, state)

    def restore_probe(self, params, dirs):
        return self.inner.restore_probe(params, dirs)

    def apply_update(self, params, dirs, lr, decay=1.0):
        return self.inner.apply_update(params, dirs, lr, decay)
